"""Large-fleet scenario: 10^4–10^5 hosts with stragglers and host failures,
driven end-to-end through the incremental device-resident fast path.

This is the scale the per-call rebuild cannot reach (an O(N·K) python loop
per scheduling call); the ``SoASimulator`` keeps the fleet as struct-of-arrays
on device, applies each event as an O(K·D) transition, and batches runs of
arrivals through one jit-compiled ``lax.scan``.

Usage:
    PYTHONPATH=src python examples/large_fleet_sim.py [n_hosts] [sim_hours] [n_shards]

Defaults to 10_000 hosts × 2 simulated hours; try 100_000 hosts for the full
stress run (the decision stays one fused array program — wall time scales
linearly in fleet size, not in python object count).

Pass ``n_shards > 1`` to partition the fleet host-major across that many
devices and run the stage-1 screen per shard
(``SchedulerPolicy(mesh=...)``) — decisions stay bit-identical to the
single-device run.  On a CPU-only box, force host devices first:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/large_fleet_sim.py 100000 2 8
"""
from __future__ import annotations

import sys
import time

from repro.core import (
    PeriodCost, SchedulerPolicy, SoASimulator, WorkloadSpec, fleet_mesh,
    make_uniform_fleet,
)
from repro.core.types import VM_SPEC

NODE = VM_SPEC.make(vcpus=8, ram_mb=16000, disk_gb=10_000)
SIZES = {
    "small": VM_SPEC.make(vcpus=1, ram_mb=2000, disk_gb=20),
    "medium": VM_SPEC.make(vcpus=2, ram_mb=4000, disk_gb=40),
}


def main() -> None:
    n_hosts = int(sys.argv[1]) if len(sys.argv) > 1 else 10_000
    hours = float(sys.argv[2]) if len(sys.argv) > 2 else 2.0
    n_shards = int(sys.argv[3]) if len(sys.argv) > 3 else 1
    mesh = fleet_mesh(n_shards) if n_shards > 1 else None
    if mesh is not None:
        print(f"sharding {n_hosts} hosts across {n_shards} devices")

    # Arrival rate scaled to the fleet so utilization climbs regardless of N.
    workload = WorkloadSpec(
        arrival_rate_per_s=n_hosts / 20_000.0,
        preemptible_fraction=0.6,
        flavors=tuple(SIZES.items()),
        flavor_probs=(0.5, 0.5),
    )
    # K=8 slots: the small flavor packs up to 8 preemptible instances/host.
    # One SchedulerPolicy carries every decision knob (mesh included).
    sim = SoASimulator(
        make_uniform_fleet(n_hosts, NODE), workload, seed=42,
        cost_fn=PeriodCost(), k_slots=8, batch_max=128,
        policy=SchedulerPolicy(mesh=mesh),
    )

    # Fault story: 5% stragglers, plus a cascade of host failures that heal.
    sim.inject_stragglers(0.05, slow_factor=4.0)
    for i in range(10):
        sim.inject_host_failure(
            f"host-{i * (n_hosts // 10)}", at_s=1800.0 + 60.0 * i,
            heal_after_s=3600.0,
        )

    t0 = time.perf_counter()
    metrics = sim.run(hours * 3600.0)
    wall = time.perf_counter() - t0

    s = metrics.summary()
    events = len(metrics.sched_latency_s)
    print(f"hosts={n_hosts}  sim_hours={hours:g}  wall={wall:.1f}s  "
          f"requests={events}  throughput={events / wall:.0f} req/s")
    for k, v in s.items():
        print(f"  {k:>28} = {v:.3f}")

    # Sync back to python objects once, at the end — this validates the
    # incremental state (Host.place re-checks every capacity constraint).
    from repro.core import Cluster

    cluster = Cluster.from_fleet(sim.fleet)
    live = len(cluster.instances())
    print(f"  sync OK: {live} live instances, "
          f"final_util={cluster.utilization():.3f}")


if __name__ == "__main__":
    main()
