"""End-to-end training driver: ~100M-parameter LM, a few hundred steps,
with periodic checkpoints and a mid-run preemption + bit-exact resume.

Default is the full ~110M model for 200 steps (CPU: slow but runs);
``--quick`` trains a ~2M model for 40 steps (used by CI/smoke).

Run:  PYTHONPATH=src python examples/train_100m.py [--quick] [--steps N]
"""
import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

from repro.configs import get_config, reduced
from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, SyntheticLMDataset
from repro.training import Trainer, TrainerConfig, TrainSettings


def model_100m() -> ModelConfig:
    return ModelConfig(
        name="repro-110m", family="dense", n_layers=12, d_model=640,
        n_heads=10, n_kv_heads=5, d_ff=2560, vocab_size=50304,
        tie_embeddings=True, remat="none", dtype="float32",
        params_dtype="float32",
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--preempt-at", type=int, default=None,
                    help="simulate a preemption at this step (default: midway)")
    args = ap.parse_args()

    if args.quick:
        cfg = reduced(get_config("qwen2-1.5b"))
        steps = args.steps or 40
        seq, batch = 64, 8
    else:
        cfg = model_100m()
        steps = args.steps or 200
        seq, batch = 256, 8
    preempt_at = args.preempt_at or steps // 2

    n_params = cfg.param_count()
    print(f"[train] {cfg.name}: ~{n_params/1e6:.0f}M params, {steps} steps, "
          f"seq={seq} batch={batch}")

    workdir = tempfile.mkdtemp(prefix="train100m_")
    data = SyntheticLMDataset(DataConfig(vocab_size=cfg.vocab_size,
                                         seq_len=seq, global_batch=batch))
    settings = TrainSettings(learning_rate=1e-3, warmup_steps=20,
                             total_steps=steps)
    tcfg = TrainerConfig(ckpt_dir=workdir, ckpt_every=25, log_every=10)

    trainer = Trainer(cfg, settings, tcfg, data=data, job_id="train100m")
    trainer.run(n_steps=preempt_at)
    first = trainer.history[0]["loss"] if trainer.history else float("nan")
    print(f"[train] step {trainer.step}: simulating spot preemption "
          f"(notice=30s) → drain + checkpoint")
    ack = trainer.on_preempt(now=0.0, deadline=30.0)
    print(f"[train] preemption ack: {ack.value}")

    # elastic resume: fresh process-equivalent trainer restores everything
    resumed = Trainer(cfg, settings, tcfg, data=data, job_id="train100m")
    resumed.init_or_restore()
    assert resumed.step == trainer.step
    print(f"[train] resumed at step {resumed.step}")
    last = resumed.run(until_step=steps)
    print(f"[train] finished step {resumed.step}: loss {first:.3f} → "
          f"{last['loss']:.3f} (lr={last['lr']:.2e}, grad_norm={last['grad_norm']:.2f})")
    for h in resumed.history[-3:]:
        print(f"[train]   step {h['step']}: loss={h['loss']:.4f}")


if __name__ == "__main__":
    main()
