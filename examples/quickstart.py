"""Quickstart: the paper's scheduler driving a real (tiny) training job.

1. Build a 4-host TPU fleet and the preemptible-aware scheduler.
2. Place a *preemptible* training job (tiny LM) and train it a bit.
3. A *normal* (on-demand) job arrives that needs the capacity: the scheduler
   picks the cost-minimal victim — our training job — which checkpoints
   inside the preemption notice window (Alg. 5 + §5 of DESIGN.md).
4. The job is re-queued, resumes from its checkpoint, and finishes.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_config, reduced
from repro.core import (
    Cluster,
    PeriodCost,
    PreemptibleScheduler,
    PreemptionController,
    Request,
    TPU_SPEC,
    make_uniform_fleet,
)
from repro.data.pipeline import DataConfig, SyntheticLMDataset
from repro.training import Trainer, TrainerConfig, TrainSettings

HOST = TPU_SPEC.make(chips=4, hbm_gb=64, host_ram_gb=192)
JOB = TPU_SPEC.make(chips=4, hbm_gb=48, host_ram_gb=64)


def main() -> None:
    # --- fleet + scheduler + preemption protocol -----------------------------
    cluster = Cluster(make_uniform_fleet(4, HOST))
    scheduler = PreemptibleScheduler(cost_fn=PeriodCost())
    controller = PreemptionController(notice_s=30.0)
    cluster.preempt_hooks.append(controller)
    now = 0.0

    # --- a tiny LM training job, submitted as PREEMPTIBLE ---------------------
    cfg = reduced(get_config("qwen2-1.5b"))
    workdir = tempfile.mkdtemp(prefix="quickstart_")
    data = SyntheticLMDataset(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                         global_batch=4))
    trainer = Trainer(cfg, TrainSettings(total_steps=100, warmup_steps=5),
                      TrainerConfig(ckpt_dir=workdir, ckpt_every=10, log_every=5),
                      data=data, job_id="train-job")

    req = Request(id="train-job", resources=JOB, preemptible=True)
    inst = cluster.schedule_and_place(scheduler, req, now)
    assert inst is not None
    controller.register(inst.id, trainer)
    print(f"[quickstart] training job placed on {inst.host} (preemptible)")

    metrics = trainer.run(n_steps=12)
    print(f"[quickstart] trained to step {trainer.step}: loss={metrics['loss']:.3f}")

    # --- fill remaining hosts so the normal job MUST evacuate our job ---------
    for i in range(3):
        blocker = Request(id=f"blocker{i}", resources=JOB, preemptible=False)
        assert cluster.schedule_and_place(scheduler, blocker, now + 60) is not None

    # --- on-demand arrival → preemption --------------------------------------
    ondemand = Request(id="ondemand", resources=JOB, preemptible=False)
    placed = cluster.schedule_and_place(scheduler, ondemand, now + 3600)
    assert placed is not None
    rec = controller.records[-1]
    print(f"[quickstart] on-demand placed on {placed.host}; preempted job "
          f"{rec.job_id} ack={rec.ack.value} lost_work={rec.lost_work_s:.0f}s")

    # --- elastic resume: a NEW trainer restores the checkpoint -----------------
    resumed = Trainer(cfg, TrainSettings(total_steps=100, warmup_steps=5),
                      TrainerConfig(ckpt_dir=workdir, ckpt_every=10, log_every=5),
                      data=data, job_id="train-job")
    resumed.init_or_restore()
    print(f"[quickstart] resumed at step {resumed.step} (checkpointed on preempt)")
    final = resumed.run(n_steps=8)
    print(f"[quickstart] done at step {resumed.step}: loss={final['loss']:.3f}")
    print(f"[quickstart] cluster stats: {cluster.stats}")


if __name__ == "__main__":
    main()
