"""Serving under preemption: a spot serving replica drains mid-stream when
an on-demand job claims its slice; unfinished requests are re-queued and a
replacement replica (fresh slice) finishes them — no request is lost.

Run:  PYTHONPATH=src python examples/preemptible_serving.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.core import (
    Cluster,
    PeriodCost,
    PreemptibleScheduler,
    PreemptionController,
    Request,
    TPU_SPEC,
    make_uniform_fleet,
)
from repro.models.model import init_params
from repro.serving import ServeConfig, ServingEngine

HOST = TPU_SPEC.make(chips=4, hbm_gb=64, host_ram_gb=192)
SLICE = TPU_SPEC.make(chips=4, hbm_gb=48, host_ram_gb=64)


def main() -> None:
    cfg = reduced(get_config("yi-9b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    cluster = Cluster(make_uniform_fleet(2, HOST))
    sched = PreemptibleScheduler(cost_fn=PeriodCost())
    controller = PreemptionController()
    cluster.preempt_hooks.append(controller)

    # spot serving replica
    engine = ServingEngine(cfg, params, ServeConfig(max_batch=4, max_len=64))
    inst = cluster.schedule_and_place(
        sched, Request(id="serve-replica", resources=SLICE, preemptible=True), 0.0
    )
    controller.register(inst.id, engine)
    for i in range(8):
        engine.submit(f"req{i}", rng.integers(2, cfg.vocab_size, 6), max_new=8)
    print(f"[serve] replica on {inst.host}, 8 requests queued")

    # fill the second host so the on-demand arrival MUST evacuate the replica
    blocker = cluster.schedule_and_place(
        sched, Request(id="blocker", resources=SLICE, preemptible=False), 0.0
    )
    assert blocker is not None

    # serve one wave, then an on-demand training job preempts the replica
    engine._run_wave()
    print(f"[serve] wave 1 done: {sorted(engine.completed)}")
    placed = cluster.schedule_and_place(
        sched, Request(id="ondemand-train", resources=SLICE, preemptible=False), 1800.0
    )
    assert placed is not None
    print(f"[serve] replica preempted (ack={controller.records[-1].ack.value}); "
          f"{len(engine.queue)} requests still queued")

    # the blocker job finishes → spot capacity returns; a replacement replica
    # picks up the re-queued requests on the freed slice
    cluster.terminate(blocker)
    engine2 = ServingEngine(cfg, params, ServeConfig(max_batch=4, max_len=64))
    engine2.queue = engine.queue
    inst2 = cluster.schedule_and_place(
        sched, Request(id="serve-replica-2", resources=SLICE, preemptible=True), 1830.0
    )
    assert inst2 is not None
    print(f"[serve] replacement replica on {inst2.host}")
    done = engine2.run_until_drained()
    all_done = {**engine.completed, **done}
    print(f"[serve] all {len(all_done)}/8 requests completed: {sorted(all_done)}")
    assert len(all_done) == 8


if __name__ == "__main__":
    main()
