"""Mixed payment models on ONE fleet — the scenario the paper's §5 says
preemptible scheduling enables ("new cloud usage and payment models") and
INDIGO-DataCloud motivates with mixed spot/on-demand economics.

Four customer classes share the fleet, each billed by its own model, chosen
PER REQUEST (``Request.cost_kind``) against the fleet policy's cost-kind
table:

  * ``period``     — classic partial-period billing (the paper's default);
  * ``count``      — flat per-preemption SLA credits (minimize evictions);
  * ``revenue``    — lost-revenue protection for priced spot instances;
  * ``recompute``  — training jobs whose eviction destroys un-checkpointed
                     work (cheap to evacuate right after a checkpoint).

The select-and-terminate phase then minimizes the SUM of heterogeneous
per-instance damages — e.g. it prefers evicting the training job that just
checkpointed over the spot instance 55 minutes into its billing hour — all
on the device-resident fast path (one ``SchedulerPolicy``, one jit cache
entry; see docs/api.md §Policy).

Run:  PYTHONPATH=src python examples/mixed_payment_sim.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import (
    MixedCost,
    Request,
    SchedulerPolicy,
    SoAFleet,
    VM_SPEC,
    make_uniform_fleet,
)

NODE = VM_SPEC.make(vcpus=8, ram_mb=16000, disk_gb=10_000)
SMALL = VM_SPEC.make(vcpus=1, ram_mb=2000, disk_gb=20)
MEDIUM = VM_SPEC.make(vcpus=2, ram_mb=4000, disk_gb=40)
KINDS = ("period", "count", "revenue", "recompute")


def main() -> None:
    rng = np.random.default_rng(7)
    policy = SchedulerPolicy.for_cost(
        MixedCost(default="period", kinds=KINDS), shortlist=16
    )
    fleet = SoAFleet(make_uniform_fleet(24, NODE), k_slots=8, policy=policy)
    now = 0.0
    placed = {k: 0 for k in KINDS}
    evicted = {k: 0 for k in KINDS}

    for tick in range(600):
        now += 60.0
        # ---- preemptible arrivals, each customer class with its own bill ----
        for _ in range(rng.poisson(1.5)):
            kind = KINDS[int(rng.integers(4))]
            req = Request(
                id=f"s{tick}-{rng.integers(1e6)}", resources=SMALL,
                preemptible=True, cost_kind=kind,
            )
            out = fleet.schedule_request(
                req, now, price=float(rng.integers(1, 5))
            )
            if out.ok:
                placed[kind] += 1
        # ---- training jobs checkpoint periodically (recompute cost resets) --
        for iid, (h, slot) in list(fleet.locator.items()):
            inst = fleet.instances[iid]
            if slot is not None and inst.cost_kind == "recompute":
                if rng.random() < 0.2:
                    fleet.checkpoint(iid, now)
        # ---- on-demand pressure forces heterogeneous-cost evictions ---------
        if tick % 3 == 0:
            req = Request(id=f"n{tick}", resources=MEDIUM, preemptible=False)
            out = fleet.schedule_request(req, now)
            for victim in out.victims:
                evicted[victim.cost_kind or policy.cost_kind] += 1
        # ---- departures ------------------------------------------------------
        for iid in list(fleet.instances):
            if rng.random() < 0.004:
                fleet.depart(iid)
        if tick % 120 == 0:
            print(f"[mixed] t={tick:3d} util={fleet.utilization():.2f} "
                  f"placed={placed} evicted={evicted}")

    stats = fleet.shortlist_stats
    print(f"[mixed] final: util={fleet.utilization():.2f}")
    print(f"[mixed] placed by kind:  {placed}")
    print(f"[mixed] evicted by kind: {evicted}  (cost-minimal mixed sums)")
    print(f"[mixed] decisions={stats['decisions']} "
          f"fallbacks={stats['fallbacks']} (shortlist M={stats['shortlist']})")


if __name__ == "__main__":
    main()
