"""Spot-market policy on top of the preemptible scheduler (the paper's §6
'more complex policies ... a preemptible instance stock market').

Spot price follows fleet utilization (Ex-CORE-flavoured linear-in-load
market); each preemptible instance carries a user bid.  Every market tick,
out-of-bid instances are terminated through the SAME preemption protocol the
scheduler uses — demonstrating that the paper's modular cost/termination
machinery hosts an Amazon-style spot market without scheduler changes.

Run:  PYTHONPATH=src python examples/spot_market_sim.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import (
    Cluster,
    PeriodCost,
    PreemptibleScheduler,
    Request,
    VM_SPEC,
    make_uniform_fleet,
)

NODE = VM_SPEC.make(vcpus=8, ram_mb=16000, disk_gb=10_000)
MEDIUM = VM_SPEC.make(vcpus=2, ram_mb=4000, disk_gb=40)
BASE_PRICE = 0.10


def spot_price(utilization: float) -> float:
    """Linear market: scarce capacity → expensive spot."""
    return BASE_PRICE * (0.2 + 2.0 * utilization ** 2)


def main() -> None:
    rng = np.random.default_rng(0)
    cluster = Cluster(make_uniform_fleet(16, NODE))
    sched = PreemptibleScheduler(cost_fn=PeriodCost())
    now = 0.0
    prices, evictions = [], 0

    for tick in range(200):
        now += 60.0
        # arrivals: mostly spot with random bids, some on-demand
        for _ in range(rng.poisson(1.2)):
            is_spot = rng.random() < 0.7
            req = Request(id=f"r{tick}-{rng.integers(1e6)}", resources=MEDIUM,
                          preemptible=is_spot)
            inst = cluster.schedule_and_place(sched, req, now)
            if inst is not None and is_spot:
                inst.metadata["bid"] = float(BASE_PRICE * rng.uniform(0.3, 2.5))
        # departures
        for inst in list(cluster.instances()):
            if rng.random() < 0.01:
                cluster.terminate(inst)
        # market tick: terminate out-of-bid spot instances
        price = spot_price(cluster.utilization())
        prices.append(price)
        for inst in list(cluster.instances()):
            if inst.preemptible and inst.metadata.get("bid", 1e9) < price:
                cluster.preempt(inst, now)   # out-of-bid ⇒ spot semantics
                evictions += 1
        if tick % 40 == 0:
            print(f"[market] t={tick:3d} util={cluster.utilization():.2f} "
                  f"price=${price:.3f} evictions={evictions}")

    print(f"[market] final: util={cluster.utilization():.2f} "
          f"mean_price=${np.mean(prices):.3f} out_of_bid_evictions={evictions} "
          f"placed={cluster.stats.placed} failed={cluster.stats.failed}")


if __name__ == "__main__":
    main()
