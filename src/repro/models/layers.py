"""Shared layer machinery: parameter definition trees, norms, RoPE, MLPs.

Parameters are plain nested dicts of jnp arrays.  Every parameter is declared
once as a ``ParamDef`` carrying shape, init and *logical sharding axes*; the
same tree therefore yields (a) materialized params, (b) PartitionSpecs for
jit boundaries, (c) shape-only ShapeDtypeStructs for the dry run — keeping
init and sharding impossible to de-synchronize.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding import constrain, resolve


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones | embed | ssm_dt | ssm_alog
    scale: float = 1.0            # fan-in style divisor applied to normal init

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def _init_leaf(d: ParamDef, key, dtype) -> jax.Array:
    if d.init == "zeros":
        return jnp.zeros(d.shape, dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, dtype)
    if d.init == "ssm_dt":  # dt bias ~ log-uniform in [1e-3, 1e-1]
        u = jax.random.uniform(key, d.shape, jnp.float32, 1e-3, 1e-1)
        inv = u + jnp.log(-jnp.expm1(-u))  # inverse softplus
        return inv.astype(dtype)
    if d.init == "ssm_alog":  # A in [1, 16], stored as log
        a = jax.random.uniform(key, d.shape, jnp.float32, 1.0, 16.0)
        return jnp.log(a).astype(dtype)
    std = d.scale / np.sqrt(max(1, d.shape[0] if d.init == "normal" else 1))
    if d.init == "embed":
        std = d.scale
    return (jax.random.normal(key, d.shape, jnp.float32) * std).astype(dtype)


def materialize(defs: Any, key: jax.Array, dtype=jnp.float32) -> Any:
    """ParamDef tree → param tree (deterministic per-leaf keys by path)."""
    leaves_with_path = jax.tree_util.tree_flatten_with_path(defs, is_leaf=is_def)[0]
    flat = {}
    for path, d in leaves_with_path:
        path_str = jax.tree_util.keystr(path)
        leaf_key = jax.random.fold_in(key, hash(path_str) % (2**31))
        flat[path_str] = _init_leaf(d, leaf_key, dtype)
    treedef = jax.tree_util.tree_structure(defs, is_leaf=is_def)
    return jax.tree_util.tree_unflatten(
        treedef, [flat[jax.tree_util.keystr(p)] for p, _ in leaves_with_path]
    )


def pspec_tree(defs: Any):
    """ParamDef tree → PartitionSpec tree (resolved against current mesh)."""
    return jax.tree.map(lambda d: resolve(d.logical), defs, is_leaf=is_def)


def shape_tree(defs: Any, dtype=jnp.float32):
    """ParamDef tree → ShapeDtypeStruct tree (dry run, no allocation)."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype), defs, is_leaf=is_def
    )


def stack_defs(defs: Any, n: int) -> Any:
    """Prepend a layer axis (for scan-over-layers stacked parameters)."""
    return jax.tree.map(
        lambda d: ParamDef((n,) + d.shape, (None,) + d.logical, d.init, d.scale),
        defs,
        is_leaf=is_def,
    )


def param_count(tree: Any) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


# ---------------------------------------------------------------------------
# Norms / rotary / MLP
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, weight: jax.Array, eps: float) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                      # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def glu_mlp(x: jax.Array, p: Dict[str, jax.Array], kind: str) -> jax.Array:
    """SwiGLU / GeGLU feed-forward with TP constraints."""
    act = jax.nn.silu if kind == "swiglu" else jax.nn.gelu
    gate = x @ p["w_gate"]
    up = x @ p["w_up"]
    h = act(gate) * up
    h = constrain(h, "batch", None, "ff")
    return h @ p["w_down"]


def mlp_defs(d_model: int, d_ff: int) -> Dict[str, ParamDef]:
    return {
        "w_gate": ParamDef((d_model, d_ff), ("embed", "ff")),
        "w_up": ParamDef((d_model, d_ff), ("embed", "ff")),
        "w_down": ParamDef((d_ff, d_model), ("ff", "embed")),
    }


def norm_defs(d_model: int) -> ParamDef:
    return ParamDef((d_model,), ("norm",), init="zeros")


def cross_entropy_loss(
    logits: jax.Array, labels: jax.Array, z_loss: float = 1e-4
) -> jax.Array:
    """Token-mean CE in fp32 with optional z-loss (stabilizes large vocabs)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    true = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = lse - true
    if z_loss:
        loss = loss + z_loss * jnp.square(lse)
    return jnp.mean(loss)
