"""xLSTM blocks: mLSTM (matrix memory, chunked-parallel train / O(1) decode)
and sLSTM (scalar memory, strictly recurrent), after arXiv:2405.04517.

Stabilized exponential gating throughout (running max ``m``).  The mLSTM
chunk form mirrors the SSD trick in models/ssm.py: intra-chunk quadratic on
Q-token tiles + an inter-chunk carried matrix state C (B,H,P,P) — MXU-shaped
and VMEM-sized, the TPU-native replacement for the paper's fused CUDA
recurrence.  sLSTM is inherently sequential (its recurrence is
non-associative), so it runs as ``lax.scan`` over time — the xLSTM paper
makes the same observation for GPUs.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding import constrain
from .layers import ParamDef, rms_norm


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


class MLSTMState(NamedTuple):
    c: jax.Array   # (B, H, P, P) matrix memory
    n: jax.Array   # (B, H, P)    normalizer
    m: jax.Array   # (B, H)       stabilizer


def mlstm_dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    d_inner = cfg.ssm_expand * cfg.d_model
    heads = cfg.n_heads
    return d_inner, heads, d_inner // heads


def mlstm_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    d = cfg.d_model
    d_inner, h, p = mlstm_dims(cfg)
    return {
        "norm": ParamDef((d,), ("norm",), init="zeros"),
        "w_up": ParamDef((d, d_inner), ("embed", "ssm_inner")),
        "w_z": ParamDef((d, d_inner), ("embed", "ssm_inner")),
        "wq": ParamDef((d_inner, d_inner), ("ssm_inner", None)),
        "wk": ParamDef((d_inner, d_inner), ("ssm_inner", None)),
        "wv": ParamDef((d_inner, d_inner), ("ssm_inner", None)),
        "w_i": ParamDef((d_inner, h), ("ssm_inner", None), init="zeros"),
        "w_f": ParamDef((d_inner, h), ("ssm_inner", None), init="zeros"),
        "b_i": ParamDef((h,), (None,), init="zeros"),
        "b_f": ParamDef((h,), (None,), init="ones", scale=3.0),
        "head_norm": ParamDef((d_inner,), ("ssm_inner",), init="zeros"),
        "w_down": ParamDef((d_inner, d), ("ssm_inner", "embed")),
    }


def _mlstm_qkvif(x_up, prm, cfg):
    d_inner, h, p = mlstm_dims(cfg)
    lead = x_up.shape[:-1]
    q = (x_up @ prm["wq"]).reshape(*lead, h, p) / jnp.sqrt(p)
    k = (x_up @ prm["wk"]).reshape(*lead, h, p) / jnp.sqrt(p)
    v = (x_up @ prm["wv"]).reshape(*lead, h, p)
    i_raw = (x_up @ prm["w_i"] + prm["b_i"]).astype(jnp.float32)
    f_raw = (x_up @ prm["w_f"] + 3.0 * prm["b_f"]).astype(jnp.float32)
    return q, k, v, i_raw, f_raw


def mlstm_forward(x, prm, cfg: ModelConfig):
    """Full-sequence chunked mLSTM (train / prefill)."""
    bsz, s, d = x.shape
    d_inner, h, p = mlstm_dims(cfg)
    q_len = min(cfg.ssm_chunk, s)
    assert s % q_len == 0
    nc = s // q_len

    hx = rms_norm(x, prm["norm"], cfg.norm_eps)
    x_up = hx @ prm["w_up"]
    z = hx @ prm["w_z"]
    q, k, v, i_raw, f_raw = _mlstm_qkvif(x_up, prm, cfg)
    logf = jax.nn.log_sigmoid(f_raw)                                    # (B,S,H)

    def to_chunks(t):
        return t.reshape(bsz, nc, q_len, *t.shape[2:]).transpose(1, 0, 2, *range(3, t.ndim + 1))

    qc, kc, vc = map(to_chunks, (q.astype(jnp.float32), k.astype(jnp.float32),
                                 v.astype(jnp.float32)))                # (nc,B,Q,H,P)
    ic, fc = map(to_chunks, (i_raw, logf))                              # (nc,B,Q,H)
    mask = jnp.tril(jnp.ones((q_len, q_len), bool))

    def chunk_body(carry, inp):
        c_prev, n_prev, m_prev = carry
        qq, kk, vv, ii, lf = inp
        fcum = jnp.cumsum(lf, axis=1)                                   # (B,Q,H)
        g = fcum + m_prev[:, None, :]                                   # total decay incl. carry
        # intra-chunk log weights: F_t - F_s + i_s (s<=t)
        logw = fcum[:, :, None, :] - fcum[:, None, :, :] + ii[:, None, :, :]
        logw = jnp.where(mask[None, :, :, None], logw, -jnp.inf)        # (B,Q,Q,H)
        m_loc = jnp.maximum(jnp.max(logw, axis=2), g)                   # (B,Q,H)
        w = jnp.exp(logw - m_loc[:, :, None, :])                        # (B,Q,Q,H)
        scores = jnp.einsum("bthp,bshp->btsh", qq, kk) * w
        num = jnp.einsum("btsh,bshp->bthp", scores, vv)
        num = num + jnp.exp(g - m_loc)[..., None] * jnp.einsum(
            "bthp,bhpr->bthr", qq, c_prev
        )
        n_eff = jnp.einsum("btsh,bshp->bthp", w, kk) + jnp.exp(g - m_loc)[..., None] * n_prev[:, None]
        den = jnp.maximum(
            jnp.abs(jnp.einsum("bthp,bthp->bth", qq, n_eff)), jnp.exp(-m_loc)
        )
        h_out = num / den[..., None]                                    # (B,Q,H,P)
        # carry update (chunk end)
        f_tot = fcum[:, -1, :]                                          # (B,H)
        m_new = jnp.maximum(
            f_tot + m_prev, jnp.max(f_tot[:, None, :] - fcum + ii, axis=1)
        )
        decay_s = jnp.exp(f_tot[:, None, :] - fcum + ii - m_new[:, None, :])  # (B,Q,H)
        c_new = jnp.exp(f_tot + m_prev - m_new)[..., None, None] * c_prev + jnp.einsum(
            "bqh,bqhp,bqhr->bhpr", decay_s, kk, vv
        )
        n_new = jnp.exp(f_tot + m_prev - m_new)[..., None] * n_prev + jnp.einsum(
            "bqh,bqhp->bhp", decay_s, kk
        )
        return (c_new, n_new, m_new), h_out

    init = (
        jnp.zeros((bsz, h, p, p), jnp.float32),
        jnp.zeros((bsz, h, p), jnp.float32),
        jnp.full((bsz, h), -jnp.inf, jnp.float32),
    )
    _, hs = jax.lax.scan(chunk_body, init, (qc, kc, vc, ic, fc))        # (nc,B,Q,H,P)
    hs = hs.transpose(1, 0, 2, 3, 4).reshape(bsz, s, d_inner).astype(x.dtype)
    hs = rms_norm(hs, prm["head_norm"], cfg.norm_eps)
    out = (hs * jax.nn.silu(z)) @ prm["w_down"]
    return constrain(out, "batch", None, None)


def mlstm_init_state(cfg: ModelConfig, batch: int) -> MLSTMState:
    _, h, p = mlstm_dims(cfg)
    return MLSTMState(
        c=jnp.zeros((batch, h, p, p), jnp.float32),
        n=jnp.zeros((batch, h, p), jnp.float32),
        m=jnp.full((batch, h), -jnp.inf, jnp.float32),
    )


def mlstm_decode_step(x, prm, cfg: ModelConfig, state: MLSTMState):
    bsz = x.shape[0]
    d_inner, h, p = mlstm_dims(cfg)
    hx = rms_norm(x, prm["norm"], cfg.norm_eps)
    x_up = (hx @ prm["w_up"])[:, 0]
    z = (hx @ prm["w_z"])[:, 0]
    q, k, v, i_raw, f_raw = _mlstm_qkvif(x_up, prm, cfg)                # (B,H,P)/(B,H)
    logf = jax.nn.log_sigmoid(f_raw)
    q, k, v = q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32)

    m_new = jnp.maximum(logf + state.m, i_raw)                          # (B,H)
    f_eff = jnp.exp(logf + state.m - m_new)
    i_eff = jnp.exp(i_raw - m_new)
    c_new = f_eff[..., None, None] * state.c + i_eff[..., None, None] * (
        k[..., :, None] * v[..., None, :]
    )
    n_new = f_eff[..., None] * state.n + i_eff[..., None] * k
    num = jnp.einsum("bhp,bhpr->bhr", q, c_new)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhp,bhp->bh", q, n_new)), jnp.exp(-m_new))
    h_out = (num / den[..., None]).reshape(bsz, 1, d_inner).astype(x.dtype)
    h_out = rms_norm(h_out, prm["head_norm"], cfg.norm_eps)
    out = (h_out * jax.nn.silu(z)[:, None]) @ prm["w_down"]
    return out, MLSTMState(c=c_new, n=n_new, m=m_new)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


class SLSTMState(NamedTuple):
    c: jax.Array   # (B, H, P)
    n: jax.Array   # (B, H, P)
    m: jax.Array   # (B, H, P)
    h: jax.Array   # (B, H, P)


def slstm_dims(cfg: ModelConfig) -> Tuple[int, int]:
    h = cfg.n_heads
    return h, cfg.d_model // h


def slstm_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    d = cfg.d_model
    h, p = slstm_dims(cfg)
    d_up = (d * 4) // 3
    defs = {"norm": ParamDef((d,), ("norm",), init="zeros")}
    for g in ("z", "i", "f", "o"):
        defs[f"w_{g}"] = ParamDef((d, d), ("embed", "ssm_inner"))
        defs[f"r_{g}"] = ParamDef((h, p, p), (None, None, None), scale=0.3)
        defs[f"b_{g}"] = ParamDef((d,), ("ssm_inner",), init="zeros")
    defs["head_norm"] = ParamDef((d,), ("ssm_inner",), init="zeros")
    # post-up/down GeGLU (factor 4/3, per the xLSTM paper's sLSTM block)
    defs["mlp_norm"] = ParamDef((d,), ("norm",), init="zeros")
    defs["w_gate"] = ParamDef((d, d_up), ("embed", "ff"))
    defs["w_upp"] = ParamDef((d, d_up), ("embed", "ff"))
    defs["w_down"] = ParamDef((d_up, d), ("ff", "embed"))
    return defs


def _slstm_step(prm, cfg, carry, gate_x):
    """One recurrent step.  gate_x: dict of pre-computed W·x_t (B,H,P)."""
    c, n, m, h_prev = carry
    hmat = lambda g: jnp.einsum("bhp,hpq->bhq", h_prev, prm[f"r_{g}"])
    z = jnp.tanh(gate_x["z"] + hmat("z"))
    i_raw = (gate_x["i"] + hmat("i")).astype(jnp.float32)
    f_raw = (gate_x["f"] + hmat("f")).astype(jnp.float32)
    o = jax.nn.sigmoid(gate_x["o"] + hmat("o"))
    logf = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(logf + m, i_raw)
    i_eff = jnp.exp(i_raw - m_new)
    f_eff = jnp.exp(logf + m - m_new)
    c_new = f_eff * c + i_eff * z.astype(jnp.float32)
    n_new = f_eff * n + i_eff
    h_new = (o.astype(jnp.float32) * c_new / jnp.maximum(n_new, 1e-6)).astype(z.dtype)
    return SLSTMState(c_new, n_new, m_new, h_new)


def _slstm_gates_x(hx, prm, cfg):
    h, p = slstm_dims(cfg)
    lead = hx.shape[:-1]
    return {
        g: (hx @ prm[f"w_{g}"] + prm[f"b_{g}"]).reshape(*lead, h, p)
        for g in ("z", "i", "f", "o")
    }


def slstm_forward(x, prm, cfg: ModelConfig):
    bsz, s, d = x.shape
    h, p = slstm_dims(cfg)
    hx = rms_norm(x, prm["norm"], cfg.norm_eps)
    gates = _slstm_gates_x(hx, prm, cfg)                                # (B,S,H,P) each

    def body(carry, gx):
        new = _slstm_step(prm, cfg, carry, gx)
        return new, new.h

    init = slstm_init_state(cfg, bsz, x.dtype)
    xs = {g: gates[g].transpose(1, 0, 2, 3) for g in gates}
    _, hs = jax.lax.scan(body, init, xs)                                # (S,B,H,P)
    hs = hs.transpose(1, 0, 2, 3).reshape(bsz, s, d)
    y = rms_norm(hs, prm["head_norm"], cfg.norm_eps)
    # GeGLU post-MLP
    hm = rms_norm(x + y, prm["mlp_norm"], cfg.norm_eps)
    mlp = (jax.nn.gelu(hm @ prm["w_gate"]) * (hm @ prm["w_upp"])) @ prm["w_down"]
    return y + mlp  # caller adds residual to x


def slstm_init_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> SLSTMState:
    h, p = slstm_dims(cfg)
    zero = jnp.zeros((batch, h, p), jnp.float32)
    return SLSTMState(c=zero, n=zero, m=zero - jnp.inf, h=jnp.zeros((batch, h, p), dtype))


def slstm_decode_step(x, prm, cfg: ModelConfig, state: SLSTMState):
    bsz = x.shape[0]
    h, p = slstm_dims(cfg)
    hx = rms_norm(x, prm["norm"], cfg.norm_eps)
    gates = {g: v[:, 0] for g, v in _slstm_gates_x(hx, prm, cfg).items()}
    new = _slstm_step(prm, cfg, state, gates)
    y = rms_norm(new.h.reshape(bsz, 1, -1), prm["head_norm"], cfg.norm_eps)
    hm = rms_norm(x + y, prm["mlp_norm"], cfg.norm_eps)
    mlp = (jax.nn.gelu(hm @ prm["w_gate"]) * (hm @ prm["w_upp"])) @ prm["w_down"]
    return y + mlp, new
