"""GQA/MQA attention with RoPE, KV cache, cross-attention, flash-kernel path.

Reference math is pure jnp (the oracle for the Pallas flash kernel and the
path used by CPU smoke tests AND the dry run — kernel lowering targets TPU).
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding import constrain, mesh_axes
from .layers import ParamDef, apply_rope


class KVCache(NamedTuple):
    """Per-layer-stack decode cache.  k/v: (L, B, S_max, G, hd)."""

    k: jax.Array
    v: jax.Array
    #: current length (tokens already written), int32 scalar
    length: jax.Array


def attn_defs(cfg: ModelConfig, cross: bool = False) -> Dict[str, ParamDef]:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nq, nkv = cfg.n_heads * hd, cfg.n_kv_heads * hd
    flat = "qkv_flat" if cfg.attn_tp else None
    defs = {
        "wq": ParamDef((d, nq), ("embed", flat)),
        "wk": ParamDef((d, nkv), ("embed", flat)),
        "wv": ParamDef((d, nkv), ("embed", flat)),
        "wo": ParamDef((nq, d), (flat, "embed")),
    }
    if cfg.qkv_bias and not cross:
        defs["bq"] = ParamDef((nq,), (flat,), init="zeros")
        defs["bk"] = ParamDef((nkv,), (flat,), init="zeros")
        defs["bv"] = ParamDef((nkv,), (flat,), init="zeros")
    return defs


def _heads_logical(cfg: ModelConfig, kv: bool = False) -> Optional[str]:
    """Shard the head axis only when divisible by the TP degree."""
    if not cfg.attn_tp:
        return None
    try:
        tp = dict(zip(mesh_axes(), jax.sharding.get_abstract_mesh().shape.values())).get(
            "model", 1
        )
    except Exception:
        tp = 1
    heads = cfg.n_kv_heads if kv else cfg.n_heads
    return "heads" if tp > 1 and heads % tp == 0 else None


def _project_qkv(x, p, cfg: ModelConfig, positions):
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, cfg.n_heads, hd)
    k = k.reshape(b, s, cfg.n_kv_heads, hd)
    v = v.reshape(b, s, cfg.n_kv_heads, hd)
    if positions is not None:  # rope (None for e.g. encoder abs-pos variants)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, "batch", None, _heads_logical(cfg), None)
    k = constrain(k, "batch", None, _heads_logical(cfg, kv=True), None)
    v = constrain(v, "batch", None, _heads_logical(cfg, kv=True), None)
    return q, k, v


def _sdpa_reference(q, k, v, causal: bool, q_offset=0, kv_len: Optional[jax.Array] = None):
    """Grouped scaled-dot-product attention, fp32 softmax.

    q: (B, Sq, H, hd);  k/v: (B, Skv, G, hd).  ``q_offset`` places queries at
    absolute positions offset..offset+Sq (decode).  ``kv_len`` masks the
    valid cache prefix.
    """
    b, sq, h, hd = q.shape
    g = k.shape[2]
    rep = h // g
    qg = q.reshape(b, sq, g, rep, hd)
    scores = jnp.einsum("bsgrh,btgh->bgrst", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(hd).astype(jnp.float32)
    skv = k.shape[1]
    if causal:
        qpos = jnp.arange(sq)[:, None] + q_offset
        kpos = jnp.arange(skv)[None, :]
        mask = kpos <= qpos                                   # (Sq, Skv)
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    if kv_len is not None:
        valid = jnp.arange(skv) < kv_len                      # (Skv,)
        scores = jnp.where(valid[None, None, None, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bgrst,btgh->bsgrh", w, v)
    return out.reshape(b, sq, h, hd)


def _sdpa_blocked(q, k, v, causal: bool, block_q: int = 512, block_k: int = 1024):
    """Flash-algorithm attention in pure jnp (lax.scan over KV blocks with
    online softmax, remat'd block body) — the XLA-path equivalent of the
    Pallas kernel: O(Bq·Bk) live intermediates instead of O(S²) HBM tensors,
    in forward AND backward (the per-block jax.checkpoint recomputes scores).
    Used by train/prefill when cfg.attention_impl == "blocked"."""
    b, sq, h, hd = q.shape
    skv, g = k.shape[1], k.shape[2]
    rep = h // g
    bq = min(block_q, sq)
    while sq % bq:
        bq //= 2
    bk = min(block_k, skv)
    while skv % bk:
        bk //= 2
    nq, nk = sq // bq, skv // bk
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    qb = q.reshape(b, nq, bq, h, hd).transpose(1, 0, 2, 3, 4)       # (nq,B,bq,H,hd)
    kb = k.reshape(b, nk, bk, g, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nk, bk, g, hd).transpose(1, 0, 2, 3, 4)

    def kv_step(carry, inp):
        m, l, acc, qi, q_blk = carry[0], carry[1], carry[2], carry[3], carry[4]
        kj, k_blk, v_blk = inp
        kf = jnp.repeat(k_blk, rep, axis=2)                          # (B,bk,H,hd)
        vf = jnp.repeat(v_blk, rep, axis=2)
        s = jnp.einsum("bqhd,bkhd->bhqk", q_blk.astype(jnp.float32),
                       kf.astype(jnp.float32)) * scale
        if causal:
            qpos = qi * bq + jnp.arange(bq)
            kpos = kj * bk + jnp.arange(bk)
            s = jnp.where((kpos[None, :] <= qpos[:, None])[None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, vf.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new, qi, q_blk), None

    kv_step = jax.checkpoint(kv_step)

    def q_block(_, inp):
        qi, q_blk = inp
        init = (
            jnp.full((b, h, bq), -1e30, jnp.float32),
            jnp.zeros((b, h, bq), jnp.float32),
            jnp.zeros((b, h, bq, hd), jnp.float32),
            qi,
            q_blk,
        )
        (m, l, acc, _, _), _ = jax.lax.scan(
            kv_step, init, (jnp.arange(nk), kb, vb)
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]                 # (B,H,bq,hd)
        return None, out.transpose(0, 2, 1, 3).astype(q.dtype)       # (B,bq,H,hd)

    _, outs = jax.lax.scan(q_block, None, (jnp.arange(nq), qb))      # (nq,B,bq,H,hd)
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, hd)


def attention(
    x: jax.Array,
    p: Dict[str, jax.Array],
    cfg: ModelConfig,
    positions: jax.Array,
    causal: bool = True,
) -> jax.Array:
    """Full-sequence attention (train / prefill)."""
    b, s, d = x.shape
    q, k, v = _project_qkv(x, p, cfg, positions)
    if cfg.attention_impl == "flash" and causal:
        from repro.kernels.flash_attention import flash_attention

        out = flash_attention(q, k, v, causal=True)
    elif cfg.attention_impl == "blocked":
        out = _sdpa_blocked(q, k, v, causal=causal)
    else:
        out = _sdpa_reference(q, k, v, causal=causal)
    out = out.reshape(b, s, cfg.n_heads * cfg.resolved_head_dim)
    y = out @ p["wo"]
    return constrain(y, "batch", None, None)


def attention_decode(
    x: jax.Array,
    p: Dict[str, jax.Array],
    cfg: ModelConfig,
    k_cache: jax.Array,       # (B, S_max, G, hd)
    v_cache: jax.Array,
    length: jax.Array,        # () int32 — tokens already in cache
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode: append K/V at ``length``, attend over the prefix."""
    from repro.sharding import decode_kv_axes

    b = x.shape[0]
    q, k, v = _project_qkv(x, p, cfg, positions=jnp.full((b, 1), length))
    k_cache = jax.lax.dynamic_update_slice(
        k_cache, k.astype(k_cache.dtype), (0, length, 0, 0)
    )
    v_cache = jax.lax.dynamic_update_slice(
        v_cache, v.astype(v_cache.dtype), (0, length, 0, 0)
    )
    # Pin q and the cache to ONE layout (heads xor head_dim on 'model') so
    # the scores contraction partial-sums instead of SPMD resharding the
    # whole cache every layer (§Perf E: 80% of decode HBM traffic).
    g_ax, hd_ax = decode_kv_axes(cfg.n_kv_heads, cfg.resolved_head_dim)
    k_cache = constrain(k_cache, "batch", None, g_ax, hd_ax)
    v_cache = constrain(v_cache, "batch", None, g_ax, hd_ax)
    q = constrain(q, "batch", None, None if g_ax else _heads_logical(cfg), hd_ax)
    out = _sdpa_reference(
        q, k_cache, v_cache, causal=False, kv_len=length + 1
    )
    y = out.reshape(b, 1, -1) @ p["wo"]
    return constrain(y, "batch", None, None), k_cache, v_cache


def cross_attention(
    x: jax.Array,
    p: Dict[str, jax.Array],
    cfg: ModelConfig,
    enc_k: jax.Array,          # (B, S_enc, G, hd) — precomputed from encoder
    enc_v: jax.Array,
) -> jax.Array:
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(b, s, cfg.n_heads, hd)
    q = constrain(q, "batch", None, _heads_logical(cfg), None)
    out = _sdpa_reference(q, enc_k, enc_v, causal=False)
    y = out.reshape(b, s, -1) @ p["wo"]
    return constrain(y, "batch", None, None)


def encode_cross_kv(enc_out: jax.Array, p: Dict[str, jax.Array], cfg: ModelConfig):
    b, s, _ = enc_out.shape
    hd = cfg.resolved_head_dim
    k = (enc_out @ p["wk"]).reshape(b, s, cfg.n_kv_heads, hd)
    v = (enc_out @ p["wv"]).reshape(b, s, cfg.n_kv_heads, hd)
    return k, v


def init_kv_cache(
    cfg: ModelConfig, n_layers: int, batch: int, max_len: int, dtype=jnp.bfloat16
) -> KVCache:
    hd = cfg.resolved_head_dim
    shape = (n_layers, batch, max_len, cfg.n_kv_heads, hd)
    return KVCache(
        k=jnp.zeros(shape, dtype),
        v=jnp.zeros(shape, dtype),
        length=jnp.zeros((), jnp.int32),
    )
