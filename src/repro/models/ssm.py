"""Mamba2 (SSD) block — chunked parallel scan for train/prefill, O(1)
recurrent step for decode.  TPU adaptation notes:

* the chunked SSD formulation turns the recurrence into MXU-shaped einsums
  (intra-chunk quadratic + inter-chunk ``lax.scan`` over chunk states),
  the TPU-native equivalent of the paper-codebase's fused CUDA scan;
* d_inner (and heads) shard over the 'model' axis; states are head-local so
  no collectives appear inside the block beyond the in/out projections.

Shapes: x (B,S,D) → y (B,S,D).  H = d_inner/head_dim heads, state N.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding import constrain
from .layers import ParamDef, rms_norm


class MambaState(NamedTuple):
    conv: jax.Array   # (B, W-1, d_conv_in)  rolling conv window
    ssd: jax.Array    # (B, H, P, N)         SSM state


def mamba_dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    return d_inner, n_heads, cfg.ssm_head_dim, cfg.ssm_state


def mamba_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    d = cfg.d_model
    d_inner, h, p, n = mamba_dims(cfg)
    d_conv_in = d_inner + 2 * n           # x-path + B + C go through the conv
    return {
        "norm": ParamDef((d,), ("norm",), init="zeros"),
        "in_proj": ParamDef((d, 2 * d_inner + 2 * n + h), ("embed", "ssm_inner")),
        "conv_w": ParamDef((cfg.ssm_conv_width, d_conv_in), ("conv", "ssm_inner")),
        "conv_b": ParamDef((d_conv_in,), ("ssm_inner",), init="zeros"),
        "a_log": ParamDef((h,), (None,), init="ssm_alog"),
        "dt_bias": ParamDef((h,), (None,), init="ssm_dt"),
        "d_skip": ParamDef((h,), (None,), init="ones"),
        "gate_norm": ParamDef((d_inner,), ("ssm_inner",), init="zeros"),
        "out_proj": ParamDef((d_inner, d), ("ssm_inner", "embed")),
    }


def _split_proj(xz: jax.Array, cfg: ModelConfig):
    d_inner, h, p, n = mamba_dims(cfg)
    z, xbc_dt = jnp.split(xz, [d_inner], axis=-1)
    xbc, dt = jnp.split(xbc_dt, [d_inner + 2 * n], axis=-1)
    return z, xbc, dt                       # (..., d_inner), (..., d_inner+2N), (..., H)


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv, width W (pure jnp shift-and-add: W is 4)."""
    width = w.shape[0]
    out = xbc * w[-1]
    for i in range(1, width):
        shifted = jnp.pad(xbc, ((0, 0), (i, 0), (0, 0)))[:, : xbc.shape[1], :]
        out = out + shifted * w[-1 - i]
    return jax.nn.silu(out + b)


def mamba_forward(
    x: jax.Array, prm: Dict[str, jax.Array], cfg: ModelConfig
) -> jax.Array:
    """Full-sequence chunked SSD (train / prefill)."""
    bsz, s, d = x.shape
    d_inner, h, p, n = mamba_dims(cfg)
    q = min(cfg.ssm_chunk, s)
    assert s % q == 0, f"seq {s} must divide chunk {q}"
    nc = s // q

    hx = rms_norm(x, prm["norm"], cfg.norm_eps)
    proj = hx @ prm["in_proj"]
    proj = constrain(proj, "batch", None, "ssm_inner")
    z, xbc, dt_raw = _split_proj(proj, cfg)
    xbc = _causal_conv(xbc, prm["conv_w"], prm["conv_b"])
    xs, bmat, cmat = jnp.split(xbc, [d_inner, d_inner + n], axis=-1)

    # chunk-major layout for the scan: (nc, B, Q, ·)
    xh = xs.reshape(bsz, nc, q, h, p).transpose(1, 0, 2, 3, 4).astype(jnp.float32)
    bm = bmat.reshape(bsz, nc, q, n).transpose(1, 0, 2, 3).astype(jnp.float32)
    cm = cmat.reshape(bsz, nc, q, n).transpose(1, 0, 2, 3).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + prm["dt_bias"])  # (B,S,H)
    dt = dt.reshape(bsz, nc, q, h).transpose(1, 0, 2, 3)
    a = -jnp.exp(prm["a_log"].astype(jnp.float32))                     # (H,)
    mask = jnp.tril(jnp.ones((q, q), bool))

    def chunk_body(state, inp):
        """One chunk: intra-chunk quadratic + cross-chunk state, so only
        (B,Q,Q,H)-sized intermediates are ever live (scan over chunks keeps
        the working set ~S/nc of the naive all-chunks form)."""
        xh_c, bm_c, cm_c, dt_c = inp                                   # (B,Q,·)
        da = dt_c * a                                                  # (B,Q,H)
        cum = jnp.cumsum(da, axis=1)                                   # (B,Q,H)
        # inter-chunk: contribution of the carried state
        y_inter = jnp.einsum("btn,bhpn->bthp", cm_c, state) * jnp.exp(cum)[..., None]
        # intra-chunk: masked decay attention.  Mask BEFORE exp (masked diffs
        # are positive and overflow; exp(inf)·0 NaNs the backward pass).
        diff = cum[:, :, None, :] - cum[:, None, :, :]                 # (B,Q,Q,H)
        lmat = jnp.exp(jnp.where(mask[None, :, :, None], diff, -1e9))
        cb = jnp.einsum("btn,bsn->bts", cm_c, bm_c)                    # (B,Q,Q)
        w = cb[..., None] * lmat * dt_c[:, None, :, :]                 # (B,Q,Q,H)
        y_intra = jnp.einsum("btsh,bshp->bthp", w, xh_c)
        # new carried state
        decay_to_end = jnp.exp(cum[:, -1:, :] - cum)                   # (B,Q,H)
        contrib = jnp.einsum("bqh,bqhp,bqn->bhpn", dt_c * decay_to_end, xh_c, bm_c)
        new_state = jnp.exp(jnp.sum(da, axis=1))[..., None, None] * state + contrib
        return new_state, y_inter + y_intra

    init = jnp.zeros((bsz, h, p, n), jnp.float32)
    _, y = jax.lax.scan(chunk_body, init, (xh, bm, cm, dt))            # (nc,B,Q,H,P)
    y = y.transpose(1, 0, 2, 3, 4).reshape(bsz, s, h, p)
    y = y + prm["d_skip"][None, None, :, None] * xh.transpose(1, 0, 2, 3, 4).reshape(
        bsz, s, h, p
    )
    y = y.reshape(bsz, s, d_inner).astype(x.dtype)

    y = rms_norm(y * jax.nn.silu(z), prm["gate_norm"], cfg.norm_eps)
    out = y @ prm["out_proj"]
    return constrain(out, "batch", None, None)


def mamba_init_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> MambaState:
    d_inner, h, p, n = mamba_dims(cfg)
    return MambaState(
        conv=jnp.zeros((batch, cfg.ssm_conv_width - 1, d_inner + 2 * n), dtype),
        ssd=jnp.zeros((batch, h, p, n), jnp.float32),
    )


def mamba_decode_step(
    x: jax.Array,                 # (B, 1, D)
    prm: Dict[str, jax.Array],
    cfg: ModelConfig,
    state: MambaState,
) -> Tuple[jax.Array, MambaState]:
    bsz = x.shape[0]
    d_inner, h, p, n = mamba_dims(cfg)
    hx = rms_norm(x, prm["norm"], cfg.norm_eps)
    proj = (hx @ prm["in_proj"])[:, 0]                                  # (B, ·)
    z, xbc, dt_raw = _split_proj(proj, cfg)

    window = jnp.concatenate([state.conv, xbc[:, None, :]], axis=1)     # (B, W, C)
    conv_out = jnp.einsum("bwc,wc->bc", window, prm["conv_w"]) + prm["conv_b"]
    xbc = jax.nn.silu(conv_out)
    new_conv = window[:, 1:, :]

    xs, bm, cm = jnp.split(xbc, [d_inner, d_inner + n], axis=-1)
    xh = xs.reshape(bsz, h, p).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + prm["dt_bias"])   # (B,H)
    a = -jnp.exp(prm["a_log"].astype(jnp.float32))
    decay = jnp.exp(dt * a)                                             # (B,H)
    upd = (dt[..., None, None] * xh[..., :, None]) * bm.astype(jnp.float32)[:, None, None, :]
    new_ssd = decay[..., None, None] * state.ssd + upd                  # (B,H,P,N)
    y = jnp.einsum("bn,bhpn->bhp", cm.astype(jnp.float32), new_ssd)
    y = y + prm["d_skip"][None, :, None] * xh
    y = y.reshape(bsz, 1, d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z)[:, None, :], prm["gate_norm"], cfg.norm_eps)
    out = y @ prm["out_proj"]
    return out, MambaState(conv=new_conv, ssd=new_ssd)
