"""Model assembly: parameter trees + train/prefill/decode forwards for all
six assigned families (dense / moe / vlm / audio enc-dec / xlstm / hybrid).

Layer stacks run under ``lax.scan`` with stacked parameters (compact HLO at
512-way SPMD; MaxText-style), except xLSTM whose 12 heterogeneous blocks are
unrolled.  Remat policy per config.  All forwards are mesh-agnostic: sharding
enters only through ``repro.sharding.constrain`` and the ParamDef logical
axes.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding import constrain
from . import xlstm as xl
from .attention import (
    attn_defs,
    attention,
    attention_decode,
    cross_attention,
    encode_cross_kv,
)
from .layers import (
    ParamDef,
    cross_entropy_loss,
    glu_mlp,
    materialize,
    mlp_defs,
    norm_defs,
    pspec_tree,
    rms_norm,
    shape_tree,
    stack_defs,
)
from .moe import moe_defs, moe_ffn
from .ssm import (
    MambaState,
    mamba_decode_step,
    mamba_defs,
    mamba_forward,
    mamba_init_state,
)

AUX_LOSS_WEIGHT = 0.01


# ---------------------------------------------------------------------------
# Parameter definitions
# ---------------------------------------------------------------------------


def _decoder_layer_defs(cfg: ModelConfig, cross: bool = False) -> Dict[str, Any]:
    defs: Dict[str, Any] = {
        "attn_norm": norm_defs(cfg.d_model),
        "attn": attn_defs(cfg),
    }
    if cross:
        defs["cross_norm"] = norm_defs(cfg.d_model)
        defs["cross"] = attn_defs(cfg, cross=True)
    defs["mlp_norm"] = norm_defs(cfg.d_model)
    if cfg.is_moe:
        defs["moe"] = moe_defs(cfg)
        if cfg.moe_dense_residual:
            defs["dense_mlp"] = mlp_defs(cfg.d_model, cfg.d_ff)
    elif cfg.mlp_type != "none":
        defs["mlp"] = mlp_defs(cfg.d_model, cfg.d_ff)
    return defs


def model_defs(cfg: ModelConfig) -> Dict[str, Any]:
    d, v = cfg.d_model, cfg.vocab_padded
    defs: Dict[str, Any] = {
        "embed": ParamDef((v, d), ("vocab", "embed"), init="embed", scale=0.02),
        "final_norm": norm_defs(d),
    }
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef((d, v), ("embed", "vocab"), scale=1.0)

    if cfg.block_pattern == "attention":
        defs["layers"] = stack_defs(
            _decoder_layer_defs(cfg, cross=cfg.encoder_decoder), cfg.n_layers
        )
        if cfg.encoder_decoder:
            enc_layer = {
                "attn_norm": norm_defs(d),
                "attn": attn_defs(cfg),
                "mlp_norm": norm_defs(d),
                "mlp": mlp_defs(d, cfg.d_ff),
            }
            defs["encoder"] = {
                "layers": stack_defs(enc_layer, cfg.n_encoder_layers),
                "final_norm": norm_defs(d),
            }
    elif cfg.block_pattern == "zamba_hybrid":
        groups, tail = divmod(cfg.n_layers, cfg.shared_attn_every)
        defs["mamba_groups"] = stack_defs(
            stack_defs(mamba_defs(cfg), cfg.shared_attn_every), groups
        )
        if tail:
            defs["mamba_tail"] = stack_defs(mamba_defs(cfg), tail)
        defs["shared"] = {
            "attn_norm": norm_defs(d),
            "attn": attn_defs(cfg),
            "mlp_norm": norm_defs(d),
            "mlp": mlp_defs(d, cfg.d_ff),
        }
    elif cfg.block_pattern == "xlstm":
        layers: Dict[str, Any] = {}
        for i in range(cfg.n_layers):
            if (i % cfg.slstm_every) == cfg.slstm_every - 1:
                layers[f"slstm_{i}"] = xl.slstm_defs(cfg)
            else:
                layers[f"mlstm_{i}"] = xl.mlstm_defs(cfg)
        defs["layers"] = layers
    else:
        raise ValueError(cfg.block_pattern)
    return defs


def init_params(cfg: ModelConfig, key: jax.Array):
    dtype = jnp.dtype(cfg.params_dtype)
    return materialize(model_defs(cfg), key, dtype)


def param_pspecs(cfg: ModelConfig):
    return pspec_tree(model_defs(cfg))


def param_shapes(cfg: ModelConfig):
    return shape_tree(model_defs(cfg), jnp.dtype(cfg.params_dtype))


# ---------------------------------------------------------------------------
# Block applications
# ---------------------------------------------------------------------------


def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    return jax.checkpoint(fn)


def _attn_layer(h, lp, cfg: ModelConfig, positions, causal=True, enc_out=None):
    """One transformer block (optionally with cross-attention)."""
    aux = jnp.zeros((), jnp.float32)
    if cfg.sequence_parallel:
        # residual stream lives seq-sharded between blocks (Megatron-SP)
        h = constrain(h, "batch", "seq_shard", None)
    a = attention(rms_norm(h, lp["attn_norm"], cfg.norm_eps), lp["attn"], cfg,
                  positions, causal=causal)
    h = h + a
    if enc_out is not None:
        ek, ev = encode_cross_kv(enc_out, lp["cross"], cfg)
        c = cross_attention(rms_norm(h, lp["cross_norm"], cfg.norm_eps),
                            lp["cross"], cfg, ek, ev)
        h = h + c
    hn = rms_norm(h, lp["mlp_norm"], cfg.norm_eps)
    if cfg.is_moe:
        y, aux = moe_ffn(hn, lp["moe"], cfg)
        if cfg.moe_dense_residual:
            y = y + glu_mlp(hn, lp["dense_mlp"], cfg.mlp_type)
    elif cfg.mlp_type != "none":
        y = glu_mlp(hn, lp["mlp"], cfg.mlp_type)
    else:
        y = jnp.zeros_like(h)
    return h + y, aux


def _decoder_stack(h, params, cfg: ModelConfig, positions, enc_out=None):
    """scan over stacked decoder layers.  Returns (h, aux_loss_sum)."""

    def body(carry, lp):
        hh, aux = carry
        hh, a = _attn_layer(hh, lp, cfg, positions, causal=True, enc_out=enc_out)
        return (hh, aux + a), None

    body = _maybe_remat(body, cfg)
    (h, aux), _ = jax.lax.scan(body, (h, jnp.zeros((), jnp.float32)), params["layers"])
    return h, aux


def _encoder_stack(enc_in, params, cfg: ModelConfig):
    pos = jnp.broadcast_to(jnp.arange(enc_in.shape[1]), enc_in.shape[:2])

    def body(h, lp):
        h, _ = _attn_layer(h, lp, cfg, pos, causal=False)
        return h, None

    body = _maybe_remat(body, cfg)
    h, _ = jax.lax.scan(body, enc_in, params["encoder"]["layers"])
    return rms_norm(h, params["encoder"]["final_norm"], cfg.norm_eps)


def _zamba_stack(h, params, cfg: ModelConfig, positions):
    shared = params["shared"]

    def group_body(carry, gp):
        hh = carry
        for i in range(cfg.shared_attn_every):
            lp = jax.tree.map(lambda x: x[i], gp)
            hh = hh + mamba_forward(hh, lp, cfg)
        hh, _ = _attn_layer(hh, shared, cfg, positions, causal=True)
        return hh, None

    body = _maybe_remat(group_body, cfg)
    h, _ = jax.lax.scan(body, h, params["mamba_groups"])
    if "mamba_tail" in params:
        tail = params["mamba_tail"]
        n_tail = jax.tree.leaves(tail)[0].shape[0]
        for i in range(n_tail):
            lp = jax.tree.map(lambda x: x[i], tail)
            h = h + mamba_forward(h, lp, cfg)
    return h, jnp.zeros((), jnp.float32)


def _xlstm_stack(h, params, cfg: ModelConfig):
    for i in range(cfg.n_layers):
        if (i % cfg.slstm_every) == cfg.slstm_every - 1:
            h = h + xl.slstm_forward(h, params["layers"][f"slstm_{i}"], cfg)
        else:
            h = h + xl.mlstm_forward(h, params["layers"][f"mlstm_{i}"], cfg)
    return h, jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# Forwards
# ---------------------------------------------------------------------------


def _embed(params, cfg: ModelConfig, tokens):
    e = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    return constrain(e, "batch", None, None)


def _logits(params, cfg: ModelConfig, h):
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = h @ w.astype(h.dtype)
    return constrain(logits, "batch", None, "vocab")


def _cast(params, cfg: ModelConfig):
    dt = jnp.dtype(cfg.dtype)
    return jax.tree.map(lambda x: x.astype(dt) if x.dtype == jnp.float32 else x, params)


def _forward_hidden(cfg: ModelConfig, params, batch: Dict[str, jax.Array]):
    """Shared backbone: embeddings (+stub frontends) → block stack → final
    norm.  Returns (h over text positions, aux loss)."""
    tokens = batch["tokens"]
    b, s_text = tokens.shape
    h = _embed(params, cfg, tokens)

    enc_out = None
    if cfg.modality == "vision_stub":
        prefix = batch["patch_embeds"].astype(h.dtype)
        h = jnp.concatenate([constrain(prefix, "batch", None, None), h], axis=1)
    if cfg.encoder_decoder:
        enc_in = constrain(batch["frame_embeds"].astype(h.dtype), "batch", None, None)
        enc_out = _encoder_stack(enc_in, params, cfg)

    s_total = h.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s_total), (b, s_total))

    if cfg.block_pattern == "attention":
        h, aux = _decoder_stack(h, params, cfg, positions, enc_out=enc_out)
    elif cfg.block_pattern == "zamba_hybrid":
        h, aux = _zamba_stack(h, params, cfg, positions)
    else:
        h, aux = _xlstm_stack(h, params, cfg)

    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    if cfg.modality == "vision_stub":  # text positions only
        h = h[:, -s_text:]
    return h, aux


def forward_train(cfg: ModelConfig, params, batch: Dict[str, jax.Array]):
    """Causal-LM (or seq2seq) loss.  batch keys per family:
    tokens/labels (+patch_embeds | frame_embeds)."""
    params = _cast(params, cfg)
    h, aux = _forward_hidden(cfg, params, batch)
    logits = _logits(params, cfg, h)
    loss = cross_entropy_loss(logits, batch["labels"])
    aux_total = AUX_LOSS_WEIGHT * aux
    metrics = {"lm_loss": loss, "aux_loss": aux_total}
    return loss + aux_total, metrics


def forward_logits(
    cfg: ModelConfig, params, batch: Dict[str, jax.Array], last_only: bool = True
):
    """Prefill-style forward: logits (last position by default), no loss."""
    params = _cast(params, cfg)
    h, _ = _forward_hidden(cfg, params, batch)
    if last_only:
        h = h[:, -1:]
    return _logits(params, cfg, h)


# ---------------------------------------------------------------------------
# Decode (serving): state containers + one-token step
# ---------------------------------------------------------------------------


class DecodeState(NamedTuple):
    length: jax.Array                                  # () int32
    kv_k: Optional[jax.Array] = None                   # (L,B,S,G,hd)
    kv_v: Optional[jax.Array] = None
    #: per-layer cache layout (serving mode): tuples of L × (B,S,G,hd)
    kv_layers_k: Optional[Tuple[jax.Array, ...]] = None
    kv_layers_v: Optional[Tuple[jax.Array, ...]] = None
    cross_k: Optional[jax.Array] = None                # (L,B,S_enc,G,hd)
    cross_v: Optional[jax.Array] = None
    mamba_groups: Optional[Any] = None                 # MambaState stacked (G,K,...)
    mamba_tail: Optional[Any] = None
    shared_k: Optional[jax.Array] = None               # (G,B,S,G_kv,hd)
    shared_v: Optional[jax.Array] = None
    xlstm: Optional[Tuple] = None


def init_decode_state(
    cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16,
    enc_len: int = 0,
) -> DecodeState:
    hd = cfg.resolved_head_dim
    g = cfg.n_kv_heads
    length = jnp.zeros((), jnp.int32)
    if cfg.block_pattern == "attention":
        if cfg.decode_cache_layout == "per_layer":
            per = (batch, max_len, g, hd)
            state = DecodeState(
                length=length,
                kv_layers_k=tuple(jnp.zeros(per, dtype) for _ in range(cfg.n_layers)),
                kv_layers_v=tuple(jnp.zeros(per, dtype) for _ in range(cfg.n_layers)),
            )
            if cfg.encoder_decoder:
                ck = (cfg.n_layers, batch, enc_len or max_len, g, hd)
                state = state._replace(
                    cross_k=jnp.zeros(ck, dtype), cross_v=jnp.zeros(ck, dtype)
                )
            return state
        kv = (cfg.n_layers, batch, max_len, g, hd)
        state = DecodeState(
            length=length,
            kv_k=jnp.zeros(kv, dtype),
            kv_v=jnp.zeros(kv, dtype),
        )
        if cfg.encoder_decoder:
            ck = (cfg.n_layers, batch, enc_len or max_len, g, hd)
            state = state._replace(
                cross_k=jnp.zeros(ck, dtype), cross_v=jnp.zeros(ck, dtype)
            )
        return state
    if cfg.block_pattern == "zamba_hybrid":
        groups, tail = divmod(cfg.n_layers, cfg.shared_attn_every)
        one = mamba_init_state(cfg, batch)
        stack_g = jax.tree.map(
            lambda x: jnp.broadcast_to(
                x, (groups, cfg.shared_attn_every) + x.shape
            ),
            one,
        )
        stack_t = (
            jax.tree.map(lambda x: jnp.broadcast_to(x, (tail,) + x.shape), one)
            if tail
            else None
        )
        sk = (groups, batch, max_len, g, hd)
        return DecodeState(
            length=length,
            mamba_groups=stack_g,
            mamba_tail=stack_t,
            shared_k=jnp.zeros(sk, dtype),
            shared_v=jnp.zeros(sk, dtype),
        )
    if cfg.block_pattern == "xlstm":
        states = []
        for i in range(cfg.n_layers):
            if (i % cfg.slstm_every) == cfg.slstm_every - 1:
                states.append(xl.slstm_init_state(cfg, batch))
            else:
                states.append(xl.mlstm_init_state(cfg, batch))
        return DecodeState(length=length, xlstm=tuple(states))
    raise ValueError(cfg.block_pattern)


def _shared_attn_decode(h, shared, cfg, k_cache, v_cache, length):
    a, k_cache, v_cache = attention_decode(
        rms_norm(h, shared["attn_norm"], cfg.norm_eps), shared["attn"], cfg,
        k_cache, v_cache, length,
    )
    h = h + a
    hn = rms_norm(h, shared["mlp_norm"], cfg.norm_eps)
    return h + glu_mlp(hn, shared["mlp"], cfg.mlp_type), k_cache, v_cache


def decode_step(cfg: ModelConfig, params, token: jax.Array, state: DecodeState):
    """token: (B, 1) int32 → (logits (B,1,V), new state)."""
    params = _cast(params, cfg)
    b = token.shape[0]
    h = _embed(params, cfg, token)
    length = state.length

    if cfg.block_pattern == "attention":

        def body(carry, xs):
            hh = carry
            lp, kc, vc, extra = xs
            a, kc, vc = attention_decode(
                rms_norm(hh, lp["attn_norm"], cfg.norm_eps), lp["attn"], cfg,
                kc, vc, length,
            )
            hh = hh + a
            if cfg.encoder_decoder:
                c = cross_attention(
                    rms_norm(hh, lp["cross_norm"], cfg.norm_eps), lp["cross"],
                    cfg, extra[0], extra[1],
                )
                hh = hh + c
            hn = rms_norm(hh, lp["mlp_norm"], cfg.norm_eps)
            if cfg.is_moe:
                y, _ = moe_ffn(hn, lp["moe"], cfg)
                if cfg.moe_dense_residual:
                    y = y + glu_mlp(hn, lp["dense_mlp"], cfg.mlp_type)
            elif cfg.mlp_type != "none":
                y = glu_mlp(hn, lp["mlp"], cfg.mlp_type)
            else:
                y = jnp.zeros_like(hh)
            return hh + y, (kc, vc)

        extra = (
            (state.cross_k, state.cross_v)
            if cfg.encoder_decoder
            else (jnp.zeros((cfg.n_layers,)), jnp.zeros((cfg.n_layers,)))
        )
        if state.kv_layers_k is not None:
            # per-layer cache buffers (serving mode): every DUS has its own
            # donated buffer — in-place aliasing is structurally guaranteed.
            new_ks, new_vs = [], []
            for i in range(cfg.n_layers):
                xs_i = (
                    jax.tree.map(lambda t: t[i], params["layers"]),
                    state.kv_layers_k[i],
                    state.kv_layers_v[i],
                    jax.tree.map(lambda t: t[i], extra),
                )
                h, (kc, vc) = body(h, xs_i)
                new_ks.append(kc)
                new_vs.append(vc)
            state = state._replace(
                kv_layers_k=tuple(new_ks), kv_layers_v=tuple(new_vs),
                length=length + 1,
            )
        elif cfg.scan_layers:
            h, (new_k, new_v) = jax.lax.scan(
                body, h, (params["layers"], state.kv_k, state.kv_v, extra)
            )
        else:
            # Unrolled decode: a scan-carried KV stack defeats XLA's in-place
            # DUS aliasing under SPMD (full-cache copy per layer — §Perf E);
            # straight-line decode graphs alias donated caches reliably.
            # Decode HLO is small (S lives in the cache), so unrolling is
            # the production norm for serving.
            new_k, new_v = state.kv_k, state.kv_v
            for i in range(cfg.n_layers):
                xs_i = jax.tree.map(
                    lambda t: t[i],
                    (params["layers"], state.kv_k, state.kv_v, extra),
                )
                h, (kc, vc) = body(h, xs_i)
                new_k = jax.lax.dynamic_update_slice_in_dim(new_k, kc[None], i, 0)
                new_v = jax.lax.dynamic_update_slice_in_dim(new_v, vc[None], i, 0)
        if state.kv_layers_k is None:
            state = state._replace(kv_k=new_k, kv_v=new_v, length=length + 1)

    elif cfg.block_pattern == "zamba_hybrid":
        shared = params["shared"]

        def gbody(carry, xs):
            hh = carry
            gp, mstate, kc, vc = xs
            new_ms = []
            for i in range(cfg.shared_attn_every):
                lp = jax.tree.map(lambda x: x[i], gp)
                ms = jax.tree.map(lambda x: x[i], mstate)
                y, ms = mamba_decode_step(hh, lp, cfg, MambaState(*ms))
                hh = hh + y
                new_ms.append(ms)
            stacked = jax.tree.map(lambda *xs_: jnp.stack(xs_), *new_ms)
            hh, kc, vc = _shared_attn_decode(hh, shared, cfg, kc, vc, length)
            return hh, (stacked, kc, vc)

        h, (new_mg, new_sk, new_sv) = jax.lax.scan(
            gbody, h,
            (params["mamba_groups"], state.mamba_groups, state.shared_k, state.shared_v),
        )
        new_tail = state.mamba_tail
        if "mamba_tail" in params:
            n_tail = jax.tree.leaves(params["mamba_tail"])[0].shape[0]
            outs = []
            for i in range(n_tail):
                lp = jax.tree.map(lambda x: x[i], params["mamba_tail"])
                ms = jax.tree.map(lambda x: x[i], state.mamba_tail)
                y, ms = mamba_decode_step(h, lp, cfg, MambaState(*ms))
                h = h + y
                outs.append(ms)
            new_tail = jax.tree.map(lambda *xs_: jnp.stack(xs_), *outs)
        state = state._replace(
            mamba_groups=MambaState(*new_mg), mamba_tail=new_tail,
            shared_k=new_sk, shared_v=new_sv, length=length + 1,
        )

    else:  # xlstm
        new_states = []
        for i in range(cfg.n_layers):
            st = state.xlstm[i]
            if (i % cfg.slstm_every) == cfg.slstm_every - 1:
                y, st = xl.slstm_decode_step(h, params["layers"][f"slstm_{i}"], cfg, st)
            else:
                y, st = xl.mlstm_decode_step(h, params["layers"][f"mlstm_{i}"], cfg, st)
            h = h + y
            new_states.append(st)
        state = state._replace(xlstm=tuple(new_states), length=length + 1)

    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = _logits(params, cfg, h)[..., : cfg.vocab_size]  # drop pad ids
    return logits, state


def prefill(cfg: ModelConfig, params, tokens: jax.Array, max_len: int,
            extras: Optional[Dict[str, jax.Array]] = None):
    """Full-sequence prefill returning logits and a primed DecodeState.
    (Supported for the attention family — the serving engine's hot path.)"""
    assert cfg.block_pattern == "attention" and not cfg.encoder_decoder
    params_c = _cast(params, cfg)
    b, s = tokens.shape
    h = _embed(params_c, cfg, tokens)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    hd = cfg.resolved_head_dim

    def body(carry, lp):
        hh, aux = carry
        x = rms_norm(hh, lp["attn_norm"], cfg.norm_eps)
        from .attention import _project_qkv  # reuse projection to expose K/V

        q, k, v = _project_qkv(x, lp["attn"], cfg, positions)
        from .attention import _sdpa_reference

        o = _sdpa_reference(q, k, v, causal=True)
        hh = hh + o.reshape(b, s, -1) @ lp["attn"]["wo"]
        hn = rms_norm(hh, lp["mlp_norm"], cfg.norm_eps)
        if cfg.is_moe:
            y, a = moe_ffn(hn, lp["moe"], cfg)
            aux = aux + a
            if cfg.moe_dense_residual:
                y = y + glu_mlp(hn, lp["dense_mlp"], cfg.mlp_type)
        elif cfg.mlp_type != "none":
            y = glu_mlp(hn, lp["mlp"], cfg.mlp_type)
        else:
            y = jnp.zeros_like(hh)
        pad = max_len - s
        cache_dt = jnp.dtype(cfg.dtype)
        kf = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(cache_dt)
        vf = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(cache_dt)
        return (hh + y, aux), (kf, vf)

    (h, _aux), (ks, vs) = jax.lax.scan(
        body, (h, jnp.zeros((), jnp.float32)), params_c["layers"]
    )
    h = rms_norm(h, params_c["final_norm"], cfg.norm_eps)
    logits = _logits(params_c, cfg, h[:, -1:])[..., : cfg.vocab_size]
    state = DecodeState(
        length=jnp.asarray(s, jnp.int32), kv_k=ks, kv_v=vs,
    )
    return logits, state
