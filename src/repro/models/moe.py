"""Mixture-of-Experts layer: top-k routing, capacity, expert+tensor parallel.

Production layout (GShard/DeepSpeed-MoE style, TPU-adapted):
  * expert axis E   → sharded over the mesh **data** axis (EP rides DP);
  * per-expert d_ff → sharded over the mesh **model** axis (TP within expert);
  * token dispatch  → `lax.all_to_all` over 'data' (send each token-choice to
    the shard owning its expert), partial-sum `psum` over 'model';
  * routing/dispatch bookkeeping is *local per shard* (argsort of T_loc·k
    elements) — no global sort, no (T, E) one-hot cumsums.

Under a mesh the layer runs inside `jax.shard_map`; with no mesh (CPU smoke
tests) the identical math runs locally with P=1 and no collectives — the
same function, so the smoke test is a genuine oracle for the distributed
path's per-shard math.
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding import mesh_axes, resolve
from .layers import ParamDef


def moe_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.d_ff
    return {
        "router": ParamDef((d, e), ("embed", None)),
        "wg": ParamDef((e, d, f), ("expert", None, "expert_ff")),
        "wu": ParamDef((e, d, f), ("expert", None, "expert_ff")),
        "wd": ParamDef((e, f, d), ("expert", "expert_ff", None)),
    }


def _local_moe(
    x: jax.Array,            # (B_loc, S, D) — replicated over 'model'
    router: jax.Array,       # (D, E) full
    wg: jax.Array,           # (E_loc, D, F_loc)
    wu: jax.Array,
    wd: jax.Array,           # (E_loc, F_loc, D)
    *,
    cfg: ModelConfig,
    n_peers: int,            # data-axis size (a2a group)
    tp: int,                 # model-axis size (psum group)
) -> Tuple[jax.Array, jax.Array]:
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    e_loc = e // n_peers
    t = b * s
    xf = x.reshape(t, d)

    # ---- routing (identical on every model shard: deterministic) ------------
    logits = (xf @ router).astype(jnp.float32)                  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                      # (T, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    # Switch-style load-balance auxiliary loss.
    me = jnp.mean(probs, axis=0)                                # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_e, e, dtype=jnp.float32), axis=1), axis=0
    )
    aux = e * jnp.sum(me * ce)

    # ---- local dispatch bookkeeping ------------------------------------------
    cap = max(1, int((t * k * cfg.capacity_factor) / e + 0.999))
    flat_e = top_e.reshape(-1)                                  # (T*k,)
    order = jnp.argsort(flat_e)                                 # stable
    sorted_e = flat_e[order]
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    pos = jnp.arange(t * k) - first                             # slot within expert
    keep = pos < cap
    src_tok = order // k
    n_slots = e * cap                                           # == P * E_loc * cap
    slot = jnp.where(keep, sorted_e * cap + pos, n_slots)       # dropped → overflow
    buf = (
        jnp.zeros((n_slots + 1, d), x.dtype)
        .at[slot]
        .set(jnp.where(keep[:, None], xf[src_tok], 0.0).astype(x.dtype))
    )[:-1]

    # ---- all-to-all to expert owners ------------------------------------------
    if n_peers > 1:
        buf = buf.reshape(n_peers, e_loc, cap, d)
        buf = jax.lax.all_to_all(buf, "data", split_axis=0, concat_axis=0, tiled=False)
        h = buf.transpose(1, 0, 2, 3).reshape(e_loc, n_peers * cap, d)
    else:
        h = buf.reshape(e_loc, cap, d)

    # ---- expert FFN (TP over d_ff; partial-sum combine) ------------------------
    gate = jnp.einsum("ecd,edf->ecf", h, wg)
    up = jnp.einsum("ecd,edf->ecf", h, wu)
    act = jax.nn.silu(gate) * up
    out = jnp.einsum("ecf,efd->ecd", act, wd)
    if tp > 1:
        out = jax.lax.psum(out, "model")

    # ---- all-to-all back + weighted combine -------------------------------------
    if n_peers > 1:
        back = out.reshape(e_loc, n_peers, cap, d).transpose(1, 0, 2, 3)
        back = jax.lax.all_to_all(back, "data", split_axis=0, concat_axis=0, tiled=False)
        retf = back.reshape(n_slots, d)
    else:
        retf = out.reshape(n_slots, d)
    contrib = retf[jnp.minimum(slot, n_slots - 1)]
    weight = top_p.reshape(-1)[order].astype(x.dtype)
    contrib = contrib * (weight * keep)[:, None]
    y = jnp.zeros((t, d), x.dtype).at[src_tok].add(contrib)
    # aux is per-data-shard (local tokens) → shape (1,) so out_specs can mark
    # it batch-sharded; caller means over shards.
    return y.reshape(b, s, d), aux.reshape(1)


def moe_ffn(
    x: jax.Array, p: Dict[str, jax.Array], cfg: ModelConfig
) -> Tuple[jax.Array, jax.Array]:
    """Dispatch → expert FFN → combine.  Returns (y, aux_loss)."""
    axes = mesh_axes()
    if "data" not in axes:
        y, aux = _local_moe(
            x, p["router"], p["wg"], p["wu"], p["wd"], cfg=cfg, n_peers=1, tp=1
        )
        return y, jnp.mean(aux)

    mesh = jax.sharding.get_abstract_mesh()
    sizes = dict(zip(mesh.axis_names, mesh.shape.values()))
    n_peers = sizes.get("data", 1)
    tp = sizes.get("model", 1)
    if cfg.n_experts % n_peers:
        # EP degree must divide E; fall back to replicated-expert local math.
        y, aux = _local_moe(
            x, p["router"], p["wg"], p["wu"], p["wd"], cfg=cfg, n_peers=1, tp=1
        )
        return y, jnp.mean(aux)

    batch_spec = resolve(("batch", None, None))
    fn = functools.partial(_local_moe, cfg=cfg, n_peers=n_peers, tp=tp)
    y, aux = jax.shard_map(
        fn,
        mesh=mesh,
        in_specs=(
            batch_spec,                                   # x
            resolve((None, None)),                        # router (replicated)
            resolve(("expert", None, "expert_ff")),       # wg
            resolve(("expert", None, "expert_ff")),       # wu
            resolve(("expert", "expert_ff", None)),       # wd
        ),
        out_specs=(batch_spec, resolve(("batch",))),
        check_vma=False,
    )(x, p["router"], p["wg"], p["wu"], p["wd"])
    return y, jnp.mean(aux)
