"""Pallas TPU flash attention: fwd + bwd, GQA-aware, causal.

TPU adaptation of the flash algorithm: the (Bq × Bk) score tile lives in
VMEM/VREGs only; online softmax statistics (m, l) and the output accumulator
persist in VMEM scratch across the innermost (KV) grid dimension.  The MXU
sees two matmuls per tile (QKᵀ and PV); HBM traffic is O(S·hd) per head
instead of O(S²).

Grid (fwd): (B, H, nQ, nK) with nK innermost ("arbitrary" semantics — the
scratch carries across it).  GQA: K/V index maps divide the head index by
H/G, so grouped heads read the same KV block without materializing repeats.

Backward uses the standard two-kernel split with recompute:
  * dq kernel: grid (B, H, nQ, nK), accumulates dq over KV blocks;
  * dkv kernel: grid (B, H, nK, nQ), accumulates dk/dv over Q blocks;
both consume the saved (o, lse) and delta = rowsum(do·o).

Oracle: ``repro.kernels.ref.attention_ref`` (== models.attention reference
math).  Validated in interpret mode over shape/dtype sweeps.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

try:  # TPU scratch memory spaces (interpret-mode safe)
    from jax.experimental.pallas import tpu as pltpu

    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    _VMEM = None

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref,
                m_scr, l_scr, acc_scr,
                *, scale, causal, block_q, block_k, n_k):
    kk = pl.program_id(3)
    qi = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def _compute():
        q = q_ref[0, ...].astype(jnp.float32)            # (Bq, hd)
        k = k_ref[0, ...].astype(jnp.float32)            # (Bk, hd)
        v = v_ref[0, ...].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                         # (Bq, Bk)
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
            kpos = kk * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new
        l_scr[...] = l_new

    if causal:
        pl.when(qi * block_q + block_q - 1 >= kk * block_k)(_compute)
    else:
        _compute()

    @pl.when(kk == n_k - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, ...] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)
        lse_ref[0, ...] = m_scr[...] + jnp.log(l)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "interpret"),
)
def _flash_fwd(q, k, v, causal, block_q, block_k, interpret):
    bh, sq, hd = q.shape           # q flattened to (B*H, S, hd)
    bg, skv, _ = k.shape           # k/v (B*G, S, hd)
    rep = bh // bg
    n_q = sq // block_q
    n_k = skv // block_k
    scale = 1.0 / np.sqrt(hd)
    grid = (bh, 1, n_q, n_k)       # (bh, dummy, q blocks, kv blocks)

    kern = functools.partial(
        _fwd_kernel, scale=scale, causal=causal,
        block_q=block_q, block_k=block_k, n_k=n_k,
    )
    scratch = []
    if _VMEM is not None:
        scratch = [
            _VMEM((block_q,), jnp.float32),
            _VMEM((block_q,), jnp.float32),
            _VMEM((block_q, hd), jnp.float32),
        ]
    out_shape = (
        jax.ShapeDtypeStruct((bh, sq, hd), q.dtype),
        jax.ShapeDtypeStruct((bh, sq), jnp.float32),
    )
    o, lse = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, _, qi, kk: (b, qi, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, _, qi, kk, rep=rep: (b // rep, kk, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, _, qi, kk, rep=rep: (b // rep, kk, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, block_q, hd), lambda b, _, qi, kk: (b, qi, 0)),
            pl.BlockSpec((1, block_q), lambda b, _, qi, kk: (b, qi)),
        ),
        out_shape=out_shape,
        scratch_shapes=scratch,
        interpret=interpret,
    )(q, k, v)
    return o, lse


# ---------------------------------------------------------------------------
# backward
# ---------------------------------------------------------------------------


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
               acc_scr, *, scale, causal, block_q, block_k, n_k):
    kk = pl.program_id(3)
    qi = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def _compute():
        q = q_ref[0, ...].astype(jnp.float32)
        k = k_ref[0, ...].astype(jnp.float32)
        v = v_ref[0, ...].astype(jnp.float32)
        do = do_ref[0, ...].astype(jnp.float32)
        lse = lse_ref[0, ...]
        delta = delta_ref[0, ...]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
            kpos = kk * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * scale
        acc_scr[...] += jax.lax.dot_general(ds, k, (((1,), (0,)), ((), ())),
                                            preferred_element_type=jnp.float32)

    if causal:
        pl.when(qi * block_q + block_q - 1 >= kk * block_k)(_compute)
    else:
        _compute()

    @pl.when(kk == n_k - 1)
    def _finalize():
        dq_ref[0, ...] = acc_scr[...].astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, dk_scr, dv_scr,
                *, scale, causal, block_q, block_k, n_q, rep):
    # grid (B*G, n_k, rep, n_q): scratch accumulates over (rep, q blocks)
    # for one KV block, then flushes — kk outer of (r, qi) is essential.
    qi = pl.program_id(3)          # innermost: q blocks
    h_in_group = pl.program_id(2)  # grouped head (0..rep-1)
    kk = pl.program_id(1)

    @pl.when(jnp.logical_and(qi == 0, h_in_group == 0))
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    def _compute():
        q = q_ref[0, ...].astype(jnp.float32)
        k = k_ref[0, ...].astype(jnp.float32)
        v = v_ref[0, ...].astype(jnp.float32)
        do = do_ref[0, ...].astype(jnp.float32)
        lse = lse_ref[0, ...]
        delta = delta_ref[0, ...]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
            kpos = kk * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])                       # (Bq, Bk)
        dv_scr[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())),
                                           preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * scale
        dk_scr[...] += jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())),
                                           preferred_element_type=jnp.float32)

    if causal:
        pl.when(qi * block_q + block_q - 1 >= kk * block_k)(_compute)
    else:
        _compute()

    @pl.when(jnp.logical_and(qi == n_q - 1, h_in_group == rep - 1))
    def _finalize():
        dk_ref[0, ...] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0, ...] = dv_scr[...].astype(dv_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "interpret"),
)
def _flash_bwd(q, k, v, o, lse, do, causal, block_q, block_k, interpret):
    bh, sq, hd = q.shape
    bg, skv, _ = k.shape
    rep = bh // bg
    n_q = sq // block_q
    n_k = skv // block_k
    scale = 1.0 / np.sqrt(hd)
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)  # (bh, sq)

    kern_dq = functools.partial(
        _dq_kernel, scale=scale, causal=causal,
        block_q=block_q, block_k=block_k, n_k=n_k,
    )
    scratch_dq = [] if _VMEM is None else [_VMEM((block_q, hd), jnp.float32)]
    dq = pl.pallas_call(
        kern_dq,
        grid=(bh, 1, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, _, qi, kk: (b, qi, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, _, qi, kk, rep=rep: (b // rep, kk, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, _, qi, kk, rep=rep: (b // rep, kk, 0)),
            pl.BlockSpec((1, block_q, hd), lambda b, _, qi, kk: (b, qi, 0)),
            pl.BlockSpec((1, block_q), lambda b, _, qi, kk: (b, qi)),
            pl.BlockSpec((1, block_q), lambda b, _, qi, kk: (b, qi)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, _, qi, kk: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=scratch_dq,
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    kern_dkv = functools.partial(
        _dkv_kernel, scale=scale, causal=causal,
        block_q=block_q, block_k=block_k, n_q=n_q, rep=rep,
    )
    scratch_dkv = [] if _VMEM is None else [
        _VMEM((block_k, hd), jnp.float32),
        _VMEM((block_k, hd), jnp.float32),
    ]
    dk, dv = pl.pallas_call(
        kern_dkv,
        grid=(bg, n_k, rep, n_q),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, kk, r, qi, rep=rep: (b * rep + r, qi, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, kk, r, qi: (b, kk, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, kk, r, qi: (b, kk, 0)),
            pl.BlockSpec((1, block_q, hd), lambda b, kk, r, qi, rep=rep: (b * rep + r, qi, 0)),
            pl.BlockSpec((1, block_q), lambda b, kk, r, qi, rep=rep: (b * rep + r, qi)),
            pl.BlockSpec((1, block_q), lambda b, kk, r, qi, rep=rep: (b * rep + r, qi)),
        ],
        out_specs=(
            pl.BlockSpec((1, block_k, hd), lambda b, kk, r, qi: (b, kk, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, kk, r, qi: (b, kk, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct(k.shape, k.dtype),
            jax.ShapeDtypeStruct(v.shape, v.dtype),
        ),
        scratch_shapes=scratch_dkv,
        interpret=interpret,
    )(q, k, v, do, lse, delta)
    return dq, dk, dv


# ---------------------------------------------------------------------------
# public op with custom VJP
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash(q, k, v, causal, block_q, block_k, interpret):
    o, _ = _flash_fwd(q, k, v, causal, block_q, block_k, interpret)
    return o


def _flash_fwd_rule(q, k, v, causal, block_q, block_k, interpret):
    o, lse = _flash_fwd(q, k, v, causal, block_q, block_k, interpret)
    return o, (q, k, v, o, lse)


def _flash_bwd_rule(causal, block_q, block_k, interpret, res, do):
    q, k, v, o, lse = res
    dq, dk, dv = _flash_bwd(q, k, v, o, lse, do, causal, block_q, block_k, interpret)
    return dq, dk, dv


_flash.defvjp(_flash_fwd_rule, _flash_bwd_rule)


def flash_attention(
    q: jax.Array,                  # (B, S, H, hd)
    k: jax.Array,                  # (B, S, G, hd)
    v: jax.Array,
    causal: bool = True,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Flash attention; returns (B, S, H, hd).  GQA via head grouping."""
    if interpret is None:
        interpret = _interpret_default()
    b, sq, h, hd = q.shape
    g = k.shape[2]
    assert h % g == 0
    block_q = min(block_q, sq)
    while sq % block_q:
        block_q //= 2
    block_k = min(block_k, k.shape[1])
    while k.shape[1] % block_k:
        block_k //= 2
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * g, k.shape[1], hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * g, v.shape[1], hd)
    o = _flash(qf, kf, vf, causal, block_q, block_k, interpret)
    return o.reshape(b, h, sq, hd).transpose(0, 2, 1, 3)
