"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` layer).

These are the ground truth in kernel tests: interpret-mode kernels must
``assert_allclose`` against these across shape/dtype sweeps.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def attention_ref(q, k, v, causal: bool = True):
    """q (B,S,H,hd); k/v (B,S,G,hd) → (B,S,H,hd).  fp32 softmax."""
    b, sq, h, hd = q.shape
    g = k.shape[2]
    rep = h // g
    qg = q.reshape(b, sq, g, rep, hd).astype(jnp.float32)
    scores = jnp.einsum("bsgrh,btgh->bgrst", qg, k.astype(jnp.float32))
    scores = scores / np.sqrt(hd)
    if causal:
        skv = k.shape[1]
        mask = jnp.arange(skv)[None, :] <= jnp.arange(sq)[:, None]
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bgrst,btgh->bsgrh", w, v.astype(jnp.float32))
    return out.reshape(b, sq, h, hd).astype(q.dtype)


def rmsnorm_ref(x, weight, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + weight.astype(jnp.float32))
    return out.astype(x.dtype)


def sched_weigh_ref(free_f, inst_res, inst_cost, inst_valid, req_res, masks):
    """== core.jax_scheduler.host_plan_terms (re-exported for the kernels
    test-layer convention)."""
    from repro.core.jax_scheduler import host_plan_terms

    return host_plan_terms(
        jnp.asarray(free_f), jnp.asarray(inst_res), jnp.asarray(inst_cost),
        jnp.asarray(inst_valid), jnp.asarray(req_res), jnp.asarray(masks),
    )
