"""Pallas TPU kernels (TARGET: v5e; validated via interpret=True on CPU).

Each kernel ships three layers: the pallas_call implementation
(<name>.py with explicit BlockSpec VMEM tiling), the jit'd public wrapper
(ops.py), and the pure-jnp oracle (ref.py / repro.core.screen_math) used by
the parity sweeps in tests/test_kernels.py, tests/test_kernels_sched.py and
tests/test_sched_screen.py.
"""
from .ops import (
    TIE_EPS,
    flash_attention,
    rmsnorm,
    sched_screen,
    sched_screen_consts,
    sched_screen_topm,
    sched_weigh,
    sched_weigh_gathered,
)

__all__ = [
    "TIE_EPS",
    "flash_attention",
    "rmsnorm",
    "sched_screen",
    "sched_screen_consts",
    "sched_screen_topm",
    "sched_weigh",
    "sched_weigh_gathered",
]
