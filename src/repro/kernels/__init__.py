"""Pallas TPU kernels (TARGET: v5e; validated via interpret=True on CPU).

Each kernel ships three layers: the pallas_call implementation
(<name>.py with explicit BlockSpec VMEM tiling), the jit'd public wrapper
(ops.py), and the pure-jnp oracle (ref.py) used by the allclose sweeps in
tests/test_kernels.py and tests/test_jax_scheduler.py.
"""
from .ops import flash_attention, rmsnorm, sched_weigh, sched_weigh_gathered

__all__ = ["flash_attention", "rmsnorm", "sched_weigh", "sched_weigh_gathered"]
