"""Jit'd public wrappers for the Pallas kernels (the ``ops.py`` layer)."""
from .flash_attention import flash_attention
from .rmsnorm import rmsnorm
from .sched_weigh import sched_weigh, sched_weigh_gathered

__all__ = ["flash_attention", "rmsnorm", "sched_weigh", "sched_weigh_gathered"]
