"""Jit'd public wrappers for the Pallas kernels (the ``ops.py`` layer).

``TIE_EPS`` (the enumeration tie-break epsilon shared by ``sched_weigh``
and the jnp oracle) is *defined* in ``repro.core.screen_math`` — the one
dependency-free module both the kernel and scheduler layers import — and
re-exported here as part of the kernels' public surface.
"""
from repro.core.screen_math import TIE_EPS

from .flash_attention import flash_attention
from .rmsnorm import rmsnorm
from .sched_screen import (
    sched_screen,
    sched_screen_consts,
    sched_screen_topm,
)
from .sched_weigh import sched_weigh, sched_weigh_gathered

__all__ = [
    "TIE_EPS",
    "flash_attention",
    "rmsnorm",
    "sched_screen",
    "sched_screen_consts",
    "sched_screen_topm",
    "sched_weigh",
    "sched_weigh_gathered",
]
