"""Pallas TPU kernel: fused stage-1 screen + on-chip top-M shortlist.

One pass over the fleet replaces the pure-jnp stage-1 pipeline (dual-view
fit mask, exact full-subset feasibility, sorted-prefix termination-cost
bounds, optimistic ``omega_ub``, global ``lax.top_k``), whose separate
O(N·K) passes each round-trip the full host arrays through HBM — the
dominant latency term at 10^5 hosts once stage 2 only enumerates a
shortlist.  Here every term is computed per 128-host tile from VMEM via the
*shared* bounds math in ``repro.core.screen_math`` (both screens execute the
same functions, so shortlist decisions stay bit-exact), and the only HBM
writes are the (M+1,) shortlist plus 10 normalization scalars.

Structure (grid = (2, N/T), sequential on TPU):

  phase 0   fold the global weigher-normalization constants (termination
            cost envelope min/max + raw base-term min/max over the valid
            set) tile-by-tile into SMEM scratch — min/max are
            reassociation-free, so the folded constants match the jnp
            reductions bitwise;
  phase 1   recompute the tile's screen terms, assemble ``omega_ub`` from
            the SMEM constants, and fold (score, host-index) pairs into a
            running top-M kept sorted in the output VMEM block by a bitonic
            lane network (``pltpu.roll`` partner exchanges).  Ties order by
            lowest host index — exactly ``lax.top_k``'s tie rule, so the
            emitted shortlist equals the oracle's up to nothing at all.

The buffer holds S = next_pow2(m_keep + T) lanes: each step concatenates the
previous top-(S-T) with the tile's T candidates and re-sorts, so the keep
region always contains the true running top-(S-T) — no reset logic.  Entry
``m_keep-1`` (= M) is the best *non-shortlisted* ``omega_ub`` and its index:
precisely the (u, j_u) pair the admissibility fallback check needs.

VMEM per step at K=8, D=4, T=128: res tile (8,4,128)f32 16 KB + buffer
2×(1,256) + odds and ends ≈ 25 KB — far inside the v5e budget; T=128 keeps
the kernel latency-bound like ``sched_weigh``.

Oracle: ``repro.core.jax_scheduler.screen_terms`` + ``_decision_core``'s
stage-1 assembly (same shared math).  Validated in interpret mode by
tests/test_sched_screen.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.screen_math import (
    EPS,
    N_CONSTS,
    NEG_INF,
    POS_INF,
    ScreenConsts,
    _m_churn,
    base_from_consts,
    inv_span,
    omega_of,
    screen_bounds_rows,
    total_rows,
)

TILE_HOSTS = 128
#: index sentinel for empty buffer slots — larger than any real host index,
#: so initial entries sort after every real candidate (ties break low-index).
IDX_SENTINEL = 2 ** 30


def _fold_top(scores_ref, idx_ref, tile_scores, tile_idx, s_buf, tile):
    """Fold a tile's (1, T) candidates into the sorted (1, S) running top.

    Concatenate the previous top-(S-T) with the new tile and re-sort
    descending by (score, -index) with a bitonic lane network.  Partner
    lookup ``x[i ^ j]`` is two ``pltpu.roll``s selected by the j-bit; the
    comparator is total (indices are unique), so the result is deterministic
    and matches ``lax.top_k`` tie ordering."""
    keep = s_buf - tile
    scores = jnp.concatenate([scores_ref[...][:, :keep], tile_scores], axis=1)
    idx = jnp.concatenate([idx_ref[...][:, :keep], tile_idx], axis=1)
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, s_buf), 1)
    k = 2
    while k <= s_buf:
        j = k // 2
        while j >= 1:
            bit0 = (lane & j) == 0

            def partner(x):
                return jnp.where(
                    bit0,
                    pltpu.roll(x, s_buf - j, axis=1),
                    pltpu.roll(x, j, axis=1),
                )

            ps, pi = partner(scores), partner(idx)
            self_first = (scores > ps) | ((scores == ps) & (idx < pi))
            want_first = ((lane & k) == 0) == bit0
            take_self = self_first == want_first
            scores = jnp.where(take_self, scores, ps)
            idx = jnp.where(take_self, idx, pi)
            j //= 2
        k *= 2
    scores_ref[...] = scores
    idx_ref[...] = idx


def _tile_stage1(
    free_f_ref, free_n_ref, sched_ref, domain_ref, slow_ref,
    res_ref, cost_ref, valid_ref, req_ref, pre_ref, rdom_ref,
    *, require_free_slot, churn_ref=None, churn_threshold=None,
    zone_ref=None, excl_ref=None,
):
    """One tile's stage-1 screen terms from VMEM refs — the shared
    ``screen_math`` bounds plus the dual-view filtering (same formulas as
    ``_decision_core``).  Returns ``(valid, cost_lb, cost_ub, over_raw,
    pack_raw, strag_raw, churn_raw)``, each (T,)-shaped (``churn_raw`` is
    ``None`` without a churn column).  ONE definition executed by all three
    kernels below (2-phase fused, consts-only, topm-only), which is what
    keeps the split phases bit-identical to the fused pass.

    ``churn_ref`` is the optional (1, T) per-host learned zone-churn rate ẑ;
    a static ``churn_threshold`` applies the hot-zone steering filter to
    preemptible requests (same gate as ``_stage1_rows``).  ``zone_ref`` is
    the optional (1, T) per-host zone-id column and ``excl_ref`` the (1, 1)
    per-request excluded-zone scalar: relocation re-placements hard-filter
    every host of the zone they are fleeing (integer compare, so the gate is
    trivially bit-exact vs ``_stage1_rows``); a negative scalar disables."""
    k = res_ref.shape[0]
    pre = pre_ref[0, 0] != 0
    rdom = rdom_ref[0, 0]
    free_f = free_f_ref[...]                                     # (D, T)
    req = req_ref[...]                                           # (D, 1)
    validf = valid_ref[...]                                      # (K, T)

    # ---- shared stage-1 bounds math on slot-major rows ----------------------
    res_rows = [res_ref[i] * validf[i][None, :] for i in range(k)]
    cost_rows = [
        jnp.where(validf[i] > 0.5, cost_ref[i], POS_INF) for i in range(k)
    ]
    total = total_rows(
        [jnp.where(validf[i] > 0.5, cost_ref[i], 0.0) for i in range(k)]
    )
    need = req - free_f                                          # (D, T)
    feasible, overcommitted, cost_lb, cost_ub = screen_bounds_rows(
        need, res_rows, cost_rows, total
    )

    # ---- dual-view filtering (same formula as _decision_core) ---------------
    view = jnp.where(pre, free_f, free_n_ref[...])
    fits = jnp.all(view >= req - EPS, axis=0)                    # (T,)
    fits &= sched_ref[...][0] > 0.5
    fits &= (rdom < 0) | (domain_ref[...][0] == rdom)
    if zone_ref is not None and excl_ref is not None:
        excl = excl_ref[0, 0]
        fits &= (excl < 0) | (zone_ref[...][0] != excl)
    if churn_threshold is not None and churn_ref is not None:
        fits &= jnp.where(
            pre, churn_ref[...][0] <= jnp.float32(churn_threshold), True
        )
    if require_free_slot:
        has_free = jnp.min(validf, axis=0) < 0.5
        fits &= jnp.where(pre, has_free, True)
    cost_lb = jnp.where(pre, 0.0, cost_lb)
    cost_ub = jnp.where(pre, 0.0, cost_ub)
    feasible = jnp.where(pre, fits, feasible)
    valid = fits & feasible

    over_raw = jnp.where(overcommitted, -1.0, 0.0)
    pack_raw = -jnp.sum(free_f, axis=0)
    strag_raw = -slow_ref[...][0]
    churn_raw = None if churn_ref is None else -churn_ref[...][0]
    return valid, cost_lb, cost_ub, over_raw, pack_raw, strag_raw, churn_raw


def _split_refs(refs, n_extra, has_churn, has_zone):
    """Unpack a kernel's positional refs: the 11 fleet/request inputs, the
    optional churn input, the optional zone-row + excluded-zone pair, then
    ``n_extra`` output/scratch refs.  Returns
    ``(fleet_refs, churn_ref, zone_ref, excl_ref, extra_refs)``."""
    fleet = refs[:11]
    n_in = 11
    churn_ref = zone_ref = excl_ref = None
    if has_churn:
        churn_ref = refs[n_in]
        n_in += 1
    if has_zone:
        zone_ref = refs[n_in]
        excl_ref = refs[n_in + 1]
        n_in += 2
    return fleet, churn_ref, zone_ref, excl_ref, refs[n_in:]


def _fold_consts(smem, valid, cost_lb, cost_ub, raws):
    """One tile's constants fold into SMEM: the termination-cost envelope
    always, each raw base term only when its multiplier is on (identical
    gating to ``consts_of``).  ``raws`` pairs (multiplier, raw-or-None) in
    ScreenConsts slot order."""
    smem[0] = jnp.minimum(smem[0], jnp.min(jnp.where(valid, cost_lb, POS_INF)))
    smem[1] = jnp.maximum(smem[1], jnp.max(jnp.where(valid, cost_ub, NEG_INF)))
    for slot, (on, raw) in enumerate(raws):
        if on and raw is not None:
            smem[2 + 2 * slot] = jnp.minimum(
                smem[2 + 2 * slot], jnp.min(jnp.where(valid, raw, POS_INF))
            )
            smem[3 + 2 * slot] = jnp.maximum(
                smem[3 + 2 * slot], jnp.max(jnp.where(valid, raw, NEG_INF))
            )


def _kernel(
    *refs,
    multipliers, require_free_slot, churn_threshold, tile, s_buf, has_churn,
    has_zone,
):
    m_term = multipliers[1]
    m_churn = _m_churn(multipliers)
    (fleet, churn_ref, zone_ref, excl_ref,
     (scores_ref, idx_ref, consts_ref, smem)) = _split_refs(
        refs, 4, has_churn, has_zone
    )
    phase = pl.program_id(0)
    t = pl.program_id(1)
    (valid, cost_lb, cost_ub, over_raw, pack_raw, strag_raw,
     churn_raw) = _tile_stage1(
        *fleet,
        require_free_slot=require_free_slot,
        churn_ref=churn_ref, churn_threshold=churn_threshold,
        zone_ref=zone_ref, excl_ref=excl_ref,
    )

    # ---- phase 0: fold normalization constants into SMEM --------------------
    @pl.when((phase == 0) & (t == 0))
    def _():
        for i in range(N_CONSTS // 2):
            smem[2 * i] = jnp.float32(POS_INF)
            smem[2 * i + 1] = jnp.float32(NEG_INF)

    @pl.when(phase == 0)
    def _():
        _fold_consts(
            smem, valid, cost_lb, cost_ub,
            [(multipliers[0], over_raw), (multipliers[2], pack_raw),
             (multipliers[3], strag_raw), (m_churn, churn_raw)],
        )

    # ---- phase 1: omega_ub from the constants + running top-M ---------------
    @pl.when((phase == 1) & (t == 0))
    def _():
        scores_ref[...] = jnp.full((1, s_buf), NEG_INF, jnp.float32)
        idx_ref[...] = jnp.full((1, s_buf), IDX_SENTINEL, jnp.int32)

    @pl.when(phase == 1)
    def _():
        consts = ScreenConsts(*(smem[i] for i in range(N_CONSTS)))
        base = base_from_consts(
            multipliers, over_raw, pack_raw, strag_raw, consts,
            churn_raw=churn_raw,
        )
        ispan = inv_span(consts.c_lo, consts.c_hi)
        opt_cost = cost_lb if m_term >= 0 else cost_ub
        omega_ub = omega_of(opt_cost, base, valid, consts, ispan, m_term)
        gidx = t * tile + jax.lax.broadcasted_iota(jnp.int32, (1, tile), 1)
        _fold_top(scores_ref, idx_ref, omega_ub[None, :], gidx, s_buf, tile)
        consts_ref[...] = consts.pack()[None, :]


def _consts_kernel(
    *refs, multipliers, require_free_slot, churn_threshold, has_churn,
    has_zone,
):
    """Phase 0 alone: fold the 10 normalization constants over the fleet
    (identical folds to ``_kernel``'s phase 0) and emit them — the
    per-shard half of the split the sharded fused screen needs, so the
    mesh can pmin/pmax-merge constants BEFORE any omega is scored."""
    m_churn = _m_churn(multipliers)
    fleet, churn_ref, zone_ref, excl_ref, (consts_ref, smem) = _split_refs(
        refs, 2, has_churn, has_zone
    )
    t = pl.program_id(0)
    (valid, cost_lb, cost_ub, over_raw, pack_raw, strag_raw,
     churn_raw) = _tile_stage1(
        *fleet,
        require_free_slot=require_free_slot,
        churn_ref=churn_ref, churn_threshold=churn_threshold,
        zone_ref=zone_ref, excl_ref=excl_ref,
    )

    @pl.when(t == 0)
    def _():
        for i in range(N_CONSTS // 2):
            smem[2 * i] = jnp.float32(POS_INF)
            smem[2 * i + 1] = jnp.float32(NEG_INF)

    _fold_consts(
        smem, valid, cost_lb, cost_ub,
        [(multipliers[0], over_raw), (multipliers[2], pack_raw),
         (multipliers[3], strag_raw), (m_churn, churn_raw)],
    )
    consts_ref[...] = jnp.stack([smem[i] for i in range(N_CONSTS)])[None, :]


def _topm_kernel(
    *refs,
    multipliers, require_free_slot, churn_threshold, tile, s_buf, has_churn,
    has_zone,
):
    """Phase 1 alone, scoring against EXTERNAL constants (``consts_in_ref``,
    e.g. the mesh-merged ``ScreenConsts``): recompute the tile's screen
    terms, assemble ``omega_ub``, fold the running top-M — the same ops as
    ``_kernel``'s phase 1 reading merged constants instead of SMEM."""
    m_term = multipliers[1]
    (fleet, churn_ref, zone_ref, excl_ref,
     (consts_in_ref, scores_ref, idx_ref)) = _split_refs(
        refs, 3, has_churn, has_zone
    )
    t = pl.program_id(0)
    (valid, cost_lb, cost_ub, over_raw, pack_raw, strag_raw,
     churn_raw) = _tile_stage1(
        *fleet,
        require_free_slot=require_free_slot,
        churn_ref=churn_ref, churn_threshold=churn_threshold,
        zone_ref=zone_ref, excl_ref=excl_ref,
    )

    @pl.when(t == 0)
    def _():
        scores_ref[...] = jnp.full((1, s_buf), NEG_INF, jnp.float32)
        idx_ref[...] = jnp.full((1, s_buf), IDX_SENTINEL, jnp.int32)

    consts = ScreenConsts(*(consts_in_ref[0, i] for i in range(N_CONSTS)))
    base = base_from_consts(
        multipliers, over_raw, pack_raw, strag_raw, consts, churn_raw=churn_raw
    )
    ispan = inv_span(consts.c_lo, consts.c_hi)
    opt_cost = cost_lb if m_term >= 0 else cost_ub
    omega_ub = omega_of(opt_cost, base, valid, consts, ispan, m_term)
    gidx = t * tile + jax.lax.broadcasted_iota(jnp.int32, (1, tile), 1)
    _fold_top(scores_ref, idx_ref, omega_ub[None, :], gidx, s_buf, tile)


def _in_specs(k, d, tile, has_churn, has_zone):
    """The fleet/request BlockSpec list shared by all three kernels (the
    host axis is the grid's LAST dimension, so the index maps take the
    final program id as the tile index).  ``has_churn`` appends the (1, T)
    churn-row spec; ``has_zone`` the (1, T) zone-id row plus the (1, 1)
    excluded-zone scalar."""
    host = lambda *ids: (0, ids[-1])
    fixed = lambda *ids: (0, 0)
    specs = [
        pl.BlockSpec((d, tile), host),
        pl.BlockSpec((d, tile), host),
        pl.BlockSpec((1, tile), host),
        pl.BlockSpec((1, tile), host),
        pl.BlockSpec((1, tile), host),
        pl.BlockSpec((k, d, tile), lambda *ids: (0, 0, ids[-1])),
        pl.BlockSpec((k, tile), host),
        pl.BlockSpec((k, tile), host),
        pl.BlockSpec((d, 1), fixed),
        pl.BlockSpec((1, 1), fixed),
        pl.BlockSpec((1, 1), fixed),
    ]
    if has_churn:
        specs.append(pl.BlockSpec((1, tile), host))
    if has_zone:
        specs.append(pl.BlockSpec((1, tile), host))
        specs.append(pl.BlockSpec((1, 1), fixed))
    return specs


def _decode_extras(args):
    """Recover the static (has_churn, has_zone) pair from an ``args`` tuple
    built by ``_prep_inputs``: 11 fleet/request inputs, +1 churn row, +2
    zone row + excluded-zone scalar."""
    extras = len(args) - 11
    return extras in (1, 3), extras >= 2


@functools.partial(
    jax.jit,
    static_argnames=(
        "multipliers", "require_free_slot", "churn_threshold", "s_buf",
        "tile", "interpret",
    ),
)
def _sched_screen_padded(
    args, multipliers, require_free_slot, churn_threshold, s_buf, tile,
    interpret,
):
    has_churn, has_zone = _decode_extras(args)
    k, d, n = args[5].shape
    fixed = lambda *ids: (0, 0)
    kern = functools.partial(
        _kernel,
        multipliers=multipliers,
        require_free_slot=require_free_slot,
        churn_threshold=churn_threshold,
        tile=tile,
        s_buf=s_buf,
        has_churn=has_churn,
        has_zone=has_zone,
    )
    return pl.pallas_call(
        kern,
        grid=(2, n // tile),
        in_specs=_in_specs(k, d, tile, has_churn, has_zone),
        out_specs=(
            pl.BlockSpec((1, s_buf), fixed),
            pl.BlockSpec((1, s_buf), fixed),
            pl.BlockSpec((1, N_CONSTS), fixed),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((1, s_buf), jnp.float32),
            jax.ShapeDtypeStruct((1, s_buf), jnp.int32),
            jax.ShapeDtypeStruct((1, N_CONSTS), jnp.float32),
        ),
        scratch_shapes=[pltpu.SMEM((N_CONSTS,), jnp.float32)],
        interpret=interpret,
    )(*args)


@functools.partial(
    jax.jit,
    static_argnames=(
        "multipliers", "require_free_slot", "churn_threshold", "tile",
        "interpret",
    ),
)
def _sched_consts_padded(
    args, multipliers, require_free_slot, churn_threshold, tile, interpret,
):
    has_churn, has_zone = _decode_extras(args)
    k, d, n = args[5].shape
    fixed = lambda t: (0, 0)
    kern = functools.partial(
        _consts_kernel,
        multipliers=multipliers,
        require_free_slot=require_free_slot,
        churn_threshold=churn_threshold,
        has_churn=has_churn,
        has_zone=has_zone,
    )
    return pl.pallas_call(
        kern,
        grid=(n // tile,),
        in_specs=_in_specs(k, d, tile, has_churn, has_zone),
        out_specs=pl.BlockSpec((1, N_CONSTS), fixed),
        out_shape=jax.ShapeDtypeStruct((1, N_CONSTS), jnp.float32),
        scratch_shapes=[pltpu.SMEM((N_CONSTS,), jnp.float32)],
        interpret=interpret,
    )(*args)


@functools.partial(
    jax.jit,
    static_argnames=(
        "multipliers", "require_free_slot", "churn_threshold", "s_buf",
        "tile", "interpret",
    ),
)
def _sched_topm_padded(
    args, consts, multipliers, require_free_slot, churn_threshold, s_buf,
    tile, interpret,
):
    has_churn, has_zone = _decode_extras(args)
    k, d, n = args[5].shape
    fixed = lambda t: (0, 0)
    kern = functools.partial(
        _topm_kernel,
        multipliers=multipliers,
        require_free_slot=require_free_slot,
        churn_threshold=churn_threshold,
        tile=tile,
        s_buf=s_buf,
        has_churn=has_churn,
        has_zone=has_zone,
    )
    return pl.pallas_call(
        kern,
        grid=(n // tile,),
        in_specs=_in_specs(k, d, tile, has_churn, has_zone)
        + [pl.BlockSpec((1, N_CONSTS), fixed)],
        out_specs=(
            pl.BlockSpec((1, s_buf), fixed),
            pl.BlockSpec((1, s_buf), fixed),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((1, s_buf), jnp.float32),
            jax.ShapeDtypeStruct((1, s_buf), jnp.int32),
        ),
        interpret=interpret,
    )(*args, consts)


def _prep_inputs(
    free_f, free_n, schedulable, domain, slow,
    inst_res, inst_cost, inst_valid,
    req_res, req_preemptible, req_domain,
    tile: int,
    churn=None,
    host_zone=None,
    exclude_zone=None,
):
    """Dtype-normalize, pad the host axis to the tile, and transpose to the
    kernels' slot-major layout.  Padding rows are unschedulable, so they
    can never outrank a real host.  An optional ``churn`` column (per-host
    ẑ, padded with zeros — padding rows are filtered anyway) rides along as
    the 12th element; an optional ``host_zone`` i32 column (padded with
    zeros, same reasoning) plus the ``exclude_zone`` i32 scalar ride as the
    next two."""
    n, d = free_f.shape
    k = inst_cost.shape[1]
    pad = (-n) % tile
    free_f = jnp.asarray(free_f, jnp.float32)
    free_n = jnp.asarray(free_n, jnp.float32)
    sched = jnp.asarray(schedulable, jnp.float32)
    domain = jnp.asarray(domain, jnp.int32)
    slow = jnp.asarray(slow, jnp.float32)
    inst_res = jnp.asarray(inst_res, jnp.float32)
    inst_cost = jnp.asarray(inst_cost, jnp.float32)
    inst_valid = jnp.asarray(inst_valid, jnp.float32)
    if churn is not None:
        churn = jnp.asarray(churn, jnp.float32)
    if host_zone is not None:
        host_zone = jnp.asarray(host_zone, jnp.int32)
    if pad:
        zf = jnp.zeros((pad, d), jnp.float32)
        free_f = jnp.concatenate([free_f, zf])
        free_n = jnp.concatenate([free_n, zf])
        sched = jnp.concatenate([sched, jnp.zeros((pad,), jnp.float32)])
        domain = jnp.concatenate([domain, jnp.zeros((pad,), jnp.int32)])
        slow = jnp.concatenate([slow, jnp.ones((pad,), jnp.float32)])
        inst_res = jnp.concatenate([inst_res, jnp.zeros((pad, k, d), jnp.float32)])
        inst_cost = jnp.concatenate([inst_cost, jnp.zeros((pad, k), jnp.float32)])
        inst_valid = jnp.concatenate([inst_valid, jnp.zeros((pad, k), jnp.float32)])
        if churn is not None:
            churn = jnp.concatenate([churn, jnp.zeros((pad,), jnp.float32)])
        if host_zone is not None:
            host_zone = jnp.concatenate(
                [host_zone, jnp.zeros((pad,), jnp.int32)]
            )
    out = (
        free_f.T, free_n.T, sched[None, :], domain[None, :], slow[None, :],
        inst_res.transpose(1, 2, 0), inst_cost.T, inst_valid.T,
        jnp.asarray(req_res, jnp.float32).reshape(d, 1),
        jnp.asarray(req_preemptible, jnp.int32).reshape(1, 1),
        jnp.asarray(req_domain, jnp.int32).reshape(1, 1),
    )
    if churn is not None:
        out += (churn[None, :],)
    if host_zone is not None:
        out += (
            host_zone[None, :],
            jnp.asarray(exclude_zone, jnp.int32).reshape(1, 1),
        )
    return out


def sched_screen(
    free_f, free_n, schedulable, domain, slow,
    inst_res, inst_cost, inst_valid,
    req_res, req_preemptible, req_domain,
    weigher_multipliers,
    require_free_slot: bool,
    m_keep: int,
    interpret=None,
    tile: int = TILE_HOSTS,
    churn=None,
    churn_threshold=None,
    host_zone=None,
    exclude_zone=None,
):
    """Fused stage-1 screen.  Returns ``(top_scores, top_idx, consts)``:

      top_scores  (m_keep,) the m_keep best ``omega_ub`` values, descending,
                  ties by lowest host index (== ``lax.top_k`` order);
      top_idx     (m_keep,) their host indices.  Callers shortlist the first
                  m_keep-1 and use entry m_keep-1 as the admissibility
                  (u, j_u) witness — pass ``m_keep = M + 1``;
      consts      (10,) packed ``ScreenConsts`` for reconstructing the exact
                  per-candidate base terms / tolerances outside the kernel.

    Requires ``m_keep <= n_hosts`` (the caller's shortlist branch guarantees
    M < N).  Hosts are padded to the 128-lane tile with unschedulable
    entries, which can never outrank a real host.  ``churn`` (optional
    per-host ẑ column) and a static ``churn_threshold`` enable the
    failure-domain weigher term and hot-zone steering (see
    ``_tile_stage1``); with a 5th ``weigher_multipliers`` entry the churn
    normalization folds into consts slots 8/9.  ``host_zone`` (per-host
    zone-id i32 column) + ``exclude_zone`` (i32 scalar, negative = off)
    hard-filter the excluded zone for relocation re-placements.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if host_zone is None or exclude_zone is None:
        host_zone = exclude_zone = None
    n = free_f.shape[0]
    if not 1 <= m_keep <= n:
        raise ValueError(f"m_keep={m_keep} out of range for {n} hosts")
    s_buf = 1
    while s_buf < m_keep + tile:
        s_buf *= 2
    scores, idx, consts = _sched_screen_padded(
        _prep_inputs(
            free_f, free_n, schedulable, domain, slow,
            inst_res, inst_cost, inst_valid,
            req_res, req_preemptible, req_domain, tile, churn,
            host_zone, exclude_zone,
        ),
        multipliers=tuple(weigher_multipliers),
        require_free_slot=bool(require_free_slot),
        churn_threshold=(
            None if churn_threshold is None else float(churn_threshold)
        ),
        s_buf=s_buf,
        tile=tile,
        interpret=interpret,
    )
    return scores[0, :m_keep], idx[0, :m_keep], consts[0]


def sched_screen_consts(
    free_f, free_n, schedulable, domain, slow,
    inst_res, inst_cost, inst_valid,
    req_res, req_preemptible, req_domain,
    weigher_multipliers,
    require_free_slot: bool,
    interpret=None,
    tile: int = TILE_HOSTS,
    churn=None,
    churn_threshold=None,
    host_zone=None,
    exclude_zone=None,
):
    """Constants half of the split screen: fold ONLY the 10 normalization
    scalars over the given hosts (identical folds to ``sched_screen``'s
    phase 0).  Returns the packed (10,) ``ScreenConsts``.

    The sharded fused path (``jax_scheduler._sharded_screen`` with
    ``fused_screen=True``) runs this per shard, pmin/pmax-merges the
    results across the mesh, and feeds them to ``sched_screen_topm`` — the
    constants barrier the single-kernel 2-phase grid cannot cross."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if host_zone is None or exclude_zone is None:
        host_zone = exclude_zone = None
    consts = _sched_consts_padded(
        _prep_inputs(
            free_f, free_n, schedulable, domain, slow,
            inst_res, inst_cost, inst_valid,
            req_res, req_preemptible, req_domain, tile, churn,
            host_zone, exclude_zone,
        ),
        multipliers=tuple(weigher_multipliers),
        require_free_slot=bool(require_free_slot),
        churn_threshold=(
            None if churn_threshold is None else float(churn_threshold)
        ),
        tile=tile,
        interpret=interpret,
    )
    return consts[0]


def sched_screen_topm(
    free_f, free_n, schedulable, domain, slow,
    inst_res, inst_cost, inst_valid,
    req_res, req_preemptible, req_domain,
    consts,
    weigher_multipliers,
    require_free_slot: bool,
    m_keep: int,
    interpret=None,
    tile: int = TILE_HOSTS,
    churn=None,
    churn_threshold=None,
    host_zone=None,
    exclude_zone=None,
):
    """Top-M half of the split screen: score ``omega_ub`` against EXTERNAL
    packed constants (``consts``, e.g. mesh-merged) and fold the on-chip
    running top-``m_keep``.  Returns ``(top_scores, top_idx)`` with the
    same ordering contract as ``sched_screen``."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if host_zone is None or exclude_zone is None:
        host_zone = exclude_zone = None
    n = free_f.shape[0]
    if not 1 <= m_keep <= n:
        raise ValueError(f"m_keep={m_keep} out of range for {n} hosts")
    s_buf = 1
    while s_buf < m_keep + tile:
        s_buf *= 2
    scores, idx = _sched_topm_padded(
        _prep_inputs(
            free_f, free_n, schedulable, domain, slow,
            inst_res, inst_cost, inst_valid,
            req_res, req_preemptible, req_domain, tile, churn,
            host_zone, exclude_zone,
        ),
        jnp.asarray(consts, jnp.float32).reshape(1, N_CONSTS),
        multipliers=tuple(weigher_multipliers),
        require_free_slot=bool(require_free_slot),
        churn_threshold=(
            None if churn_threshold is None else float(churn_threshold)
        ),
        s_buf=s_buf,
        tile=tile,
        interpret=interpret,
    )
    return scores[0, :m_keep], idx[0, :m_keep]
