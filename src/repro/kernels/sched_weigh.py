"""Pallas TPU kernel: fused filter + Alg.5 subset enumeration per host tile.

This is the scheduling hot loop at fleet scale: for every host, evaluate all
2^K termination subsets of its (padded) K preemptible-instance slots —
feasibility against the request's resource vector and additive cost — and
reduce to the per-host best plan.  Formulated as two small matmuls per tile
(``res_d @ masks`` and ``cost @ masks``) so the MXU does the enumeration,
plus VPU compares/reductions.

Tiling: hosts are tiled T=128 per grid step (sublane-aligned); the mask
matrix (K, M=2^K) and the request vector live in VMEM for the whole grid
(index_map → block 0).  VMEM working set per step, K=8, D=4:
  inst_res (128,8,4)f32 + masks (8,256) + ok/sub_cost (128,256)f32×2 ≈ 300 KB
— comfortably inside the ~16 MB v5e VMEM budget; T could rise to 2048, but
128 keeps the kernel latency-bound rather than occupancy-bound at small
fleets (see EXPERIMENTS.md §Perf for the sweep).

Oracle: ``repro.core.jax_scheduler.host_plan_terms`` (pure jnp).  Validated
in interpret mode over shape/dtype sweeps in tests/test_kernels_sched.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.screen_math import TIE_EPS

POS_INF = 1e30
TILE_HOSTS = 128


def _kernel(free_f_ref, inst_res_ref, inst_cost_ref, inst_valid_ref,
            req_ref, masks_ref, best_cost_ref, best_mask_ref, feas_ref, *, ndim):
    free_f = free_f_ref[...]          # (T, D)
    res = inst_res_ref[...]           # (T, K, D)
    cost = inst_cost_ref[...]         # (T, K)
    valid = inst_valid_ref[...]       # (T, K) float 0/1
    req = req_ref[...]                # (1, D)
    masks = masks_ref[...]            # (K, M)

    # Invalid (padding) slots free nothing and poison any subset they join.
    res = res * valid[:, :, None]
    cost = jnp.where(valid > 0.5, cost, POS_INF)

    # Feasibility: for every mask m, all D dims satisfied.  One MXU matmul
    # per resource dimension (D is small and static → unrolled).
    ok = None
    for d in range(ndim):
        freed_d = jnp.dot(res[:, :, d], masks,
                          preferred_element_type=jnp.float32)       # (T, M)
        cond = free_f[:, d][:, None] + freed_d >= req[0, d] - 1e-6
        ok = cond if ok is None else (ok & cond)

    sub_cost = jnp.dot(cost, masks, preferred_element_type=jnp.float32)
    sub_cost = jnp.where(ok, sub_cost, POS_INF)                     # (T, M)

    best_cost = jnp.min(sub_cost, axis=1)                           # (T,)
    # tie-break: fewest instances, then lowest mask index (argmin is first-hit)
    sizes = jnp.sum(masks, axis=0)                                  # (M,)
    is_tie = sub_cost <= best_cost[:, None] + TIE_EPS
    size_key = jnp.where(is_tie, sizes[None, :], POS_INF)
    best_mask = jnp.argmin(size_key, axis=1).astype(jnp.int32)

    best_cost_ref[...] = best_cost[:, None]
    best_mask_ref[...] = best_mask[:, None]
    feas_ref[...] = jnp.any(ok, axis=1)[:, None].astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret", "tile"))
def _sched_weigh_padded(free_f, inst_res, inst_cost, inst_valid, req, masks_t,
                        interpret=True, tile=TILE_HOSTS):
    n, d = free_f.shape
    k = inst_cost.shape[1]
    m = masks_t.shape[1]
    t = tile
    grid = (n // t,)
    kern = functools.partial(_kernel, ndim=d)
    out_shapes = (
        jax.ShapeDtypeStruct((n, 1), jnp.float32),
        jax.ShapeDtypeStruct((n, 1), jnp.int32),
        jax.ShapeDtypeStruct((n, 1), jnp.int32),
    )
    in_specs = [
        pl.BlockSpec((t, d), lambda i: (i, 0)),
        pl.BlockSpec((t, k, d), lambda i: (i, 0, 0)),
        pl.BlockSpec((t, k), lambda i: (i, 0)),
        pl.BlockSpec((t, k), lambda i: (i, 0)),
        pl.BlockSpec((1, d), lambda i: (0, 0)),
        pl.BlockSpec((k, m), lambda i: (0, 0)),
    ]
    out_specs = (
        pl.BlockSpec((t, 1), lambda i: (i, 0)),
        pl.BlockSpec((t, 1), lambda i: (i, 0)),
        pl.BlockSpec((t, 1), lambda i: (i, 0)),
    )
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shapes,
        interpret=interpret,
    )(free_f, inst_res, inst_cost, inst_valid, req, masks_t)


def sched_weigh(free_f, inst_res, inst_cost, inst_valid, req_res, masks,
                interpret=None, tile=TILE_HOSTS):
    """Fused per-host best-plan terms.  Same contract as
    ``core.jax_scheduler.host_plan_terms`` → (best_cost, best_mask, feasible).

    ``masks``: (M, K) subset enumeration matrix (row 0 = empty set).
    ``tile``: hosts per grid step (sublane-aligned multiple of 8).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n, d = free_f.shape
    k = inst_cost.shape[1]
    t = tile
    pad = (-n) % t
    if pad:
        neg = jnp.full((pad, d), -POS_INF, free_f.dtype)
        free_f = jnp.concatenate([free_f, neg])
        inst_res = jnp.concatenate([inst_res, jnp.zeros((pad, k, d), inst_res.dtype)])
        inst_cost = jnp.concatenate([inst_cost, jnp.zeros((pad, k), inst_cost.dtype)])
        inst_valid = jnp.concatenate([inst_valid, jnp.zeros((pad, k), inst_valid.dtype)])
    best_cost, best_mask, feas = _sched_weigh_padded(
        jnp.asarray(free_f, jnp.float32),
        jnp.asarray(inst_res, jnp.float32),
        jnp.asarray(inst_cost, jnp.float32),
        jnp.asarray(inst_valid, jnp.float32),
        jnp.asarray(req_res, jnp.float32).reshape(1, d),
        jnp.asarray(masks, jnp.float32).T,
        interpret=interpret,
        tile=t,
    )
    return best_cost[:n, 0], best_mask[:n, 0], feas[:n, 0].astype(bool)


def sched_weigh_gathered(free_f, inst_res, inst_cost, inst_valid, req_res,
                         masks, interpret=None):
    """Shortlist stage-2 entry point: the same fused enumeration over a
    *gathered* candidate set — (M, K, D) slot rows for the top-M hosts the
    O(N·K) screen surfaced — instead of the full fleet.

    M is small (tens), so the tile shrinks to the padded candidate count
    (sublane-aligned) and the whole enumeration is a single grid step; with
    the default 128-host tile a 16-candidate shortlist would waste 7/8 of
    the VMEM working set on padding.
    """
    m = free_f.shape[0]
    tile = min(TILE_HOSTS, max(8, -(-m // 8) * 8))
    return sched_weigh(
        free_f, inst_res, inst_cost, inst_valid, req_res, masks,
        interpret=interpret, tile=tile,
    )
