"""Pallas fused RMSNorm kernel: one HBM read, one write per row tile.

Grid over row tiles (T, D): mean-of-squares reduction, rsqrt, scale — all in
VMEM.  D is the model width (128-lane aligned for every assigned arch).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_ROWS = 256


def _kernel(x_ref, w_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)                    # (T, D)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + w_ref[...].astype(jnp.float32))
    o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def _rmsnorm_2d(x, w, eps, interpret):
    n, d = x.shape
    t = min(TILE_ROWS, n)
    grid = (n // t,)
    return pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((t, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((t, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=interpret,
    )(x, w)


def rmsnorm(x: jax.Array, weight: jax.Array, eps: float = 1e-5,
            interpret: Optional[bool] = None) -> jax.Array:
    """Fused RMSNorm over the last axis; any leading shape."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    shape = x.shape
    n = 1
    for s in shape[:-1]:
        n *= s
    x2 = x.reshape(n, shape[-1])
    pad = (-n) % min(TILE_ROWS, max(n, 1))
    if pad:
        x2 = jnp.concatenate([x2, jnp.zeros((pad, shape[-1]), x.dtype)])
    out = _rmsnorm_2d(x2, weight, eps, interpret)
    return out[:n].reshape(shape)
