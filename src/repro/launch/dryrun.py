"""Multi-pod dry run: lower + compile every (arch × shape × mesh) cell and
extract memory / cost / collective statistics for the roofline analysis.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out artifacts/dryrun]

The XLA_FLAGS line below MUST run before any other import touches jax —
jax locks the device count on first init.  Only the dry run sees 512 fake
devices; tests and benchmarks see the real single CPU device.
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse
import dataclasses
import json
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, SHAPES, applicable_shapes, get_config
from repro.data.pipeline import make_batch_specs
from repro.launch import roofline as rl
from repro.launch import sharding as shr
from repro.launch.mesh import make_production_mesh
from repro.models.layers import shape_tree
from repro.models.model import (
    decode_step,
    forward_logits,
    init_decode_state,
    model_defs,
    param_pspecs,
)
from repro.optim.optimizers import make_optimizer
from repro.training.train_step import TrainSettings, make_train_step

from jax.sharding import PartitionSpec as P

#: microbatch counts keeping per-shard batch ≥1 and activations inside HBM.
MICROBATCHES = {"train_4k": 8}


def input_specs(arch: str, shape_name: str) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of a cell."""
    cfg = get_config(arch)
    return make_batch_specs(cfg, SHAPES[shape_name])


def _serve_step(cfg):
    def serve_step(params, token, state):
        logits, state = decode_step(cfg, params, token, state)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), state

    return serve_step


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               verbose: bool = True,
               cfg_overrides: Dict[str, object] = None,
               settings_overrides: Dict[str, object] = None,
               mesh_shape: str = None) -> Dict[str, object]:
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = SHAPES[shape_name]
    if mesh_shape:
        # right-sized slice (scheduler-level decision): "DxM" data x model
        d, m = (int(x) for x in mesh_shape.split("x"))
        mesh = jax.make_mesh((d, m), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        mesh_name = f"slice{d}x{m}"
        chips = d * m
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
        mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
        chips = 512 if multi_pod else 256
    t0 = time.time()

    with jax.set_mesh(mesh):
        pspecs = param_pspecs(cfg)
        pshapes = shape_tree(model_defs(cfg), jnp.dtype(cfg.params_dtype))
        batch = make_batch_specs(cfg, shape)
        bspecs = shr.batch_pspecs(cfg, batch)

        if shape.kind == "train":
            skw = dict(microbatches=MICROBATCHES.get(shape.name, 1))
            skw.update(settings_overrides or {})
            settings = TrainSettings(**skw)
            opt = make_optimizer(cfg.optimizer)
            ostate = jax.eval_shape(opt.init, pshapes)
            ospecs = opt.state_specs(pspecs)
            step = make_train_step(cfg, settings, opt)
            fn = jax.jit(
                step,
                in_shardings=(pspecs, ospecs, bspecs),
                donate_argnums=(0, 1),
            )
            lowered = fn.lower(pshapes, ostate, batch)
        elif shape.kind == "prefill":
            fn = jax.jit(
                lambda p, b: forward_logits(cfg, p, b, last_only=True),
                in_shardings=(pspecs, bspecs),
            )
            lowered = fn.lower(pshapes, batch)
        else:  # decode
            b = shape.global_batch
            state = jax.eval_shape(
                lambda: init_decode_state(cfg, b, shape.seq_len, enc_len=shape.seq_len)
            )
            sspecs = shr.decode_state_pspecs(cfg, state)
            tok = jax.ShapeDtypeStruct((b, 1), jnp.int32)
            tspec = P(shr._batch_entry(b), None)
            fn = jax.jit(
                _serve_step(cfg),
                in_shardings=(pspecs, tspec, sspecs),
                donate_argnums=(2,),
            )
            lowered = fn.lower(pshapes, tok, state)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()

    bytes_per_device = None
    mem_repr = None
    if mem is not None:
        mem_repr = {
            k: getattr(mem, k)
            for k in dir(mem)
            if not k.startswith("_") and isinstance(getattr(mem, k, None), (int, float))
        }
        for key in ("temp_size_in_bytes",):
            if key in mem_repr:
                bytes_per_device = (
                    mem_repr.get("argument_size_in_bytes", 0)
                    + mem_repr.get("output_size_in_bytes", 0)
                    - mem_repr.get("alias_size_in_bytes", 0)
                    + mem_repr.get("temp_size_in_bytes", 0)
                )

    roof = rl.build(
        arch, shape, mesh_name, chips, cost or {}, hlo, cfg, bytes_per_device
    )
    row = roof.row()
    row.update(
        status="ok",
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        memory_analysis=mem_repr,
        hlo_collective_lines=sum(roof.collective_counts.values()),
    )
    if verbose:
        print(f"[dryrun] {arch} × {shape_name} × {mesh_name}: "
              f"flops={row['hlo_flops']:.3e} bytes={row['hlo_bytes']:.3e} "
              f"coll={row['collective_bytes']:.3e} dominant={row['dominant']} "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
        if mem_repr:
            print(f"        memory_analysis: {mem_repr}")
    return row


def run_cells(archs, shapes, multi_pod: bool, out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    for arch in archs:
        cfg = get_config(arch)
        for shape, skip in applicable_shapes(cfg):
            if shapes and shape.name not in shapes:
                continue
            path = os.path.join(out_dir, f"{mesh_name}__{arch}__{shape.name}.json")
            if skip is not None:
                row = {"arch": arch, "shape": shape.name, "mesh": mesh_name,
                       "status": "skipped", "reason": skip}
                with open(path, "w") as f:
                    json.dump(row, f, indent=1)
                print(f"[dryrun] SKIP {arch} × {shape.name}: {skip}")
                continue
            if os.path.exists(path):
                with open(path) as f:
                    if json.load(f).get("status") == "ok":
                        print(f"[dryrun] cached {arch} × {shape.name}")
                        continue
            try:
                row = lower_cell(arch, shape.name, multi_pod)
            except Exception as e:
                row = {"arch": arch, "shape": shape.name, "mesh": mesh_name,
                       "status": "error", "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-4000:]}
                print(f"[dryrun] ERROR {arch} × {shape.name}: {e}")
            with open(path, "w") as f:
                json.dump(row, f, indent=1, default=str)


def _parse_overrides(pairs):
    out = {}
    for p in pairs or []:
        k, v = p.split("=", 1)
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        if v in ("true", "True"):
            v = True
        if v in ("false", "False"):
            v = False
        out[k] = v
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default=None)
    ap.add_argument("--shape", choices=list(SHAPES), default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--cfg", action="append", metavar="K=V",
                    help="ModelConfig override (perf experiments)")
    ap.add_argument("--settings", action="append", metavar="K=V",
                    help="TrainSettings override (perf experiments)")
    ap.add_argument("--tag", default=None, help="experiment tag for the artifact name")
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="right-sized slice mesh, e.g. 64x1 (perf experiments)")
    args = ap.parse_args()

    cfg_o = _parse_overrides(args.cfg)
    set_o = _parse_overrides(args.settings)
    if cfg_o or set_o or args.tag:
        assert args.arch and args.shape and args.tag, "--cfg/--settings need --arch --shape --tag"
        row = lower_cell(args.arch, args.shape, args.multi_pod,
                         cfg_overrides=cfg_o, settings_overrides=set_o,
                         mesh_shape=args.mesh)
        row["experiment"] = {"tag": args.tag, "cfg": cfg_o, "settings": set_o}
        os.makedirs(args.out, exist_ok=True)
        mesh_name = "pod2x16x16" if args.multi_pod else "pod16x16"
        path = os.path.join(
            args.out, f"{mesh_name}__{args.arch}__{args.shape}__{args.tag}.json"
        )
        with open(path, "w") as f:
            json.dump(row, f, indent=1, default=str)
        return

    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else []
    run_cells(archs, shapes, args.multi_pod, args.out)


if __name__ == "__main__":
    main()
