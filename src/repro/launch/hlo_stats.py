"""Loop-aware cost extraction from optimized (SPMD-partitioned) HLO text.

``compiled.cost_analysis()`` visits every while-loop body ONCE, so with
scan-over-layers (and microbatch / chunk scans) it understates FLOPs and
bytes by the trip counts.  This parser rebuilds the totals:

  * computations are parsed into symbol tables (instr name → shape);
  * ``while`` ops carry ``known_trip_count {n:"L"}`` in backend_config —
    bodies are scaled by L (recursively; fusions/calls recurse at ×1);
  * FLOPs: 2 · numel(result) · prod(contracted dims) per dot;
  * HBM bytes: Σ over *top-level* instructions of result + operand bytes
    (fusion interiors are never materialized; parameters/GTE/tuple/bitcast
    and other no-traffic ops are skipped);
  * collective link-bytes per chip with ring conventions (see roofline.py).

Shapes in partitioned HLO are per-device, so every total is per-chip.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s+(?:ROOT )?%?([\w\.\-]+) = (.*)$")
_OP_RE = re.compile(r"([a-z][a-z0-9\-]*(?:-start|-done)?)\(")
_TRIP_RE = re.compile(r'known_trip_count[\"\\:{\s]+n[\"\\:\s]+(\d+)')
_CALL_RE = re.compile(r"(?:calls|body|to_apply)=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_NO_TRAFFIC = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "bitcast-convert", "copy", "copy-start", "copy-done", "after-all",
    "partition-id", "replica-id", "iota", "reshape",
}
_COLLECTIVES = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
}


def _shape_list(text: str) -> List[Tuple[str, int]]:
    out = []
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        out.append((dt, n))
    return out


def _bytes_of(text: str) -> int:
    return sum(n * _DTYPE_BYTES[dt] for dt, n in _shape_list(text))


@dataclasses.dataclass
class Instr:
    name: str
    op: str
    result_text: str
    args_text: str
    attrs_text: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    shapes: Dict[str, str]  # instr name → result text (shape spec)
    root: Optional[str] = None


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_counts: Dict[str, int] = dataclasses.field(default_factory=dict)

    def add(self, other: "Costs", scale: float = 1.0) -> None:
        self.flops += other.flops * scale
        self.bytes += other.bytes * scale
        self.collective_bytes += other.collective_bytes * scale
        for k, v in other.coll_by_kind.items():
            self.coll_by_kind[k] = self.coll_by_kind.get(k, 0.0) + v * scale
        for k, v in other.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0) + int(v * scale)


def _group_size(attrs: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(attrs)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(attrs)
    if m:
        return len(m.group(1).split(","))
    return default


def _collective_link_bytes(instr: Instr, comp: Computation, n_devices: int) -> Tuple[str, float]:
    kind = instr.op.replace("-start", "")
    n = max(2, _group_size(instr.attrs_text + instr.args_text, n_devices))
    result_bytes = _bytes_of(instr.result_text)
    operand_bytes = 0
    for om in _OPERAND_RE.finditer(instr.args_text):
        operand_bytes += _bytes_of(comp.shapes.get(om.group(1), ""))
    if kind == "all-reduce":
        link = 2.0 * (n - 1) / n * max(result_bytes, operand_bytes)
    elif kind == "all-gather":
        link = (n - 1) / n * result_bytes
    elif kind == "reduce-scatter":
        link = (n - 1) / n * max(operand_bytes, result_bytes * n)
    elif kind == "all-to-all":
        link = (n - 1) / (n * n) * max(result_bytes, operand_bytes)
    else:  # collective-permute
        link = result_bytes
    return kind, link


def _dot_flops(instr: Instr, comp: Computation) -> float:
    result = _shape_list(instr.result_text)
    if not result:
        return 0.0
    numel = sum(n for _, n in result)
    contract = 1
    m = _LHS_CONTRACT_RE.search(instr.attrs_text)
    operands = _OPERAND_RE.findall(instr.args_text)
    if m and operands:
        lhs_text = comp.shapes.get(operands[0], "")
        sm = _SHAPE_RE.search(lhs_text)
        if sm and sm.group(2):
            dims = [int(d) for d in sm.group(2).split(",")]
            for di in m.group(1).split(","):
                if di != "" and int(di) < len(dims):
                    contract *= dims[int(di)]
    return 2.0 * numel * contract


class HloCost:
    def __init__(self, text: str, n_devices: int):
        self.comps, self.entry = {}, None
        comps: Dict[str, Computation] = {}
        # parse_module inlined to also capture entry
        current = None
        for raw in text.splitlines():
            if raw and not raw[0].isspace() and "{" in raw and "(" in raw:
                header = raw.strip()
                is_entry = header.startswith("ENTRY")
                name = header.split("(", 1)[0].replace("ENTRY", "").strip().lstrip("%").rstrip()
                current = Computation(name=name, instrs=[], shapes={})
                comps[name] = current
                if is_entry:
                    self.entry = name
                continue
            if raw.startswith("}"):
                current = None
                continue
            if current is None:
                continue
            m = _INSTR_RE.match(raw)
            if not m:
                continue
            iname, rest = m.group(1), m.group(2)
            om = _OP_RE.search(rest)
            if not om:
                continue
            op = om.group(1)
            result_text = rest[: om.start()]
            after = rest[om.end():]
            depth, idx = 1, 0
            for idx, ch in enumerate(after):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        break
            current.instrs.append(
                Instr(name=iname, op=op, result_text=result_text,
                      args_text=after[:idx], attrs_text=after[idx + 1:], line=rest)
            )
            current.shapes[iname] = result_text
            if "ROOT " in raw:
                current.root = iname
        self.comps = comps
        self.n_devices = n_devices
        self._memo: Dict[str, Costs] = {}

    def total(self) -> Costs:
        if self.entry is None:
            return Costs()
        return self._visit(self.entry, count_bytes=True)

    def _visit(self, comp_name: str, count_bytes: bool) -> Costs:
        """count_bytes=False inside fused computations: interiors are never
        materialized, so only flops/collectives count there."""
        key = (comp_name, count_bytes)
        if key in self._memo:
            return self._memo[key]
        self._memo[key] = Costs()  # cycle guard
        comp = self.comps.get(comp_name)
        if comp is None:
            return self._memo[key]
        total = Costs()
        for ins in comp.instrs:
            op = ins.op
            if op == "while":
                trips = 1
                tm = _TRIP_RE.search(ins.attrs_text)
                if tm:
                    trips = int(tm.group(1))
                bm = _CALL_RE.search(ins.attrs_text)
                if bm:
                    total.add(self._visit(bm.group(1), count_bytes), scale=trips)
                cm = _COND_RE.search(ins.attrs_text)
                if cm:
                    total.add(self._visit(cm.group(1), False), scale=trips)
                continue
            if op in ("fusion", "call", "custom-call", "map", "reduce",
                      "reduce-window", "sort", "scatter", "select-and-scatter",
                      "conditional"):
                bm = _CALL_RE.search(ins.attrs_text)
                if bm:
                    total.add(self._visit(bm.group(1), False))
            if op == "dot":
                total.flops += _dot_flops(ins, comp)
            if op in _COLLECTIVES or op.replace("-start", "") in _COLLECTIVES:
                if op.endswith("-done"):
                    continue
                kind, link = _collective_link_bytes(ins, comp, self.n_devices)
                total.collective_bytes += link
                total.coll_by_kind[kind] = total.coll_by_kind.get(kind, 0.0) + link
                total.coll_counts[kind] = total.coll_counts.get(kind, 0) + 1
                if count_bytes:
                    total.bytes += _bytes_of(ins.result_text)
                continue
            if op in _NO_TRAFFIC or op.endswith("-done"):
                continue
            if count_bytes:
                operands = _OPERAND_RE.findall(ins.args_text)
                if op in ("dynamic-slice", "slice", "gather"):
                    # in-place views: traffic = slice read + write, not buffer
                    tb = 2 * _bytes_of(ins.result_text)
                elif op == "dynamic-update-slice":
                    upd = comp.shapes.get(operands[1], "") if len(operands) > 1 else ""
                    tb = 2 * _bytes_of(upd)
                elif op == "scatter":
                    upd = comp.shapes.get(operands[-1], "") if operands else ""
                    tb = 2 * _bytes_of(upd) + _bytes_of(ins.result_text)
                elif op == "fusion":
                    tb = self._fusion_traffic(ins, comp)
                else:
                    # HBM traffic: result + named operands (top-level buffers)
                    tb = _bytes_of(ins.result_text)
                    for name in operands:
                        tb += _bytes_of(comp.shapes.get(name, ""))
                total.bytes += tb
        self._memo[key] = total
        return total

    def _fusion_traffic(self, ins: Instr, comp: Computation) -> float:
        """HBM traffic of a fusion call site, accounting for operands that
        the fused computation only *slices* (scan xs reads) or updates
        in place (scan ys / stacked-activation DUS roots)."""
        operands = _OPERAND_RE.findall(ins.args_text)
        bm = _CALL_RE.search(ins.attrs_text)
        called = self.comps.get(bm.group(1)) if bm else None
        if called is None:
            tb = _bytes_of(ins.result_text)
            for name in operands:
                tb += _bytes_of(comp.shapes.get(name, ""))
            return tb
        params: Dict[int, str] = {}
        for pi in called.instrs:
            if pi.op == "parameter":
                try:
                    params[int(pi.args_text.strip() or "0")] = pi.name
                except ValueError:
                    pass
        root = next((i for i in called.instrs if i.name == called.root), None)
        if root is not None and root.op == "dynamic-update-slice":
            upd_ops = _OPERAND_RE.findall(root.args_text)
            upd = called.shapes.get(upd_ops[1], "") if len(upd_ops) > 1 else ""
            tb = 2.0 * _bytes_of(upd)  # write slice (+read-modify)
        else:
            tb = float(_bytes_of(ins.result_text))
        for i, name in enumerate(operands):
            pname = params.get(i)
            full = _bytes_of(comp.shapes.get(name, ""))
            if pname is None:
                tb += full
                continue
            pat = re.compile(rf"%{re.escape(pname)}\b")
            users = [u for u in called.instrs if pat.search(u.args_text)]
            if users and all(u.op in ("dynamic-slice", "slice") for u in users):
                tb += sum(_bytes_of(u.result_text) for u in users)
            elif (
                root is not None
                and root.op == "dynamic-update-slice"
                and users == [root]
                and _OPERAND_RE.findall(root.args_text)[:1] == [pname]
            ):
                tb += 0.0  # aliased in-place destination buffer
            else:
                tb += full
        return tb
