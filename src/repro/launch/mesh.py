"""Production mesh factory.  A FUNCTION, not a module constant — importing
this module never touches jax device state (smoke tests see 1 device; only
dryrun.py forces 512 host-platform devices)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod meshes: 16×16 = 256 chips per pod; 2 pods = 512 chips.

    Axes: 'data' carries DP+FSDP (and the expert axis of MoE layers),
    'model' carries TP/SP, 'pod' is pure DP across the DCN.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_dev_mesh(data: int = 1, model: int = 1):
    """Small mesh for tests on whatever devices exist."""
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )
