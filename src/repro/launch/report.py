"""Render §Dry-run and §Roofline markdown tables from dry-run artifacts.

Usage: PYTHONPATH=src python -m repro.launch.report [--dir artifacts/dryrun]
Writes artifacts/roofline_tables.md (pasted into EXPERIMENTS.md).
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

FIX_HINT = {
    # dominant term → one-sentence lever
    "compute": "already compute-led: raise MFU via larger per-chip microbatch "
               "or lower remat recompute",
    "memory": "cut HBM traffic: blocked/flash attention (kills S^2 f32 "
              "intermediates), bf16 param gathers, remat=dots",
    "collective": "cut link bytes: bf16 all-gathers, sequence-parallel "
                  "residuals (all-reduce→reduce-scatter), head-divisible TP",
}


def load(d: str) -> List[Dict]:
    rows = []
    for p in sorted(glob.glob(os.path.join(d, "*.json"))):
        rows.append(json.load(open(p)))
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    rows.sort(key=lambda r: (r["mesh"], r["arch"], order.get(r["shape"], 9)))
    return rows


def fmt_bytes(n) -> str:
    if n is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(n) < 1024:
            return f"{n:.1f}{unit}"
        n /= 1024
    return f"{n:.1f}PB"


def dryrun_table(rows: List[Dict]) -> str:
    out = ["| mesh | arch | shape | status | compile_s | args/dev | temp/dev | HLO colls |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] == "skipped":
            out.append(f"| {r['mesh']} | {r['arch']} | {r['shape']} | SKIP | - | - | - | - |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['mesh']} | {r['arch']} | {r['shape']} | ERROR | - | - | - | - |")
            continue
        mem = r.get("memory_analysis") or {}
        out.append(
            f"| {r['mesh']} | {r['arch']} | {r['shape']} | ok | {r.get('compile_s','-')} "
            f"| {fmt_bytes(mem.get('argument_size_in_bytes'))} "
            f"| {fmt_bytes(mem.get('temp_size_in_bytes'))} "
            f"| {r.get('hlo_collective_lines','-')} |"
        )
    return "\n".join(out)


def roofline_table(rows: List[Dict]) -> str:
    out = ["| mesh | arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | dominant | "
           "roofline frac | useful FLOPs | lever |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] != "ok":
            continue
        out.append(
            f"| {r['mesh']} | {r['arch']} | {r['shape']} "
            f"| {r['t_compute_s']:.4g} | {r['t_memory_s']:.4g} | {r['t_collective_s']:.4g} "
            f"| **{r['dominant']}** | {r['roofline_fraction']:.3f} "
            f"| {r['useful_ratio']:.2f} | {FIX_HINT[r['dominant']]} |"
        )
    return "\n".join(out)


def collective_breakdown(rows: List[Dict]) -> str:
    out = ["| mesh | arch | shape | all-reduce | all-gather | reduce-scatter | all-to-all | permute |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] != "ok":
            continue
        k = r.get("collective_by_kind", {})
        g = lambda key: fmt_bytes(k.get(key, 0.0))
        out.append(
            f"| {r['mesh']} | {r['arch']} | {r['shape']} | {g('all-reduce')} "
            f"| {g('all-gather')} | {g('reduce-scatter')} | {g('all-to-all')} "
            f"| {g('collective-permute')} |"
        )
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--out", default="artifacts/roofline_tables.md")
    args = ap.parse_args()
    rows = load(args.dir)
    with open(args.out, "w") as f:
        f.write("## Dry-run status\n\n" + dryrun_table(rows))
        f.write("\n\n## Roofline terms (per chip, per step)\n\n" + roofline_table(rows))
        f.write("\n\n## Collective link-bytes per chip by kind\n\n" + collective_breakdown(rows))
        f.write("\n")
    ok = sum(r["status"] == "ok" for r in rows)
    skip = sum(r["status"] == "skipped" for r in rows)
    print(f"wrote {args.out}: {ok} ok, {skip} skipped, {len(rows)-ok-skip} error")


if __name__ == "__main__":
    main()
