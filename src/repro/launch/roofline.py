"""Roofline analysis from compiled dry-run artifacts (no hardware needed).

Three terms per (arch × shape × mesh), in seconds:

    compute    = HLO_FLOPs / (chips · peak_FLOP/s)
    memory     = HLO_bytes / (chips · HBM_bw)
    collective = Σ per-op link-bytes / link_bw        (per chip)

``cost_analysis()`` provides FLOPs and bytes.  Collective bytes are parsed
from the optimized HLO: for each all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute we take operand/result sizes and apply the
standard ring-cost conventions *per participating chip*:

    all-reduce        2·(n−1)/n · B        (B = full tensor bytes)
    all-gather        (n−1)/n · B_result
    reduce-scatter    (n−1)/n · B_operand
    all-to-all        (n−1)/n² · B ≈ B/n   (each chip keeps 1/n)
    collective-permute B                   (one hop)

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (per the assignment).  Cross-pod (DCN) bytes are reported separately
when replica groups span pods.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

import numpy as np

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link
DCN_BW = 25e9                # bytes/s / host across pods (assumption, noted)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_REPL_RE = re.compile(r"replica_groups=\{(.*?)\}")
_REPL_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _parse_shape_bytes(text: str) -> int:
    """Sum byte sizes of every typed shape literal in ``text``."""
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, total_devices: int) -> int:
    m = _REPL_IOTA_RE.search(line)
    if m:  # iota format [groups,size]
        return int(m.group(2))
    m = _REPL_RE.search(line)
    if m:
        body = m.group(1)
        first = body.split("}", 1)[0].strip("{} ")
        if first:
            return len(first.split(","))
    return total_devices


@dataclasses.dataclass
class CollectiveStats:
    #: per-chip link bytes by op kind
    by_kind: Dict[str, float]
    #: number of collective ops by kind
    counts: Dict[str, int]

    @property
    def total_bytes(self) -> float:
        return sum(self.by_kind.values())


def parse_collectives(hlo_text: str, total_devices: int) -> CollectiveStats:
    by_kind: Dict[str, float] = {}
    counts: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        s = line.strip()
        # match op instructions like: %x = bf16[..] all-reduce(...)
        kind = None
        for k in _COLLECTIVES:
            if re.search(rf"= ?\S* {k}\(", s) or re.search(rf"= {k}\(", s) or (
                f" {k}(" in s and "=" in s.split(f" {k}(")[0]
            ):
                kind = k
                break
        if kind is None or s.startswith("//"):
            continue
        if f"{kind}-start" in s or f"{kind}-done" in s:
            # async pair: count the -start only (has the shapes)
            if f"{kind}-done" in s:
                continue
        lhs = s.split("=", 1)[0] + "= "
        result_part = s.split("=", 1)[1]
        result_bytes = _parse_shape_bytes(result_part.split("(", 1)[0])
        operand_bytes = _parse_shape_bytes(result_part.split("(", 1)[1].split(")", 1)[0]) \
            if "(" in result_part else 0
        n = max(2, _group_size(s, total_devices))
        if kind == "all-reduce":
            link = 2.0 * (n - 1) / n * result_bytes
        elif kind == "all-gather":
            link = (n - 1) / n * result_bytes
        elif kind == "reduce-scatter":
            link = (n - 1) / n * operand_bytes
        elif kind == "all-to-all":
            link = (n - 1) / (n * n) * max(result_bytes, operand_bytes)
        else:  # collective-permute
            link = result_bytes
        by_kind[kind] = by_kind.get(kind, 0.0) + link
        counts[kind] = counts.get(kind, 0) + 1
    return CollectiveStats(by_kind=by_kind, counts=counts)


@dataclasses.dataclass
class Roofline:
    """All flop/byte figures are PER CHIP (partitioned-HLO shapes are local;
    the loop-aware parser in hlo_stats.py scales while bodies by trip count).
    """

    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float              # per chip, loop-scaled
    hlo_bytes: float              # per chip, loop-scaled HBM traffic estimate
    collective_bytes: float       # per chip link bytes, loop-scaled
    collective_by_kind: Dict[str, float]
    collective_counts: Dict[str, int]
    model_flops: float            # global 6·N·D-style useful flops
    bytes_per_device: Optional[float]
    raw_cost_analysis: Optional[Dict[str, float]] = None

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        total = self.hlo_flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """compute-term share of the critical path — the score we hillclimb."""
        total = max(self.t_compute, self.t_memory, self.t_collective)
        return self.t_compute / total if total else 0.0

    def row(self) -> Dict[str, object]:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "collective_bytes": self.collective_bytes,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "bytes_per_device": self.bytes_per_device,
            "collective_by_kind": self.collective_by_kind,
            "collective_counts": self.collective_counts,
        }


def model_flops_for(cfg, shape) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE); decode counts one token/step."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def build(arch: str, shape, mesh_name: str, chips: int,
          cost: Dict[str, float], hlo_text: str, cfg,
          bytes_per_device: Optional[float]) -> Roofline:
    from .hlo_stats import HloCost

    totals = HloCost(hlo_text, chips).total()
    return Roofline(
        arch=arch, shape=shape.name, mesh=mesh_name, chips=chips,
        hlo_flops=totals.flops,
        hlo_bytes=totals.bytes,
        collective_bytes=totals.collective_bytes,
        collective_by_kind=totals.coll_by_kind,
        collective_counts=totals.coll_counts,
        model_flops=model_flops_for(cfg, shape),
        bytes_per_device=bytes_per_device,
        raw_cost_analysis={
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(
                cost.get("bytes accessed", cost.get("bytes_accessed", 0.0))
            ),
        },
    )
