"""Jit-boundary sharding assembly: batch specs, decode-state specs, and the
divisibility-aware rules (DP/FSDP/TP/SP/EP) for every (arch × shape) cell.

Param shardings come from ParamDef logical axes (models/layers.pspec_tree);
this module covers the *data plane*: input batches and decode caches.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.model import DecodeState
from repro.sharding import mesh_axes


def axis_sizes() -> Dict[str, int]:
    mesh = jax.sharding.get_abstract_mesh()
    return dict(zip(mesh.axis_names, mesh.shape.values())) if mesh.axis_names else {}


def _dp_axes() -> Tuple[str, ...]:
    present = mesh_axes()
    return tuple(a for a in ("pod", "data") if a in present)


def _dp_size() -> int:
    s = axis_sizes()
    return int(np.prod([s[a] for a in _dp_axes()])) if _dp_axes() else 1


def _tp_size() -> int:
    return axis_sizes().get("model", 1)


def _batch_entry(n: int):
    dp = _dp_axes()
    if dp and n % _dp_size() == 0:
        return dp if len(dp) > 1 else dp[0]
    return None


def batch_pspecs(cfg: ModelConfig, batch_specs: Dict[str, jax.ShapeDtypeStruct]):
    out = {}
    for k, v in batch_specs.items():
        spec = [None] * v.ndim
        spec[0] = _batch_entry(v.shape[0])
        out[k] = P(*spec)
    return out


def decode_state_pspecs(cfg: ModelConfig, state_template: Any):
    """PartitionSpec tree matching a DecodeState template (field-name-driven).

    KV-style caches (·, B, S, G, hd): batch→DP, seq→model (sequence-parallel
    KV — the long-context rule; with B=1 the seq dim additionally takes the
    data axes).  Mamba states shard heads/channels over model.
    """
    tp = _tp_size()
    dp = _dp_axes()

    def kv_spec(shape):
        """(·, B, S, G, hd).  NEVER shard S when an in-place DUS write at a
        traced position must land there: SPMD lowers that as a full-cache
        masked select per layer (measured: 80% of phi3 decode traffic, §Perf
        E).  Preference: batch→DP, then heads→model, then head_dim→model;
        seq-sharding only as the last resort for B=1 long-context."""
        lead = len(shape) - 4                     # layer-stack dims
        b, s, g, hd = shape[lead], shape[lead + 1], shape[lead + 2], shape[lead + 3]
        spec = [None] * len(shape)
        spec[lead] = _batch_entry(b)
        from repro.sharding import decode_kv_axes

        g_ax, hd_ax = decode_kv_axes(g, hd)
        if g_ax:
            spec[lead + 2] = "model"
        elif hd_ax:
            spec[lead + 3] = "model"
        elif s % tp == 0 and tp > 1:
            spec[lead + 1] = "model"              # last resort (select cost)
        return P(*spec)

    def path_spec(path, leaf):
        name = ""
        for entry in path:
            if isinstance(entry, jax.tree_util.GetAttrKey):
                name = entry.name
        shape = leaf.shape
        if name in ("kv_k", "kv_v", "cross_k", "cross_v", "shared_k", "shared_v",
                    "kv_layers_k", "kv_layers_v"):
            return kv_spec(shape)
        if name == "length" or leaf.ndim == 0:
            return P()
        # mamba / xlstm states: shard batch dim; shard a channel dim over model
        spec = [None] * leaf.ndim
        # locate batch dim: first dim equal to known batch (heuristic: after
        # any layer-stack dims).  Mamba stacked: (G,K,B,...) / (K,B,...);
        # xlstm: (B,...).
        for i, n in enumerate(shape):
            if _batch_entry(n) is not None:
                spec[i] = _batch_entry(n)
                # channel dim right after batch (H for ssd / conv channels)
                if i + 1 < leaf.ndim and shape[i + 1] % tp == 0 and tp > 1:
                    spec[i + 1] = "model"
                break
        return P(*spec)

    return jax.tree_util.tree_map_with_path(path_spec, state_template)


def replicated_like(tree: Any):
    return jax.tree.map(lambda _: P(), tree)
