"""Sharded, async, atomic checkpointing (no orbax dependency).

Layout:  <dir>/step_<N>/
            manifest.msgpack       tree structure, shapes, dtypes, meta
            shard_<i>.npz.zst      leaf payloads (zstd-compressed)
         <dir>/LATEST              atomic pointer (written last)

Properties needed by the preemption protocol (core/preemption.py):
  * async:   ``save()`` returns immediately; the writer thread drains in the
             preemption notice window; ``wait()`` blocks until durable.
  * atomic:  a checkpoint is visible only after LATEST flips — a job killed
             mid-write restores the previous checkpoint, never a torn one.
  * exact:   restore() round-trips dtypes/shapes bit-exactly, including the
             data-pipeline cursor, so preempt→resume is step-deterministic.
"""
from __future__ import annotations

import dataclasses
import io
import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import msgpack
import numpy as np

try:  # optional: zstd shard compression (install the `compression` extra)
    import zstandard
except ImportError:  # graceful fallback: write uncompressed .npz shards
    zstandard = None

SHARD_BYTES = 256 * 1024 * 1024


@dataclasses.dataclass
class CheckpointMeta:
    step: int
    extra: Dict[str, Any] = dataclasses.field(default_factory=dict)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- public API -------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: Optional[Dict] = None,
             blocking: bool = False) -> None:
        """Snapshot to host memory synchronously, write to disk async."""
        self.wait()  # one in-flight write at a time
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        host_leaves = [np.asarray(x) for x in leaves]  # device→host copy now
        meta = CheckpointMeta(step=step, extra=extra or {})

        def write():
            try:
                self._write(step, host_leaves, treedef, meta)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        if blocking:
            write()
            self._raise_if_failed()
        else:
            self._thread = threading.Thread(target=write, daemon=False)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_if_failed()

    def latest_step(self) -> Optional[int]:
        path = os.path.join(self.dir, "LATEST")
        if not os.path.exists(path):
            return None
        with open(path) as f:
            return int(f.read().strip())

    def restore(self, template: Any, step: Optional[int] = None
                ) -> Tuple[Any, CheckpointMeta]:
        """Restore into the structure of ``template`` (arrays or
        ShapeDtypeStructs).  Device placement/sharding follows the template's
        shardings when present (elastic resume onto a different mesh)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {self.dir}")
        d = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(d, "manifest.msgpack"), "rb") as f:
            manifest = msgpack.unpackb(f.read(), raw=False)
        arrays: Dict[str, np.ndarray] = {}
        for shard in manifest["shards"]:
            with open(os.path.join(d, shard), "rb") as f:
                buf = f.read()
            if shard.endswith(".zst"):
                if zstandard is None:
                    raise RuntimeError(
                        f"checkpoint shard {shard} is zstd-compressed but the "
                        "'zstandard' package is not installed "
                        "(pip install 'repro-preemptible-scheduler[compression]' "
                        "or, from a checkout, pip install -e '.[compression]')"
                    )
                buf = zstandard.ZstdDecompressor().decompress(buf)
            with np.load(io.BytesIO(buf)) as z:
                for k in z.files:
                    arrays[k] = z[k]
        leaves = [arrays[f"leaf_{i}"] for i in range(manifest["n_leaves"])]
        # restore special dtypes
        for i, dt in enumerate(manifest["dtypes"]):
            leaves[i] = leaves[i].view(dt) if dt == "bfloat16" else leaves[i].astype(dt)

        t_leaves, treedef = jax.tree_util.tree_flatten(template)
        assert len(t_leaves) == len(leaves), "checkpoint/template structure mismatch"
        out = []
        for tmpl, val in zip(t_leaves, leaves):
            assert tuple(tmpl.shape) == tuple(val.shape), (tmpl.shape, val.shape)
            sharding = getattr(tmpl, "sharding", None)
            if sharding is not None and not isinstance(tmpl, jax.ShapeDtypeStruct):
                out.append(jax.device_put(val, sharding))
            else:
                out.append(jax.numpy.asarray(val))
        meta = CheckpointMeta(step=manifest["step"], extra=manifest["extra"])
        return jax.tree_util.tree_unflatten(treedef, out), meta

    # -- internals ------------------------------------------------------------
    def _write(self, step: int, leaves, treedef, meta: CheckpointMeta) -> None:
        final = os.path.join(self.dir, f"step_{step}")
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        cctx = zstandard.ZstdCompressor(level=1) if zstandard is not None else None

        shards, current, size, idx = [], {}, 0, 0

        def flush():
            nonlocal current, size, idx
            if not current:
                return
            buf = io.BytesIO()
            np.savez(buf, **current)
            payload = buf.getvalue()
            name = f"shard_{idx}.npz"
            if cctx is not None:
                payload = cctx.compress(payload)
                name += ".zst"
            with open(os.path.join(tmp, name), "wb") as f:
                f.write(payload)
            shards.append(name)
            current, size = {}, 0
            idx += 1

        dtypes = []
        for i, leaf in enumerate(leaves):
            arr = np.asarray(leaf)
            dtypes.append(str(arr.dtype))
            if arr.dtype == jax.numpy.bfloat16:
                arr = arr.view(np.uint16)  # npz-safe carrier
            current[f"leaf_{i}"] = arr
            size += arr.nbytes
            if size >= SHARD_BYTES:
                flush()
        flush()

        manifest = {
            "step": step,
            "extra": meta.extra,
            "n_leaves": len(leaves),
            "dtypes": dtypes,
            "shards": shards,
        }
        with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
            f.write(msgpack.packb(manifest, use_bin_type=True))
        shutil.rmtree(final, ignore_errors=True)
        os.replace(tmp, final)
        # atomic LATEST flip
        ptr = os.path.join(self.dir, "LATEST")
        with open(ptr + ".tmp", "w") as f:
            f.write(str(step))
        os.replace(ptr + ".tmp", ptr)
        self._gc(step)

    def _gc(self, newest: int) -> None:
        steps = sorted(
            int(n.split("_")[1])
            for n in os.listdir(self.dir)
            if n.startswith("step_") and not n.endswith(".tmp")
        )
        for s in steps[: -self.keep]:
            if s != newest:
                shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    def _raise_if_failed(self) -> None:
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError("async checkpoint write failed") from err
