"""repro — preemptible-aware cluster scheduling (FGCS 2018) + a multi-pod
JAX training/serving framework.  See README.md / DESIGN.md."""

__version__ = "1.0.0"
