"""Batched serving engine: wave batching over the jit'd prefill/decode steps,
preemption-aware.

A *wave* admits up to ``max_batch`` queued requests, right-align-pads their
prompts to a common length, primes the KV cache with one prefill call, then
decodes the whole wave together (shared cache cursor — the simple/robust
batching mode; per-slot cursors are a serving-layer extension).  On a
PREEMPT signal the engine finishes the in-flight decode step, re-queues
unfinished requests, and releases its slice — serving replicas are stateless
so the scheduler's RecomputeCost treats them as free to evacuate.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.preemption import PreemptAck
from repro.models.model import decode_step, prefill


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 8
    max_len: int = 256
    eos_id: int = 1


@dataclasses.dataclass
class RequestState:
    rid: str
    prompt: np.ndarray
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)


class ServingEngine:
    job_id = "serve"

    def __init__(self, cfg: ModelConfig, params, scfg: ServeConfig):
        assert cfg.block_pattern == "attention" and not cfg.encoder_decoder
        self.cfg = cfg
        self.scfg = scfg
        self.params = params
        self._decode = jax.jit(lambda p, t, s: decode_step(cfg, p, t, s))
        self._prefill = jax.jit(lambda p, toks: prefill(cfg, p, toks, scfg.max_len))
        self.queue: List[RequestState] = []
        self.completed: Dict[str, List[int]] = {}
        self._preempted = False
        self.steps_executed = 0

    # -- client API -------------------------------------------------------------
    def submit(self, rid: str, prompt: np.ndarray, max_new: int = 32) -> None:
        self.queue.append(RequestState(rid=rid, prompt=prompt, max_new=max_new))

    def run_until_drained(self) -> Dict[str, List[int]]:
        while self.queue and not self._preempted:
            self._run_wave()
        return self.completed

    # -- engine internals ----------------------------------------------------------
    def _run_wave(self) -> None:
        wave = [self.queue.pop(0) for _ in range(min(self.scfg.max_batch, len(self.queue)))]
        plen = max(len(r.prompt) for r in wave)
        toks = np.zeros((len(wave), plen), np.int32)
        for i, r in enumerate(wave):
            toks[i, plen - len(r.prompt):] = r.prompt  # right-aligned padding
        logits, state = self._prefill(self.params, jnp.asarray(toks))
        nxt = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)[:, None]
        for i, r in enumerate(wave):
            r.out.append(int(nxt[i, 0]))

        done = [False] * len(wave)
        max_new = max(r.max_new for r in wave)
        budget = min(max_new, self.scfg.max_len - plen)
        for _ in range(budget - 1):
            if all(done):
                break
            logits, state = self._decode(self.params, nxt, state)
            self.steps_executed += 1
            nxt = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)[:, None]
            vals = np.asarray(nxt[:, 0])
            for i, r in enumerate(wave):
                if done[i]:
                    continue
                r.out.append(int(vals[i]))
                if int(vals[i]) == self.scfg.eos_id or len(r.out) >= r.max_new:
                    done[i] = True
            if self._preempted:
                break

        for i, r in enumerate(wave):
            if done[i] or len(r.out) >= r.max_new or not self._preempted:
                self.completed[r.rid] = list(r.out)
            else:  # preempted mid-wave: re-queue from scratch
                r.out.clear()
                self.queue.insert(0, r)

    # -- PreemptibleJob protocol ------------------------------------------------
    def on_preempt(self, now: float, deadline: float) -> PreemptAck:
        self._preempted = True
        return PreemptAck.DRAINED
