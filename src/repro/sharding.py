"""Logical-axis sharding: one place that maps model-logical axes onto the
physical mesh, used both for activation constraints inside model code and for
parameter/out shardings at jit boundaries.

Physical mesh axes (launch/mesh.py):
    single-pod  : ("data", "model")                 16 × 16
    multi-pod   : ("pod", "data", "model")          2 × 16 × 16

Logical → physical rules.  "fsdp" rides the data axis (ZeRO-style weight
sharding); the pod axis joins the batch dimension (pure DP across pods).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

Axis = Union[None, str, Tuple[str, ...]]

RULES = {
    None: None,
    # activations
    "batch": ("pod", "data"),
    "seq": None,
    "seq_shard": ("model",),      # SP for long-context KV caches
    "embed_act": None,
    # weights
    "vocab": ("model",),
    "embed": ("data",),           # fsdp dim
    "heads": ("model",),
    "kv_heads": ("model",),
    "qkv_flat": ("model",),
    "ff": ("model",),
    # MoE: expert-parallel over the data axis, tensor-parallel d_ff over model
    # (GShard/DeepSpeed-MoE layout — see models/moe.py).
    "expert": ("data",),
    "expert_ff": ("model",),
    "conv": None,
    "ssm_inner": ("model",),
    "ssm_state": None,
    "norm": None,
}


def mesh_axes() -> Tuple[str, ...]:
    try:
        return tuple(jax.sharding.get_abstract_mesh().axis_names)
    except Exception:
        return ()


def resolve(logical: Sequence[Optional[str]]) -> P:
    """Logical names → PartitionSpec, dropping axes absent from the mesh."""
    present = set(mesh_axes())
    spec = []
    for name in logical:
        phys = RULES.get(name, None) if not isinstance(name, tuple) else name
        if phys is None:
            spec.append(None)
            continue
        kept = tuple(a for a in phys if a in present)
        spec.append(kept if len(kept) > 1 else (kept[0] if kept else None))
    return P(*spec)


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical axes; no-op without a mesh."""
    if not mesh_axes():
        return x
    if x.ndim != len(logical):
        raise ValueError(f"rank {x.ndim} vs logical {logical}")
    return jax.lax.with_sharding_constraint(x, resolve(logical))


def decode_kv_axes(n_kv_heads: int, head_dim: int):
    """The ONE sharded axis of decode KV caches: heads if TP-divisible, else
    head_dim, else nothing.  Used by BOTH the cache specs
    (launch/sharding.py) and the in-graph constraints (models/attention.py):
    any disagreement makes GSPMD reshard the cache per layer with a
    last-resort full rematerialization (measured: 80% of decode traffic)."""
    sizes = {}
    try:
        mesh = jax.sharding.get_abstract_mesh()
        sizes = dict(zip(mesh.axis_names, mesh.shape.values()))
    except Exception:
        pass
    tp = sizes.get("model", 1)
    if tp > 1 and n_kv_heads % tp == 0:
        return "kv_heads", None
    if tp > 1 and head_dim % tp == 0:
        return None, "head_dim"
    return None, None


RULES["head_dim"] = ("model",)


def spec_tree(logical_tree):
    """Map a pytree of logical-axis tuples to PartitionSpecs."""
    return jax.tree.map(
        lambda names: resolve(names),
        logical_tree,
        is_leaf=lambda v: isinstance(v, tuple) and all(
            n is None or isinstance(n, (str, tuple)) for n in v
        ),
    )
