"""Deterministic synthetic LM data pipeline.

Production shape: an infinite, *seekable* stream — ``state = (seed, step)``
is the entire cursor, so a preempted job that restores ``step`` from its
checkpoint resumes on exactly the token stream it would have seen (tested in
tests/test_e2e_preemption.py).  Host-side numpy generation, double-buffered
prefetch thread, per-shard slicing for multi-host feeds.

The token distribution is a order-0 Markov chain with a learnable structure
(deterministic per position block), so small models actually reduce loss —
giving the examples/ drivers a real training signal.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    #: this host's shard of the global batch (host_id, n_hosts)
    host_shard: Tuple[int, int] = (0, 1)


class SyntheticLMDataset:
    """Infinite deterministic stream of (tokens, labels) batches."""

    def __init__(self, cfg: DataConfig, prefetch: int = 2):
        self.cfg = cfg
        host, n_hosts = cfg.host_shard
        assert cfg.global_batch % n_hosts == 0
        self.local_batch = cfg.global_batch // n_hosts
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._step = 0

    # -- deterministic batch at an arbitrary step ------------------------------
    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        host, _ = cfg.host_shard
        rng = np.random.default_rng((cfg.seed, step, host))
        b, s, v = self.local_batch, cfg.seq_len, cfg.vocab_size
        # Markov-ish stream: next token = (a*tok + drift) % V with noise.
        toks = np.empty((b, s + 1), np.int32)
        toks[:, 0] = rng.integers(0, v, b)
        drift = rng.integers(1, 7)
        noise = rng.random((b, s)) < 0.1
        rand = rng.integers(0, v, (b, s))
        for t in range(s):
            nxt = (toks[:, t] * 31 + drift) % v
            toks[:, t + 1] = np.where(noise[:, t], rand[:, t], nxt)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    # -- prefetching iterator ----------------------------------------------------
    def start(self, from_step: int = 0) -> None:
        self._step = from_step
        self._stop.clear()

        def worker():
            step = from_step
            while not self._stop.is_set():
                batch = self.batch_at(step)
                while not self._stop.is_set():
                    try:
                        self._q.put((step, batch), timeout=0.1)
                        break
                    except queue.Full:
                        continue
                step += 1

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def __next__(self) -> Tuple[int, Dict[str, np.ndarray]]:
        if self._thread is None:
            batch = self.batch_at(self._step)
            self._step += 1
            return self._step - 1, batch
        return self._q.get()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
            self._thread = None
        while not self._q.empty():
            self._q.get_nowait()


def make_batch_specs(cfg: ModelConfig, shape: ShapeConfig):
    """ShapeDtypeStructs for every model input of a (arch × shape) cell —
    the dry-run stand-ins (weak-type-correct, no allocation)."""
    import jax
    import jax.numpy as jnp

    b, s = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        text = s - cfg.n_prefix_tokens if cfg.modality == "vision_stub" else s
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, text), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, text), jnp.int32),
        }
        if cfg.modality == "vision_stub":
            specs["patch_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_prefix_tokens, cfg.d_model), jnp.bfloat16
            )
        if cfg.encoder_decoder:
            specs["frame_embeds"] = jax.ShapeDtypeStruct(
                (b, s, cfg.d_model), jnp.bfloat16
            )
        return specs
    # decode: one new token against a seq_len-deep cache (built separately)
    return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
