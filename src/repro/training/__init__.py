from .train_step import TrainSettings, make_eval_step, make_train_step
from .trainer import Trainer, TrainerConfig

__all__ = [
    "TrainSettings",
    "Trainer",
    "TrainerConfig",
    "make_eval_step",
    "make_train_step",
]
