"""Trainer: the runnable job that the cluster scheduler places and preempts.

Implements the ``PreemptibleJob`` protocol (core/preemption.py): on a
PREEMPT signal it drains the in-flight step, writes a checkpoint inside the
notice window, and can later resume — possibly elsewhere — bit-exactly
(params, opt state, data cursor).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs.base import ModelConfig
from repro.core.preemption import PreemptAck
from repro.data.pipeline import DataConfig, SyntheticLMDataset
from repro.models.model import init_params
from repro.optim.optimizers import make_optimizer
from .train_step import TrainSettings, make_train_step


@dataclasses.dataclass
class TrainerConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    log_every: int = 10
    seed: int = 0


class Trainer:
    """Single-process trainer (multi-host launch shards the data feed)."""

    def __init__(
        self,
        cfg: ModelConfig,
        settings: TrainSettings,
        tcfg: TrainerConfig,
        data: Optional[SyntheticLMDataset] = None,
        job_id: str = "job0",
    ):
        self.cfg = cfg
        self.settings = settings
        self.tcfg = tcfg
        self.job_id = job_id
        self.optimizer = make_optimizer(cfg.optimizer, weight_decay=settings.weight_decay)
        self.ckpt = Checkpointer(tcfg.ckpt_dir)
        self.data = data or SyntheticLMDataset(
            DataConfig(vocab_size=cfg.vocab_size, seq_len=256, global_batch=8,
                       seed=tcfg.seed)
        )
        self._step_fn = jax.jit(make_train_step(cfg, settings, self.optimizer),
                                donate_argnums=(0, 1))
        self.params = None
        self.opt_state = None
        self.step = 0
        self.history: list = []
        self._preempted = False

    # -- lifecycle ------------------------------------------------------------
    def init_or_restore(self) -> None:
        latest = self.ckpt.latest_step()
        if latest is None:
            self.params = init_params(self.cfg, jax.random.PRNGKey(self.tcfg.seed))
            self.opt_state = self.optimizer.init(self.params)
            self.step = 0
        else:
            template = {
                "params": init_params(self.cfg, jax.random.PRNGKey(self.tcfg.seed)),
            }
            template["opt"] = self.optimizer.init(template["params"])
            restored, meta = self.ckpt.restore(template)
            self.params = restored["params"]
            self.opt_state = restored["opt"]
            self.step = meta.step

    def run(self, n_steps: Optional[int] = None,
            until_step: Optional[int] = None) -> Dict[str, float]:
        if self.params is None:
            self.init_or_restore()
        target = until_step if until_step is not None else self.step + (n_steps or 0)
        last = {}
        while self.step < target and not self._preempted:
            batch = self.data.batch_at(self.step)
            self.params, self.opt_state, metrics = self._step_fn(
                self.params, self.opt_state, batch
            )
            self.step += 1
            if self.step % self.tcfg.log_every == 0 or self.step == target:
                last = {k: float(v) for k, v in metrics.items()}
                self.history.append({"step": self.step, **last})
            if self.step % self.tcfg.ckpt_every == 0:
                self.save_checkpoint()
        return last

    def save_checkpoint(self, blocking: bool = False) -> None:
        self.ckpt.save(
            self.step,
            {"params": self.params, "opt": self.opt_state},
            extra={"job_id": self.job_id},
            blocking=blocking,
        )

    # -- PreemptibleJob protocol -------------------------------------------------
    def on_preempt(self, now: float, deadline: float) -> PreemptAck:
        """Drain + checkpoint.  With real wall-clock semantics in tests the
        deadline is generous; a hard kill corresponds to skipping this call."""
        self._preempted = True
        t0 = time.monotonic()
        self.save_checkpoint(blocking=True)
        return (
            PreemptAck.DRAINED
            if time.monotonic() - t0 <= max(0.0, deadline - now)
            else PreemptAck.HARD_KILLED
        )

    def resume_marker(self) -> int:
        return self.step
