"""Training step factory: microbatched grad accumulation, global-norm clip,
optional cross-pod gradient compression, optimizer update.

The returned function is pure and jit-able with donated (params, opt_state);
sharding comes from ParamDef logical axes (launch/sharding.py assembles the
in/out shardings at the jit boundary).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import forward_train
from repro.optim.optimizers import (
    Optimizer,
    clip_by_global_norm,
    cosine_schedule,
    make_optimizer,
)


@dataclasses.dataclass(frozen=True)
class TrainSettings:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    clip_norm: float = 1.0
    microbatches: int = 1
    #: cast the fp32 master params to bf16 *per-shard at the step boundary*
    #: (sharding-constrained), so FSDP all-gathers inside the layer scan move
    #: bf16 — half the link bytes and half the gathered-buffer HBM traffic.
    bf16_param_gathers: bool = False
    #: dtype for the accumulated gradient (bf16 halves the accumulation
    #: buffer for the 480B MoE at ~0 quality cost over ≤32 microbatches).
    accum_dtype: str = "float32"
    #: int8 error-feedback compression for the cross-pod gradient reduction.
    grad_compression: str = "none"  # none | int8
    weight_decay: float = 0.1


def _compress_int8(g: jax.Array) -> jax.Array:
    """Simulated int8 quantize→dequantize of a gradient tensor (the wire
    format of the cross-pod all-reduce).  Error feedback is carried by the
    caller; here we apply symmetric per-tensor scaling."""
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q.astype(g.dtype) * scale


def make_train_step(
    cfg: ModelConfig,
    settings: TrainSettings,
    optimizer: Optional[Optimizer] = None,
) -> Callable:
    optimizer = optimizer or make_optimizer(
        cfg.optimizer, weight_decay=settings.weight_decay
    )
    schedule = cosine_schedule(
        settings.learning_rate, settings.warmup_steps, settings.total_steps
    )
    n_mb = settings.microbatches
    accum_dtype = jnp.dtype(settings.accum_dtype)

    def loss_fn(params, batch):
        if settings.bf16_param_gathers:
            from repro.models.layers import pspec_tree
            from repro.models.model import _cast, model_defs
            from repro.sharding import mesh_axes

            params = _cast(params, cfg)          # bf16 per-shard...
            if mesh_axes():                       # ...pinned to param sharding
                params = jax.lax.with_sharding_constraint(
                    params, pspec_tree(model_defs(cfg))
                )
        loss, metrics = forward_train(cfg, params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch: Dict[str, jax.Array]):
        if n_mb == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            mb = jax.tree.map(
                lambda x: x.reshape((n_mb, x.shape[0] // n_mb) + x.shape[1:]), batch
            )

            def body(acc, one):
                (l, m), g = grad_fn(params, one)
                acc = jax.tree.map(
                    lambda a, b: a + b.astype(accum_dtype), acc, g
                )
                return acc, (l, m)

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params
            )
            grads, (losses, ms) = jax.lax.scan(body, zeros, mb)
            grads = jax.tree.map(lambda g: (g / n_mb).astype(jnp.float32), grads)
            loss = jnp.mean(losses)
            metrics = jax.tree.map(jnp.mean, ms)

        if settings.grad_compression == "int8":
            grads = jax.tree.map(_compress_int8, grads)
        grads, gnorm = clip_by_global_norm(grads, settings.clip_norm)
        lr = schedule(opt_state.step)
        delta, opt_state = optimizer.update(grads, opt_state, params, lr)
        params = jax.tree.map(lambda p, d: p + d.astype(p.dtype), params, delta)
        metrics = dict(metrics)
        metrics.update(loss=loss, grad_norm=gnorm, lr=lr)
        return params, opt_state, metrics

    return train_step


def make_eval_step(cfg: ModelConfig) -> Callable:
    def eval_step(params, batch):
        loss, metrics = forward_train(cfg, params, batch)
        return metrics

    return eval_step
