"""Cost modules for the select-and-terminate phase (paper Alg. 5).

A cost module scores a *set* of preemptible instances: the provider-side
damage of terminating exactly that set.  Alg. 5 picks the feasible subset with
minimal cost.  Modularity is a first-class requirement in the paper ("an
instance selection ... only based on the minimization of instances terminated
... may not work for a provider that wish to terminate the instances that
generate less revenues").
"""
from __future__ import annotations

import abc
from typing import Sequence

from .types import Instance

#: The paper's billing quantum: "commercial providers tend to charge by
#: complete periods of 1 h, so partial hours are not accounted".
BILL_PERIOD_S = 3600.0


class CostFunction(abc.ABC):
    name: str = "cost"

    @abc.abstractmethod
    def cost(self, instances: Sequence[Instance], now: float) -> float:
        ...


class PeriodCost(CostFunction):
    """Paper Alg. 4 / §4.2 cost: sum of *partial-period* run time.

    An instance whose run time is an exact multiple of the period costs 0 to
    terminate (the provider bills every started period, so nothing accrued in
    the current period is lost).  E.g. 120 min → 0; 119 min → 59 min lost.
    """

    name = "period"

    def __init__(self, period_s: float = BILL_PERIOD_S):
        self.period_s = float(period_s)

    def cost(self, instances: Sequence[Instance], now: float) -> float:
        # an instance carrying its own contract period bills by it
        # (``Instance.period``; the device path's ``inst_period`` column)
        return sum(
            i.run_time(now) % (i.period or self.period_s) for i in instances
        )


class CountCost(CostFunction):
    """Minimize the *number* of terminated instances (the naive policy the
    paper argues a provider may NOT want — kept as a baseline)."""

    name = "count"

    def cost(self, instances: Sequence[Instance], now: float) -> float:
        return float(len(instances))


class RevenueCost(CostFunction):
    """Lost revenue: unbilled partial period × the instance's price rate."""

    name = "revenue"

    def __init__(self, period_s: float = BILL_PERIOD_S):
        self.period_s = float(period_s)

    def cost(self, instances: Sequence[Instance], now: float) -> float:
        # per-instance contract periods (``Instance.period``) override the
        # shared billing quantum, exactly like the ``inst_period`` column
        def one(i: Instance) -> float:
            p = i.period or self.period_s
            return (i.run_time(now) % p) / p * i.price_rate

        return sum(one(i) for i in instances)


class RecomputeCost(CostFunction):
    """Beyond-paper, TPU adaptation: preempting a *training* job destroys the
    work done since its last durable checkpoint.  Cost = chip-seconds to
    recompute.  Jobs that just checkpointed are nearly free to evacuate —
    this couples the scheduler to the fault-tolerance layer (core/preemption).
    """

    name = "recompute"

    def cost(self, instances: Sequence[Instance], now: float) -> float:
        total = 0.0
        for i in instances:
            anchor = i.last_checkpoint if i.last_checkpoint is not None else i.start_time
            lost_s = max(0.0, now - anchor)
            chips = i.resources.vec[0]  # first dim is chips/vcpus by convention
            total += lost_s * max(1.0, chips)
        return total


class WeightedSumCost(CostFunction):
    """Combine cost modules with multipliers (provider policy composition)."""

    name = "weighted_sum"

    def __init__(self, parts: Sequence[tuple[float, CostFunction]]):
        self.parts = list(parts)

    def cost(self, instances: Sequence[Instance], now: float) -> float:
        return sum(m * c.cost(instances, now) for m, c in self.parts)


class MixedCost(CostFunction):
    """Heterogeneous per-instance billing: each instance is scored by ITS OWN
    kind (``Instance.cost_kind``; ``None`` falls back to ``default``), and a
    set's cost is the sum of those per-instance terms — still per-instance
    additive, so the whole two-stage device pipeline applies unchanged.

    This is the mixed spot/on-demand economics the paper's §5 payment-model
    discussion (and INDIGO-DataCloud) motivates: one fleet can bill some
    instances by partial period, others by count / lost revenue / recompute
    work.  The python oracle of the device path's kind-table selection
    (``SchedulerPolicy`` + the ``inst_cost_kind`` column); pinned
    decision-for-decision by tests/test_mixed_cost.py.

    ``kinds`` lists the extra kinds instances may carry beyond ``default``
    (the policy's cost-kind table); an instance carrying a kind outside the
    table is a configuration error and raises.
    """

    name = "mixed"

    def __init__(
        self,
        default: str = "period",
        kinds: Sequence[str] = (),
        period_s: float = BILL_PERIOD_S,
    ):
        self.default = str(default)
        self.kinds = tuple(str(k) for k in kinds)
        self.period_s = float(period_s)
        for kind in (self.default,) + self.kinds:
            if kind not in COST_REGISTRY:
                raise ValueError(
                    f"unknown cost kind {kind!r}; known: {sorted(COST_REGISTRY)}"
                )
        self._table = {self.default, *self.kinds}
        period_kw = {"period_s": self.period_s}
        self._fns = {
            kind: COST_REGISTRY[kind](
                **(period_kw if kind in ("period", "revenue") else {})
            )
            for kind in self._table
        }

    def kind_of(self, instance: Instance) -> str:
        kind = instance.cost_kind or self.default
        if kind not in self._table:
            raise ValueError(
                f"instance {instance.id} bills by {kind!r}, which is not in "
                f"this fleet's cost-kind table {sorted(self._table)}"
            )
        return kind

    def cost(self, instances: Sequence[Instance], now: float) -> float:
        return sum(
            self._fns[self.kind_of(i)].cost([i], now) for i in instances
        )


COST_REGISTRY = {
    "period": PeriodCost,
    "count": CountCost,
    "revenue": RevenueCost,
    "recompute": RecomputeCost,
}
