"""Device-sharded fleet utilities: mesh construction, host-major state
padding/placement, and the cross-shard shortlist merge.

The stage-1 screen is O(N·K) over the whole fleet — the term that caps a
single device around 10^5 hosts.  The sharded path partitions every per-host
array *host-major* over a 1-D device mesh and runs the unchanged
``screen_math`` bounds per shard under ``jax.shard_map``
(``jax_scheduler._sharded_screen``); only two things ever cross shards:

  * the 10 weigher-normalization scalars (``ScreenConsts``) — merged with
    ``lax.pmin``/``lax.pmax``, which are reassociation-free, so the merged
    constants are bitwise equal to the unsharded fleet-wide folds;
  * each shard's top-M shortlist plus its admissibility witness — merged by
    ``merge_shortlists`` below, which reproduces ``lax.top_k``'s exact
    (value-descending, index-ascending) tie ordering over the union.

Everything downstream (stage-2 enumeration on the gathered shortlist rows,
the admissibility check, the ``lax.cond`` full-enumeration fallback) runs on
replicated data, so sharded decisions are bit-identical to the unsharded
oracle (pinned by tests/test_sharded_parity.py under 8 forced host devices:
``XLA_FLAGS=--xla_force_host_platform_device_count=8``).

Padding: the shard size must divide N and leave every shard at least
``M + 1`` hosts (top-M + one witness candidate).  ``padded_hosts`` computes
the padded row count and ``pad_fleet_state`` appends all-zero rows —
``schedulable=False`` / ``inst_valid=False``, so padding hosts are invalid
everywhere, score ``NEG_INF``, and (having the highest indices) lose every
``lax.top_k`` tie against real hosts; decisions on a padded state are
bit-identical to the unpadded ones.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .screen_math import POS_INF

#: Mesh axis name of the host partition (the only axis the scheduler shards).
HOST_AXIS = "hosts"

#: State fields indexed by ZONE, not by host: never padded with the host
#: rows, and replicated (not partitioned) across the mesh.  Matched by field
#: NAME — zone count Z can coincide with the host count N, so shape-based
#: dispatch would silently corrupt the accumulators.
ZONE_FIELDS = frozenset({"zone_term", "zone_up"})


def fleet_mesh(
    n_shards: Optional[int] = None,
    devices: Optional[Sequence[jax.Device]] = None,
    axis_name: str = HOST_AXIS,
) -> Mesh:
    """A 1-D device mesh for host-major fleet sharding.

    ``n_shards`` defaults to every visible device (``jax.devices()``); pass a
    smaller count to benchmark strong scaling on device subsets.  On CPU,
    force multiple host devices with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (before jax
    initializes) — that is how CI runs the sharded parity suite.
    """
    devices = list(devices if devices is not None else jax.devices())
    if n_shards is not None:
        if n_shards > len(devices):
            raise ValueError(
                f"n_shards={n_shards} > {len(devices)} visible devices"
            )
        devices = devices[:n_shards]
    return Mesh(np.asarray(devices), (axis_name,))


def padded_hosts_for(n_hosts: int, policy) -> int:
    """``padded_hosts`` with the shard count and shortlist ceiling read off a
    ``SchedulerPolicy`` (``policy.mesh`` must be set): the padded size that
    lets every shard emit the largest top-M this policy can ever run —
    the adaptive ceiling when the controller is on.  What ``SoAFleet``
    pads sharded fleets to at build."""
    if policy.mesh is None:
        raise ValueError("padded_hosts_for needs a policy with mesh set")
    return padded_hosts(
        n_hosts, policy.mesh.size, m_keep=policy.max_shortlist() + 1
    )


def padded_hosts(n_hosts: int, n_shards: int, m_keep: int = 65) -> int:
    """Smallest padded fleet size that (a) divides evenly into ``n_shards``
    host-major blocks and (b) leaves every shard ≥ ``m_keep`` hosts, so each
    shard can emit a full top-M shortlist plus the admissibility witness
    (``m_keep = M + 1``).  The decision core silently falls back to the
    unsharded screen when either condition fails (still correct — just not
    shard-parallel), so callers building sharded fleets should pad to this."""
    per_shard = max(math.ceil(n_hosts / n_shards), m_keep)
    return n_shards * per_shard


def pad_fleet_state(state, n_padded: int):
    """Append all-zero host rows to every per-host leaf of a state dataclass
    (``SoAFleetState`` or ``SoAHostState``) up to ``n_padded`` rows.

    Zero rows are inert: ``schedulable``/``inst_valid`` pad as False, so the
    screen marks padding invalid (omega = NEG_INF) and transitions never
    touch it.  Zero-id ``host_zone`` padding is equally inert — padding
    hosts never host instances, so they feed the zone accumulators nothing.
    The per-zone ``ZONE_FIELDS`` accumulators are not host-indexed and pass
    through unpadded.  Returns ``state`` unchanged when already at least as
    large."""
    n = state.free_f.shape[0]
    if n_padded <= n:
        return state

    def pad(x):
        widths = [(0, n_padded - n)] + [(0, 0)] * (x.ndim - 1)
        return jnp.pad(x, widths)

    updates = {}
    for f in dataclasses.fields(state):
        x = getattr(state, f.name)
        if x is None or f.name in ZONE_FIELDS:
            continue
        updates[f.name] = pad(x)
    return dataclasses.replace(state, **updates)


def host_sharding(mesh: Mesh, ndim: int) -> NamedSharding:
    """NamedSharding partitioning axis 0 (hosts) and replicating the rest."""
    axis = mesh.axis_names[0]
    return NamedSharding(mesh, P(axis, *([None] * (ndim - 1))))


def shard_fleet_state(state, mesh: Mesh):
    """Place every leaf of a state dataclass host-major across ``mesh``.

    The per-zone ``ZONE_FIELDS`` accumulators are replicated instead (every
    shard reads the full zone table to derive its hosts' ẑ; the updates are
    scalar scatters the replication keeps consistent).  The row count must
    already be a multiple of the mesh size (see
    ``padded_hosts``/``pad_fleet_state``)."""
    n = state.free_f.shape[0]
    if n % mesh.size:
        raise ValueError(
            f"fleet size {n} does not divide across {mesh.size} shards; "
            "pad with pad_fleet_state(state, padded_hosts(...)) first"
        )
    replicated = NamedSharding(mesh, P())
    updates = {}
    for f in dataclasses.fields(state):
        x = getattr(state, f.name)
        if x is None:
            continue
        sharding = (
            replicated if f.name in ZONE_FIELDS else host_sharding(mesh, x.ndim)
        )
        updates[f.name] = jax.device_put(x, sharding)
    return dataclasses.replace(state, **updates)


def merge_shortlists(
    scores: jax.Array,  # (S·(M+1),) per-shard top-M + witness omega_ub
    idxs: jax.Array,    # (S·(M+1),) matching GLOBAL host indices
    m_cand: int,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Merge per-shard shortlist candidates into the global top-M + witness.

    Returns ``(cand (M,), u, j_u)`` where ``cand`` lists the global top-M
    hosts in exactly ``lax.top_k``'s order — score descending, ties by
    ascending host index — and ``(u, j_u)`` is the best remaining candidate
    (the admissibility witness, matching the unsharded path's masked argmax:
    max score, ties to the lowest index).

    Correctness of the union: any host ranking ≤ M+1 globally under
    (score desc, index asc) ranks ≤ M+1 within its own shard, so it appears
    in that shard's top-M or as its witness — the merge never needs hosts
    that were not forwarded.  The only duplicates possible are a shard whose
    hosts ALL sit in its local top-M re-emitting one of them (at NEG_INF) as
    its witness; the dedup pass drops those before the final cut, keeping
    the candidate list duplicate-free like ``lax.top_k``'s.

    Exactness: two ``lax.sort`` passes on ``(key, index)`` pairs — sorting
    moves values, never recombines them, so the merged ordering is bitwise
    faithful to the per-shard scores.
    """
    neg = -scores  # ascending sort on -score == descending on score (exact)
    idx = idxs.astype(jnp.int32)
    neg_s, idx_s = jax.lax.sort((neg, idx), num_keys=2)
    # Drop duplicate hosts (same index ⇒ same score ⇒ adjacent after the
    # lexicographic sort): push them past every real entry and re-sort.
    # The sentinel key +POS_INF collides with real NEG_INF scores (-(-inf)),
    # but the int32 max index breaks that tie behind every real host.
    dup = jnp.concatenate(
        [jnp.zeros((1,), bool), idx_s[1:] == idx_s[:-1]]
    )
    neg_s = jnp.where(dup, jnp.float32(POS_INF), neg_s)
    idx_s = jnp.where(dup, jnp.int32(jnp.iinfo(jnp.int32).max), idx_s)
    neg_s, idx_s = jax.lax.sort((neg_s, idx_s), num_keys=2)
    return idx_s[:m_cand], -neg_s[m_cand], idx_s[m_cand]
