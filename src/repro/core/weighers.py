"""Modular host weighers (phase 2 of the paper's Alg. 2).

Weighing ALWAYS sees the full host state ``h_f`` — ranking the "costless"
host requires knowing which preemptible instances sit on it (paper §3.1).

Normalization follows OpenStack (paper §4.1):

    Ω(h) = Σ_i  m_i · N(w_i(h)),      N(w) = (w − min W) / (max W − min W)

with N ≡ 0 when all weights are equal.  The best host is the Ω-argmax with
random tie-breaking.

Paper-fidelity note: the paper's prose Alg. 4 (PeriodRank) sums the partial
periods of *all* preemptible instances on a host, but its evaluation
(Table 5: host-A chosen with min-subset cost 55 over host-B's single-instance
cost 58, despite host-A's all-instance sum being 113) shows the implementation
ranked hosts by the *cost of the optimal termination subset* — i.e. Alg. 5's
objective evaluated during weighing.  We provide both: ``PeriodRank`` (the
literal Alg. 4) and ``TerminationCostRank`` (what reproduces Tables 3–6, and
what our PreemptibleScheduler uses by default, sharing its subset computation
with the terminate phase through a plan cache).
"""
from __future__ import annotations

import abc
import dataclasses
from typing import Dict, Optional, Sequence

import numpy as np

from .cost import BILL_PERIOD_S, CostFunction, PeriodCost
from .select_terminate import plan_for_host
from .types import Host, Request


@dataclasses.dataclass
class WeighContext:
    """Shared state for one scheduling call."""

    now: float
    cost_fn: CostFunction
    #: memoized Alg. 5 plans, shared between weighing and termination.
    plan_cache: Dict[tuple, object] = dataclasses.field(default_factory=dict)


class Weigher(abc.ABC):
    name: str = "weigher"
    multiplier: float = 1.0

    @abc.abstractmethod
    def weight(self, req: Request, host: Host, ctx: WeighContext) -> float:
        ...


class OvercommitRank(Weigher):
    """Paper Alg. 3: −1 when placing the request would overcommit ``h_f``
    (i.e. requires terminating preemptible instances), else 0."""

    name = "overcommit"

    def weight(self, req: Request, host: Host, ctx: WeighContext) -> float:
        return -1.0 if not req.resources.fits_in(host.free_full) else 0.0


class PeriodRank(Weigher):
    """Paper Alg. 4, literal: −Σ (run_time mod period) over ALL preemptible
    instances on the host."""

    name = "period"

    def __init__(self, period_s: float = BILL_PERIOD_S):
        self.period_s = float(period_s)

    def weight(self, req: Request, host: Host, ctx: WeighContext) -> float:
        w = 0.0
        for inst in host.preemptible_instances():
            w += inst.run_time(ctx.now) % self.period_s
        return -w


class TerminationCostRank(Weigher):
    """Rank hosts by −(cost of the optimal Alg. 5 termination subset); 0 when
    no termination is needed.  Reproduces the paper's Tables 3–6.  Infeasible
    hosts get −inf (they should already have been filtered out)."""

    name = "termination_cost"

    def weight(self, req: Request, host: Host, ctx: WeighContext) -> float:
        plan = plan_for_host(host, req, ctx.cost_fn, ctx.now, cache=ctx.plan_cache)
        if not plan.feasible:
            return -float("inf")
        return -plan.cost


class PackingRank(Weigher):
    """Prefer fuller hosts (consolidation → fewer preemptions later).
    Weight = −Σ normalized free capacity of ``h_f``."""

    name = "packing"

    def weight(self, req: Request, host: Host, ctx: WeighContext) -> float:
        cap = np.maximum(host.capacity.vec, 1e-9)
        return -float(np.sum(host.free_full.vec / cap))


class StragglerRank(Weigher):
    """TPU adaptation: penalize historically slow hosts (heartbeat-derived
    ``slow_factor``) so synchronous-SPMD jobs avoid stragglers."""

    name = "straggler"

    def weight(self, req: Request, host: Host, ctx: WeighContext) -> float:
        return -float(host.slow_factor)


def normalized_weights(
    weighers: Sequence[Weigher],
    req: Request,
    hosts: Sequence[Host],
    ctx: WeighContext,
) -> np.ndarray:
    """OpenStack-style Ω for each host: Σ m_i · N(w_i(h))."""
    if not hosts:
        return np.zeros(0)
    omega = np.zeros(len(hosts))
    for wg in weighers:
        raw = np.array([wg.weight(req, h, ctx) for h in hosts], dtype=np.float64)
        finite = np.isfinite(raw)
        if not finite.any():
            continue
        lo = raw[finite].min()
        hi = raw[finite].max()
        if hi - lo < 1e-12:
            norm = np.zeros_like(raw)
        else:
            norm = (raw - lo) / (hi - lo)
        norm[~finite] = -np.inf  # infeasible hosts can never win
        omega = omega + wg.multiplier * norm
    return omega


DEFAULT_WEIGHERS: Sequence[Weigher] = (
    OvercommitRank(),
    TerminationCostRank(),
)

WEIGHER_REGISTRY = {
    "overcommit": OvercommitRank,
    "period": PeriodRank,
    "termination_cost": TerminationCostRank,
    "packing": PackingRank,
    "straggler": StragglerRank,
}
