"""Event-driven fleet simulator.

Recreates the paper's experimental conditions at arbitrary scale (its testbed
was 24 nodes; we run the same dynamics at 10^2..10^5 hosts):

* request arrivals (Poisson), exponential lifetimes in [min,max] (the paper
  drew durations 10–300 min from an exponential distribution, §4.4.1);
* normal / preemptible mix;
* voluntary departures, scheduler-driven preemptions;
* utilization / failure / latency / lost-work metrics over time;
* straggler injection (slow hosts) and host failures (fault tolerance).

The simulator is deterministic given a seed.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import time as _time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .cluster import Cluster
from .cost import CostFunction
from .scheduler import BaseScheduler
from .soa_fleet import SoAFleet
from .types import Host, Instance, Request, Resources


@dataclasses.dataclass(order=True)
class _Event:
    time: float
    seq: int
    # arrival|departure|fail_host|heal_host|drain (drain = admission SLO tick)
    kind: str = dataclasses.field(compare=False)
    payload: object = dataclasses.field(compare=False, default=None)


@dataclasses.dataclass
class WorkloadSpec:
    """Synthetic workload mirroring §4.4 plus knobs for scale studies."""

    arrival_rate_per_s: float = 1 / 60.0
    lifetime_min_s: float = 600.0        # 10 min
    lifetime_max_s: float = 18000.0      # 300 min
    lifetime_mean_s: float = 5400.0
    preemptible_fraction: float = 0.5
    flavors: Sequence[Tuple[str, Resources]] = ()
    flavor_probs: Optional[Sequence[float]] = None


@dataclasses.dataclass
class SimMetrics:
    t: List[float] = dataclasses.field(default_factory=list)
    utilization: List[float] = dataclasses.field(default_factory=list)
    utilization_normal: List[float] = dataclasses.field(default_factory=list)
    sched_latency_s: List[float] = dataclasses.field(default_factory=list)
    failures_normal: int = 0
    failures_preemptible: int = 0
    placed_normal: int = 0
    placed_preemptible: int = 0
    preemptions: int = 0
    #: correlated zone-level preemption storms fired / instances they killed
    storms: int = 0
    storm_kills: int = 0
    #: relocation plane (SoAFleet.relocate): passes run, victims moved,
    #: re-placements rejected (victims left running), victims reclaimed
    #: mid-flight (replacement stood as the checkpoint restore)
    relocation_passes: int = 0
    relocations: int = 0
    relocation_failed: int = 0
    relocation_lost: int = 0

    def summary(self) -> Dict[str, float]:
        return {
            "mean_utilization": float(np.mean(self.utilization)) if self.utilization else 0.0,
            "mean_utilization_normal": float(np.mean(self.utilization_normal)) if self.utilization_normal else 0.0,
            "p50_sched_latency_us": float(np.percentile(self.sched_latency_s, 50) * 1e6) if self.sched_latency_s else 0.0,
            "p99_sched_latency_us": float(np.percentile(self.sched_latency_s, 99) * 1e6) if self.sched_latency_s else 0.0,
            "failures_normal": float(self.failures_normal),
            "failures_preemptible": float(self.failures_preemptible),
            "placed_normal": float(self.placed_normal),
            "placed_preemptible": float(self.placed_preemptible),
            "preemptions": float(self.preemptions),
            "storms": float(self.storms),
            "storm_kills": float(self.storm_kills),
            "relocation_passes": float(self.relocation_passes),
            "relocations": float(self.relocations),
            "relocation_failed": float(self.relocation_failed),
            "relocation_lost": float(self.relocation_lost),
        }


class Simulator:
    def __init__(
        self,
        cluster: Cluster,
        scheduler: BaseScheduler,
        workload: WorkloadSpec,
        seed: int = 0,
    ):
        self.cluster = cluster
        self.scheduler = scheduler
        self.workload = workload
        self.rng = np.random.default_rng(seed)
        self.metrics = SimMetrics()
        self._heap: List[_Event] = []
        self._seq = itertools.count()
        self._req_ids = itertools.count()
        self.now = 0.0

    # -- event helpers ----------------------------------------------------------
    def _push(self, t: float, kind: str, payload=None) -> None:
        heapq.heappush(self._heap, _Event(t, next(self._seq), kind, payload))

    def _draw_lifetime(self) -> float:
        w = self.workload
        # exponential, truncated to [min,max] (paper §4.4.1 + Knuth ref)
        for _ in range(64):
            x = self.rng.exponential(w.lifetime_mean_s)
            if w.lifetime_min_s <= x <= w.lifetime_max_s:
                return x
        return float(np.clip(x, w.lifetime_min_s, w.lifetime_max_s))

    def _draw_request(self) -> Request:
        w = self.workload
        names = [f[0] for f in w.flavors]
        probs = w.flavor_probs
        idx = self.rng.choice(len(names), p=probs)
        name, res = w.flavors[idx]
        preempt = bool(self.rng.random() < w.preemptible_fraction)
        return Request(
            id=f"r{next(self._req_ids)}", resources=res, preemptible=preempt
        )

    # -- main loop ----------------------------------------------------------------
    def run(
        self,
        duration_s: float,
        stop_on_normal_failure: bool = False,
        sample_every_s: float = 300.0,
    ) -> SimMetrics:
        self._push(self.rng.exponential(1.0 / self.workload.arrival_rate_per_s), "arrival")
        next_sample = 0.0
        while self._heap:
            ev = heapq.heappop(self._heap)
            if ev.time > duration_s:
                break
            self.now = ev.time
            if self.now >= next_sample:
                self._sample()
                next_sample = self.now + sample_every_s
            if ev.kind == "arrival":
                stop = self._handle_arrival()
                self._push(
                    self.now + self.rng.exponential(1.0 / self.workload.arrival_rate_per_s),
                    "arrival",
                )
                if stop and stop_on_normal_failure:
                    break
            elif ev.kind == "departure":
                inst = ev.payload
                host = self.cluster.hosts[inst.host]
                if inst.id in host.instances:  # may have been preempted already
                    self.cluster.terminate(inst)
            elif ev.kind == "fail_host":
                self._fail_host(ev.payload)
            elif ev.kind == "heal_host":
                self.cluster.hosts[ev.payload].schedulable = True
        self._sample()
        return self.metrics

    def _handle_arrival(self) -> bool:
        """Returns True when a NORMAL request failed (paper's stop signal)."""
        req = self._draw_request()
        t0 = _time.perf_counter()
        result = self.scheduler.schedule(req, self.cluster.host_list(), self.now)
        self.metrics.sched_latency_s.append(_time.perf_counter() - t0)
        preempted_before = self.cluster.stats.preemptions
        inst = self.cluster.apply(result, self.now)
        self.metrics.preemptions += self.cluster.stats.preemptions - preempted_before
        if inst is None:
            if req.preemptible:
                self.metrics.failures_preemptible += 1
            else:
                self.metrics.failures_normal += 1
                return True
            return False
        if req.preemptible:
            self.metrics.placed_preemptible += 1
        else:
            self.metrics.placed_normal += 1
        self._push(self.now + self._draw_lifetime(), "departure", inst)
        return False

    # -- fault injection ------------------------------------------------------------
    def inject_host_failure(self, host_name: str, at_s: float, heal_after_s: float = 0.0):
        self._push(at_s, "fail_host", host_name)
        if heal_after_s:
            self._push(at_s + heal_after_s, "heal_host", host_name)

    def inject_stragglers(self, fraction: float, slow_factor: float = 3.0):
        hosts = self.cluster.host_list()
        n = max(1, int(len(hosts) * fraction))
        for h in self.rng.choice(len(hosts), size=n, replace=False):
            hosts[int(h)].slow_factor = slow_factor

    def _fail_host(self, host_name: str) -> None:
        """Hard host failure: all instances die; preemptible ones re-queue."""
        host = self.cluster.hosts[host_name]
        host.schedulable = False
        for inst in list(host.instances.values()):
            if inst.preemptible:
                self.cluster.preempt(inst, self.now)
            else:
                self.cluster.terminate(inst)

    def _sample(self) -> None:
        self.metrics.t.append(self.now)
        self.metrics.utilization.append(self.cluster.utilization())
        self.metrics.utilization_normal.append(self.cluster.utilization_normal())


class SoASimulator:
    """Fast-path event loop on the incremental device-resident fleet state.

    Same dynamics as ``Simulator`` but instead of handing the scheduler a
    python ``Host`` list per arrival (which triggers an O(N·K) array rebuild),
    it drives the persistent ``SoAFleet``: each event is an O(K·D) on-device
    transition, and runs of consecutive arrivals are batched through one
    jit-compiled ``lax.scan`` (``schedule_many``) so consecutive decisions
    still see each other's placements exactly.  Python ``Host`` objects are
    materialized only on demand (``fleet.sync_hosts()``).  Decision knobs
    ride on one ``SchedulerPolicy`` (``policy=``) — e.g. ``policy.mesh``
    (a 1-D device mesh, see ``fleet_sharding``) shards the fleet state
    host-major across devices and the whole event loop then runs on the
    sharded stage-1 screen, bit-identical to the single-device run; a mixed
    ``policy.cost_kinds`` table bills each instance by its own kind.

    With ``policy.queue_capacity > 0`` the loop runs in **streaming
    admission mode**: arrivals ``submit`` into the fleet's admission front
    end instead of being decided inline, and queue-drain events fire on the
    three triggers of ``core.admission`` — a full ``admit_batch``, the
    ``slo_target_s`` deadline of the oldest waiting arrival, and any
    capacity-freeing event (departure / host failure / heal) while requests
    wait (the backfill path).  Drains dispatch non-blocking
    (double-buffered: the host accumulates the next batch while the device
    decides this one); rejected requests (queue overflow or
    ``max_retries`` exhausted) count as failures, and
    ``metrics.sched_latency_s`` then holds each placement's wall-clock
    admission latency (submit → outcome absorbed).

    Behavioral deltas vs ``Simulator`` (documented, both benign):
      * lifetimes are drawn at arrival time (not on placement success), so
        the rng streams differ once a request fails;
      * with ``stop_on_normal_failure`` the loop stops at the end of the
        batch (or drain) containing the failure, not mid-batch.
    """

    def __init__(
        self,
        hosts,
        workload: WorkloadSpec,
        seed: int = 0,
        cost_fn: Optional[CostFunction] = None,
        k_slots: int = 8,
        batch_max: int = 64,
        policy=None,
    ):
        self.fleet = (
            hosts
            if isinstance(hosts, SoAFleet)
            else SoAFleet(hosts, cost_fn=cost_fn, k_slots=k_slots, policy=policy)
        )
        self.workload = workload
        self.batch_max = batch_max
        self.rng = np.random.default_rng(seed)
        self.metrics = SimMetrics()
        self._heap: List[_Event] = []
        self._seq = itertools.count()
        self._req_ids = itertools.count()
        self.now = 0.0
        #: buffered (arrival_time, request, lifetime) awaiting one scan flush
        self._pending: List[Tuple[float, Request, float]] = []
        self._min_dep = float("inf")
        #: request id → lifetime drawn at arrival (streaming mode: the
        #: departure is scheduled only once the drain places the request)
        self._lifetimes: Dict[str, float] = {}

    # -- event helpers (identical draws to Simulator) -------------------------
    _push = Simulator._push
    _draw_lifetime = Simulator._draw_lifetime
    _draw_request = Simulator._draw_request

    # -- main loop ------------------------------------------------------------
    def run(
        self,
        duration_s: float,
        stop_on_normal_failure: bool = False,
        sample_every_s: float = 300.0,
    ) -> SimMetrics:
        if self.fleet.admission is not None:
            return self._run_streaming(
                duration_s, stop_on_normal_failure, sample_every_s
            )
        self._push(self.rng.exponential(1.0 / self.workload.arrival_rate_per_s), "arrival")
        if self.fleet.policy.relocation_on:
            self._push(self.fleet.policy.relocate_every_s, "relocate")
        next_sample = 0.0
        while self._heap:
            ev = heapq.heappop(self._heap)
            # The buffer must drain before anything that observes or mutates
            # fleet state out of arrival order: a departure/failure event, a
            # departure *generated by a buffered arrival* (min_dep), a sample
            # point, end-of-run, or a full batch.
            if self._pending and (
                ev.kind != "arrival"
                or ev.time > duration_s
                or ev.time >= self._min_dep
                or ev.time >= next_sample
                or len(self._pending) >= self.batch_max
            ):
                heapq.heappush(self._heap, _Event(ev.time, ev.seq, ev.kind, ev.payload))
                failed_normal = self._flush()
                if failed_normal and stop_on_normal_failure:
                    break
                continue
            if ev.time > duration_s:
                break
            self.now = ev.time
            if self.now >= next_sample:
                self._sample()
                next_sample = self.now + sample_every_s
            if ev.kind == "arrival":
                req = self._draw_request()
                lifetime = self._draw_lifetime()
                self._pending.append((self.now, req, lifetime))
                self._min_dep = min(self._min_dep, self.now + lifetime)
                self._push(
                    self.now + self.rng.exponential(1.0 / self.workload.arrival_rate_per_s),
                    "arrival",
                )
            elif ev.kind == "departure":
                self.fleet.depart(self._depart_id(ev.payload), now=self.now)
            elif ev.kind == "fail_host":
                self.fleet.fail_host(ev.payload, now=self.now)
            elif ev.kind == "heal_host":
                self.fleet.heal_host(ev.payload)
            elif ev.kind == "zone_storm":
                zone, kill_frac = ev.payload
                self._zone_storm(zone, kill_frac)
            elif ev.kind == "regime_on":
                self._regime_on(ev.payload)
            elif ev.kind == "relocate":
                self.fleet.relocate(self.now)
                self._push(
                    self.now + self.fleet.policy.relocate_every_s, "relocate"
                )
        if self._pending:
            self._flush()
        self._sample()
        self._fold_relocation_metrics()
        return self.metrics

    def _depart_id(self, iid: str) -> str:
        """Resolve a departure event's id through the relocation chain: a
        relocated instance's scheduled departure reaps its replacement (and
        the replacement's replacement, if it moved again)."""
        relocated = self.fleet.relocated_ids
        while iid in relocated:
            iid = relocated[iid]
        return iid

    def _fold_relocation_metrics(self) -> None:
        rs = self.fleet.relocation
        self.metrics.relocation_passes = rs.passes
        self.metrics.relocations = rs.relocated
        self.metrics.relocation_failed = rs.failed
        self.metrics.relocation_lost = rs.lost_victims

    def _flush(self) -> bool:
        """Run the buffered arrivals through one scan.  Returns True when a
        normal request failed (the paper's stop signal)."""
        items = [(req, t, 1.0) for t, req, _ in self._pending]
        t0 = _time.perf_counter()
        outcomes = self.fleet.schedule_batch(items)
        per_req = (_time.perf_counter() - t0) / len(items)
        failed_normal = False
        for (t, req, lifetime), out in zip(self._pending, outcomes):
            self.metrics.sched_latency_s.append(per_req)
            self.metrics.preemptions += len(out.victims)
            if not out.ok:
                if req.preemptible:
                    self.metrics.failures_preemptible += 1
                else:
                    self.metrics.failures_normal += 1
                    failed_normal = True
                continue
            if req.preemptible:
                self.metrics.placed_preemptible += 1
            else:
                self.metrics.placed_normal += 1
            self._push(t + lifetime, "departure", out.instance.id)
        self._pending.clear()
        self._min_dep = float("inf")
        return failed_normal

    # -- pre-materialized trace replay (core.scan_sim oracle) ------------------
    def run_trace(self, trace, sample_every_s: float = 300.0) -> SimMetrics:
        """Replay an ``EventTrace`` (``core.scan_sim``) through the python
        event loop — the differential oracle the scanned simulator is pinned
        against.  Same flush/sample discipline as ``run``, but events come
        from the trace rows in index order instead of the heap/rng, so the
        two engines process the identical stream.

        Returns ``SimMetrics``; per-arrival outcomes land in
        ``self.trace_outcomes`` as ``(host_idx, slot, ok, n_victims)``
        rows aligned with the trace's arrival rows (-1/-1/False/0 for
        non-arrival rows), mirroring ``ScanResult.host/slot/ok/n_kill``.

        With ``policy.queue_capacity > 0`` the replay runs in streaming
        admission mode (``_run_trace_streaming``): arrivals submit to the
        front end and blocking drains fire on the exact event-boundary
        triggers of the scanned engine — the oracle the in-scan admission
        plane is pinned bit-exact against.
        """
        from . import scan_sim as ss

        fleet = self.fleet
        if fleet.policy.relocation_on:
            raise NotImplementedError(
                "run_trace: the relocation plane rewrites instance ids "
                "mid-trace; run it via SoASimulator.run"
            )
        if fleet.admission is not None:
            return self._run_trace_streaming(trace, sample_every_s)
        e = trace.n_events
        inv_dom = {i: name for name, i in fleet.domain_ids.items()}
        #: arrival row -> live instance id (None = rejected / never placed)
        iids: List[Optional[str]] = [None] * e
        self.trace_outcomes = np.full((e, 4), -1, np.int64)
        self.trace_outcomes[:, 2:] = 0
        pending: List[int] = []  # buffered arrival row indices
        next_sample = 0.0

        def flush() -> None:
            items = []
            for row in pending:
                req = self._trace_request(trace, row, inv_dom)
                items.append((req, float(trace.time[row]), float(trace.price[row])))
            outcomes = fleet.schedule_batch(items)
            for row, out in zip(pending, outcomes):
                self.metrics.preemptions += len(out.victims)
                ok = out.ok
                pre = bool(trace.preemptible[row])
                if ok:
                    iids[row] = out.instance.id
                    h = fleet.index[out.instance.host]
                    s = out.instance.metadata.get("slot", -1)
                    self.trace_outcomes[row] = (h, s, 1, len(out.victims))
                    if pre:
                        self.metrics.placed_preemptible += 1
                    else:
                        self.metrics.placed_normal += 1
                else:
                    self.trace_outcomes[row] = (-1, -1, 0, len(out.victims))
                    if pre:
                        self.metrics.failures_preemptible += 1
                    else:
                        self.metrics.failures_normal += 1
            pending.clear()

        for row in range(e):
            kind = int(trace.kind[row])
            t = float(trace.time[row])
            if pending and (
                kind != ss.ARRIVAL
                or t >= next_sample
                or len(pending) >= self.batch_max
            ):
                flush()
            self.now = t
            if self.now >= next_sample:
                self._sample()
                next_sample = self.now + sample_every_s
            if kind == ss.ARRIVAL:
                pending.append(row)
            elif kind == ss.DEPARTURE:
                iid = iids[int(trace.inst_id[row])]
                if iid is not None:
                    fleet.depart(self._depart_id(iid), now=self.now)
            elif kind == ss.FAIL_HOST:
                fleet.fail_host(fleet.names[int(trace.host[row])], now=self.now)
            elif kind == ss.HEAL_HOST:
                fleet.heal_host(fleet.names[int(trace.host[row])])
            elif kind == ss.CHECKPOINT:
                iid = iids[int(trace.inst_id[row])]
                if iid is not None:
                    fleet.checkpoint(iid, now=self.now)
            elif kind == ss.ZONE_STORM:
                self._trace_storm(
                    int(trace.zone[row]), float(trace.frac[row])
                )
        if pending:
            flush()
        self._sample()
        return self.metrics

    def _trace_request(self, trace, row: int, inv_dom) -> Request:
        from .policy import COST_KINDS

        kind_id = int(trace.cost_kind[row])
        period = float(trace.period[row])
        dom_id = int(trace.domain[row])
        prio = int(trace.priority[row])
        return Request(
            id=f"e{row}",
            resources=Resources(self.fleet.spec, np.asarray(trace.res[row])),
            preemptible=bool(trace.preemptible[row]),
            domain=None if dom_id < 0 else inv_dom[dom_id],
            cost_kind=None if kind_id < 0 else COST_KINDS[kind_id],
            period=None if period <= 0 else period,
            priority=None if prio < 0 else prio,
        )

    def _trace_storm(self, zone_id: int, kill_frac: float) -> int:
        """Deterministic storm used by trace replay (no rng, unlike
        ``_zone_storm``): kill the ``n`` lowest ``(host, slot)``-indexed
        live preemptible slots of the zone, ``n = min(max(1,
        round_f32(count * frac)), count)`` — the exact rule the scanned
        simulator's storm branch computes on device."""
        fleet = self.fleet
        victims = sorted(
            (h, slot, iid)
            for iid, (h, slot) in fleet.locator.items()
            if slot is not None and fleet.zone_ids[fleet.zones[h]] == zone_id
        )
        self.metrics.storms += 1
        if not victims:
            return 0
        n = min(
            max(1, int(np.round(np.float32(len(victims)) * np.float32(kill_frac)))),
            len(victims),
        )
        killed = 0
        for h, slot, iid in victims[:n]:
            killed += bool(fleet.preempt_instance(iid, now=self.now))
        self.metrics.storm_kills += killed
        return killed

    # -- pre-materialized trace replay, streaming admission mode ---------------
    def _run_trace_streaming(self, trace, sample_every_s: float) -> SimMetrics:
        """Streaming-mode trace replay: the python oracle for the scanned
        simulator's in-carry admission plane.

        Every drain is BLOCKING and fires at an event boundary on the exact
        triggers the scan compiles: (1) before the event, when the incoming
        timestamp crosses the oldest waiting arrival's f32 SLO deadline (at
        most one per boundary); (2) after an arrival, when a full
        ``admit_batch`` waits; (3) after any capacity-freeing event
        (departure / fail / heal / storm) while anything waits.  Placements
        book under the request's EFFECTIVE (post-degradation) preemptible
        flag, rejections under the ORIGINAL trace flag — matching the
        scanned carry's counters bitwise.
        """
        from . import scan_sim as ss

        fleet = self.fleet
        front = fleet.admission
        policy = fleet.policy
        e = trace.n_events
        inv_dom = {i: name for name, i in fleet.domain_ids.items()}
        iids: List[Optional[str]] = [None] * e
        self.trace_outcomes = np.full((e, 4), -1, np.int64)
        self.trace_outcomes[:, 2:] = 0
        next_sample = 0.0
        slo32 = np.float32(policy.slo_target_s)

        def handle(dr) -> None:
            for out in dr.outcomes:
                req = out.request
                row = int(req.id[1:])
                self.metrics.preemptions += len(out.victims)
                iids[row] = out.instance.id
                h = fleet.index[out.instance.host]
                s = out.instance.metadata.get("slot", -1)
                self.trace_outcomes[row] = (h, s, 1, len(out.victims))
                if req.preemptible:  # effective flag (degradation demotes)
                    self.metrics.placed_preemptible += 1
                else:
                    self.metrics.placed_normal += 1
            for req in dr.rejected:
                row = int(req.id[1:])
                if bool(trace.preemptible[row]):  # original flag
                    self.metrics.failures_preemptible += 1
                else:
                    self.metrics.failures_normal += 1

        for row in range(e):
            kind = int(trace.kind[row])
            t = float(trace.time[row])
            self.now = t
            if self.now >= next_sample:
                self._sample()
                next_sample = self.now + sample_every_s
            oldest = front.oldest_enq_t()
            if oldest is not None and np.float32(t) >= np.float32(oldest) + slo32:
                handle(front.drain(self.now, block=True))
            if kind == ss.ARRIVAL:
                front.submit(
                    self._trace_request(trace, row, inv_dom), self.now,
                    price=float(trace.price[row]),
                )
                if front.waiting >= policy.admit_batch:
                    handle(front.drain(self.now, block=True))
            elif kind == ss.DEPARTURE:
                iid = iids[int(trace.inst_id[row])]
                if iid is not None:
                    fleet.depart(self._depart_id(iid), now=self.now)
                if front.waiting:
                    handle(front.drain(self.now, block=True))
            elif kind == ss.FAIL_HOST:
                fleet.fail_host(fleet.names[int(trace.host[row])], now=self.now)
                if front.waiting:
                    handle(front.drain(self.now, block=True))
            elif kind == ss.HEAL_HOST:
                fleet.heal_host(fleet.names[int(trace.host[row])])
                if front.waiting:
                    handle(front.drain(self.now, block=True))
            elif kind == ss.CHECKPOINT:
                iid = iids[int(trace.inst_id[row])]
                if iid is not None:
                    fleet.checkpoint(iid, now=self.now)
            elif kind == ss.ZONE_STORM:
                self._trace_storm(int(trace.zone[row]), float(trace.frac[row]))
                if front.waiting:
                    handle(front.drain(self.now, block=True))
        for dr in front.drain_all(self.now):
            handle(dr)
        self._sample()
        return self.metrics

    # -- streaming admission mode (policy.queue_capacity > 0) ------------------
    def _run_streaming(
        self,
        duration_s: float,
        stop_on_normal_failure: bool,
        sample_every_s: float,
    ) -> SimMetrics:
        front = self.fleet.admission
        self._push(self.rng.exponential(1.0 / self.workload.arrival_rate_per_s), "arrival")
        if self.fleet.policy.relocation_on:
            self._push(self.fleet.policy.relocate_every_s, "relocate")
        next_sample = 0.0
        while self._heap:
            ev = heapq.heappop(self._heap)
            if ev.time > duration_s:
                break
            self.now = ev.time
            if self.now >= next_sample:
                front.sync()  # mirror current before observing state
                self._sample()
                next_sample = self.now + sample_every_s
            if ev.kind == "arrival":
                req = self._draw_request()
                self._lifetimes[req.id] = self._draw_lifetime()
                front.submit(req, self.now)
                # SLO tick: by this time the arrival must have been drained
                self._push(self.now + front.policy.slo_target_s, "drain")
                self._push(
                    self.now + self.rng.exponential(1.0 / self.workload.arrival_rate_per_s),
                    "arrival",
                )
                if front.batch_ready():
                    front.drain(self.now, block=False)
            elif ev.kind == "drain":
                deadline = front.next_deadline()
                if deadline is not None and deadline <= self.now + 1e-9:
                    front.drain(self.now, block=False)
            elif ev.kind == "departure":
                front.sync()  # instance ids must exist in the mirror
                self.fleet.depart(self._depart_id(ev.payload), now=self.now)
                if front.waiting:  # backfill the freed capacity
                    front.drain(self.now, block=False)
            elif ev.kind == "fail_host":
                front.sync()
                self.fleet.fail_host(ev.payload, now=self.now)
                if front.waiting:
                    front.drain(self.now, block=False)
            elif ev.kind == "heal_host":
                self.fleet.heal_host(ev.payload)
                if front.waiting:
                    front.drain(self.now, block=False)
            elif ev.kind == "zone_storm":
                front.sync()  # mirror must be current before mass preemption
                zone, kill_frac = ev.payload
                self._zone_storm(zone, kill_frac)
                if front.waiting:  # storms free capacity → backfill
                    front.drain(self.now, block=False)
            elif ev.kind == "regime_on":
                self._regime_on(ev.payload)
            elif ev.kind == "relocate":
                front.sync()  # mirror must be current for victim selection
                self.fleet.relocate(self.now)
                self._push(
                    self.now + self.fleet.policy.relocate_every_s, "relocate"
                )
                if front.waiting:  # dispatch the queued re-placements
                    front.drain(self.now, block=False)
            failed_normal = self._handle_drain_results(front.take_results())
            if failed_normal and stop_on_normal_failure:
                break
        # end-of-run epilogue: every still-waiting request gets its retries.
        # drain_all's blocking drains return their results directly; any
        # still-in-flight async drain got banked by its first sync() —
        # take_results() first keeps the fold chronological.
        epilogue = front.drain_all(self.now)
        self._handle_drain_results(front.take_results() + epilogue)
        self._sample()
        # in streaming mode the honest per-request latency is the wall-clock
        # admission latency (submit → outcome absorbed), not a per-flush mean
        self.metrics.sched_latency_s = list(front.stats.wall_wait_s)
        self._fold_relocation_metrics()
        return self.metrics

    def _handle_drain_results(self, results) -> bool:
        """Fold absorbed drain results into metrics + departure events.
        Returns True when a normal request was rejected (stop signal)."""
        failed_normal = False
        for dr in results:
            for out in dr.outcomes:
                req = out.request
                if "relocation" in req.metadata:
                    # settled by the relocation plane; the moved instance
                    # keeps its original departure event via relocated_ids
                    continue
                self.metrics.preemptions += len(out.victims)
                if req.preemptible:
                    self.metrics.placed_preemptible += 1
                else:
                    self.metrics.placed_normal += 1
                lifetime = self._lifetimes.pop(req.id, None)
                if lifetime is not None:
                    self._push(dr.now + lifetime, "departure", out.instance.id)
            for req in dr.rejected:
                if "relocation" in req.metadata:
                    continue  # never-worse: victim stays; not a sim failure
                self._lifetimes.pop(req.id, None)
                if req.preemptible:
                    self.metrics.failures_preemptible += 1
                else:
                    self.metrics.failures_normal += 1
                    failed_normal = True
        return failed_normal

    # -- fault injection -------------------------------------------------------
    def inject_host_failure(self, host_name: str, at_s: float, heal_after_s: float = 0.0):
        self._push(at_s, "fail_host", host_name)
        if heal_after_s:
            self._push(at_s + heal_after_s, "heal_host", host_name)

    def inject_stragglers(self, fraction: float, slow_factor: float = 3.0):
        n = max(1, int(self.fleet.n_hosts * fraction))
        for h in self.rng.choice(self.fleet.n_hosts, size=n, replace=False):
            self.fleet.set_slow(self.fleet.names[int(h)], slow_factor)

    def inject_zone_storm(
        self, zone: str, at_s: float, kill_frac: float = 1.0
    ) -> None:
        """Schedule one correlated preemption storm: at ``at_s`` a seeded
        ``kill_frac`` of the zone's live preemptible instances are reclaimed
        at once (``SoAFleet.preempt_instance``), charging the zone's churn
        accumulators — the spot-market reclaim wave the churn weigher and
        the admission plane's graceful degradation are built to ride out."""
        if zone not in self.fleet.zone_ids:
            raise ValueError(
                f"unknown zone {zone!r}; fleet zones: "
                f"{sorted(self.fleet.zone_ids)}"
            )
        if not 0.0 < kill_frac <= 1.0:
            raise ValueError(f"kill_frac must be in (0, 1], got {kill_frac}")
        self._push(at_s, "zone_storm", (zone, float(kill_frac)))

    def inject_churn_regime(
        self,
        zone: str,
        until_s: float,
        mean_on_s: float = 600.0,
        mean_off_s: float = 3600.0,
        storm_every_s: float = 120.0,
        kill_frac: float = 0.25,
        start_s: float = 0.0,
    ) -> None:
        """Markov on/off churn regime for one zone: the zone alternates
        between a calm phase (exponential, mean ``mean_off_s``) and a stormy
        phase (exponential, mean ``mean_on_s``) during which a
        ``kill_frac`` reclaim wave fires every ``storm_every_s`` — the
        bursty, time-correlated preemption process real spot markets show,
        as opposed to the i.i.d. per-instance reclaims of
        ``inject_host_failure``.  Deterministic given the simulator seed."""
        if zone not in self.fleet.zone_ids:
            raise ValueError(
                f"unknown zone {zone!r}; fleet zones: "
                f"{sorted(self.fleet.zone_ids)}"
            )
        payload = {
            "zone": zone,
            "until_s": float(until_s),
            "mean_on_s": float(mean_on_s),
            "mean_off_s": float(mean_off_s),
            "storm_every_s": float(storm_every_s),
            "kill_frac": float(kill_frac),
        }
        self._push(
            start_s + self.rng.exponential(payload["mean_off_s"]),
            "regime_on", payload,
        )

    def _regime_on(self, payload: Dict[str, float]) -> None:
        """Enter one stormy phase: lay down its storm ticks, then schedule
        the next phase after a calm gap."""
        if self.now >= payload["until_s"]:
            return
        end = min(
            self.now + self.rng.exponential(payload["mean_on_s"]),
            payload["until_s"],
        )
        t = self.now
        while t < end:
            self._push(t, "zone_storm", (payload["zone"], payload["kill_frac"]))
            t += payload["storm_every_s"]
        nxt = end + self.rng.exponential(payload["mean_off_s"])
        if nxt < payload["until_s"]:
            self._push(nxt, "regime_on", payload)

    def _zone_storm(self, zone: str, kill_frac: float) -> int:
        """Reclaim a seeded ``kill_frac`` of the zone's live preemptible
        instances right now.  Returns the kill count."""
        fleet = self.fleet
        victims = sorted(
            iid
            for iid, (h, slot) in fleet.locator.items()
            if slot is not None and fleet.zones[h] == zone
        )
        self.metrics.storms += 1
        if not victims:
            return 0
        n = max(1, int(round(len(victims) * kill_frac)))
        picks = self.rng.choice(len(victims), size=min(n, len(victims)), replace=False)
        killed = 0
        for i in np.sort(picks):
            killed += bool(fleet.preempt_instance(victims[int(i)], now=self.now))
        self.metrics.storm_kills += killed
        return killed

    def _sample(self) -> None:
        self.metrics.t.append(self.now)
        self.metrics.utilization.append(self.fleet.utilization())
        self.metrics.utilization_normal.append(self.fleet.utilization_normal())
