"""Select-and-terminate (paper Alg. 5): pick the cost-minimal feasible subset
of preemptible instances on a host.

Feasibility note (fidelity): the paper's *pseudocode* tests
``sum(instances.resources) > req.resources`` — ignoring the host's existing
free resources and using a strict inequality.  Its *evaluation* (Table 6:
terminating only BP3, a small instance, to admit a medium request on a host
with one small slot already free) shows the implementation actually tests

    free_full + sum(freed) >= req.resources        (component-wise)

which is what we implement.  See DESIGN.md §Paper-fidelity.

Complexity: exact enumeration is O(2^K) over the K preemptible instances on
one host.  K is small in practice (the paper's testbed: ≤4); we enumerate
exactly up to ``exact_k`` and fall back to a greedy + prune heuristic above
it.  The JAX path (core/jax_scheduler.py) evaluates all 2^K masks as one
vectorized program.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

from .cost import CostFunction
from .types import (
    EMPTY_PLAN,
    INFEASIBLE_PLAN,
    Host,
    Instance,
    Request,
    TerminationPlan,
)

DEFAULT_EXACT_K = 16


def best_plan(
    host: Host,
    req: Request,
    cost_fn: CostFunction,
    now: float,
    exact_k: int = DEFAULT_EXACT_K,
) -> TerminationPlan:
    """Return the cost-minimal feasible termination plan for ``req`` on
    ``host`` (EMPTY_PLAN when no termination is needed)."""
    free = host.free_full
    if req.resources.fits_in(free):
        return EMPTY_PLAN

    preemptible = sorted(host.preemptible_instances(), key=lambda i: i.id)
    if not preemptible:
        return INFEASIBLE_PLAN

    deficit = req.resources - free  # what termination must cover (>= 0 dims matter)
    need = np.maximum(deficit.vec, 0.0)

    if len(preemptible) <= exact_k:
        return _exact(preemptible, need, cost_fn, now)
    return _greedy(preemptible, need, cost_fn, now)


def _exact(
    insts: Sequence[Instance],
    need: np.ndarray,
    cost_fn: CostFunction,
    now: float,
) -> TerminationPlan:
    k = len(insts)
    res = np.stack([i.resources.vec for i in insts])  # (K, D)
    best_cost = float("inf")
    best_mask = None
    best_size = k + 1
    # Enumerate all non-empty subsets; vectorize the feasibility test in
    # blocks to keep this fast for K up to 16 (65536 subsets).
    masks = np.arange(1, 1 << k, dtype=np.uint32)
    bits = ((masks[:, None] >> np.arange(k)[None, :]) & 1).astype(np.float64)  # (M, K)
    freed = bits @ res  # (M, D)
    feasible = np.all(freed >= need[None, :] - 1e-9, axis=1)
    for m in np.nonzero(feasible)[0]:
        sel = [insts[j] for j in range(k) if bits[m, j]]
        c = cost_fn.cost(sel, now)
        size = len(sel)
        if c < best_cost - 1e-12 or (abs(c - best_cost) <= 1e-12 and size < best_size):
            best_cost, best_mask, best_size = c, m, size
    if best_mask is None:
        return INFEASIBLE_PLAN
    chosen = tuple(insts[j] for j in range(k) if bits[best_mask, j])
    return TerminationPlan(instances=chosen, cost=best_cost, feasible=True)


def _greedy(
    insts: Sequence[Instance],
    need: np.ndarray,
    cost_fn: CostFunction,
    now: float,
) -> TerminationPlan:
    """Greedy fallback: repeatedly take the instance with the lowest
    cost-per-unit-of-deficit-covered, then prune redundant members."""
    remaining = list(insts)
    chosen: List[Instance] = []
    deficit = need.copy()
    while np.any(deficit > 1e-9):
        if not remaining:
            return INFEASIBLE_PLAN

        def score(i: Instance) -> float:
            covered = float(np.sum(np.minimum(i.resources.vec, deficit)))
            c = cost_fn.cost([i], now)
            return c / covered if covered > 1e-9 else float("inf")

        remaining.sort(key=score)
        nxt = remaining.pop(0)
        if not np.any(np.minimum(nxt.resources.vec, deficit) > 1e-9):
            continue  # covers nothing useful
        chosen.append(nxt)
        deficit = np.maximum(deficit - nxt.resources.vec, 0.0)

    # prune: drop members whose removal keeps the plan feasible (cheapest-first)
    chosen.sort(key=lambda i: -cost_fn.cost([i], now))
    pruned = list(chosen)
    for cand in list(pruned):
        rest = [i for i in pruned if i is not cand]
        freed = np.sum([i.resources.vec for i in rest], axis=0) if rest else 0.0
        if rest and np.all(freed >= need - 1e-9):
            pruned = rest
    return TerminationPlan(
        instances=tuple(sorted(pruned, key=lambda i: i.id)),
        cost=cost_fn.cost(pruned, now),
        feasible=True,
    )


def plan_for_host(
    host: Host,
    req: Request,
    cost_fn: CostFunction,
    now: float,
    cache: Optional[dict] = None,
    exact_k: int = DEFAULT_EXACT_K,
) -> TerminationPlan:
    """Memoized ``best_plan`` — the weighing phase and the terminate phase of
    one scheduling call share plans (single-pass efficiency; see DESIGN.md)."""
    if cache is None:
        return best_plan(host, req, cost_fn, now, exact_k)
    key = (host.name, req.id)
    if key not in cache:
        cache[key] = best_plan(host, req, cost_fn, now, exact_k)
    return cache[key]
