"""Preemption protocol — the TPU adaptation of the paper's 'Terminate'.

In the paper, terminating a VM is a kill.  A preemptible *training job*
carries state, so `repro` turns termination into a two-phase protocol
(mirroring GCE's preemption notice):

    1. PREEMPT(job, deadline)  — scheduler decision; controller signals job.
    2. the job drains its in-flight step, writes an async checkpoint,
       acks DRAINED; past the deadline the controller hard-kills (spot
       semantics) and the job loses work since its last periodic checkpoint.
    3. the instance is evacuated; the job is re-queued (elastic: it may
       resume later on a different slice shape).

The controller is transport-agnostic: in-process here, gRPC/etcd in a real
deployment.  Everything is synchronous & deterministic for testability.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Callable, Dict, List, Optional, Protocol

from .types import Instance


class PreemptAck(enum.Enum):
    DRAINED = "drained"          # checkpoint written before deadline
    HARD_KILLED = "hard_killed"  # deadline exceeded; work since last ckpt lost


class PreemptibleJob(Protocol):
    """What a running job must expose to the controller."""

    job_id: str

    def on_preempt(self, now: float, deadline: float) -> PreemptAck:
        """Drain + checkpoint.  Return DRAINED if finished by ``deadline``."""
        ...


@dataclasses.dataclass
class PreemptionRecord:
    instance_id: str
    job_id: str
    time: float
    ack: PreemptAck
    #: seconds of training lost (0 when drained in time).
    lost_work_s: float


class PreemptionController:
    """Routes scheduler preemption decisions to job runtimes.

    Registered as a ``Cluster.preempt_hooks`` member: every evacuation decided
    by the scheduler flows through ``__call__`` before the instance is removed
    from its host.
    """

    def __init__(self, notice_s: float = 30.0):
        #: the preemption notice window (GCE gives 30 s).
        self.notice_s = notice_s
        self._jobs: Dict[str, PreemptibleJob] = {}
        self.records: List[PreemptionRecord] = []

    # -- registry -------------------------------------------------------------
    def register(self, instance_id: str, job: PreemptibleJob) -> None:
        self._jobs[instance_id] = job

    def unregister(self, instance_id: str) -> None:
        self._jobs.pop(instance_id, None)

    # -- Cluster hook ----------------------------------------------------------
    def __call__(self, inst: Instance, now: float) -> None:
        job = self._jobs.pop(inst.id, None)
        if job is None:
            # Stateless instance (serving replica): nothing to drain.
            self.records.append(
                PreemptionRecord(inst.id, "-", now, PreemptAck.DRAINED, 0.0)
            )
            return
        deadline = now + self.notice_s
        ack = job.on_preempt(now, deadline)
        if ack is PreemptAck.DRAINED:
            lost = 0.0
            inst.last_checkpoint = now
        else:
            anchor = inst.last_checkpoint if inst.last_checkpoint is not None else inst.start_time
            lost = max(0.0, now - anchor)
        self.records.append(
            PreemptionRecord(inst.id, job.job_id, now, ack, lost)
        )

    # -- metrics ---------------------------------------------------------------
    @property
    def total_lost_work_s(self) -> float:
        return sum(r.lost_work_s for r in self.records)

    @property
    def drain_rate(self) -> float:
        if not self.records:
            return 1.0
        drained = sum(1 for r in self.records if r.ack is PreemptAck.DRAINED)
        return drained / len(self.records)
