"""Python-side mirror of the persistent device-resident fleet state.

``SoAFleet`` owns a ``SoAFleetState`` (the arrays the jit'd scheduler reads
and writes incrementally) plus the minimal python bookkeeping the arrays
cannot carry: instance identities, the slot ↔ instance-id map, and the
records needed to materialize ``Host`` objects again.  Every mutation goes
through the pure jnp transitions in ``jax_scheduler`` — the arrays are never
rebuilt from python objects on the hot path (that rebuild, ``build_fleet_state``,
remains the correctness oracle; see tests/test_soa_incremental.py).

Sync discipline: per-event work touches only O(K) scalars (the decision
outputs); full python ``Host`` objects are materialized only on demand
(``sync_hosts`` — e.g. at simulator sample points or for verification).
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
from typing import Dict, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .admission import AdmissionFrontEnd, DrainResult, PAD_RES
from .cost import CostFunction
from .jax_scheduler import (
    DEFAULT_SHORTLIST,
    SoAFleetState,
    apply_checkpoint,
    apply_departure,
    apply_host_failure,
    apply_termination,
    build_fleet_state,
    jax_cost_params,  # noqa: F401  (back-compat re-export)
    relocate_many,
    schedule_many,
    schedule_step,
    set_schedulable,
    set_slow_factor,
)
from .policy import (
    COST_KIND_IDS,
    SchedulerPolicy,
    ensure_policy,
)
from .screen_math import NEG_INF, churn_stats, floor_mod
from .types import Host, Instance, Request, Resources

#: Padding sentinel for batched scheduling: a request no host can fit
#: (shared with the admission drain's untaken rows).
_PAD_RES = PAD_RES


@dataclasses.dataclass
class AdaptiveShortlist:
    """Host-side shortlist-size controller over the jit'd decision paths.

    The stage-2 shortlist size M is a compile-time constant of the decision
    executables, so the controller adapts *between* calls on the python side
    using the health signals every step/batch already returns
    (``fell_back``, ``margin`` — see ``jax_scheduler.schedule_many``):

      * grow (×2 up to ``m_max``) after ``grow_after`` consecutive flushes
        that contained an admissibility fallback — the shortlist was too
        small to certify its winner and the decision paid the full O(N·2^K)
        enumeration;
      * shrink (÷2 down to ``m_min``) after ``shrink_after`` consecutive
        fallback-free flushes whose smallest admissibility margin stayed
        above ``wide_margin`` (weigher-score units; the default multipliers
        put one weigher term in [0, 1], so 0.25 is "a quarter of a term of
        headroom beyond every non-shortlisted bound").

    M stays a power of two in [m_min, m_max] (``SchedulerPolicy.
    adaptive_bounds``, validated at construction), so the jit cache holds at
    most log2(m_max/m_min)+1 decision executables per request shape.

    Defaults (grow_after=2, shrink_after=8, wide_margin=0.25) come from the
    ``screen_adaptive_*`` workload study in benchmarks/bench_screen.py
    (rows in benchmarks/results/BENCH_screen.json), which sweeps the
    thresholds over two extreme synthetic fleets at N=4096:

      * *fallback-heavy* (every host's stage-1 bound undershoots, so small
        M can never certify a winner): grow_after ≤ 2 escapes the fallback
        storm within two flushes — 29/104 decisions fell back before the
        controller reached an M that certifies, then zero after — while
        grow_after=4 never grew within a 100-decision horizon and kept
        paying the full O(N·2^K) enumeration;
      * *calm sparse-feasibility* (the whole viable pool fits in the
        shortlist, margins effectively infinite): shrink_after=8 steps M
        down steadily (64→32 over ~100 decisions) without thrash, while
        shrink_after=4 reaches the floor twice as fast but — like
        grow_after=1 — pays a fresh XLA compile per M move (~35 ms/flush
        amortized on the study box vs ~1 ms at the defaults), which is the
        real cost of a twitchy controller.

    CPU caveat: XLA CPU rewrites ``lax.top_k`` to its fast TopK custom-call
    only for k ≤ 64, so on CPU backends growing past M=64 adds a full fleet
    sort (~22 ms at N=65536) on top of the larger stage 2 — the growth path
    really pays off on TPU (fused screen) or when fallbacks are burning far
    more than the sort.
    """

    m: int = DEFAULT_SHORTLIST
    m_min: int = 16
    m_max: int = 256
    grow_after: int = 2
    shrink_after: int = 8
    wide_margin: float = 0.25
    #: counters (exposed via ``SoAFleet.shortlist_stats``)
    grows: int = 0
    shrinks: int = 0
    _fallback_streak: int = dataclasses.field(default=0, repr=False)
    _calm_streak: int = dataclasses.field(default=0, repr=False)

    def update(self, n_fallbacks: int, min_margin: float) -> None:
        """Fold one flush's signals; possibly step M."""
        if n_fallbacks > 0:
            self._fallback_streak += 1
            self._calm_streak = 0
            if self._fallback_streak >= self.grow_after and self.m < self.m_max:
                self.m = min(self.m * 2, self.m_max)
                self.grows += 1
                self._fallback_streak = 0
        else:
            self._fallback_streak = 0
            self._calm_streak += 1
            if (
                self._calm_streak >= self.shrink_after
                and min_margin > self.wide_margin
                and self.m > self.m_min
            ):
                self.m = max(self.m // 2, self.m_min)
                self.shrinks += 1
                self._calm_streak = 0


@dataclasses.dataclass(frozen=True)
class SoAOutcome:
    """One decision of the fast path, translated back to python identities."""

    request: Request
    host: Optional[str]                  # None = failed
    instance: Optional[Instance]         # the placed record
    victims: Tuple[Instance, ...] = ()   # evacuated preemptible instances

    @property
    def ok(self) -> bool:
        return self.host is not None


#: one jit'd program behind every host-side churn read (see churn_snapshot)
_churn_stats_jit = jax.jit(churn_stats)


@functools.partial(jax.jit, static_argnames=("budget",))
def _relocation_victims(state, zone, now, default_period, budget: int):
    """Checkpoint-aware victim selection on device: rank ``zone``'s live
    preemptible slots by the loss a reclaim would cause RIGHT NOW —
    recompute work since the last durable checkpoint (the RecomputeCost
    convention: lost seconds × chips, dim 0) plus the remaining prepaid
    billing period (per-slot ``inst_period``; -1 sentinel = the policy's
    shared ``default_period``) — and return the at-most-``budget``
    highest-loss slots, ties by lowest flat index (``lax.top_k``).

    Returns ``(host (B,), slot (B,), valid (B,))``; rows with
    ``valid=False`` gathered a dead/foreign slot (fewer live slots in the
    zone than the budget) and must be skipped.
    """
    live = state.inst_valid & (state.host_zone[:, None] == zone)
    recompute = jnp.maximum(0.0, now - state.inst_ckpt) * jnp.maximum(
        1.0, state.inst_res[..., 0]
    )
    period = jnp.where(
        state.inst_period > 0, state.inst_period, default_period
    )
    remaining = period - floor_mod(now - state.inst_start, period)
    loss = jnp.where(live, recompute + remaining, NEG_INF)
    k = state.inst_valid.shape[1]
    top, idx = jax.lax.top_k(loss.reshape(-1), budget)
    return idx // k, idx % k, top > NEG_INF / 2


@dataclasses.dataclass
class _ZoneReloc:
    """Per-zone hysteresis + retry record of the relocation plane.

    ``armed`` flips on when ẑ crosses ``policy.relocate_threshold`` (and
    the cooldown has expired) and off when ẑ falls below the lower
    ``relocate_exit_threshold`` — the two-threshold hysteresis that keeps
    an oscillating zone from thrashing.  ``retry_at`` is the exponential
    backoff gate failed re-placements push forward."""

    armed: bool = False
    cooldown_until: float = float("-inf")
    fail_streak: int = 0
    retry_at: float = float("-inf")


@dataclasses.dataclass
class RelocationStats:
    """Host-side counters of the relocation plane (one per fleet).

    Conservation: every ``attempted`` victim ends in exactly one of
    ``relocated`` (moved; victim departed voluntarily after its replacement
    placed), ``failed`` (re-placement rejected; victim untouched),
    ``lost_victims`` (reclaimed mid-flight; the replacement stands as the
    checkpoint restore), ``stale`` (victim departed on its own mid-flight;
    the surplus replacement departed immediately), or ``pending`` (still
    in the admission queue)."""

    passes: int = 0
    arms: int = 0
    disarms: int = 0
    attempted: int = 0
    relocated: int = 0
    failed: int = 0
    lost_victims: int = 0
    stale: int = 0
    pending: int = 0

    def summary(self) -> Dict[str, float]:
        return {
            "relocation_passes": float(self.passes),
            "relocation_arms": float(self.arms),
            "relocation_disarms": float(self.disarms),
            "relocation_attempted": float(self.attempted),
            "relocations": float(self.relocated),
            "relocation_failed": float(self.failed),
            "relocation_lost": float(self.lost_victims),
            "relocation_stale": float(self.stale),
            "relocation_pending": float(self.pending),
        }


class SoAFleet:
    """Incremental fleet view: device arrays + id bookkeeping.

    All decision knobs live on ONE ``SchedulerPolicy`` (``core.policy``)
    threaded straight through to ``jax_scheduler`` as the single static jit
    argument.  The execution knobs (``shortlist``, ``fused_screen``,
    ``mesh``, ``use_pallas``, ``adaptive_shortlist``) select *which path
    computes the answer*, never the answer itself; the weigher multipliers
    and the cost-kind table define the provider policy proper.  A mixed
    cost table (``policy.cost_kinds`` non-empty / ``cost_fn=MixedCost``)
    bills each instance by its own ``cost_kind`` via the state's
    ``inst_cost_kind`` column.

    ``policy.mesh`` pads the state (``fleet_sharding.padded_hosts``) and
    places it across the mesh at build; stage 1 then runs per shard under
    ``shard_map`` with a bit-exact cross-shard merge.

    With ``policy.queue_capacity > 0`` the fleet additionally carries a
    streaming admission front end (``core.admission``): arrivals go through
    ``submit`` (admit-or-queue) and decisions happen at ``drain`` time in
    priority order with backfill retries; the direct entry points
    (``schedule_request``/``schedule_batch``) stay available and bypass the
    queue.
    """

    def __init__(
        self,
        hosts: Sequence[Host],
        cost_fn: Optional[CostFunction] = None,
        k_slots: int = 8,
        policy: Optional[SchedulerPolicy] = None,
    ):
        self.policy = ensure_policy(policy, "SoAFleet", cost_fn=cost_fn)
        self.cost_fn = cost_fn or self.policy.make_cost_fn()
        self.k_slots = k_slots
        #: optional host-side controller steering M between flushes
        #: (bounds + starting M from the policy).
        self.adaptive: Optional[AdaptiveShortlist] = (
            AdaptiveShortlist(
                m=(
                    DEFAULT_SHORTLIST
                    if self.policy.shortlist is None
                    else self.policy.shortlist
                ),
                m_min=self.policy.adaptive_bounds[0],
                m_max=self.policy.adaptive_bounds[1],
            )
            if self.policy.adaptive_shortlist
            else None
        )
        #: admissibility-fallback totals (every flush, adaptive or not)
        self.decisions = 0
        self.fallbacks = 0

        self.names: List[str] = [h.name for h in hosts]
        self.index: Dict[str, int] = {n: i for i, n in enumerate(self.names)}
        self.capacity: List[Resources] = [h.capacity for h in hosts]
        self.spec = hosts[0].capacity.spec if hosts else None
        self.domains: List[str] = [h.domain for h in hosts]
        self.domain_ids: Dict[str, int] = {}
        for h in hosts:
            self.domain_ids.setdefault(h.domain, len(self.domain_ids))
        #: failure-domain (zone) plane: zone label per host + insertion-order
        #: zone ids, mirroring the state's ``host_zone`` column and the
        #: per-zone churn accumulators (``zone_term``/``zone_up``).
        self.zones: List[str] = [h.zone for h in hosts]
        self.zone_ids: Dict[str, int] = {}
        for h in hosts:
            self.zone_ids.setdefault(h.zone, len(self.zone_ids))

        # Mixed-payment fleets must declare every kind they bill: an
        # instance carrying a kind outside the policy table is a
        # configuration error, caught here instead of mid-decision.
        table = self.policy.kind_table
        for h in hosts:
            for inst in h.instances.values():
                if inst.cost_kind is not None and inst.cost_kind not in table:
                    raise ValueError(
                        f"instance {inst.id} bills by {inst.cost_kind!r}, "
                        f"not in the policy's cost-kind table {table}"
                    )

        self.state, slot_rows = build_fleet_state(
            hosts, k_slots=k_slots, domain_ids=self.domain_ids,
            zone_ids=self.zone_ids,
        )
        if self.policy.mesh is not None:
            # Pad to a shard-divisible host count that leaves every shard
            # room for the largest shortlist this fleet can run (the
            # adaptive ceiling when the controller is on), then place the
            # arrays host-major across the mesh.  Padding rows are invalid
            # everywhere, so decisions are unchanged (tests/test_sharded_parity).
            from .fleet_sharding import (
                pad_fleet_state, padded_hosts_for, shard_fleet_state,
            )

            self.state = shard_fleet_state(
                pad_fleet_state(
                    self.state, padded_hosts_for(len(hosts), self.policy)
                ),
                self.policy.mesh,
            )
        #: slot → live preemptible instance id (None = free slot)
        self.slot_ids: List[List[Optional[str]]] = [
            [inst.id if inst is not None else None for inst in row]
            for row in slot_rows
        ]
        #: all live instances, including normal ones
        self.instances: Dict[str, Instance] = {}
        #: id → (host_idx, slot) — slot None for normal instances
        self.locator: Dict[str, Tuple[int, Optional[int]]] = {}
        for i, h in enumerate(hosts):
            for inst in h.instances.values():
                self.instances[inst.id] = inst
                slot = (
                    self.slot_ids[i].index(inst.id) if inst.preemptible else None
                )
                self.locator[inst.id] = (i, slot)

        self.preempted: List[Instance] = []
        self._ids = itertools.count()
        #: relocation plane (armed per zone by policy.relocate_threshold)
        self.relocation = RelocationStats()
        self._reloc_zone: Dict[str, _ZoneReloc] = {}
        #: victims whose re-placement is waiting in the admission queue
        self._reloc_inflight: Set[str] = set()
        #: relocated old id → replacement id; the simulator follows this
        #: chain when a departure event names a relocated instance
        self.relocated_ids: Dict[str, str] = {}
        cap = np.stack([c.vec for c in self.capacity]) if hosts else np.zeros((0, 1))
        self._cap0_total = float(cap[:, 0].sum())

        #: streaming admission front end (None = admission plane off)
        self.admission: Optional[AdmissionFrontEnd] = (
            AdmissionFrontEnd(self) if self.policy.queue_capacity else None
        )

    # -- back-compat views of the policy fields ------------------------------
    @property
    def cost_kind(self) -> str:
        return self.policy.cost_kind

    @property
    def period(self) -> float:
        return self.policy.period

    @property
    def use_pallas(self) -> bool:
        return self.policy.use_pallas

    @property
    def weigher_multipliers(self) -> Tuple[float, float, float, float]:
        return self.policy.weigher_multipliers

    @property
    def shortlist(self) -> Optional[int]:
        return self.policy.shortlist

    @property
    def fused_screen(self) -> Optional[bool]:
        return self.policy.fused_screen

    @property
    def mesh(self):
        return self.policy.mesh

    # -- derived metrics (device reductions; no python Host objects) ---------
    @property
    def n_hosts(self) -> int:
        return len(self.names)

    def utilization(self) -> float:
        if not self._cap0_total:
            return 0.0
        free0 = float(self.state.free_f[:, 0].sum())
        return (self._cap0_total - free0) / self._cap0_total

    def utilization_normal(self) -> float:
        if not self._cap0_total:
            return 0.0
        free0 = float(self.state.free_n[:, 0].sum())
        return (self._cap0_total - free0) / self._cap0_total

    # -- scheduling ----------------------------------------------------------
    def _req_arrays(self, req: Request):
        dom = -1 if req.domain is None else self.domain_ids.get(req.domain, -1)
        if req.cost_kind is None:
            kind = -1
        else:
            if req.cost_kind not in self.policy.kind_table:
                raise ValueError(
                    f"request {req.id} bills by {req.cost_kind!r}, not in "
                    f"the policy's cost-kind table {self.policy.kind_table}"
                )
            kind = COST_KIND_IDS[req.cost_kind]
        if req.exclude_zone is None:
            excl = -1
        else:
            # Fail closed: a typo'd zone name silently matching nothing
            # would void the never-place-back guarantee.
            if req.exclude_zone not in self.zone_ids:
                raise ValueError(
                    f"request {req.id} excludes unknown zone "
                    f"{req.exclude_zone!r}; fleet zones: "
                    f"{sorted(self.zone_ids)}"
                )
            excl = self.zone_ids[req.exclude_zone]
        return (
            req.resources.vec32,
            bool(req.preemptible),
            np.int32(dom),
            np.int32(kind),
            np.float32(-1.0 if req.period is None else req.period),
            np.int32(excl),
        )

    @property
    def effective_shortlist(self) -> Optional[int]:
        """The M the next flush will use (controller-steered when adaptive)."""
        return self.adaptive.m if self.adaptive is not None else self.shortlist

    def _flush_policy(self) -> SchedulerPolicy:
        """The policy the next flush dispatches with: the fleet policy, with
        M swapped in when the adaptive controller moved it.  Equal policies
        hash alike, so this re-hits the jit cache (≤ log2(m_max/m_min)+1
        distinct executables per request shape)."""
        m = self.effective_shortlist
        if m == self.policy.shortlist:
            return self.policy
        return dataclasses.replace(self.policy, shortlist=m)

    @property
    def shortlist_stats(self) -> Dict[str, int]:
        """Shortlist-health counters: decisions seen, admissibility
        fallbacks paid, and the adaptive controller's moves (0s when the
        controller is off).  ``shortlist`` is the M decisions actually run
        with — ``shortlist=None`` resolves to the same auto value the
        decision core uses (M=64 at fleet scale, 0 = full enumeration on
        small fleets)."""
        a = self.adaptive
        m = self.effective_shortlist
        if m is None:  # mirror _decision_core's auto rule (padded state size)
            m = (
                DEFAULT_SHORTLIST
                if self.state.n_hosts > 4 * DEFAULT_SHORTLIST
                else 0
            )
        return {
            "decisions": self.decisions,
            "fallbacks": self.fallbacks,
            "shortlist": m,
            "grows": a.grows if a else 0,
            "shrinks": a.shrinks if a else 0,
        }

    def _observe(self, n_fallbacks: int, min_margin: float, n_decisions: int):
        self.decisions += n_decisions
        self.fallbacks += n_fallbacks
        if self.adaptive is not None:
            self.adaptive.update(n_fallbacks, min_margin)

    def schedule_request(
        self, req: Request, now: float, price: float = 1.0
    ) -> SoAOutcome:
        """One decide-and-apply step on the persistent state."""
        res, pre, dom, kind, period, excl = self._req_arrays(req)
        self.state, (host_idx, slot, ok, kill, fell_back, margin) = schedule_step(
            self.state, res, pre, dom, now, price,
            policy=self._flush_policy(), req_cost_kind=kind, req_period=period,
            req_exclude_zone=excl,
        )
        self._observe(int(fell_back), float(margin), 1)
        return self._absorb(
            req, now, price, int(host_idx), int(slot), bool(ok), np.asarray(kill)
        )

    def schedule_batch(
        self, items: Sequence[Tuple[Request, float, float]]
    ) -> List[SoAOutcome]:
        """Run ``(request, now, price)`` triples through one ``lax.scan``.

        The batch is padded to the next power of two with unsatisfiable
        sentinel requests so jit recompiles only O(log B) distinct shapes.
        """
        if not items:
            return []
        if len(items) == 1:  # fused single step — no scan compile for B=1
            req, t, p = items[0]
            return [self.schedule_request(req, t, price=p)]
        b = len(items)
        # floor of 4 keeps the number of distinct compiled scan lengths small
        padded = max(4, 1 << (b - 1).bit_length())
        d = len(self.spec.dims)
        res = np.full((padded, d), _PAD_RES, np.float32)
        pre = np.zeros((padded,), bool)
        dom = np.full((padded,), -1, np.int32)
        now = np.full((padded,), items[-1][1], np.float32)
        price = np.ones((padded,), np.float32)
        kind = np.full((padded,), -1, np.int32)
        period = np.full((padded,), -1.0, np.float32)
        excl = np.full((padded,), -1, np.int32)
        for i, (req, t, p) in enumerate(items):
            (res[i], pre[i], dom[i], kind[i], period[i],
             excl[i]) = self._req_arrays(req)
            now[i] = t
            price[i] = p
        self.state, (host_idx, slot, ok, kill, fell_back, margin) = schedule_many(
            self.state, res, pre, dom, now, price,
            policy=self._flush_policy(), req_cost_kind=kind, req_period=period,
            req_exclude_zone=excl,
        )
        host_idx, slot = np.asarray(host_idx), np.asarray(slot)
        ok, kill = np.asarray(ok), np.asarray(kill)
        # Health signals from the REAL rows only (padding sentinels can
        # neither fall back nor tighten the margin, but stay out anyway).
        fb = np.asarray(fell_back)[:b]
        mg = np.asarray(margin)[:b]
        self._observe(int(fb.sum()), float(mg.min()), b)
        return [
            self._absorb(
                req, t, p, int(host_idx[i]), int(slot[i]), bool(ok[i]), kill[i]
            )
            for i, (req, t, p) in enumerate(items)
        ]

    def _absorb(
        self,
        req: Request,
        now: float,
        price: float,
        host_idx: int,
        slot: int,
        ok: bool,
        kill_row: np.ndarray,
    ) -> SoAOutcome:
        """Fold one decision's outputs back into the python bookkeeping."""
        if not ok:
            return SoAOutcome(request=req, host=None, instance=None)
        name = self.names[host_idx]
        victims: List[Instance] = []
        if not req.preemptible:
            for k in np.flatnonzero(kill_row):
                vid = self.slot_ids[host_idx][k]
                assert vid is not None, "terminated an empty slot"
                victim = self.instances.pop(vid)
                del self.locator[vid]
                self.slot_ids[host_idx][k] = None
                self.preempted.append(victim)
                victims.append(victim)
        inst = Instance(
            id=f"i{next(self._ids)}-{req.id}",
            resources=req.resources,
            preemptible=req.preemptible,
            host=name,
            start_time=now,
            user=req.user,
            price_rate=price,
            cost_kind=req.cost_kind,
            period=req.period,
        )
        self.instances[inst.id] = inst
        if req.preemptible:
            assert self.slot_ids[host_idx][slot] is None, "slot collision"
            self.slot_ids[host_idx][slot] = inst.id
            self.locator[inst.id] = (host_idx, slot)
            # survives the locator entry (an in-batch preemption may reap
            # this instance before the caller reads the outcome)
            inst.metadata["slot"] = int(slot)
        else:
            self.locator[inst.id] = (host_idx, None)
        return SoAOutcome(
            request=req, host=name, instance=inst, victims=tuple(victims)
        )

    # -- streaming admission (policy.queue_capacity > 0) ---------------------
    def _front(self) -> AdmissionFrontEnd:
        if self.admission is None:
            raise RuntimeError(
                "admission plane is off; build the fleet with "
                "SchedulerPolicy(queue_capacity=...) to use submit/drain"
            )
        return self.admission

    def submit(self, req: Request, now: float, price: float = 1.0) -> None:
        """Admit-or-queue: accept an arrival into the admission plane (the
        decision happens at the next drain, in priority order)."""
        self._front().submit(req, now, price=price)

    def drain(self, now: float, block: bool = True) -> Optional[DrainResult]:
        """Run one admission drain (see ``AdmissionFrontEnd.drain``)."""
        return self._front().drain(now, block=block)

    def drain_all(self, now: float) -> List[DrainResult]:
        """Drain until the queue empties or retries exhaust."""
        return self._front().drain_all(now)

    @property
    def admission_stats(self) -> Dict[str, float]:
        """Counters + latency percentiles of the admission plane."""
        front = self._front()
        front.sync()
        return front.stats.summary()

    # -- lifecycle transitions ----------------------------------------------
    def depart(self, instance_id: str, now: Optional[float] = None) -> bool:
        """Voluntary departure.  Returns False if the instance is already
        gone (preempted / host failure) — departures are idempotent.

        Pass ``now`` to credit the departing slot's accrued uptime to its
        zone's churn denominator (a voluntary exit is evidence the zone is
        *healthy*: uptime without a termination).  Without ``now`` the zone
        accumulators are untouched — the exact pre-churn transition."""
        inst = self.instances.pop(instance_id, None)
        if inst is None:
            return False
        host_idx, slot = self.locator.pop(instance_id)
        if slot is not None:
            mask = np.zeros((self.k_slots,), bool)
            mask[slot] = True
            self.state = apply_termination(
                self.state, host_idx, mask, now=now, involuntary=False
            )
            self.slot_ids[host_idx][slot] = None
        else:
            self.state = apply_departure(
                self.state, host_idx, inst.resources.vec32
            )
        return True

    def preempt_instance(
        self, instance_id: str, now: Optional[float] = None
    ) -> bool:
        """Involuntary out-of-band preemption (storm injection / provider
        reclaim): the instance dies like a scheduler kill — freed on device,
        recorded in ``preempted`` for re-queueing, and (when ``now`` is
        given) charged to its host's zone churn accumulators.  Returns False
        when the instance is already gone (benign — storms and relocations
        race, so reclaims are idempotent); raises for a live NORMAL
        instance, which no provider reclaims out of band (a normal id here
        is a caller bug, not a race)."""
        loc = self.locator.get(instance_id)
        if loc is None:
            return False
        if loc[1] is None:
            raise ValueError(
                f"instance {instance_id} is not preemptible; out-of-band "
                "reclaim only takes preemptible slots (normal instances "
                "leave via depart/fail_host)"
            )
        host_idx, slot = loc
        inst = self.instances.pop(instance_id)
        del self.locator[instance_id]
        mask = np.zeros((self.k_slots,), bool)
        mask[slot] = True
        self.state = apply_termination(
            self.state, host_idx, mask, now=now, involuntary=True
        )
        self.slot_ids[host_idx][slot] = None
        self.preempted.append(inst)
        return True

    def fail_host(self, name: str, now: Optional[float] = None) -> Tuple[int, int]:
        """Hard failure: every instance dies (preemptible ones are recorded
        as preempted for re-queueing).  Returns (n_preempted, n_terminated).

        Pass ``now`` to charge the failure to the host's zone churn
        accumulators (every live slot's termination + accrued uptime)."""
        host_idx = self.index[name]
        n_pre = n_norm = 0
        normal_res = np.zeros((len(self.spec.dims),), np.float32)
        for iid in [
            i for i, (h, _) in self.locator.items() if h == host_idx
        ]:
            inst = self.instances.pop(iid)
            _, slot = self.locator.pop(iid)
            if slot is not None:
                self.slot_ids[host_idx][slot] = None
                self.preempted.append(inst)
                n_pre += 1
            else:
                normal_res += inst.resources.vec32
                n_norm += 1
        self.state = apply_host_failure(
            self.state, host_idx, normal_res, now=now
        )
        return n_pre, n_norm

    # -- failure-domain plane (zone churn readers) ---------------------------
    def churn_snapshot(self) -> Tuple[Dict[str, float], float]:
        """Every churn statistic in ONE fused device reduction + transfer
        (``screen_math.churn_stats``): returns ``(per-zone ẑ by name,
        fleet-wide rate)``.  The single reader behind ``zone_rates``,
        ``fleet_churn_rate``, and the relocation trigger — callers needing
        both halves should call this once instead of both wrappers."""
        out = np.asarray(
            _churn_stats_jit(self.state.zone_term, self.state.zone_up)
        )
        rates = {z: float(out[i]) for z, i in self.zone_ids.items()}
        return rates, float(out[-1])

    def zone_rates(self) -> Dict[str, float]:
        """Observed per-zone churn rates ẑ = T / max(U, eps): involuntary
        terminations over accrued preemptible uptime — the same statistic the
        device decision reads via ``screen_math.churn_of``."""
        return self.churn_snapshot()[0]

    def fleet_churn_rate(self) -> float:
        """Fleet-wide churn rate ΣT / max(ΣU, eps) — the storm signal the
        admission plane's graceful degradation compares against
        ``policy.storm_threshold``."""
        return self.churn_snapshot()[1]

    # -- relocation plane (hot-zone evacuation) ------------------------------
    def relocate(self, now: float) -> int:
        """One relocation pass: evacuate up to ``policy.relocate_budget``
        of the highest-expected-loss preemptible instances from every ARMED
        hot zone, checkpoint → place → kill, never the reverse.

        Hysteresis: a zone arms when its learned churn ẑ crosses
        ``policy.relocate_threshold`` (outside its cooldown window) and
        disarms — entering a ``relocate_cooldown_s`` cooldown — when ẑ
        falls below ``policy.relocate_exit_threshold``.  Failed
        re-placements leave their victim running and push the zone's
        ``retry_at`` out exponentially (``relocate_backoff_s`` doubling per
        consecutive failure).

        Re-placements go through the ordinary decision pipeline with the
        source zone hard-excluded (``Request.exclude_zone``); with the
        admission plane on they ride the queue as class-0 preemptible
        entries and settle asynchronously at the drain that decides them.
        Returns the number of evacuations initiated this pass."""
        pol = self.policy
        if not pol.relocation_on:
            raise RuntimeError(
                "relocation plane is off; build the fleet with "
                "SchedulerPolicy(relocate_threshold=...)"
            )
        st = self.relocation
        st.passes += 1
        rates, _ = self.churn_snapshot()
        started = 0
        for zone in self.zone_ids:
            z = self._reloc_zone.setdefault(zone, _ZoneReloc())
            rate = rates[zone]
            if z.armed and rate < pol.relocate_exit_threshold:
                z.armed = False
                z.cooldown_until = now + pol.relocate_cooldown_s
                st.disarms += 1
            elif (
                not z.armed
                and rate > pol.relocate_threshold
                and now >= z.cooldown_until
            ):
                z.armed = True
                z.fail_streak = 0
                z.retry_at = float("-inf")
                st.arms += 1
            if z.armed and now >= z.retry_at:
                started += self._evacuate_zone(zone, now)
        return started

    def _evacuate_zone(self, zone: str, now: float) -> int:
        """Evacuate one armed zone's worst-loss victims (≤ budget).

        Direct (unqueued) mode runs the whole batch as ONE fused
        ``relocate_many`` dispatch — per victim checkpoint → re-place →
        terminate in the exact sequence the old per-victim
        ``schedule_request`` loop applied, so decisions are bit-identical
        while the dispatch count drops from one per victim to one per zone
        (``tests/test_relocation.py`` pins both).  With the admission plane
        on, victims still ride the queue one entry each and settle at the
        drain that decides them."""
        pol = self.policy
        st = self.relocation
        budget = min(pol.relocate_budget, self.state.n_hosts * self.k_slots)
        hosts, slots, valid = _relocation_victims(
            self.state, jnp.int32(self.zone_ids[zone]), jnp.float32(now),
            jnp.float32(pol.period), budget=budget,
        )
        hosts, slots = np.asarray(hosts), np.asarray(slots)
        valid = np.asarray(valid)
        started = 0
        batch: List[Tuple[str, int, int, Instance, Request]] = []
        for h, s, v in zip(hosts, slots, valid):
            if not v:
                continue
            iid = self.slot_ids[int(h)][int(s)]
            assert iid is not None, "relocation victim slot empty in mirror"
            if iid in self._reloc_inflight:
                continue  # already mid-flight from an earlier pass
            inst = self.instances[iid]
            st.attempted += 1
            req = Request(
                id=f"reloc-{iid}",
                resources=inst.resources,
                preemptible=True,
                user=inst.user,
                cost_kind=inst.cost_kind,
                period=inst.period,
                priority=0,
                exclude_zone=zone,
                metadata={"relocation": iid},
            )
            if self.admission is not None:
                # Checkpoint FIRST: the replacement restarts from here, and
                # a storm racing the move loses only the work since now.
                self.checkpoint(iid, now)
                self.admission.submit_relocation(
                    req, iid, zone, now, price=inst.price_rate
                )
                self._reloc_inflight.add(iid)
                st.pending += 1
                started += 1
            else:
                # Mirror half of the checkpoint now; the device half runs
                # inside the fused scan (gated per row), keeping the
                # checkpoint→place→kill order per victim.
                inst.last_checkpoint = now
                batch.append((iid, int(h), int(s), inst, req))
        if batch:
            started += self._relocate_batch(zone, batch, now)
        return started

    def _relocate_batch(
        self,
        zone: str,
        batch: List[Tuple[str, int, int, Instance, Request]],
        now: float,
    ) -> int:
        """Direct-mode settle of one fused ``relocate_many`` dispatch."""
        b = len(batch)
        padded = max(4, 1 << (b - 1).bit_length())
        d = len(self.spec.dims)
        vh = np.zeros((padded,), np.int32)
        vs = np.zeros((padded,), np.int32)
        von = np.zeros((padded,), bool)
        res = np.full((padded, d), _PAD_RES, np.float32)
        dom = np.full((padded,), -1, np.int32)
        kind = np.full((padded,), -1, np.int32)
        period = np.full((padded,), -1.0, np.float32)
        price = np.ones((padded,), np.float32)
        excl = np.full((padded,), -1, np.int32)
        for i, (iid, h, s, inst, req) in enumerate(batch):
            (res[i], _, dom[i], kind[i], period[i],
             excl[i]) = self._req_arrays(req)
            vh[i], vs[i], von[i] = h, s, True
            price[i] = inst.price_rate
        self.state, (host_idx, slot, ok, fell_back, margin) = relocate_many(
            self.state, vh, vs, von, res, dom, kind, period, price, excl,
            now, policy=self._flush_policy(),
        )
        host_idx, slot = np.asarray(host_idx), np.asarray(slot)
        ok = np.asarray(ok)
        fb = np.asarray(fell_back)[:b]
        mg = np.asarray(margin)[:b]
        self._observe(int(fb.sum()), float(mg.min()), b)
        st = self.relocation
        z = self._reloc_zone.setdefault(zone, _ZoneReloc())
        no_kill = np.zeros((self.k_slots,), bool)
        started = 0
        for i, (iid, h, s, inst, req) in enumerate(batch):
            if bool(ok[i]):
                out = self._absorb(
                    req, now, inst.price_rate,
                    int(host_idx[i]), int(slot[i]), True, no_kill,
                )
                # The fused scan already departed the victim on device
                # (make-before-break, voluntary); fold the mirror here —
                # the python half of ``_settle_relocation_placed`` minus
                # the device transition.  Direct mode is single-threaded,
                # so the lost/stale races of the queued path cannot occur.
                self.instances.pop(iid)
                del self.locator[iid]
                self.slot_ids[h][s] = None
                self.relocated_ids[iid] = out.instance.id
                st.relocated += 1
                z.fail_streak = 0
                started += 1
            else:
                self._settle_relocation_rejected(iid, zone, now)
        return started

    def _settle_relocation_placed(
        self, victim_id: str, zone: str, out: SoAOutcome, now: float
    ) -> None:
        """Make-before-break settle: the replacement is live, so the victim
        (if still running) departs — voluntarily: a move is not churn, so
        the source zone's ẑ numerator is untouched."""
        st = self.relocation
        if victim_id in self._reloc_inflight:
            self._reloc_inflight.discard(victim_id)
            st.pending -= 1
        z = self._reloc_zone.setdefault(zone, _ZoneReloc())
        if victim_id in self.instances:
            self.depart(victim_id, now=now)
            self.relocated_ids[victim_id] = out.instance.id
            st.relocated += 1
            z.fail_streak = 0
        elif any(i.id == victim_id for i in self.preempted):
            # The storm beat the move: the victim is already dead, and the
            # replacement stands as its restore from the checkpoint taken
            # at evacuation time.
            self.relocated_ids[victim_id] = out.instance.id
            st.lost_victims += 1
        else:
            # Victim departed on its own mid-flight: the replacement is
            # surplus — drop it immediately (no duplicate, no double bill).
            self.depart(out.instance.id, now=now)
            st.stale += 1

    def _settle_relocation_rejected(
        self, victim_id: str, zone: str, now: float
    ) -> None:
        """Never-worse: a failed re-placement leaves the victim running and
        backs the zone off exponentially."""
        st = self.relocation
        if victim_id in self._reloc_inflight:
            self._reloc_inflight.discard(victim_id)
            st.pending -= 1
        st.failed += 1
        z = self._reloc_zone.setdefault(zone, _ZoneReloc())
        z.fail_streak += 1
        z.retry_at = now + self.policy.relocate_backoff_s * (
            2.0 ** (z.fail_streak - 1)
        )

    def checkpoint(self, instance_id: str, now: float) -> bool:
        """Record a durable checkpoint for a live preemptible instance (its
        recompute cost restarts from ``now``).  Returns False when the
        instance is gone or not preemptible — checkpoints are idempotent."""
        loc = self.locator.get(instance_id)
        if loc is None or loc[1] is None:
            return False
        host_idx, slot = loc
        self.instances[instance_id].last_checkpoint = now
        self.state = apply_checkpoint(self.state, host_idx, slot, now)
        return True

    def heal_host(self, name: str) -> None:
        self.state = set_schedulable(self.state, self.index[name], True)

    def set_slow(self, name: str, slow_factor: float) -> None:
        self.state = set_slow_factor(self.state, self.index[name], slow_factor)

    # -- python-object sync (sample points / verification only) --------------
    def slot_assignment(self) -> List[Dict[str, int]]:
        """Per-host id → slot map, for bit-exact oracle rebuilds."""
        return [
            {iid: k for k, iid in enumerate(row) if iid is not None}
            for row in self.slot_ids
        ]

    def sync_hosts(self) -> List[Host]:
        """Materialize python ``Host`` objects from the mirror records.

        Placement goes through ``Host.place`` so capacity violations in the
        incremental state surface here as hard errors."""
        schedulable = np.asarray(self.state.schedulable)
        slow = np.asarray(self.state.slow)
        hosts = [
            Host(
                name=self.names[i],
                capacity=self.capacity[i],
                domain=self.domains[i],
                zone=self.zones[i],
                schedulable=bool(schedulable[i]),
                slow_factor=float(slow[i]),
            )
            for i in range(self.n_hosts)
        ]
        for inst in self.instances.values():
            host_idx, _ = self.locator[inst.id]
            hosts[host_idx].place(inst)
        return hosts
