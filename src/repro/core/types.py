"""Core control-plane types for the preemptible-aware scheduler.

The paper (López García et al., FGCS 2019) schedules VM requests onto physical
hosts.  In `repro` the same algebra places *jobs* (training / serving shards)
onto TPU hosts; the resource vector is generic so both the paper's testbed
(vCPU / RAM / disk) and the TPU fleet (chips / HBM / host-RAM) are expressible.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

import numpy as np

# ---------------------------------------------------------------------------
# Resource vectors
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ResourceSpec:
    """Names the dimensions of a resource vector."""

    dims: Tuple[str, ...]

    def zeros(self) -> "Resources":
        return Resources(self, np.zeros(len(self.dims)))

    def make(self, **kwargs: float) -> "Resources":
        vec = np.zeros(len(self.dims))
        for key, val in kwargs.items():
            vec[self.dims.index(key)] = float(val)
        return Resources(self, vec)


#: The paper's testbed dimensions (Table 1 / Table 2).
VM_SPEC = ResourceSpec(("vcpus", "ram_mb", "disk_gb"))
#: TPU fleet dimensions used by the `repro` cluster runtime.
TPU_SPEC = ResourceSpec(("chips", "hbm_gb", "host_ram_gb"))


@dataclasses.dataclass(frozen=True)
class Resources:
    """Immutable resource vector with component-wise algebra."""

    spec: ResourceSpec
    vec: np.ndarray

    def __post_init__(self):  # defensive copy + freeze
        v = np.asarray(self.vec, dtype=np.float64).copy()
        v.setflags(write=False)
        object.__setattr__(self, "vec", v)

    # -- algebra ------------------------------------------------------------
    def __add__(self, other: "Resources") -> "Resources":
        self._check(other)
        return Resources(self.spec, self.vec + other.vec)

    def __sub__(self, other: "Resources") -> "Resources":
        self._check(other)
        return Resources(self.spec, self.vec - other.vec)

    def __le__(self, other: "Resources") -> bool:
        self._check(other)
        return bool(np.all(self.vec <= other.vec + 1e-9))

    def fits_in(self, free: "Resources") -> bool:
        """True when this request fits inside ``free`` on every dimension."""
        return self <= free

    @property
    def vec32(self) -> np.ndarray:
        """float32 view for the device-resident SoA paths (resource values
        are small integers in practice, so the cast is exact)."""
        return np.asarray(self.vec, dtype=np.float32)

    def any_negative(self) -> bool:
        return bool(np.any(self.vec < -1e-9))

    def get(self, dim: str) -> float:
        return float(self.vec[self.spec.dims.index(dim)])

    def _check(self, other: "Resources") -> None:
        if self.spec is not other.spec and self.spec != other.spec:
            raise ValueError(f"resource spec mismatch: {self.spec} vs {other.spec}")

    def __repr__(self) -> str:
        parts = ", ".join(f"{d}={v:g}" for d, v in zip(self.spec.dims, self.vec))
        return f"Resources({parts})"


def sum_resources(spec: ResourceSpec, items: Iterable[Resources]) -> Resources:
    total = np.zeros(len(spec.dims))
    for it in items:
        total = total + it.vec
    return Resources(spec, total)


# ---------------------------------------------------------------------------
# Requests and instances
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Flavor:
    """A named instance size (paper Table 2: small / medium / large)."""

    name: str
    resources: Resources


@dataclasses.dataclass(frozen=True)
class Request:
    """A placement request (VM in the paper; job shard in `repro`).

    ``preemptible`` selects the host-state view used during filtering
    (Alg. 2): normal requests filter against ``h_n``, preemptible against
    ``h_f``.
    """

    id: str
    resources: Resources
    preemptible: bool = False
    user: str = "anon"
    #: Optional ICI-domain constraint (TPU adaptation): a job restricted to a
    #: contiguous slice domain.  ``None`` means any domain.
    domain: Optional[str] = None
    #: Billing kind this request's instance will be scored under at
    #: termination time ("period" | "count" | "revenue" | "recompute");
    #: ``None`` = the fleet policy's default kind.  Mixed-payment fleets
    #: (``SchedulerPolicy.cost_kinds`` / ``cost.MixedCost``) set this per
    #: request; homogeneous fleets leave it None.
    cost_kind: Optional[str] = None
    #: Admission-priority class for the streaming front end
    #: (``core.admission``): 0 = highest (interactive), larger = lower.
    #: ``None`` derives the class from ``preemptible`` — normal requests are
    #: interactive (class 0), preemptible requests are batch (the lowest
    #: class).  Ignored by the direct (unqueued) entry points.
    priority: Optional[int] = None
    #: Per-instance billing period in seconds for the period/revenue kinds
    #: (contract terms vary per customer class); ``None`` = the fleet
    #: policy's shared ``period``.
    period: Optional[float] = None
    #: Hard zone exclusion: the decision pipeline filters every host in this
    #: failure zone out of stage 1, regardless of churn state.  Set by the
    #: relocation plane on evacuation re-placements so a victim can never be
    #: re-placed into the zone it is fleeing; ``None`` = no exclusion.
    exclude_zone: Optional[str] = None
    metadata: Mapping[str, object] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class Instance:
    """A placed instance/job-shard occupying resources on a host."""

    id: str
    resources: Resources
    preemptible: bool
    host: str
    start_time: float
    user: str = "anon"
    #: $/hour equivalent used by revenue-aware cost modules.
    price_rate: float = 1.0
    #: Timestamp of the last durable checkpoint (training jobs).  Used by the
    #: beyond-paper RecomputeCost module: preempting a job that checkpointed
    #: recently is cheap.
    last_checkpoint: Optional[float] = None
    #: Billing kind this instance is scored under (mirrors
    #: ``Request.cost_kind``); ``None`` = the fleet policy's default.
    cost_kind: Optional[str] = None
    #: Per-instance billing period in seconds (mirrors ``Request.period``);
    #: ``None`` = the fleet policy's shared ``period``.
    period: Optional[float] = None
    metadata: Dict[str, object] = dataclasses.field(default_factory=dict)

    def run_time(self, now: float) -> float:
        return max(0.0, now - self.start_time)


# ---------------------------------------------------------------------------
# Dual host state (the paper's central data structure)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Host:
    """A physical host with the paper's dual resource views.

    ``free_full``  — the ``h_f`` view: every running instance counted.
    ``free_normal`` — the ``h_n`` view: preemptible instances *not* counted,
    so a normal request can "see through" them during filtering.
    """

    name: str
    capacity: Resources
    domain: str = "d0"
    #: Failure domain (cloud zone / rack): preemption-storm correlation and
    #: the learned churn rates are tracked per zone, not per host.
    zone: str = "z0"
    #: hosts marked unschedulable (drain / failure) are filtered out.
    schedulable: bool = True
    #: Relative slowness factor learned from heartbeats (1.0 == nominal);
    #: used by the straggler-aware weigher.
    slow_factor: float = 1.0
    instances: Dict[str, Instance] = dataclasses.field(default_factory=dict)

    # -- derived views -------------------------------------------------------
    def used(self, include_preemptible: bool = True) -> Resources:
        return sum_resources(
            self.capacity.spec,
            (
                i.resources
                for i in self.instances.values()
                if include_preemptible or not i.preemptible
            ),
        )

    @property
    def free_full(self) -> Resources:
        """``h_f``: free resources counting ALL instances."""
        return self.capacity - self.used(include_preemptible=True)

    @property
    def free_normal(self) -> Resources:
        """``h_n``: free resources counting only NON-preemptible instances."""
        return self.capacity - self.used(include_preemptible=False)

    def preemptible_instances(self) -> List[Instance]:
        return [i for i in self.instances.values() if i.preemptible]

    def normal_instances(self) -> List[Instance]:
        return [i for i in self.instances.values() if not i.preemptible]

    # -- mutation (used by the cluster state machine) ------------------------
    def place(self, inst: Instance) -> None:
        if inst.id in self.instances:
            raise ValueError(f"duplicate instance id {inst.id} on {self.name}")
        if not inst.resources.fits_in(self.free_full):
            raise ValueError(
                f"instance {inst.id} does not fit on {self.name}: "
                f"need {inst.resources}, free {self.free_full}"
            )
        inst.host = self.name
        self.instances[inst.id] = inst

    def remove(self, instance_id: str) -> Instance:
        return self.instances.pop(instance_id)


# ---------------------------------------------------------------------------
# Scheduling outcomes
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TerminationPlan:
    """Alg. 5 output: the cost-minimal feasible set of preemptible instances
    whose evacuation (plus existing free resources) admits the request."""

    instances: Tuple[Instance, ...]
    cost: float
    feasible: bool

    @property
    def ids(self) -> Tuple[str, ...]:
        return tuple(i.id for i in self.instances)


EMPTY_PLAN = TerminationPlan(instances=(), cost=0.0, feasible=True)
INFEASIBLE_PLAN = TerminationPlan(instances=(), cost=float("inf"), feasible=False)


@dataclasses.dataclass(frozen=True)
class ScheduleResult:
    """Outcome of one scheduling call."""

    request: Request
    host: Optional[str]
    plan: TerminationPlan = EMPTY_PLAN
    #: number of filter/weigh passes executed (1 for the paper's design,
    #: 2 for the retry baseline when termination triggers).
    passes: int = 1

    @property
    def ok(self) -> bool:
        return self.host is not None


class ScheduleError(RuntimeError):
    """Raised when a request cannot be scheduled (maps to the paper's
    'failure process defined in the scheduling algorithm')."""
