"""Shared stage-1 screen/bounds math — ONE definition for both screens.

The O(N·K) stage-1 screen exists in two executions: the pure-jnp oracle
(``jax_scheduler.screen_terms`` + the weigher assembly in ``_decision_core``)
and the fused Pallas kernel (``repro.kernels.sched_screen``), which runs the
same math per 128-host tile with a running top-M shortlist kept in VMEM.
Shortlist decisions are only bit-exact when the two agree on every float op,
so the bounds math lives here once and both callers execute *these*
functions — the kernel on slot-major ``(K, D, T)`` tiles, the oracle on the
whole fleet.

Layout convention: *slot-major rows*.  Per-slot data is a python list of K
arrays whose trailing axis is the host axis (``res_rows[i]`` is ``(D, X)``,
``cost_rows[i]`` is ``(X,)`` for X hosts).  The Batcher compare-exchange
network then works on whole host-vectors per step — contiguous lanes on TPU
(the VPU's native orientation) and contiguous memory on CPU, where the
previous host-major ``(N, K)`` column slices strided badly.

Exactness: with integer-valued resources/costs (the paper regime and every
parity test) all sums here are exact in f32, so sorted-prefix bounds hold
bitwise and both screens produce identical arrays.  With arbitrary float
inputs the two executions still agree on CPU (same HLO ops); on TPU the
admissibility fallback absorbs reassociation-ulp differences (see
``jax_scheduler`` module docstring).
"""
from __future__ import annotations

import functools
from typing import List, NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30
POS_INF = 1e30
#: resource-comparison slack (integer-valued resources make it inert).
EPS = 1e-6
#: degenerate-span guard for the [0, 1] weight normalizations.
NORM_EPS = 1e-12
#: Termination-cost tie-break epsilon of the Alg. 5 enumeration: subsets
#: whose cost is within TIE_EPS of the optimum count as tied and resolve by
#: (fewer instances, lower mask index).  ONE constant shared by the Pallas
#: ``sched_weigh`` kernel and the jnp oracle (``host_plan_terms``) — a
#: drifted epsilon would let the two paths break ties differently (pinned by
#: tests/test_kernels_sched.py::test_tie_epsilon_*).  Defined here (the only
#: module both layers can import without a cycle) and re-exported by
#: ``repro.kernels.ops``, the kernels' public surface.
TIE_EPS = 1e-3


@functools.lru_cache(maxsize=None)
def oem_pairs(n: int) -> Tuple[Tuple[int, int], ...]:
    """Compare-exchange pairs of Batcher's odd-even mergesort for n lanes."""
    pairs = []
    p = 1
    while p < n:
        k = p
        while k >= 1:
            for j in range(k % p, n - k, 2 * k):
                for i in range(min(k, n - j - k)):
                    if (i + j) // (2 * p) == (i + j + k) // (2 * p):
                        pairs.append((i + j, i + j + k))
            k //= 2
        p *= 2
    return tuple(pairs)


def sort_rows(rows: Sequence[jax.Array], descending: bool = False) -> List[jax.Array]:
    """Sort K row arrays elementwise with a Batcher network: O(K log² K)
    fused min/max stages.  XLA CPU's generic ``sort`` is ~10x slower on these
    short (K ≤ 16) rows at fleet-scale N, and Mosaic has no sort at all —
    the same static network serves both."""
    rows = list(rows)
    for i, j in oem_pairs(len(rows)):
        lo = jnp.minimum(rows[i], rows[j])
        hi = jnp.maximum(rows[i], rows[j])
        rows[i], rows[j] = (hi, lo) if descending else (lo, hi)
    return rows


def total_rows(rows: Sequence[jax.Array]) -> jax.Array:
    """Sequential sum of row arrays — one canonical add order for both
    screens (``jnp.sum`` over a stacked axis may reassociate)."""
    tot = rows[0]
    for row in rows[1:]:
        tot = tot + row
    return tot


def screen_bounds_rows(
    need: jax.Array,                    # (D, X) req - free_f, host-trailing
    res_rows: Sequence[jax.Array],      # K × (D, X), invalid slots zeroed
    cost_rows: Sequence[jax.Array],     # K × (X,), invalid slots +POS_INF
    total_cost: jax.Array,              # (X,) Σ valid slot costs
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Stage-1 per-host screening terms, O(X·K) — no subset enumeration.

    Returns ``(feasible, overcommitted, cost_lb, cost_ub)`` (all (X,)):
      feasible      EXACT Alg. 5 feasibility: the full valid-slot subset
                    frees the per-dim maximum, so the descending prefix's
                    final sum ≥ need decides feasibility of *some* subset;
      overcommitted the request does not fit ``free_f`` as-is;
      cost_lb       lower bound on the optimal termination cost: any
                    feasible subset needs ≥ m* slots (per-dim sorted-resource
                    prefix argument), and slot costs are non-negative, so it
                    pays at least the m* cheapest slot costs;
      cost_ub       upper bound: cost of evacuating every valid slot
                    (a feasible plan whenever any plan is).
    Hosts that fit directly have ``cost_lb == cost_ub == 0`` (exact).
    """
    k = len(res_rows)
    # Fewest slots that could cover dim d: descending per-dim resource prefix
    # sums (any m-subset frees at most the top-m sum on every dim).  Each dim
    # sorts independently — the bound only needs per-dim maxima coverage.
    res_desc = sort_rows(res_rows, descending=True)
    lacking = jnp.zeros(need.shape, jnp.int32)
    prefix = jnp.zeros_like(need)
    for row in res_desc:
        prefix = prefix + row
        lacking = lacking + (prefix < need - EPS).astype(jnp.int32)
    # The full descending prefix is the total freed by evacuating everything,
    # so exact feasibility falls out of the same pass.
    feasible = jnp.all(prefix >= need - EPS, axis=0)
    overcommitted = jnp.any(need > EPS, axis=0)
    m_d = jnp.where(need > EPS, lacking + 1, 0)
    m_star = jnp.minimum(jnp.max(m_d, axis=0), k)                    # (X,)
    cost_asc = sort_rows(cost_rows)
    lb = jnp.zeros_like(cost_asc[0])
    for i, row in enumerate(cost_asc):
        lb = lb + jnp.where(i < m_star, row, 0.0)
    cost_lb = jnp.where(overcommitted, lb, 0.0)
    cost_ub = jnp.where(overcommitted, total_cost, 0.0)
    return feasible, overcommitted, cost_lb, cost_ub


# ---------------------------------------------------------------------------
# Weigher normalization: bound-derived constants shared by every path
# ---------------------------------------------------------------------------


class ScreenConsts(NamedTuple):
    """Global normalization constants of one decision (all f32 scalars).

    ``c_lo``/``c_hi`` bracket the termination-cost envelope over the valid
    set; the four ``*_lo``/``*_hi`` pairs are the min/max of the raw
    overcommit / packing / straggler / zone-churn weigher terms.  Terms
    whose multiplier is 0 keep the fold identities (+inf, -inf) — both
    screens gate identically on the static multipliers."""

    c_lo: jax.Array
    c_hi: jax.Array
    over_lo: jax.Array
    over_hi: jax.Array
    pack_lo: jax.Array
    pack_hi: jax.Array
    strag_lo: jax.Array
    strag_hi: jax.Array
    churn_lo: jax.Array = POS_INF
    churn_hi: jax.Array = NEG_INF

    def pack(self) -> jax.Array:
        return jnp.stack([jnp.asarray(x, jnp.float32) for x in self])

    @classmethod
    def unpack(cls, arr: jax.Array) -> "ScreenConsts":
        return cls(*(arr[i] for i in range(10)))


#: number of packed ``ScreenConsts`` scalars (SMEM scratch / consts blocks).
N_CONSTS = 10

#: uptime floor of the churn rate ẑ = T / max(U, CHURN_EPS): zones with no
#: observed uptime read as zero-churn rather than dividing by zero.
CHURN_EPS = 1e-6


def churn_of(
    zone_term: jax.Array, zone_up: jax.Array, host_zone: jax.Array
) -> jax.Array:
    """Per-host learned churn rate: the zone accumulators' ẑ = T/max(U, ε)
    (terminations per accumulated uptime second — the gce-manager rate)
    gathered onto hosts by their zone id.  ONE definition so every decision
    path derives bit-identical churn inputs from the same (T, U) state."""
    rate = zone_term / jnp.maximum(zone_up, CHURN_EPS)
    return rate[host_zone]


def churn_stats(zone_term: jax.Array, zone_up: jax.Array) -> jax.Array:
    """Every churn statistic the host side reads, in ONE fused reduction:
    returns (Z+1,) — the Z per-zone rates ẑ = T/max(U, ε) followed by the
    fleet-wide rate ΣT/max(ΣU, ε).  The sampler (``SoAFleet.zone_rates`` /
    ``fleet_churn_rate``), the admission drain's storm check, and the
    relocation trigger all derive from this one program, so one device
    transfer serves every reader per event."""
    rate = zone_term / jnp.maximum(zone_up, CHURN_EPS)
    fleet = jnp.sum(zone_term) / jnp.maximum(jnp.sum(zone_up), CHURN_EPS)
    return jnp.concatenate([rate, fleet[None]])


def raw_base_terms(
    free_f_sum: jax.Array,
    slow: jax.Array,
    overcommitted: jax.Array,
    churn: jax.Array = None,
) -> Tuple[jax.Array, ...]:
    """Raw (pre-normalization) enumeration-free weigher terms.

    ``free_f_sum`` is the per-host sum of free_f over resource dims (callers
    reduce their own layout); returns (over_raw, pack_raw, strag_raw) and,
    when a per-host ``churn`` rate is given, appends ``churn_raw = -churn``
    (negated: a positive churn multiplier must *penalize* hot zones)."""
    over_raw = jnp.where(overcommitted, -1.0, 0.0)
    out = (over_raw, -free_f_sum, -slow)
    if churn is None:
        return out
    return out + (-churn,)


def _m_churn(multipliers) -> float:
    """5th (churn) multiplier of a 4- or 5-tuple; 0 when absent."""
    return multipliers[4] if len(multipliers) > 4 else 0.0


def consts_of(
    multipliers: Tuple[float, ...],
    valid: jax.Array,
    cost_lb: jax.Array,
    cost_ub: jax.Array,
    over_raw: jax.Array,
    pack_raw: jax.Array,
    strag_raw: jax.Array,
    churn_raw: jax.Array = None,
) -> ScreenConsts:
    """Fold the per-host terms into ``ScreenConsts`` (pure-jnp reduction;
    the Pallas screen folds the same min/maxes tile-by-tile into SMEM —
    min/max are reassociation-free, so the two agree bitwise)."""
    m_over, _, m_pack, m_strag = multipliers[:4]
    m_churn = _m_churn(multipliers)
    pos = jnp.float32(POS_INF)
    neg = jnp.float32(NEG_INF)

    def fold(w, on):
        if not on or w is None:
            return pos, neg
        return (
            jnp.min(jnp.where(valid, w, POS_INF)),
            jnp.max(jnp.where(valid, w, NEG_INF)),
        )

    c_lo = jnp.min(jnp.where(valid, cost_lb, POS_INF))
    c_hi = jnp.max(jnp.where(valid, cost_ub, NEG_INF))
    over_lo, over_hi = fold(over_raw, m_over)
    pack_lo, pack_hi = fold(pack_raw, m_pack)
    strag_lo, strag_hi = fold(strag_raw, m_strag)
    churn_lo, churn_hi = fold(churn_raw, m_churn)
    return ScreenConsts(c_lo, c_hi, over_lo, over_hi, pack_lo, pack_hi,
                        strag_lo, strag_hi, churn_lo, churn_hi)


def norm01(w: jax.Array, lo: jax.Array, hi: jax.Array) -> jax.Array:
    """OpenStack weight normalization against fixed global constants."""
    span = hi - lo
    return jnp.where(
        span > NORM_EPS, (w - lo) / jnp.where(span > NORM_EPS, span, 1.0), 0.0
    )


def inv_span(c_lo: jax.Array, c_hi: jax.Array) -> jax.Array:
    """1/(c_hi - c_lo) with the degenerate-span guard (0 disables the term)."""
    span = c_hi - c_lo
    good = span > NORM_EPS
    return jnp.where(good, 1.0 / jnp.where(good, span, 1.0), 0.0)


def base_from_consts(
    multipliers: Tuple[float, ...],
    over_raw: jax.Array,
    pack_raw: jax.Array,
    strag_raw: jax.Array,
    consts: ScreenConsts,
    churn_raw: jax.Array = None,
    gates: Tuple[float, ...] = None,
) -> jax.Array:
    """Enumeration-free weigher terms, summed in the ONE fixed order every
    path shares (bit-exact parity requires identical float ops); the churn
    term is added LAST so churn-off programs are unchanged.

    ``gates`` splits compile-time term selection from the arithmetic values:
    the scanned ensemble (``scan_sim.simulate_ensemble``) vmaps over a
    traced multiplier axis, so the term gates come from the STATIC policy
    (``gates``) while the per-lane values ride in ``multipliers``.  The
    default (``gates=None``) gates on ``multipliers`` itself — the exact
    pre-ensemble program."""
    if gates is None:
        gates = multipliers
    m_over, _, m_pack, m_strag = multipliers[:4]
    m_churn = _m_churn(multipliers)
    base = jnp.zeros_like(over_raw)
    if gates[0]:
        base = base + m_over * norm01(over_raw, consts.over_lo, consts.over_hi)
    if gates[2]:
        base = base + m_pack * norm01(pack_raw, consts.pack_lo, consts.pack_hi)
    if gates[3]:
        base = base + m_strag * norm01(strag_raw, consts.strag_lo, consts.strag_hi)
    if _m_churn(gates) and churn_raw is not None:
        base = base + m_churn * norm01(churn_raw, consts.churn_lo, consts.churn_hi)
    return base


def omega_of(
    best_cost: jax.Array,
    base: jax.Array,
    valid: jax.Array,
    consts: ScreenConsts,
    ispan: jax.Array,
    m_term: float,
    gate: float = None,
) -> jax.Array:
    """Total weigher score: base terms + the termination-cost weigher
    normalized with the *bound-derived* constants (not the enumerated costs'
    min/max) — computable in O(N·K), which is what lets stage 2 skip the
    enumeration for every non-shortlisted host while staying bit-exact.

    ``gate`` plays the same role as ``base_from_consts``'s ``gates``: the
    static include-the-term decision when ``m_term`` itself is traced
    (ensemble multiplier axis); ``None`` gates on ``m_term``."""
    w = base
    if m_term if gate is None else gate:
        w = w + m_term * ((consts.c_hi - jnp.minimum(best_cost, POS_INF)) * ispan)
    return jnp.where(valid, w, NEG_INF)


def slot_cost_by_kind(
    kind_eff: jax.Array,   # int32, effective kind id per slot (no -1 left)
    start: jax.Array,      # slot start times
    price: jax.Array,      # slot price rates
    ckpt: jax.Array,       # last durable-checkpoint times
    res0: jax.Array,       # slot resource dim 0 (chips/vcpus by convention)
    now: jax.Array,
    period,
) -> jax.Array:
    """Heterogeneous per-slot termination cost: a branchless ``where`` chain
    selecting among the four device-resident kinds by the slot's kind id
    (0=period, 1=count, 2=revenue, 3=recompute — ``policy.COST_KIND_IDS``).

    Each branch is the VERBATIM single-kind formula from
    ``jax_scheduler.slot_costs`` evaluated fleet-wide and then selected, so a
    slot billed by kind ``k`` gets bit-identical cost to a homogeneous
    kind-``k`` fleet — which is what keeps mixed-kind decisions bit-exact
    against the python ``MixedCost`` oracle on every backend (the select
    happens before the screen, so jnp / fused-kernel / sharded paths all
    consume the same cost array).

    Elementwise over any layout — callers pass (N, K) fleets or slot-major
    kernel rows alike.
    """
    part = floor_mod(now - start, period)
    cost = part                                               # kind 0: period
    cost = jnp.where(kind_eff == 1, jnp.ones_like(start), cost)  # count
    cost = jnp.where(kind_eff == 2, part / period * price, cost)  # revenue
    lost = jnp.maximum(0.0, now - ckpt) * jnp.maximum(1.0, res0)
    return jnp.where(kind_eff == 3, lost, cost)               # recompute


def floor_mod(x: jax.Array, period) -> jax.Array:
    """``x % period`` for non-negative x via floor — an order of magnitude
    faster than ``lax.rem``'s fmod on XLA CPU, where fmod was one of the
    biggest single terms of the whole decision at 10^5 hosts.  The rounding
    of ``x * (1/p)`` can put ``floor`` off by one exactly at period
    boundaries; the correction step folds the result back into [0, p),
    after which it matches fmod bitwise whenever x and p are exactly
    representable (the integer-second regime — all parity tests) and to
    1 ulp otherwise."""
    r = x - jnp.floor(x * (1.0 / period)) * period
    return jnp.where(r < 0, r + period, jnp.where(r >= period, r - period, r))
