"""The three schedulers evaluated in the paper (§4.5, Fig. 2).

* ``FilterScheduler``      — the unmodified OpenStack-style baseline:
                             filter on ``h_f``, weigh, pick.  Preemption-blind.
* ``RetryScheduler``       — the two-cycle design the paper argues against:
                             pass 1 = FilterScheduler; on failure of a normal
                             request, pass 2 re-filters against ``h_n`` and
                             runs select-and-terminate.
* ``PreemptibleScheduler`` — the paper's contribution (Alg. 2 + 6): ONE pass,
                             filtering view switched per request type
                             (normal → h_n, preemptible → h_f), weighing on
                             h_f, then select-and-terminate on the winner.

Schedulers are *pure deciders*: they return a ``ScheduleResult`` carrying the
winning host and the termination plan; applying the plan (evacuating jobs,
checkpointing) is the cluster runtime's job (core/cluster.py,
core/preemption.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from .cost import CostFunction, PeriodCost
from .filters import DEFAULT_FILTERS, Filter, run_filters
from .select_terminate import plan_for_host
from .types import (
    EMPTY_PLAN,
    Host,
    Request,
    Resources,
    ScheduleError,
    ScheduleResult,
    TerminationPlan,
)
from .weighers import (
    DEFAULT_WEIGHERS,
    PackingRank,
    WeighContext,
    Weigher,
    normalized_weights,
)


class BaseScheduler:
    def __init__(
        self,
        filters: Sequence[Filter] = DEFAULT_FILTERS,
        weighers: Optional[Sequence[Weigher]] = None,
        cost_fn: Optional[CostFunction] = None,
        seed: int = 0,
    ):
        self.filters = list(filters)
        self.weighers = list(weighers) if weighers is not None else list(DEFAULT_WEIGHERS)
        self.cost_fn = cost_fn or PeriodCost()
        self._rng = np.random.default_rng(seed)

    # -- shared machinery ----------------------------------------------------
    def _filter(
        self, req: Request, hosts: Sequence[Host], view: str
    ) -> List[Host]:
        """``view``: 'full' → h_f, 'normal' → h_n."""
        out = []
        for h in hosts:
            free = h.free_full if view == "full" else h.free_normal
            if run_filters(self.filters, h, req, free):
                out.append(h)
        return out

    def _pick(
        self, req: Request, candidates: Sequence[Host], ctx: WeighContext
    ) -> Optional[Host]:
        if not candidates:
            return None
        omega = normalized_weights(self.weighers, req, candidates, ctx)
        best = np.max(omega)
        if not np.isfinite(best):
            return None
        ties = np.flatnonzero(omega >= best - 1e-12)
        idx = int(ties[self._rng.integers(len(ties))]) if len(ties) > 1 else int(ties[0])
        return candidates[idx]

    def schedule(
        self, req: Request, hosts: Sequence[Host], now: float
    ) -> ScheduleResult:
        raise NotImplementedError


class FilterScheduler(BaseScheduler):
    """Unmodified baseline: one pass over ``h_f``; no preemption."""

    def __init__(self, **kw):
        kw.setdefault("weighers", (PackingRank(),))
        super().__init__(**kw)

    def schedule(self, req: Request, hosts: Sequence[Host], now: float) -> ScheduleResult:
        ctx = WeighContext(now=now, cost_fn=self.cost_fn)
        candidates = self._filter(req, hosts, view="full")
        host = self._pick(req, candidates, ctx)
        return ScheduleResult(request=req, host=host.name if host else None, passes=1)


class RetryScheduler(BaseScheduler):
    """Two-cycle comparison baseline (paper §4.5).

    Cycle 1 is the plain filter scheduler.  Only when a *normal* request
    fails does cycle 2 run: re-filter against ``h_n``, weigh on ``h_f``,
    select-and-terminate.  The doubled filter+weigh work on the unhappy path
    is exactly the latency penalty Fig. 2 shows.
    """

    def schedule(self, req: Request, hosts: Sequence[Host], now: float) -> ScheduleResult:
        ctx = WeighContext(now=now, cost_fn=self.cost_fn)
        # ---- cycle 1: preemption-blind
        candidates = self._filter(req, hosts, view="full")
        host = self._pick(req, candidates, ctx)
        if host is not None:
            return ScheduleResult(request=req, host=host.name, passes=1)
        if req.preemptible:
            return ScheduleResult(request=req, host=None, passes=1)
        # ---- cycle 2: evacuation-aware retry
        candidates = self._filter(req, hosts, view="normal")
        host = self._pick(req, candidates, ctx)
        if host is None:
            return ScheduleResult(request=req, host=None, passes=2)
        plan = plan_for_host(host, req, self.cost_fn, now, cache=ctx.plan_cache)
        if not plan.feasible:
            return ScheduleResult(request=req, host=None, passes=2)
        return ScheduleResult(request=req, host=host.name, plan=plan, passes=2)


class PreemptibleScheduler(BaseScheduler):
    """The paper's single-pass preemptible-aware scheduler (Alg. 2 + Alg. 6).

    Normal requests filter against ``h_n`` (seeing through preemptible
    instances); preemptible requests filter against ``h_f``.  Weighing always
    uses ``h_f``.  The Alg. 5 subset computed while weighing
    (TerminationCostRank) is memoized in the per-call plan cache and reused by
    the final select-and-terminate — the single-pass efficiency claim.
    """

    def schedule(self, req: Request, hosts: Sequence[Host], now: float) -> ScheduleResult:
        ctx = WeighContext(now=now, cost_fn=self.cost_fn)
        view = "full" if req.preemptible else "normal"
        candidates = self._filter(req, hosts, view=view)
        host = self._pick(req, candidates, ctx)
        if host is None:
            return ScheduleResult(request=req, host=None, passes=1)
        if req.preemptible or req.resources.fits_in(host.free_full):
            return ScheduleResult(request=req, host=host.name, passes=1)
        # overcommitted → select and terminate (Alg. 6 line 3-4)
        plan = plan_for_host(host, req, self.cost_fn, now, cache=ctx.plan_cache)
        if not plan.feasible:
            return ScheduleResult(request=req, host=None, passes=1)
        return ScheduleResult(request=req, host=host.name, plan=plan, passes=1)


SCHEDULER_REGISTRY = {
    "filter": FilterScheduler,
    "retry": RetryScheduler,
    "preemptible": PreemptibleScheduler,
}
