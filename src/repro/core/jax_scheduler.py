"""Vectorized JAX implementation of the preemptible-aware scheduler.

The paper's single-pass design (Alg. 2+5+6) has a property the retry design
lacks: *the whole decision is a pure function of the host-state arrays* — no
data-dependent second cycle.  We exploit that to turn scheduling into one
jit-compiled array program over struct-of-arrays host state, organized as a
**two-stage shortlist-pruned pipeline**:

    stage 1 (O(N·K))  screen:    dual-view fit mask, exact feasibility
                                 (full-subset test), termination-cost bounds
                                 from the sorted per-slot costs, and an
                                 optimistic weigher score ``omega_ub``;
    stage 2 (O(M·2^K)) decide:   ``lax.top_k`` shortlist of M candidates,
                                 gather their (M, K, D) slot rows, exact
                                 Alg. 5 subset enumeration + exact weighing
                                 on the shortlist only.

Stage 1 itself has two executions sharing ONE definition of the bounds math
(``core.screen_math``): the pure-jnp assembly below (the oracle, and the CPU
default), and the fused Pallas kernel ``repro.kernels.sched_screen`` that
computes every screen term per 128-host tile and keeps the running top-M
resident on chip, emitting only the (M+1,) shortlist + 10 normalization
scalars — one pass over the fleet instead of a dozen HBM round-trips
(``fused_screen``: None = auto, on for TPU backends, interpret-capable
elsewhere; pinned bit-exact against the jnp screen by
tests/test_sched_screen.py).

Only the argmax host's termination plan is ever applied, so pruning is
*exact*: an admissibility check compares the winner's exact score against the
optimistic bound of every non-shortlisted host and falls back to the full
O(N·2^K) enumeration (``lax.cond``) in the rare case the shortlist could have
excluded the true winner.  Decisions are therefore bit-identical with the
unpruned path (pinned by tests/test_shortlist_parity.py), while the complexity
drops from O(N·2^K) to O(N·K + M·2^K) — K=12 (4096 masks) becomes affordable
at 10^5 hosts.

Cost functions must be *per-instance additive* (all of the paper's are:
period, count, revenue, recompute), so a subset's cost is ``mask @ inst_cost``
and Alg. 5 becomes a masked matmul + argmin — MXU-shaped work.  The Pallas
kernel in ``repro.kernels.sched_weigh`` fuses the stage-2 enumeration over
VMEM tiles (both the full fleet and the gathered shortlist); this module
provides the pure-jnp equivalent (also the kernel's oracle) and the
end-to-end scheduler wrapper used by benchmarks.

Capacity model: each host carries up to ``K`` preemptible instances (padded,
masked).  2^K subset masks are enumerated exactly — K≤12 covers every
practical oversubscription level (the paper's testbed peaked at 4).

Two state flavors:

* ``SoAHostState`` + ``build_soa_state`` — rebuilt from python ``Host``
  objects per call (the correctness oracle; O(N·K) python work per request);
* ``SoAFleetState`` + ``build_fleet_state`` — built once, then updated
  incrementally on device via the pure transitions below (``schedule_step``,
  ``schedule_many``, ``apply_*``) — the fleet-scale fast path driven by
  ``core.soa_fleet.SoAFleet`` / ``core.simulator.SoASimulator``.  The
  decision/transition entry points donate the input state's buffers
  (``donate_argnums``) so per-event updates happen in place; pass
  ``donate=False`` when the caller needs the input state afterwards.

Exactness note: with integer-valued resources and slot costs (the paper's
workload regime, and what every parity test generates) all the screen's sums
are exact in f32, its bounds hold bitwise, and shortlist decisions are
unconditionally identical to the full enumeration.  With arbitrary float
costs (e.g. the "revenue" kind's ``/period``), the bound sums can differ
from the enumeration's subset sums by f32 reassociation ulps; the
admissibility check pads its strict branch by that margin, leaving one
residual caveat: two hosts whose *exact* scores collide to the same f32
omega may resolve their tie differently between the two paths.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .cost import (
    BILL_PERIOD_S,
    CostFunction,
    CountCost,
    MixedCost,
    PeriodCost,
    RecomputeCost,
    RevenueCost,
)
from .policy import (
    COST_KIND_IDS,
    DEFAULT_SHORTLIST,
    SchedulerPolicy,
    ensure_policy,
)
from .screen_math import (
    EPS,
    NEG_INF,
    POS_INF,
    TIE_EPS,
    ScreenConsts,
    base_from_consts,
    churn_of,
    consts_of,
    floor_mod,
    inv_span,
    omega_of,
    oem_pairs as _oem_pairs,  # noqa: F401  (back-compat re-export)
    raw_base_terms,
    screen_bounds_rows,
    slot_cost_by_kind,
    sort_rows as _net_sort_cols,  # noqa: F401  (back-compat re-export)
    total_rows,
)
from .types import (
    EMPTY_PLAN,
    Host,
    Instance,
    Request,
    ScheduleResult,
    TerminationPlan,
)

# DEFAULT_SHORTLIST (the shortlist=None auto size; fleets not meaningfully
# larger keep the single-stage full enumeration) lives in ``policy`` and is
# re-exported here for back-compat.


# ---------------------------------------------------------------------------
# SoA host state
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SoAHostState:
    """Struct-of-arrays mirror of a host fleet (device-resident)."""

    free_f: jax.Array       # (N, D) h_f free resources
    free_n: jax.Array       # (N, D) h_n free resources
    schedulable: jax.Array  # (N,)   bool
    domain: jax.Array       # (N,)   int32
    slow: jax.Array         # (N,)   float32 straggler factor
    inst_res: jax.Array     # (N, K, D) preemptible instance resources (padded)
    inst_cost: jax.Array    # (N, K)    per-instance termination cost
    inst_valid: jax.Array   # (N, K)    bool
    #: optional per-host learned zone-churn rate ẑ (None = churn-blind;
    #: the persistent path derives it from the zone accumulators per step,
    #: the rebuild oracle freezes it at build via ``zone_rates``).
    churn: Optional[jax.Array] = None  # (N,) float32
    #: optional per-host zone id (None = zone-blind); consumed by the
    #: relocation plane's per-request zone-exclusion filter.
    host_zone: Optional[jax.Array] = None  # (N,) int32

    @property
    def n_hosts(self) -> int:
        return self.free_f.shape[0]

    @property
    def k_slots(self) -> int:
        return self.inst_res.shape[1]


def _hosts_to_arrays(
    hosts: Sequence[Host],
    k_slots: int,
    domain_ids: Optional[Dict[str, int]],
):
    """Shared host→array conversion for both state flavors: the common
    per-host columns plus the per-host preemptible lists (sorted by id),
    with the ``k_slots`` overflow check applied.

    Returns ``(d, free_f, free_n, schedulable, domain, slow, pre_lists)``.
    """
    n = len(hosts)
    d = len(hosts[0].capacity.spec.dims) if hosts else 0
    if domain_ids is None:
        domain_ids = {}
        for h in hosts:
            domain_ids.setdefault(h.domain, len(domain_ids))
    free_f = np.zeros((n, d), np.float32)
    free_n = np.zeros((n, d), np.float32)
    schedulable = np.zeros((n,), bool)
    domain = np.zeros((n,), np.int32)
    slow = np.ones((n,), np.float32)
    pre_lists: List[List[Instance]] = []
    for i, h in enumerate(hosts):
        free_f[i] = h.free_full.vec
        free_n[i] = h.free_normal.vec
        schedulable[i] = h.schedulable
        domain[i] = domain_ids[h.domain]
        slow[i] = h.slow_factor
        pre = sorted(h.preemptible_instances(), key=lambda x: x.id)
        if len(pre) > k_slots:
            raise ValueError(
                f"host {h.name} has {len(pre)} preemptible instances > k_slots={k_slots}"
            )
        pre_lists.append(pre)
    return d, free_f, free_n, schedulable, domain, slow, pre_lists


def build_soa_state(
    hosts: Sequence[Host],
    now: float,
    cost_fn: Optional[CostFunction] = None,
    k_slots: int = 8,
    domain_ids: Optional[Dict[str, int]] = None,
    zone_rates: Optional[Dict[str, float]] = None,
    zone_ids: Optional[Dict[str, int]] = None,
) -> Tuple[SoAHostState, List[List[Instance]]]:
    """Convert python ``Host`` objects to device arrays.

    Returns the state plus the per-host preemptible instance lists (slot
    order), needed to translate a winning mask back into instance ids.

    ``zone_rates`` optionally freezes a per-zone churn rate ẑ (zone name →
    rate; missing zones read 0.0) into the state's ``churn`` column — the
    rebuild oracle's counterpart of the persistent path's online-learned
    zone accumulators.  ``zone_ids`` (zone name → id; missing zones map to
    -2, which no exclusion operand ever matches) builds the ``host_zone``
    column the relocation plane's zone-exclusion filter reads.
    """
    cost_fn = cost_fn or PeriodCost()
    n = len(hosts)
    d, free_f, free_n, schedulable, domain, slow, pre_lists = _hosts_to_arrays(
        hosts, k_slots, domain_ids
    )
    inst_res = np.zeros((n, k_slots, d), np.float32)
    inst_cost = np.zeros((n, k_slots), np.float32)
    inst_valid = np.zeros((n, k_slots), bool)
    slots: List[List[Instance]] = []
    for i, pre in enumerate(pre_lists):
        slots.append(pre)
        for k, inst in enumerate(pre):
            inst_res[i, k] = inst.resources.vec
            inst_cost[i, k] = cost_fn.cost([inst], now)
            inst_valid[i, k] = True
    churn = None
    if zone_rates is not None:
        churn = jnp.asarray(
            [float(zone_rates.get(h.zone, 0.0)) for h in hosts], jnp.float32
        )
    host_zone = None
    if zone_ids is not None:
        host_zone = jnp.asarray(
            [int(zone_ids.get(h.zone, -2)) for h in hosts], jnp.int32
        )
    state = SoAHostState(
        free_f=jnp.asarray(free_f),
        free_n=jnp.asarray(free_n),
        schedulable=jnp.asarray(schedulable),
        domain=jnp.asarray(domain),
        slow=jnp.asarray(slow),
        inst_res=jnp.asarray(inst_res),
        inst_cost=jnp.asarray(inst_cost),
        inst_valid=jnp.asarray(inst_valid),
        churn=churn,
        host_zone=host_zone,
    )
    return state, slots


def subset_masks(k: int) -> np.ndarray:
    """(2^k, k) 0/1 matrix enumerating all subsets (row 0 = empty set)."""
    m = np.arange(1 << k, dtype=np.uint32)
    return ((m[:, None] >> np.arange(k)[None, :]) & 1).astype(np.float32)


def _masks_const(k: int) -> jax.Array:
    """The (2^k, k) mask matrix as a trace-time constant.

    Built from the *static* slot count inside jit, so it is folded into the
    compiled executable once instead of being transferred per call."""
    return jnp.asarray(subset_masks(k))


# ---------------------------------------------------------------------------
# The jit'd decision (pure jnp; also the Pallas kernel's oracle)
# ---------------------------------------------------------------------------


def host_plan_terms(
    free_f: jax.Array,
    inst_res: jax.Array,
    inst_cost: jax.Array,
    inst_valid: jax.Array,
    req_res: jax.Array,
    masks: jax.Array,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Per-host Alg. 5 terms, vectorized over all hosts and all 2^K masks.

    Returns (best_cost, best_mask_idx, any_feasible):
      best_cost   (N,)  cost of the cheapest feasible termination subset
                        (0 where the request already fits h_f),
      best_mask   (N,)  int32 index into ``masks``,
      feasible    (N,)  whether ANY subset admits the request.
    """
    # Invalid slots contribute nothing and cost +inf if ever selected.
    res = jnp.where(inst_valid[..., None], inst_res, 0.0)            # (N,K,D)
    cost = jnp.where(inst_valid, inst_cost, POS_INF)                 # (N,K)
    # One (N,K)@(K,M) matmul per resource dimension (D small, static →
    # unrolled) instead of materializing the (N,M,D) freed tensor — the same
    # MXU-shaped formulation as the Pallas kernel, and ~1.5x faster on CPU.
    mT = masks.T                                                     # (K,M)
    ok = None
    for d in range(res.shape[-1]):
        cond = free_f[:, d][:, None] + res[:, :, d] @ mT >= req_res[d] - EPS
        ok = cond if ok is None else (ok & cond)                     # (N,M)
    # Subsets touching an invalid slot are excluded via +inf cost.
    sub_cost = jnp.where(ok, cost @ mT, POS_INF)                     # (N,M)
    # Tie-break: cheaper cost first, then fewer instances, then first index
    # (matches the python reference).  Two-stage to stay exact in f32.
    best_cost = jnp.min(sub_cost, axis=-1)                           # (N,)
    size = masks.sum(-1)                                             # (M,)
    is_tie = sub_cost <= best_cost[:, None] + TIE_EPS
    size_key = jnp.where(is_tie, size[None, :], POS_INF)
    best_mask = jnp.argmin(size_key, axis=-1).astype(jnp.int32)      # (N,)
    feasible = jnp.any(ok, axis=-1)
    return best_cost, best_mask, feasible


def screen_terms(
    free_f: jax.Array,
    inst_res: jax.Array,
    inst_cost: jax.Array,
    inst_valid: jax.Array,
    req_res: jax.Array,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Stage-1 per-host screening terms, O(N·K) — no subset enumeration.

    Thin row-major adapter over ``screen_math.screen_bounds_rows`` (ONE
    shared definition with the fused Pallas screen): slices the (N, K, ...)
    slot arrays into slot-major rows so the Batcher compare-exchange network
    runs on contiguous host-vectors, which is also ~15% faster on XLA CPU
    than the previous host-major column slices.

    Returns ``(feasible, overcommitted, cost_lb, cost_ub)``, all (N,) —
    see ``screen_bounds_rows`` for the exact semantics of each term.
    """
    k = inst_res.shape[1]
    need = (req_res[None, :] - free_f).T                             # (D,N)
    res_rows = [
        jnp.where(inst_valid[:, i, None], inst_res[:, i, :], 0.0).T
        for i in range(k)
    ]
    cost_rows = [
        jnp.where(inst_valid[:, i], inst_cost[:, i], POS_INF) for i in range(k)
    ]
    total = total_rows(
        [jnp.where(inst_valid[:, i], inst_cost[:, i], 0.0) for i in range(k)]
    )
    return screen_bounds_rows(need, res_rows, cost_rows, total)


def _stage1_rows(
    free_f: jax.Array,
    free_n: jax.Array,
    schedulable: jax.Array,
    domain: jax.Array,
    slow: jax.Array,
    inst_res: jax.Array,
    inst_cost: jax.Array,
    inst_valid: jax.Array,
    req_res: jax.Array,
    req_preemptible: jax.Array,
    req_domain: jax.Array,
    require_free_slot: bool,
    churn: Optional[jax.Array] = None,
    churn_threshold: Optional[float] = None,
    host_zone: Optional[jax.Array] = None,
    exclude_zone: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array, Tuple[jax.Array, ...]]:
    """Stage-1 screen assembly on row-major host arrays: the dual-view fit
    mask (the paper's trick), the shared ``screen_math`` bounds, and the raw
    enumeration-free weigher terms.

    ONE definition executed for the full fleet (jnp screen / fallback), for
    gathered candidate rows (the fused path's per-candidate recompute), and
    per shard under ``shard_map`` (the device-sharded screen) — all three
    see identical elementwise outputs, which is what keeps every stage-1
    backend bit-exact with the others.

    ``churn`` (per-host learned zone-churn rate ẑ, see ``churn_of``) adds
    the churn-penalty raw term; a static ``churn_threshold`` additionally
    steers preemptible placements off hot zones entirely (the graceful-
    degradation hard filter — normal requests are unaffected).

    ``host_zone`` + ``exclude_zone`` (the relocation plane's per-request
    operand, -1 = none) hard-filter an entire failure zone out of the
    screen — pure integer/boolean math, so the gate is trivially identical
    on every backend (the same shape of filter as ``req_domain``).

    Returns ``(valid, cost_lb, cost_ub, raw)`` (``raw`` grows a 4th entry
    when churn-aware).
    """
    view = jnp.where(req_preemptible, free_f, free_n)
    fits = jnp.all(view >= req_res[None, :] - EPS, axis=-1)
    fits &= schedulable
    fits &= (req_domain < 0) | (domain == req_domain)
    if exclude_zone is not None and host_zone is not None:
        # Relocation re-placements flee their source zone: no host of that
        # zone may win, regardless of how calm its churn currently reads.
        fits &= (exclude_zone < 0) | (host_zone != exclude_zone)
    if churn_threshold is not None and churn is not None:
        # Hot-zone steering: preemptible work avoids zones whose learned
        # churn rate crossed the policy threshold (normal work still lands —
        # its instances are not the ones zone churn kills).
        fits &= jnp.where(
            req_preemptible, churn <= jnp.float32(churn_threshold), True
        )
    if require_free_slot:
        # Persistent state carries K slots per host: a preemptible request
        # needs an empty slot (the rebuild path raises on overflow instead).
        fits &= jnp.where(req_preemptible, jnp.any(~inst_valid, axis=-1), True)
    feas, overcommitted, cost_lb, cost_ub = screen_terms(
        free_f, inst_res, inst_cost, inst_valid, req_res
    )
    # Preemptible requests never terminate others: zero cost everywhere.
    cost_lb = jnp.where(req_preemptible, 0.0, cost_lb)
    cost_ub = jnp.where(req_preemptible, 0.0, cost_ub)
    feas = jnp.where(req_preemptible, fits, feas)
    valid = fits & feas
    raw = raw_base_terms(jnp.sum(free_f, axis=-1), slow, overcommitted, churn)
    return valid, cost_lb, cost_ub, raw


def _base_of(mult, raw, consts: ScreenConsts, gates=None) -> jax.Array:
    """``base_from_consts`` over a 3- or 4-entry ``raw`` tuple (the 4th is
    the churn term) — the one unpacking every assembly site shares.
    ``gates`` = the static multipliers when ``mult`` carries traced
    per-lane values (ensemble axis); None gates on ``mult`` itself."""
    churn_raw = raw[3] if len(raw) > 3 else None
    return base_from_consts(
        mult, raw[0], raw[1], raw[2], consts, churn_raw=churn_raw,
        gates=gates,
    )


def _sharded_screen(
    mesh,
    free_f, free_n, schedulable, domain, slow,
    inst_res, inst_cost, inst_valid,
    req_res, req_preemptible, req_domain,
    mult: Tuple[float, ...],
    require_free_slot: bool,
    m_cand: int,
    use_fused: bool = False,
    churn: Optional[jax.Array] = None,
    churn_threshold: Optional[float] = None,
    host_zone: Optional[jax.Array] = None,
    exclude_zone: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Stage-1 screen per host-major shard under ``jax.shard_map``.

    Each shard runs the unchanged ``screen_math`` bounds on its block of
    hosts, folds its local normalization partials, and the mesh merges:

      * ``ScreenConsts`` via ``lax.pmin``/``lax.pmax`` — min/max are
        reassociation-free, so the merged scalars are bitwise equal to the
        unsharded fleet-wide folds in ``consts_of``;
      * a per-shard top-M (``lax.top_k`` — kept at M so XLA CPU's fast TopK
        custom-call still applies per shard) plus the shard's own
        admissibility witness (masked argmax, ties to the lowest index),
        tagged with GLOBAL host indices and ``all_gather``-ed.

    Returns replicated ``(scores (S·(M+1),), idxs (S·(M+1),), consts (10,))``
    for ``fleet_sharding.merge_shortlists`` to reduce into the global
    shortlist.  Callers guarantee ``N % S == 0`` and ``N/S ≥ m_cand + 1``.
    ``churn`` (optional per-host ẑ, sharded host-major like the other rows)
    and a static ``churn_threshold`` thread the failure-domain terms through
    the per-shard screen — the merged churn-normalization scalars come out
    of the same pmin/pmax folds, so churn-aware sharded decisions stay
    bit-exact with the unsharded screen.  ``host_zone`` (sharded host-major)
    + ``exclude_zone`` (replicated scalar) thread the relocation plane's
    zone-exclusion filter the same way — a pure boolean row gate, so
    sharding cannot perturb it.

    ``use_fused`` runs the shard-local screen through the fused Pallas
    kernel instead of the jnp assembly, split at the constants barrier
    (``sched_screen_consts`` → pmin/pmax merge → ``sched_screen_topm``): the
    per-shard top-(M+1) then comes out of the kernel's on-chip bitonic fold,
    computed from the SAME merged constants the jnp shards use, so the
    forwarded (score, index) pairs are identical and the kernel and mesh
    stop being mutually exclusive.  (One benign exception: a shard whose
    non-shortlisted hosts are ALL invalid (score NEG_INF) may forward a
    different — equally inert — witness index than the jnp masked argmax;
    both are dominated by every real candidate and cannot change a
    decision.)  On non-TPU backends the kernel runs in interpret mode
    (parity-gated by tests/test_sharded_parity.py).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    axis = mesh.axis_names[0]
    m_term = mult[1]

    def shard_fn(free_f, free_n, schedulable, domain, slow,
                 inst_res, inst_cost, inst_valid,
                 req_res, req_preemptible, req_domain, *extras):
        # The optional failure-domain operands arrive positionally in a
        # fixed order (churn row, zone row, exclusion scalar) — decode by
        # which ones the caller actually supplied.
        extra = list(extras)
        churn_l = extra.pop(0) if churn is not None else None
        zone_l = extra.pop(0) if host_zone is not None else None
        excl_l = extra.pop(0) if exclude_zone is not None else None
        t = free_f.shape[0]  # hosts per shard
        offset = (jax.lax.axis_index(axis) * t).astype(jnp.int32)
        if use_fused:
            from repro.kernels.sched_screen import (
                sched_screen_consts,
                sched_screen_topm,
            )

            kern_args = (
                free_f, free_n, schedulable, domain, slow,
                inst_res, inst_cost, inst_valid,
                req_res, req_preemptible, req_domain,
            )
            local = ScreenConsts.unpack(sched_screen_consts(
                *kern_args,
                weigher_multipliers=mult,
                require_free_slot=require_free_slot,
                churn=churn_l,
                churn_threshold=churn_threshold,
                host_zone=zone_l,
                exclude_zone=excl_l,
            ))
        else:
            valid, cost_lb, cost_ub, raw = _stage1_rows(
                free_f, free_n, schedulable, domain, slow,
                inst_res, inst_cost, inst_valid,
                req_res, req_preemptible, req_domain, require_free_slot,
                churn=churn_l, churn_threshold=churn_threshold,
                host_zone=zone_l, exclude_zone=excl_l,
            )
            local = consts_of(mult, valid, cost_lb, cost_ub, *raw)
        consts = ScreenConsts(
            jax.lax.pmin(local.c_lo, axis), jax.lax.pmax(local.c_hi, axis),
            jax.lax.pmin(local.over_lo, axis), jax.lax.pmax(local.over_hi, axis),
            jax.lax.pmin(local.pack_lo, axis), jax.lax.pmax(local.pack_hi, axis),
            jax.lax.pmin(local.strag_lo, axis), jax.lax.pmax(local.strag_hi, axis),
            jax.lax.pmin(local.churn_lo, axis), jax.lax.pmax(local.churn_hi, axis),
        )
        if use_fused:
            # Kernel top-(M+1) from the MERGED constants; entry M is the
            # shard's admissibility witness (best non-shortlisted omega_ub,
            # lax.top_k tie order — the same candidate the masked argmax
            # surfaces whenever it is a real score).
            s_all, i_all = sched_screen_topm(
                *kern_args,
                consts=consts.pack(),
                weigher_multipliers=mult,
                require_free_slot=require_free_slot,
                m_keep=m_cand + 1,
                churn=churn_l,
                churn_threshold=churn_threshold,
                host_zone=zone_l,
                exclude_zone=excl_l,
            )
            scores = s_all
            idxs = i_all.astype(jnp.int32) + offset
        else:
            base = _base_of(mult, raw, consts)
            ispan_ub = inv_span(consts.c_lo, consts.c_hi)
            opt_cost = cost_lb if m_term >= 0 else cost_ub
            omega_ub = omega_of(opt_cost, base, valid, consts, ispan_ub, m_term)
            s_loc, p_loc = jax.lax.top_k(omega_ub, m_cand)
            in_short = jnp.zeros((t,), bool).at[p_loc].set(True)
            out_ub = jnp.where(in_short, jnp.float32(NEG_INF), omega_ub)
            u_loc = jnp.max(out_ub)
            ju_loc = jnp.argmax(out_ub).astype(jnp.int32) + offset
            scores = jnp.concatenate([s_loc, u_loc[None]])
            idxs = jnp.concatenate(
                [p_loc.astype(jnp.int32) + offset, ju_loc[None]]
            )
        all_s = jax.lax.all_gather(scores, axis).reshape(-1)
        all_i = jax.lax.all_gather(idxs, axis).reshape(-1)
        return all_s, all_i, consts.pack()

    row = P(axis)
    rep = P()
    operands = (
        free_f, free_n, schedulable, domain, slow,
        inst_res, inst_cost, inst_valid,
        req_res, req_preemptible, req_domain,
    )
    in_specs = (row,) * 8 + (rep, rep, rep)
    if churn is not None:
        # The churn column shards host-major like every other per-host row.
        operands += (churn,)
        in_specs += (row,)
    if host_zone is not None:
        operands += (host_zone,)
        in_specs += (row,)
    if exclude_zone is not None:
        # The per-request exclusion id is a replicated scalar (like req_*).
        operands += (exclude_zone,)
        in_specs += (rep,)
    return shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=(rep, rep, rep),
        check_rep=False,
    )(*operands)


def _plan_terms(use_pallas: bool, gathered: bool = False):
    """Enumeration backend: Pallas kernel (full-fleet or gathered-shortlist
    tiling) or the pure-jnp oracle."""
    if use_pallas:
        from repro.kernels.sched_weigh import sched_weigh, sched_weigh_gathered

        return sched_weigh_gathered if gathered else sched_weigh
    return host_plan_terms


def _decision_core(
    free_f: jax.Array,
    free_n: jax.Array,
    schedulable: jax.Array,
    domain: jax.Array,
    slow: jax.Array,
    inst_res: jax.Array,
    inst_cost: jax.Array,
    inst_valid: jax.Array,
    req_res: jax.Array,
    req_preemptible: jax.Array,
    req_domain: jax.Array,
    policy: SchedulerPolicy,
    require_free_slot: bool,
    churn: Optional[jax.Array] = None,
    host_zone: Optional[jax.Array] = None,
    exclude_zone: Optional[jax.Array] = None,
    mult_val: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """The two-stage decision pipeline on raw SoA arrays (shared by the
    rebuild path, the persistent fast path, and the batched ``lax.scan``
    path).  ``policy`` is the ONE static knob bundle (``core.policy``); the
    fields it reads here:

    ``policy.shortlist``: stage-2 candidate count M.  ``None`` = auto (64 at
    fleet scale, full enumeration for small fleets); ``0`` disables pruning.
    Any value yields decisions bit-identical to the full enumeration — when
    the admissibility check cannot certify the shortlist, the full path runs
    via ``lax.cond``.

    ``policy.fused_screen``: run stage 1 through the fused Pallas kernel
    (``repro.kernels.sched_screen``) instead of the jnp assembly.  ``None``
    = auto (on for TPU backends, where it collapses the screen's HBM
    round-trips into one pass; off elsewhere — the kernel stays available in
    interpret mode for parity testing).  Both screens execute the shared
    ``screen_math`` definitions, so the decision is identical either way.

    ``policy.mesh``: a 1-D ``jax.sharding.Mesh`` (see ``fleet_sharding``)
    running stage 1 per host-major shard under ``shard_map`` with a bit-exact
    cross-shard merge — the fleet-scale path past the single-device ceiling.
    Combined with ``fused_screen=True`` the kernel runs *per shard* inside
    ``shard_map`` (split at the constants barrier).  Requires the host
    count to divide across the mesh with ≥ M+1 hosts per shard (pad with
    ``fleet_sharding.padded_hosts``/``pad_fleet_state``); otherwise the
    unsharded screen runs (same decision, just not shard-parallel).

    ``policy.use_pallas`` selects the stage-2 enumeration backend;
    ``policy.weigher_multipliers`` the scoring policy.  The slot costs in
    ``inst_cost`` are computed by the caller (``fleet_slot_costs`` for
    persistent states — including the heterogeneous kind-table selection —
    or frozen at build for ``SoAHostState``), so every screen backend
    consumes identical cost arrays.

    Returns ``(host_idx, term_mask_idx, ok, fell_back, margin)``:
    ``fell_back`` flags decisions where the admissibility check could not
    certify the shortlist and the full enumeration ran; ``margin`` is the
    admissibility headroom ``best_val - u`` (POS_INF when no valid host or
    pruning was off) — the signals the adaptive shortlist controller
    (``soa_fleet.AdaptiveShortlist``) steers M with.
    """
    use_pallas = policy.use_pallas
    mesh = policy.mesh
    shortlist = policy.shortlist
    fused_screen = policy.fused_screen
    n_hosts, k = inst_res.shape[0], inst_res.shape[1]
    masks = _masks_const(k)
    if shortlist is None:
        shortlist = DEFAULT_SHORTLIST if n_hosts > 4 * DEFAULT_SHORTLIST else 0
    m_cand = min(int(shortlist), n_hosts)
    if fused_screen is None:
        fused_screen = jax.default_backend() == "tpu" and mesh is None
    # Failure-domain plane: churn-aware only when the caller supplied the ẑ
    # column AND the policy turns a churn knob — otherwise both are dropped
    # statically and the compiled program is the exact churn-blind one.
    churn_on = churn is not None and policy.churn_aware
    if not churn_on:
        churn = None
    # Relocation plane: the zone-exclusion operand rides only when the
    # caller supplied the zone column AND the policy turns the plane on —
    # relocation-off policies compile the exact pre-relocation program.
    zone_on = (
        host_zone is not None
        and exclude_zone is not None
        and policy.relocation_on
    )
    if not zone_on:
        host_zone = None
        exclude_zone = None
    mult = policy.all_multipliers if churn_on else policy.weigher_multipliers
    thr = policy.churn_threshold if churn_on else None
    # Ensemble multiplier axis: ``mult_val`` carries traced per-lane weigher
    # values (a (5,) f32 vector under vmap); the STATIC policy multipliers
    # keep their role as compile-time term gates (``gates``), so lanes share
    # one program whose included terms — and the termination-cost bound side
    # (`opt_cost`) — are fixed by the policy while the arithmetic rides the
    # lane values.  ``mult_val=None`` (every pre-existing caller) compiles
    # the exact unchanged program.
    gates = mult
    if mult_val is not None:
        mult = tuple(mult_val[i] for i in range(len(gates)))
    m_term = mult[1]
    m_term_gate = gates[1]
    use_mesh = (
        mesh is not None
        and m_cand > 0
        and n_hosts % mesh.size == 0
        and n_hosts // mesh.size >= m_cand + 1
    )
    if mult_val is not None and (use_mesh or fused_screen):
        raise NotImplementedError(
            "traced multiplier values (ensemble axis) are not supported on "
            "the mesh/fused-screen stage-1 paths — those close the static "
            "multipliers over shard_map / the Pallas kernel; run the "
            "ensemble with fused_screen=False and mesh=None"
        )

    def stage1_of(free_f, free_n, schedulable, domain, slow, inst_res,
                  inst_cost, inst_valid, churn=None, host_zone=None):
        """Stage-1 screen assembly on row-major arrays (the shared
        ``_stage1_rows`` with this decision's request closed over) — used
        for the full fleet (jnp screen / fallback) and for gathered
        candidate rows (the fused/sharded paths' per-candidate recompute).
        Same shared math as the kernel and the sharded screen, so the
        outputs agree elementwise.  ``exclude_zone`` (a replicated scalar,
        like the request operands) is closed over."""
        return _stage1_rows(
            free_f, free_n, schedulable, domain, slow,
            inst_res, inst_cost, inst_valid,
            req_res, req_preemptible, req_domain, require_free_slot,
            churn=churn, churn_threshold=thr,
            host_zone=host_zone, exclude_zone=exclude_zone,
        )

    def full_decision(_):
        """Single-stage path: exact enumeration over every host.  Fully
        self-contained (the fused screen never materializes fleet-wide
        terms, so the fallback recomputes stage 1 with the same shared math
        — bit-identical to the ``shortlist=0`` result either way)."""
        valid, cost_lb, cost_ub, raw = stage1_of(
            free_f, free_n, schedulable, domain, slow,
            inst_res, inst_cost, inst_valid, churn, host_zone,
        )
        consts = consts_of(gates, valid, cost_lb, cost_ub, *raw)
        base = _base_of(mult, raw, consts, gates=gates)
        ispan = inv_span(consts.c_lo, consts.c_hi)
        best_cost, best_mask, _ = _plan_terms(use_pallas)(
            free_f, inst_res, inst_cost, inst_valid, req_res, masks
        )
        best_cost = jnp.where(req_preemptible, 0.0, best_cost)
        best_mask = jnp.where(req_preemptible, 0, best_mask)
        omega = omega_of(best_cost, base, valid, consts, ispan, m_term,
                         gate=m_term_gate)
        host_idx = jnp.argmax(omega).astype(jnp.int32)
        return host_idx, best_mask[host_idx], omega[host_idx] > NEG_INF / 2

    if m_cand <= 0 or m_cand >= n_hosts:
        h, bm, ok = full_decision(None)
        return h, bm, ok, jnp.asarray(False), jnp.float32(POS_INF)

    # ---- stage 1: O(N·K) screen → top-M candidates + (u, j_u) witness -------
    # omega_ub ≥ omega at float level: cost_lb ≤ best_cost and every op in
    # omega_of is monotone (shared constants, shared add order).
    if use_mesh:
        # Per-shard screen under shard_map; the merge reduces the gathered
        # per-shard (top-M + witness) pairs into the global shortlist with
        # lax.top_k's exact tie ordering, and the pmin/pmax-merged constants
        # are bitwise equal to the fleet-wide folds.  fused_screen=True runs
        # the per-shard screen through the Pallas kernel (no longer mutually
        # exclusive with the mesh).
        from .fleet_sharding import merge_shortlists

        all_s, all_i, consts_arr = _sharded_screen(
            mesh,
            free_f, free_n, schedulable, domain, slow,
            inst_res, inst_cost, inst_valid,
            req_res, req_preemptible, req_domain,
            mult, require_free_slot, m_cand,
            use_fused=bool(fused_screen),
            churn=churn, churn_threshold=thr,
            host_zone=host_zone, exclude_zone=exclude_zone,
        )
        consts = ScreenConsts.unpack(consts_arr)
        cand, u, j_u = merge_shortlists(all_s, all_i, m_cand)
        # Per-candidate base/valid recomputed on the gathered (replicated)
        # shortlist rows — elementwise identical to the fleet-wide values.
        valid_c, _, _, raw_c = stage1_of(
            free_f[cand], free_n[cand], schedulable[cand], domain[cand],
            slow[cand], inst_res[cand], inst_cost[cand], inst_valid[cand],
            churn[cand] if churn_on else None,
            host_zone[cand] if zone_on else None,
        )
        base_c = _base_of(mult, raw_c, consts, gates=gates)
    elif fused_screen:
        # One fused pass over the fleet; only the (M+1,) shortlist and the 10
        # normalization scalars come back.  Entry M is the best omega_ub
        # outside the shortlist with lax.top_k tie ordering — the (u, j_u)
        # admissibility witness.
        from repro.kernels.sched_screen import sched_screen

        top_s, top_i, consts_arr = sched_screen(
            free_f, free_n, schedulable, domain, slow,
            inst_res, inst_cost, inst_valid,
            req_res, req_preemptible, req_domain,
            weigher_multipliers=mult,
            require_free_slot=require_free_slot,
            m_keep=m_cand + 1,
            churn=churn,
            churn_threshold=thr,
            host_zone=host_zone,
            exclude_zone=exclude_zone,
        )
        consts = ScreenConsts.unpack(consts_arr)
        cand = top_i[:m_cand]
        u, j_u = top_s[m_cand], top_i[m_cand]
        # Per-candidate base/valid recomputed on the gathered rows from the
        # kernel's constants — elementwise identical to the fleet-wide jnp
        # values (min/max folds are reassociation-free).
        valid_c, _, _, raw_c = stage1_of(
            free_f[cand], free_n[cand], schedulable[cand], domain[cand],
            slow[cand], inst_res[cand], inst_cost[cand], inst_valid[cand],
            churn[cand] if churn_on else None,
            host_zone[cand] if zone_on else None,
        )
        base_c = _base_of(mult, raw_c, consts, gates=gates)
    else:
        valid, cost_lb, cost_ub, raw = stage1_of(
            free_f, free_n, schedulable, domain, slow,
            inst_res, inst_cost, inst_valid, churn, host_zone,
        )
        consts = consts_of(gates, valid, cost_lb, cost_ub, *raw)
        base = _base_of(mult, raw, consts, gates=gates)
        ispan_ub = inv_span(consts.c_lo, consts.c_hi)
        # Bound side chosen by the STATIC sign: ensemble lanes must keep the
        # policy's sign so omega_ub stays an upper bound (validated by
        # scan_sim.simulate_ensemble before any lane runs).
        opt_cost = cost_lb if m_term_gate >= 0 else cost_ub
        omega_ub = omega_of(opt_cost, base, valid, consts, ispan_ub, m_term,
                            gate=m_term_gate)
        # NOTE: top_k(M) + a masked argmax for the (u, j_u) witness, NOT the
        # seemingly cleaner top_k(M+1) whose entry M is the same witness:
        # XLA CPU only rewrites top_k into its fast TopK custom-call for
        # k ≤ 64, so with the default M=64 the +1 falls off a cliff into a
        # full stable sort of all N hosts (~22 ms at N=65536 — measured).
        _, cand = jax.lax.top_k(omega_ub, m_cand)                # ties → low idx
        in_short = jnp.zeros((n_hosts,), bool).at[cand].set(True)
        out_ub = jnp.where(in_short, NEG_INF, omega_ub)
        u = jnp.max(out_ub)
        j_u = jnp.argmax(out_ub).astype(jnp.int32)
        valid_c, base_c = valid[cand], base[cand]

    # ---- stage 2: exact enumeration on the gathered shortlist ---------------
    ispan = inv_span(consts.c_lo, consts.c_hi)
    bc_s, bm_s, _ = _plan_terms(use_pallas, gathered=True)(
        free_f[cand], inst_res[cand], inst_cost[cand], inst_valid[cand],
        req_res, masks,
    )
    bc_s = jnp.where(req_preemptible, 0.0, bc_s)
    bm_s = jnp.where(req_preemptible, 0, bm_s)
    omega_s = omega_of(bc_s, base_c, valid_c, consts, ispan, m_term,
                       gate=m_term_gate)  # (M,)
    best_val = jnp.max(omega_s)
    # Winner = lowest ORIGINAL index among exact-score ties (what the full
    # path's argmax-first-hit does over the whole fleet).
    tie_idx = jnp.where(omega_s == best_val, cand, n_hosts)
    winner_pos = jnp.argmin(tie_idx).astype(jnp.int32)
    w_star = tie_idx[winner_pos].astype(jnp.int32)
    ok_s = best_val > NEG_INF / 2

    # ---- admissibility: can any non-shortlisted host still win? -------------
    # An outside host beats w* only with omega > best_val, or omega == best_val
    # and a lower index; its omega_ub caps both.  ~ok_s ⇒ no valid host exists
    # anywhere (the top-M would have surfaced one), so the shortlist result
    # (host 0, ok=False) already matches the full path.
    #
    # With integer-valued costs (the paper regime; all sums are exact in f32)
    # ``cost_lb ≤ best_cost`` holds bitwise and ``u < best_val`` is already
    # safe.  With arbitrary float costs the bound's ≤K-term sums may overshoot
    # the enumeration's subset sums by a few ulp of reassociation error, so
    # pad the strict branch by that margin; the exact-tie branch keeps the
    # fast path for mass-tied fleets (see module docstring for the residual
    # ulp-tie caveat on non-integer inputs).
    if m_term_gate:
        # python ``abs`` for the static program (constant-folded as before);
        # jnp.abs when the lane value is a tracer.
        m_abs = abs(m_term) if mult_val is None else jnp.abs(m_term)
        tol = m_abs * ispan * (3.0 * k * 1.2e-7) * jnp.maximum(
            jnp.abs(consts.c_hi), jnp.abs(consts.c_lo)
        )
    else:
        tol = 0.0
    admissible = (u < best_val - tol) | ((u == best_val) & (j_u > w_star)) | ~ok_s
    margin = jnp.where(ok_s, best_val - u, jnp.float32(POS_INF))

    h, bm, ok = jax.lax.cond(
        admissible,
        lambda _: (w_star, bm_s[winner_pos], ok_s),
        full_decision,
        operand=None,
    )
    return h, bm, ok, ~admissible, margin


@functools.partial(jax.jit, static_argnames=("policy",))
def _decision_entry(
    state: SoAHostState,
    req_res: jax.Array,
    req_preemptible: jax.Array,
    req_domain: jax.Array,
    req_exclude_zone: jax.Array,
    *,
    policy: SchedulerPolicy,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    churn = state.churn
    if churn is None and policy.churn_aware:
        # Churn-aware policy over a state built without rates: all-zero ẑ
        # (every host equally calm — the weigher term normalizes away).
        churn = jnp.zeros_like(state.slow)
    host_zone = state.host_zone
    if host_zone is None and policy.relocation_on:
        # Relocation-capable policy over a state built without zone ids:
        # every host in zone 0 — an exclusion id of 0 then excludes the
        # whole fleet, anything else excludes nothing (and -1 = none).
        host_zone = jnp.zeros_like(state.domain)
    return _decision_core(
        state.free_f, state.free_n, state.schedulable, state.domain,
        state.slow, state.inst_res, state.inst_cost, state.inst_valid,
        req_res, req_preemptible, req_domain,
        policy, require_free_slot=False, churn=churn,
        host_zone=host_zone, exclude_zone=req_exclude_zone,
    )[:3]


def schedule_decision(
    state: SoAHostState,
    req_res: jax.Array,          # (D,)
    req_preemptible: jax.Array,  # () bool
    req_domain: jax.Array,       # () int32; -1 = any
    policy: Optional[SchedulerPolicy] = None,
    req_exclude_zone: jax.Array = -1,  # () int32 zone id; -1 = none
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One scheduling decision.  Returns (host_idx, term_mask_idx, ok).

    ``policy`` is the single static knob bundle (``SchedulerPolicy``):
    ``weigher_multipliers`` = (overcommit, termination_cost, packing,
    straggler) — the first two reproduce the paper's evaluation policy;
    ``shortlist`` = stage-2 candidate count (None = auto, 0 = off);
    ``fused_screen`` = stage-1 backend (None = auto: fused Pallas screen on
    TPU, jnp elsewhere); ``mesh`` = optional 1-D device mesh sharding
    stage 1 host-major (see ``fleet_sharding``); any setting returns the
    same decision (see ``_decision_core``).  Equal policies hit one jit
    cache entry.
    """
    policy = ensure_policy(policy, "schedule_decision")
    return _decision_entry(
        state, req_res, req_preemptible, req_domain,
        jnp.asarray(req_exclude_zone, jnp.int32), policy=policy,
    )


# ---------------------------------------------------------------------------
# Persistent device-resident fleet state + incremental transitions
# ---------------------------------------------------------------------------
#
# ``build_soa_state`` rebuilds every array from python ``Host`` objects on
# every call — O(N·K) python work that dominates latency at fleet scale.  The
# persistent view below is built ONCE and then mutated purely on device:
# termination costs are derived from per-slot start times at decision time
# (so the state never goes stale), placements allocate a free slot, and a
# ``lax.scan`` runs whole request batches with each decision seeing the
# previous ones' placements.  The rebuild path stays as the correctness
# oracle (see tests/test_soa_incremental.py).


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SoAFleetState:
    """Persistent struct-of-arrays fleet view (device-resident).

    Unlike ``SoAHostState`` (whose ``inst_cost`` is frozen at build time),
    slots carry ``inst_start``/``inst_price``/``inst_ckpt`` so the
    termination cost is a pure function of (state, now) — the prerequisite
    for incremental reuse.
    """

    free_f: jax.Array       # (N, D) h_f free resources
    free_n: jax.Array       # (N, D) h_n free resources
    schedulable: jax.Array  # (N,)   bool
    domain: jax.Array       # (N,)   int32
    slow: jax.Array         # (N,)   float32 straggler factor
    inst_res: jax.Array     # (N, K, D) preemptible slot resources (padded)
    inst_start: jax.Array   # (N, K)    slot start times
    inst_price: jax.Array   # (N, K)    slot price rates
    inst_ckpt: jax.Array    # (N, K)    last durable-checkpoint times
    inst_cost_kind: jax.Array  # (N, K) int32 billing-kind id (COST_KIND_IDS;
                               #        -1 = the policy's default kind)
    inst_period: jax.Array  # (N, K) per-slot billing period (s) for the
                            #        period/revenue kinds; -1 = policy default
    inst_valid: jax.Array   # (N, K)    bool
    #: Failure-domain plane: each host belongs to one zone (cloud AZ / rack),
    #: and the involuntary-termination (T) and accumulated-uptime (U)
    #: counters are tracked PER ZONE, updated in place by the transitions
    #: below.  The learned zone churn rate ẑ = T / max(U, ε) feeds the
    #: churn-penalty weigher and the hot-zone steering filter
    #: (``SchedulerPolicy.churn_multiplier`` / ``churn_threshold``).
    host_zone: jax.Array    # (N,)   int32 zone id
    zone_term: jax.Array    # (Z,)   float32 involuntary terminations (T)
    zone_up: jax.Array      # (Z,)   float32 accumulated uptime seconds (U)

    @property
    def n_hosts(self) -> int:
        return self.free_f.shape[0]

    @property
    def k_slots(self) -> int:
        return self.inst_res.shape[1]

    @property
    def n_zones(self) -> int:
        return self.zone_term.shape[0]


def jax_cost_params(cost_fn: CostFunction) -> Tuple[str, float]:
    """Map a python cost module onto the jnp slot-cost kinds.

    Returns ``(kind, period_s)``.  Only per-instance additive costs that are
    pure functions of (start_time, price, last_checkpoint, resources, now)
    are expressible on device; anything else must use the rebuild path
    (``build_soa_state``).
    """
    if isinstance(cost_fn, PeriodCost):
        return "period", cost_fn.period_s
    if isinstance(cost_fn, CountCost):
        return "count", BILL_PERIOD_S
    if isinstance(cost_fn, RevenueCost):
        return "revenue", cost_fn.period_s
    if isinstance(cost_fn, RecomputeCost):
        return "recompute", BILL_PERIOD_S
    if isinstance(cost_fn, MixedCost):
        raise ValueError(
            "MixedCost is a kind TABLE, not a single kind; build the policy "
            "with SchedulerPolicy.for_cost(cost_fn) instead"
        )
    raise ValueError(
        f"cost function {cost_fn.name!r} has no device-resident equivalent; "
        "use the rebuild path (build_soa_state + schedule_decision)"
    )


def slot_costs(
    cost_kind: str,
    inst_start: jax.Array,
    inst_price: jax.Array,
    now: jax.Array,
    period: jax.Array,
    inst_ckpt: Optional[jax.Array] = None,
    inst_res: Optional[jax.Array] = None,
) -> jax.Array:
    """Per-slot termination cost at time ``now`` (invalid slots are masked
    downstream, so garbage values on them are harmless).

    The period kinds use ``screen_math.floor_mod`` instead of ``%``: XLA
    CPU's fmod was the single most expensive op of the whole decision at
    10^5 hosts (~19 ms at N=65536·K=8 vs ~0.6 ms for the floor form, which
    is bit-identical on the integer-second workloads every parity test
    runs — see ``floor_mod`` for the boundary-correction argument)."""
    if cost_kind == "period":
        return floor_mod(now - inst_start, period)
    if cost_kind == "count":
        return jnp.ones_like(inst_start)
    if cost_kind == "revenue":
        return floor_mod(now - inst_start, period) / period * inst_price
    if cost_kind == "recompute":
        # Chip-seconds of work lost since the last durable checkpoint
        # (== core.cost.RecomputeCost; dim 0 is chips/vcpus by convention).
        lost = jnp.maximum(0.0, now - inst_ckpt)
        return lost * jnp.maximum(1.0, inst_res[..., 0])
    raise ValueError(f"unknown cost kind {cost_kind!r}")


def mixed_slot_costs(
    policy: SchedulerPolicy,
    inst_cost_kind: jax.Array,
    inst_start: jax.Array,
    inst_price: jax.Array,
    inst_ckpt: jax.Array,
    inst_res: jax.Array,
    now: jax.Array,
    inst_period: Optional[jax.Array] = None,
) -> jax.Array:
    """Heterogeneous per-slot termination cost: each slot billed by ITS OWN
    kind (``inst_cost_kind``; -1 = the policy default) through the branchless
    ``screen_math.slot_cost_by_kind`` select.  Every branch is the verbatim
    single-kind formula, so slot values are bit-identical to the homogeneous
    paths kind-for-kind (the device half of the ``cost.MixedCost`` oracle).
    ``inst_period`` (optional, -1 sentinel = policy default) carries per-slot
    contract periods for the period/revenue kinds."""
    eff = jnp.where(
        inst_cost_kind >= 0, inst_cost_kind, jnp.int32(policy.default_kind_id)
    )
    period = jnp.float32(policy.period)
    if inst_period is not None:
        period = jnp.where(inst_period > 0, inst_period, period)
    return slot_cost_by_kind(
        eff, inst_start, inst_price, inst_ckpt, inst_res[..., 0],
        now, period,
    )


def fleet_slot_costs(
    state: "SoAFleetState", now: jax.Array, policy: SchedulerPolicy
) -> jax.Array:
    """Per-slot termination costs of a persistent fleet state under
    ``policy``'s cost table.  Single-kind policies compile the exact
    pre-policy program (the kind column is never read); mixed tables select
    per slot.  The ``inst_period`` column overrides the policy's shared
    billing period per slot (-1 sentinel = default); with every slot at the
    sentinel the select yields elementwise-identical values to the shared
    period, so homogeneous parity is bitwise."""
    period = jnp.where(
        state.inst_period > 0, state.inst_period, jnp.float32(policy.period)
    )
    if not policy.mixed:
        return slot_costs(
            policy.cost_kind, state.inst_start, state.inst_price, now,
            period, inst_ckpt=state.inst_ckpt, inst_res=state.inst_res,
        )
    return mixed_slot_costs(
        policy, state.inst_cost_kind, state.inst_start, state.inst_price,
        state.inst_ckpt, state.inst_res, now, inst_period=state.inst_period,
    )


def build_fleet_state(
    hosts: Sequence[Host],
    k_slots: int = 8,
    domain_ids: Optional[Dict[str, int]] = None,
    slot_assignment: Optional[Sequence[Dict[str, int]]] = None,
    zone_ids: Optional[Dict[str, int]] = None,
    n_zones: Optional[int] = None,
    zone_term: Optional[np.ndarray] = None,
    zone_up: Optional[np.ndarray] = None,
) -> Tuple[SoAFleetState, List[List[Optional[Instance]]]]:
    """Convert python ``Host`` objects to a persistent ``SoAFleetState``.

    ``slot_assignment`` optionally fixes the slot index of each preemptible
    instance per host (id → slot); the default packs them sorted by id.  The
    parity tests use it to rebuild with the exact slot layout the incremental
    path produced, making the comparison bit-exact.

    ``zone_ids`` optionally fixes the zone-name → id mapping (default:
    insertion order of ``Host.zone``); ``n_zones`` widens the accumulator
    arrays beyond the mapped zones.  ``zone_term``/``zone_up`` seed the
    per-zone T/U churn accumulators (both (Z,) float32; default zeros) —
    oracle rebuilds pass the incremental path's accumulator history here so
    churn-aware decisions compare bit-exact.
    """
    n = len(hosts)
    d, free_f, free_n, schedulable, domain, slow, pre_lists = _hosts_to_arrays(
        hosts, k_slots, domain_ids
    )
    if zone_ids is None:
        zone_ids = {}
        for h in hosts:
            zone_ids.setdefault(h.zone, len(zone_ids))
    host_zone = np.zeros((n,), np.int32)
    for i, h in enumerate(hosts):
        if h.zone not in zone_ids:
            raise ValueError(
                f"host {h.name} is in unknown zone {h.zone!r}; "
                f"known: {sorted(zone_ids)}"
            )
        host_zone[i] = zone_ids[h.zone]
    z = int(n_zones) if n_zones is not None else max(len(zone_ids), 1)
    if zone_ids and max(zone_ids.values()) >= z:
        raise ValueError(
            f"zone id {max(zone_ids.values())} out of range for n_zones={z}"
        )
    if zone_term is None:
        zone_term = np.zeros((z,), np.float32)
    if zone_up is None:
        zone_up = np.zeros((z,), np.float32)
    inst_res = np.zeros((n, k_slots, d), np.float32)
    inst_start = np.zeros((n, k_slots), np.float32)
    inst_price = np.ones((n, k_slots), np.float32)
    inst_ckpt = np.zeros((n, k_slots), np.float32)
    inst_cost_kind = np.full((n, k_slots), -1, np.int32)
    inst_period = np.full((n, k_slots), -1.0, np.float32)
    inst_valid = np.zeros((n, k_slots), bool)
    slots: List[List[Optional[Instance]]] = []
    for i, pre in enumerate(pre_lists):
        row: List[Optional[Instance]] = [None] * k_slots
        for k, inst in enumerate(pre):
            if slot_assignment is not None:
                k = slot_assignment[i][inst.id]
            if row[k] is not None:
                raise ValueError(
                    f"slot collision on host {hosts[i].name} slot {k}"
                )
            row[k] = inst
            inst_res[i, k] = inst.resources.vec
            inst_start[i, k] = inst.start_time
            inst_price[i, k] = inst.price_rate
            inst_ckpt[i, k] = (
                inst.last_checkpoint
                if inst.last_checkpoint is not None
                else inst.start_time
            )
            if inst.cost_kind is not None:
                if inst.cost_kind not in COST_KIND_IDS:
                    raise ValueError(
                        f"instance {inst.id} bills by unknown cost kind "
                        f"{inst.cost_kind!r}"
                    )
                inst_cost_kind[i, k] = COST_KIND_IDS[inst.cost_kind]
            if inst.period is not None:
                inst_period[i, k] = float(inst.period)
            inst_valid[i, k] = True
        slots.append(row)
    state = SoAFleetState(
        free_f=jnp.asarray(free_f),
        free_n=jnp.asarray(free_n),
        schedulable=jnp.asarray(schedulable),
        domain=jnp.asarray(domain),
        slow=jnp.asarray(slow),
        inst_res=jnp.asarray(inst_res),
        inst_start=jnp.asarray(inst_start),
        inst_price=jnp.asarray(inst_price),
        inst_ckpt=jnp.asarray(inst_ckpt),
        inst_cost_kind=jnp.asarray(inst_cost_kind),
        inst_period=jnp.asarray(inst_period),
        inst_valid=jnp.asarray(inst_valid),
        host_zone=jnp.asarray(host_zone),
        # copy, never alias: callers seed these with a LIVE state's buffers
        # (oracle rebuilds), and the transitions donate their inputs
        zone_term=jnp.array(np.asarray(zone_term), dtype=jnp.float32),
        zone_up=jnp.array(np.asarray(zone_up), dtype=jnp.float32),
    )
    return state, slots


# -- pure transitions (all O(K·D) scatter updates; fully jit-able) -----------
#
# Every transition donates the input state's buffers: the caller's reference
# is consumed and must be rebound to the returned state (the ``SoAFleet``
# mirror and the simulators do exactly that).


def _apply_decision(
    state: SoAFleetState,
    host_idx: jax.Array,      # () int32
    mask_idx: jax.Array,      # () int32 subset-mask index (bit k = slot k)
    ok: jax.Array,            # () bool — no-op when False
    req_res: jax.Array,       # (D,)
    preemptible: jax.Array,   # () bool
    now: jax.Array,           # () float
    price: jax.Array,         # () float
    cost_kind: jax.Array,     # () int32 kind id; -1 = policy default
    period: jax.Array,        # () float billing period; -1 = policy default
) -> Tuple[SoAFleetState, jax.Array, jax.Array]:
    """Apply one decision: evacuate the winning subset, place the request.

    Returns ``(state', slot, kill)`` where ``slot`` is the slot index a
    preemptible placement landed in (undefined for normal/failed requests)
    and ``kill`` the (K,) bool mask of terminated slots on ``host_idx``.

    Scheduler-driven evacuations are involuntary from the victims' point of
    view, so the winner's zone T/U accumulators absorb the kill count and
    the victims' accrued uptime — the same churn signal storms feed.
    """
    k = state.k_slots
    row_valid = state.inst_valid[host_idx]                       # (K,)
    mask_bits = ((mask_idx >> jnp.arange(k)) & 1) > 0            # (K,)
    kill = mask_bits & row_valid & ok & ~preemptible
    freed = jnp.sum(
        jnp.where(kill[:, None], state.inst_res[host_idx], 0.0), axis=0
    )                                                            # (D,)
    take = jnp.where(ok, req_res, 0.0)
    free_f = state.free_f.at[host_idx].add(freed - take)
    free_n = state.free_n.at[host_idx].add(
        -jnp.where(ok & ~preemptible, req_res, 0.0)
    )
    valid_after = row_valid & ~kill
    slot = jnp.argmin(valid_after).astype(jnp.int32)             # first free
    place = ok & preemptible
    onehot = (jnp.arange(k) == slot) & place                     # (K,)
    z = state.host_zone[host_idx]
    n_kill = jnp.sum(kill.astype(jnp.float32))
    lost_up = jnp.sum(
        jnp.where(kill, now - state.inst_start[host_idx], 0.0)
    )
    new_state = dataclasses.replace(
        state,
        free_f=free_f,
        free_n=free_n,
        inst_valid=state.inst_valid.at[host_idx].set(valid_after | onehot),
        inst_res=state.inst_res.at[host_idx].set(
            jnp.where(onehot[:, None], req_res[None, :], state.inst_res[host_idx])
        ),
        inst_start=state.inst_start.at[host_idx].set(
            jnp.where(onehot, now, state.inst_start[host_idx])
        ),
        inst_price=state.inst_price.at[host_idx].set(
            jnp.where(onehot, price, state.inst_price[host_idx])
        ),
        inst_ckpt=state.inst_ckpt.at[host_idx].set(
            jnp.where(onehot, now, state.inst_ckpt[host_idx])
        ),
        inst_cost_kind=state.inst_cost_kind.at[host_idx].set(
            jnp.where(
                onehot,
                jnp.asarray(cost_kind, jnp.int32),
                state.inst_cost_kind[host_idx],
            )
        ),
        inst_period=state.inst_period.at[host_idx].set(
            jnp.where(
                onehot,
                jnp.asarray(period, jnp.float32),
                state.inst_period[host_idx],
            )
        ),
        zone_term=state.zone_term.at[z].add(n_kill),
        zone_up=state.zone_up.at[z].add(lost_up),
    )
    return new_state, slot, kill


def _step_core(
    state: SoAFleetState,
    req_res, req_preemptible, req_domain, now, price, req_cost_kind,
    req_period, policy: SchedulerPolicy, req_exclude=None, mult_val=None,
):
    inst_cost = fleet_slot_costs(state, now, policy)
    # The learned per-host churn rate ẑ is derived from the zone T/U
    # accumulators fresh each step (statically dropped for churn-blind
    # policies — the compiled program is then the exact pre-churn one).
    churn = (
        churn_of(state.zone_term, state.zone_up, state.host_zone)
        if policy.churn_aware
        else None
    )
    host_idx, mask_idx, ok, fell_back, margin = _decision_core(
        state.free_f, state.free_n, state.schedulable, state.domain,
        state.slow, state.inst_res, inst_cost, state.inst_valid,
        req_res, req_preemptible, req_domain,
        policy, require_free_slot=True, churn=churn,
        host_zone=state.host_zone if req_exclude is not None else None,
        exclude_zone=req_exclude, mult_val=mult_val,
    )
    state, slot, kill = _apply_decision(
        state, host_idx, mask_idx, ok, req_res, req_preemptible, now, price,
        req_cost_kind, req_period,
    )
    return state, (host_idx, slot, ok, kill, fell_back, margin)


_STEP_STATICS = ("policy",)


def _step_entry(state, req_res, req_preemptible, req_domain, now, price,
                req_cost_kind, req_period, req_exclude, *, policy):
    return _step_core(
        state, req_res, req_preemptible, req_domain, now, price,
        req_cost_kind, req_period, policy, req_exclude=req_exclude,
    )


def _many_entry(state, req_res, req_preemptible, req_domain, req_now,
                req_price, req_cost_kind, req_period, req_exclude, *, policy):
    def body(st, xs):
        res, pre, dom, now, price, kind, period, excl = xs
        return _step_core(
            st, res, pre, dom, now, price, kind, period, policy,
            req_exclude=excl,
        )

    return jax.lax.scan(
        body, state,
        (req_res, req_preemptible, req_domain, req_now, req_price,
         req_cost_kind, req_period, req_exclude),
    )


_step_donated = functools.partial(
    jax.jit, static_argnames=_STEP_STATICS, donate_argnums=(0,)
)(_step_entry)
_step_kept = functools.partial(jax.jit, static_argnames=_STEP_STATICS)(_step_entry)
_many_donated = functools.partial(
    jax.jit, static_argnames=_STEP_STATICS, donate_argnums=(0,)
)(_many_entry)
_many_kept = functools.partial(jax.jit, static_argnames=_STEP_STATICS)(_many_entry)


def schedule_step(
    state: SoAFleetState,
    req_res: jax.Array,          # (D,)
    req_preemptible: jax.Array,  # () bool
    req_domain: jax.Array,       # () int32; -1 = any
    now: jax.Array,              # () float
    price: jax.Array,            # () float
    policy: Optional[SchedulerPolicy] = None,
    req_cost_kind: jax.Array = -1,  # () int32 kind id; -1 = policy default
    donate: Optional[bool] = None,
    req_period: jax.Array = -1.0,  # () float period (s); -1 = policy default
    req_exclude_zone: jax.Array = -1,  # () int32 zone id; -1 = none
) -> Tuple[SoAFleetState, Tuple[jax.Array, ...]]:
    """Fused decide-and-apply on the persistent state (one dispatch/event).

    Returns ``(state', (host_idx, slot, ok, kill, fell_back, margin))`` — a
    6-tuple: the winning host index, the slot a preemptible placement landed
    in, whether the request was placed at all, the (K,) bool mask of slots
    evacuated on the winner, and the two shortlist-health signals (see
    ``_decision_core``) the adaptive controller consumes.

    ``policy`` (a ``SchedulerPolicy``) is the one static knob bundle: cost
    table + period, weigher multipliers, shortlist M, and the execution
    backends; equal policies share a single compile-cache entry.
    ``req_cost_kind`` tags the billing kind recorded on a preemptible
    placement (``COST_KIND_IDS``; -1 = the policy's default) — the
    per-request half of the mixed-payment model.  ``req_period`` likewise
    records the request's contract billing period (seconds; -1 = the
    policy's shared ``period``) into the ``inst_period`` column.
    ``req_exclude_zone`` (zone id; -1 = none) hard-filters one failure zone
    out of the decision — the relocation plane's operand; it is read only
    when ``policy.relocation_on`` (off-policies compile the exact
    pre-relocation program).

    With ``donate`` unset the policy's ``donate`` field applies (default
    True): the input state's buffers are reused for the output — the caller
    must not touch ``state`` afterwards; pass ``donate=False`` to keep the
    input alive (oracle comparisons, repeated benchmarks).  ``policy.mesh``
    shards stage 1 host-major across devices (the state should already be
    padded + placed via ``fleet_sharding``).
    """
    policy = ensure_policy(policy, "schedule_step")
    if donate is None:
        donate = policy.donate
    fn = _step_donated if donate else _step_kept
    return fn(
        state, req_res, req_preemptible, req_domain,
        jnp.asarray(now, jnp.float32), jnp.asarray(price, jnp.float32),
        jnp.asarray(req_cost_kind, jnp.int32),
        jnp.asarray(req_period, jnp.float32),
        jnp.asarray(req_exclude_zone, jnp.int32), policy=policy,
    )


def schedule_many(
    state: SoAFleetState,
    req_res: jax.Array,          # (B, D)
    req_preemptible: jax.Array,  # (B,) bool
    req_domain: jax.Array,       # (B,) int32; -1 = any
    req_now: jax.Array,          # (B,) float — each request's arrival time
    req_price: jax.Array,        # (B,) float
    policy: Optional[SchedulerPolicy] = None,
    req_cost_kind: Optional[jax.Array] = None,  # (B,) int32; None = defaults
    donate: Optional[bool] = None,
    req_period: Optional[jax.Array] = None,  # (B,) float; None = defaults
    req_exclude_zone: Optional[jax.Array] = None,  # (B,) int32; None = none
) -> Tuple[SoAFleetState, Tuple[jax.Array, ...]]:
    """Run a request batch through ``lax.scan`` carrying the fleet state, so
    each decision sees every earlier placement/termination in the batch —
    bit-identical to ``schedule_step`` in a loop, at one dispatch per batch.

    Returns ``(state', (host_idx (B,), slot (B,), ok (B,), kill (B, K),
    fell_back (B,), margin (B,)))`` — the batched 6-tuple of
    ``schedule_step``.  ``fell_back.sum()`` is the batch's
    admissibility-fallback counter and ``margin`` the per-decision headroom
    — the signals the adaptive shortlist controller steers M with.
    ``policy`` / ``req_cost_kind`` (per-request billing kinds) / ``donate``
    semantics as in ``schedule_step`` (the sharded stage 1 runs inside the
    scan body; the carried state stays sharded).
    """
    policy = ensure_policy(policy, "schedule_many")
    if donate is None:
        donate = policy.donate
    if req_cost_kind is None:
        req_cost_kind = jnp.full(jnp.shape(req_now), -1, jnp.int32)
    if req_period is None:
        req_period = jnp.full(jnp.shape(req_now), -1.0, jnp.float32)
    if req_exclude_zone is None:
        req_exclude_zone = jnp.full(jnp.shape(req_now), -1, jnp.int32)
    fn = _many_donated if donate else _many_kept
    return fn(
        state, req_res, req_preemptible, req_domain,
        jnp.asarray(req_now, jnp.float32), jnp.asarray(req_price, jnp.float32),
        jnp.asarray(req_cost_kind, jnp.int32),
        jnp.asarray(req_period, jnp.float32),
        jnp.asarray(req_exclude_zone, jnp.int32), policy=policy,
    )


def _reloc_entry(state, v_host, v_slot, v_on, req_res, req_domain,
                 req_cost_kind, req_period, req_price, req_exclude, now,
                 *, policy):
    k = state.inst_valid.shape[1]
    slot_ids = jnp.arange(k)

    def body(st, xs):
        vh, vs, on, res, dom, kind, period, price, excl = xs
        # 1. checkpoint FIRST (never-worse: the replacement restarts from
        #    here, and a storm racing the move loses only the work since
        #    this instant) — gated on `on` so padding rows are no-ops.
        row = jnp.where((slot_ids == vs) & on, now, st.inst_ckpt[vh])
        st = dataclasses.replace(st, inst_ckpt=st.inst_ckpt.at[vh].set(row))
        # 2. re-place through the ordinary pipeline, source zone excluded.
        st, (h, s, ok, _kill, fb, mg) = _step_core(
            st, res, jnp.asarray(True), dom, now, price, kind, period,
            policy, req_exclude=excl,
        )
        # 3. make-before-break: the victim departs only once its
        #    replacement is live (voluntary — a move is not churn, so the
        #    source zone's T numerator is untouched while U still accrues).
        mask = (slot_ids == vs) & on & ok
        st = apply_termination(st, vh, mask, now=now, involuntary=False)
        return st, (h, s, ok, fb, mg)

    return jax.lax.scan(
        body, state,
        (v_host, v_slot, v_on, req_res, req_domain, req_cost_kind,
         req_period, req_price, req_exclude),
    )


_reloc_donated = functools.partial(
    jax.jit, static_argnames=_STEP_STATICS, donate_argnums=(0,)
)(_reloc_entry)
_reloc_kept = functools.partial(jax.jit, static_argnames=_STEP_STATICS)(_reloc_entry)


def relocate_many(
    state: SoAFleetState,
    v_host: jax.Array,        # (B,) int32 — victim host index
    v_slot: jax.Array,        # (B,) int32 — victim slot on that host
    v_on: jax.Array,          # (B,) bool  — False = padding row (full no-op)
    req_res: jax.Array,       # (B, D) — replacement request sizes
    req_domain: jax.Array,    # (B,) int32; -1 = any
    req_cost_kind: jax.Array,  # (B,) int32 kind ids; -1 = policy default
    req_period: jax.Array,    # (B,) float32; -1 = policy default
    req_price: jax.Array,     # (B,) float32 — the victim's price rate
    req_exclude_zone: jax.Array,  # (B,) int32 — the source zone, hard-excluded
    now: jax.Array,           # () float — one relocation pass instant
    policy: Optional[SchedulerPolicy] = None,
    donate: Optional[bool] = None,
) -> Tuple[SoAFleetState, Tuple[jax.Array, ...]]:
    """One evacuation batch as ONE fused ``lax.scan`` dispatch: per victim,
    checkpoint → re-place (zone-excluded, always preemptible) → terminate
    the victim iff its replacement landed — the exact op sequence the
    per-victim ``schedule_step`` loop ran, so decisions are bit-identical
    to sequential evacuation while the dispatch count drops from one per
    victim to one per zone batch (the PR-8 follow-up).

    Returns ``(state', (host_idx (B,), slot (B,), ok (B,), fell_back (B,),
    margin (B,)))``; replacement requests are preemptible so they never
    kill (no ``kill`` column).  Padding rows (``v_on=False`` + sentinel
    unsatisfiable ``req_res``) leave the carried state bitwise untouched,
    exactly like ``schedule_many``'s padding."""
    policy = ensure_policy(policy, "relocate_many")
    if donate is None:
        donate = policy.donate
    fn = _reloc_donated if donate else _reloc_kept
    return fn(
        state, jnp.asarray(v_host, jnp.int32), jnp.asarray(v_slot, jnp.int32),
        jnp.asarray(v_on, bool), req_res, jnp.asarray(req_domain, jnp.int32),
        jnp.asarray(req_cost_kind, jnp.int32),
        jnp.asarray(req_period, jnp.float32),
        jnp.asarray(req_price, jnp.float32),
        jnp.asarray(req_exclude_zone, jnp.int32),
        jnp.asarray(now, jnp.float32), policy=policy,
    )


@functools.partial(jax.jit, donate_argnums=(0,))
def apply_placement(
    state: SoAFleetState,
    host_idx: jax.Array,
    req_res: jax.Array,
    preemptible: jax.Array,
    now: jax.Array,
    price: jax.Array = 1.0,
    cost_kind: jax.Array = -1,  # () int32 kind id; -1 = policy default
    period: jax.Array = -1.0,   # () float period (s); -1 = policy default
) -> Tuple[SoAFleetState, jax.Array]:
    """Unconditionally place a request on ``host_idx`` (caller checked
    feasibility — e.g. re-applying a recorded decision, or initializing
    state without a rebuild).  Returns (state', slot).

    Precondition for preemptible placements: the host has a free slot
    (``~inst_valid[host_idx].all()``) — with all K slots valid, slot 0
    would be overwritten.  The decision paths (``schedule_step``)
    enforce this via ``require_free_slot``; direct callers must too."""
    take = req_res
    free_f = state.free_f.at[host_idx].add(-take)
    free_n = state.free_n.at[host_idx].add(
        -jnp.where(preemptible, jnp.zeros_like(take), take)
    )
    k = state.k_slots
    slot = jnp.argmin(state.inst_valid[host_idx]).astype(jnp.int32)
    onehot = (jnp.arange(k) == slot) & preemptible
    state = dataclasses.replace(
        state,
        free_f=free_f,
        free_n=free_n,
        inst_valid=state.inst_valid.at[host_idx].set(
            state.inst_valid[host_idx] | onehot
        ),
        inst_res=state.inst_res.at[host_idx].set(
            jnp.where(onehot[:, None], req_res[None, :], state.inst_res[host_idx])
        ),
        inst_start=state.inst_start.at[host_idx].set(
            jnp.where(onehot, jnp.asarray(now, jnp.float32), state.inst_start[host_idx])
        ),
        inst_price=state.inst_price.at[host_idx].set(
            jnp.where(onehot, jnp.asarray(price, jnp.float32), state.inst_price[host_idx])
        ),
        inst_ckpt=state.inst_ckpt.at[host_idx].set(
            jnp.where(onehot, jnp.asarray(now, jnp.float32), state.inst_ckpt[host_idx])
        ),
        inst_cost_kind=state.inst_cost_kind.at[host_idx].set(
            jnp.where(
                onehot,
                jnp.asarray(cost_kind, jnp.int32),
                state.inst_cost_kind[host_idx],
            )
        ),
        inst_period=state.inst_period.at[host_idx].set(
            jnp.where(
                onehot,
                jnp.asarray(period, jnp.float32),
                state.inst_period[host_idx],
            )
        ),
    )
    return state, slot


@functools.partial(jax.jit, donate_argnums=(0,), static_argnames=("involuntary",))
def apply_termination(
    state: SoAFleetState,
    host_idx: jax.Array,
    slot_mask: jax.Array,  # (K,) bool — slots to evacuate (preempt/depart)
    now: Optional[jax.Array] = None,
    involuntary: bool = False,
) -> SoAFleetState:
    """Free the given preemptible slots on ``host_idx`` (h_n untouched —
    preemptible instances never counted there).

    With ``now`` given, the host's zone churn accumulators learn from the
    event: the evacuated slots' accrued uptime always feeds U, and
    ``involuntary=True`` (preemption storms, spot reclaims — anything the
    customer didn't ask for) additionally counts the kills into T.
    Voluntary departures therefore DILUTE the zone's learned churn rate ẑ =
    T/U, exactly as gce-manager's per-zone preemption rates behave.  Callers
    that omit ``now`` (legacy call sites) compile the exact pre-churn
    program and leave the accumulators untouched.
    """
    row_valid = state.inst_valid[host_idx]
    kill = slot_mask & row_valid
    freed = jnp.sum(
        jnp.where(kill[:, None], state.inst_res[host_idx], 0.0), axis=0
    )
    updates = dict(
        free_f=state.free_f.at[host_idx].add(freed),
        inst_valid=state.inst_valid.at[host_idx].set(row_valid & ~kill),
    )
    if now is not None:
        z = state.host_zone[host_idx]
        up = jnp.sum(
            jnp.where(
                kill,
                jnp.asarray(now, jnp.float32) - state.inst_start[host_idx],
                0.0,
            )
        )
        updates["zone_up"] = state.zone_up.at[z].add(up)
        if involuntary:
            n_kill = jnp.sum(kill.astype(jnp.float32))
            updates["zone_term"] = state.zone_term.at[z].add(n_kill)
    return dataclasses.replace(state, **updates)


@functools.partial(jax.jit, donate_argnums=(0,))
def apply_departure(
    state: SoAFleetState,
    host_idx: jax.Array,
    res: jax.Array,  # (D,) resources of the departing NORMAL instance
) -> SoAFleetState:
    """Voluntary departure of a normal instance (both views regain ``res``).
    Preemptible departures go through ``apply_termination`` with the slot."""
    return dataclasses.replace(
        state,
        free_f=state.free_f.at[host_idx].add(res),
        free_n=state.free_n.at[host_idx].add(res),
    )


@functools.partial(jax.jit, donate_argnums=(0,))
def apply_checkpoint(
    state: SoAFleetState,
    host_idx: jax.Array,
    slot: jax.Array,
    now: jax.Array,
) -> SoAFleetState:
    """Record a durable checkpoint for the preemptible instance in ``slot``:
    from ``now`` on, its recompute cost accrues from this anchor (the
    device-resident counterpart of ``Instance.last_checkpoint``)."""
    return dataclasses.replace(
        state,
        inst_ckpt=state.inst_ckpt.at[host_idx, slot].set(
            jnp.asarray(now, jnp.float32)
        ),
    )


@functools.partial(jax.jit, donate_argnums=(0,))
def set_schedulable(
    state: SoAFleetState, host_idx: jax.Array, value: jax.Array
) -> SoAFleetState:
    return dataclasses.replace(
        state, schedulable=state.schedulable.at[host_idx].set(value)
    )


@functools.partial(jax.jit, donate_argnums=(0,))
def set_slow_factor(
    state: SoAFleetState, host_idx: jax.Array, value: jax.Array
) -> SoAFleetState:
    return dataclasses.replace(state, slow=state.slow.at[host_idx].set(value))


@functools.partial(jax.jit, donate_argnums=(0,))
def apply_host_failure(
    state: SoAFleetState,
    host_idx: jax.Array,
    normal_res: jax.Array,  # (D,) total resources of the host's NORMAL instances
    now: Optional[jax.Array] = None,
) -> SoAFleetState:
    """Hard host failure: mark unschedulable, evacuate every slot, release
    the normal aggregate (the python mirror terminates the Instance records).

    With ``now`` given the failure is learned as involuntary churn in the
    host's zone: every occupied slot's accrued uptime feeds U and its kill
    feeds T (callers omitting ``now`` keep the legacy churn-blind program).
    """
    row_valid = state.inst_valid[host_idx]
    freed = jnp.sum(
        jnp.where(row_valid[:, None], state.inst_res[host_idx], 0.0), axis=0
    )
    updates = dict(
        schedulable=state.schedulable.at[host_idx].set(False),
        free_f=state.free_f.at[host_idx].add(freed + normal_res),
        free_n=state.free_n.at[host_idx].add(normal_res),
        inst_valid=state.inst_valid.at[host_idx].set(
            jnp.zeros_like(row_valid)
        ),
    )
    if now is not None:
        z = state.host_zone[host_idx]
        up = jnp.sum(
            jnp.where(
                row_valid,
                jnp.asarray(now, jnp.float32) - state.inst_start[host_idx],
                0.0,
            )
        )
        updates["zone_up"] = state.zone_up.at[z].add(up)
        updates["zone_term"] = state.zone_term.at[z].add(
            jnp.sum(row_valid.astype(jnp.float32))
        )
    return dataclasses.replace(state, **updates)


# ---------------------------------------------------------------------------
# Drop-in scheduler wrapper (same .schedule() contract as the python ones)
# ---------------------------------------------------------------------------


class JaxPreemptibleScheduler:
    """Beyond-paper vectorized scheduler with the python-class interface.

    For apples-to-apples latency benchmarks against the python schedulers it
    rebuilds device arrays from the python hosts per call unless the caller
    maintains the SoA state incrementally (``schedule_soa``).
    """

    def __init__(
        self,
        cost_fn: Optional[CostFunction] = None,
        k_slots: int = 8,
        policy: Optional[SchedulerPolicy] = None,
        zone_rates: Optional[Dict[str, float]] = None,
    ):
        #: the one static knob bundle; ``policy.mesh`` note: the rebuild
        #: path does not pad, so sharding only engages when the host count
        #: already divides the mesh with ≥ M+1 hosts per shard; the
        #: persistent path (SoAFleet(mesh=...)) pads automatically.
        self.policy = ensure_policy(
            policy, "JaxPreemptibleScheduler", cost_fn=cost_fn
        )
        #: python cost module used to translate winning masks back into
        #: ``TerminationPlan`` costs (and to freeze slot costs at rebuild);
        #: derived from the policy's cost table when not given explicitly.
        self.cost_fn = cost_fn or self.policy.make_cost_fn()
        self.k_slots = k_slots
        #: frozen per-zone churn rates ẑ (zone name → rate) baked into each
        #: rebuild's ``churn`` column — the oracle counterpart of the
        #: persistent path's online-learned zone accumulators.
        self.zone_rates = dict(zone_rates) if zone_rates is not None else None

    # -- full pipeline from python objects ------------------------------------
    def schedule(
        self, req: Request, hosts: Sequence[Host], now: float
    ) -> ScheduleResult:
        # Zone ids by insertion order of Host.zone — the same derivation
        # rule SoAFleet/build_fleet_state use, so an exclusion id resolved
        # here names the same zone the persistent path excludes.
        zone_ids: Dict[str, int] = {}
        for h in hosts:
            zone_ids.setdefault(h.zone, len(zone_ids))
        state, slots = build_soa_state(
            hosts, now, cost_fn=self.cost_fn, k_slots=self.k_slots,
            zone_rates=self.zone_rates, zone_ids=zone_ids,
        )
        domains = {h.domain: i for i, h in enumerate({h.domain: h for h in hosts}.values())}
        dom = -1
        if req.domain is not None:
            dom = domains.get(req.domain, -1)
        excl = -1
        if req.exclude_zone is not None:
            # An unknown zone name excludes nothing (nothing to flee from).
            excl = zone_ids.get(req.exclude_zone, -1)
        host_idx, mask_idx, ok = self.schedule_soa(
            state,
            jnp.asarray(req.resources.vec, jnp.float32),
            bool(req.preemptible),
            dom,
            exclude_zone=excl,
        )
        if not bool(ok):
            return ScheduleResult(request=req, host=None, passes=1)
        hi = int(host_idx)
        mask = int(mask_idx)
        victims = tuple(
            slots[hi][k] for k in range(len(slots[hi])) if (mask >> k) & 1
        )
        plan = (
            EMPTY_PLAN
            if not victims
            else TerminationPlan(
                instances=victims,
                cost=self.cost_fn.cost(victims, now),
                feasible=True,
            )
        )
        return ScheduleResult(request=req, host=hosts[hi].name, plan=plan, passes=1)

    # -- jit'd core (device arrays in/out) -------------------------------------
    def schedule_soa(self, state: SoAHostState, req_res, preemptible: bool,
                     domain: int = -1, exclude_zone: int = -1):
        return schedule_decision(
            state,
            req_res,
            jnp.asarray(preemptible),
            jnp.asarray(domain, jnp.int32),
            policy=self.policy,
            req_exclude_zone=jnp.asarray(exclude_zone, jnp.int32),
        )
