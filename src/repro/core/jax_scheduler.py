"""Vectorized JAX implementation of the preemptible-aware scheduler.

The paper's single-pass design (Alg. 2+5+6) has a property the retry design
lacks: *the whole decision is a pure function of the host-state arrays* — no
data-dependent second cycle.  We exploit that to turn scheduling into one
jit-compiled array program over struct-of-arrays host state:

    filter (dual-view)  →  subset enumeration (2^K masks)  →
    weigh (normalized)  →  argmax  →  termination mask

Cost functions must be *per-instance additive* (all of the paper's are:
period, count, revenue, recompute), so a subset's cost is ``mask @ inst_cost``
and Alg. 5 becomes a masked matmul + argmin — MXU-shaped work.  The Pallas
kernel in ``repro.kernels.sched_weigh`` fuses the hot part (filter + subset
feasibility/cost + per-host reduction) over VMEM tiles; this module provides
the pure-jnp equivalent (also the kernel's oracle) and the end-to-end
scheduler wrapper used by benchmarks.

Capacity model: each host carries up to ``K`` preemptible instances (padded,
masked).  2^K subset masks are enumerated exactly — K≤12 covers every
practical oversubscription level (the paper's testbed peaked at 4).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .cost import BILL_PERIOD_S, CostFunction, PeriodCost
from .types import (
    EMPTY_PLAN,
    Host,
    Instance,
    Request,
    ScheduleResult,
    TerminationPlan,
)

NEG_INF = -1e30
POS_INF = 1e30


# ---------------------------------------------------------------------------
# SoA host state
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SoAHostState:
    """Struct-of-arrays mirror of a host fleet (device-resident)."""

    free_f: jax.Array       # (N, D) h_f free resources
    free_n: jax.Array       # (N, D) h_n free resources
    schedulable: jax.Array  # (N,)   bool
    domain: jax.Array       # (N,)   int32
    slow: jax.Array         # (N,)   float32 straggler factor
    inst_res: jax.Array     # (N, K, D) preemptible instance resources (padded)
    inst_cost: jax.Array    # (N, K)    per-instance termination cost
    inst_valid: jax.Array   # (N, K)    bool

    @property
    def n_hosts(self) -> int:
        return self.free_f.shape[0]

    @property
    def k_slots(self) -> int:
        return self.inst_res.shape[1]


def build_soa_state(
    hosts: Sequence[Host],
    now: float,
    cost_fn: Optional[CostFunction] = None,
    k_slots: int = 8,
    domain_ids: Optional[Dict[str, int]] = None,
) -> Tuple[SoAHostState, List[List[Instance]]]:
    """Convert python ``Host`` objects to device arrays.

    Returns the state plus the per-host preemptible instance lists (slot
    order), needed to translate a winning mask back into instance ids.
    """
    cost_fn = cost_fn or PeriodCost()
    n = len(hosts)
    d = len(hosts[0].capacity.spec.dims) if hosts else 0
    if domain_ids is None:
        domain_ids = {}
        for h in hosts:
            domain_ids.setdefault(h.domain, len(domain_ids))
    free_f = np.zeros((n, d), np.float32)
    free_n = np.zeros((n, d), np.float32)
    schedulable = np.zeros((n,), bool)
    domain = np.zeros((n,), np.int32)
    slow = np.ones((n,), np.float32)
    inst_res = np.zeros((n, k_slots, d), np.float32)
    inst_cost = np.zeros((n, k_slots), np.float32)
    inst_valid = np.zeros((n, k_slots), bool)
    slots: List[List[Instance]] = []
    for i, h in enumerate(hosts):
        free_f[i] = h.free_full.vec
        free_n[i] = h.free_normal.vec
        schedulable[i] = h.schedulable
        domain[i] = domain_ids[h.domain]
        slow[i] = h.slow_factor
        pre = sorted(h.preemptible_instances(), key=lambda x: x.id)
        if len(pre) > k_slots:
            raise ValueError(
                f"host {h.name} has {len(pre)} preemptible instances > k_slots={k_slots}"
            )
        slots.append(pre)
        for k, inst in enumerate(pre):
            inst_res[i, k] = inst.resources.vec
            inst_cost[i, k] = cost_fn.cost([inst], now)
            inst_valid[i, k] = True
    state = SoAHostState(
        free_f=jnp.asarray(free_f),
        free_n=jnp.asarray(free_n),
        schedulable=jnp.asarray(schedulable),
        domain=jnp.asarray(domain),
        slow=jnp.asarray(slow),
        inst_res=jnp.asarray(inst_res),
        inst_cost=jnp.asarray(inst_cost),
        inst_valid=jnp.asarray(inst_valid),
    )
    return state, slots


def subset_masks(k: int) -> np.ndarray:
    """(2^k, k) 0/1 matrix enumerating all subsets (row 0 = empty set)."""
    m = np.arange(1 << k, dtype=np.uint32)
    return ((m[:, None] >> np.arange(k)[None, :]) & 1).astype(np.float32)


# ---------------------------------------------------------------------------
# The jit'd decision (pure jnp; also the Pallas kernel's oracle)
# ---------------------------------------------------------------------------


def host_plan_terms(
    free_f: jax.Array,
    inst_res: jax.Array,
    inst_cost: jax.Array,
    inst_valid: jax.Array,
    req_res: jax.Array,
    masks: jax.Array,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Per-host Alg. 5 terms, vectorized over all hosts and all 2^K masks.

    Returns (best_cost, best_mask_idx, any_feasible):
      best_cost   (N,)  cost of the cheapest feasible termination subset
                        (0 where the request already fits h_f),
      best_mask   (N,)  int32 index into ``masks``,
      feasible    (N,)  whether ANY subset admits the request.
    """
    # Invalid slots contribute nothing and cost +inf if ever selected.
    res = jnp.where(inst_valid[..., None], inst_res, 0.0)            # (N,K,D)
    cost = jnp.where(inst_valid, inst_cost, POS_INF)                 # (N,K)
    freed = jnp.einsum("mk,nkd->nmd", masks, res)                    # (N,M,D)
    ok = jnp.all(free_f[:, None, :] + freed >= req_res[None, None, :] - 1e-6, axis=-1)
    # Subsets touching an invalid slot are excluded via +inf cost.
    sub_cost = jnp.einsum("mk,nk->nm", masks, cost)                  # (N,M)
    sub_cost = jnp.where(ok, sub_cost, POS_INF)
    # Tie-break: cheaper cost first, then fewer instances, then first index
    # (matches the python reference).  Two-stage to stay exact in f32.
    best_cost = jnp.min(sub_cost, axis=-1)                           # (N,)
    size = masks.sum(-1)                                             # (M,)
    is_tie = sub_cost <= best_cost[:, None] + 1e-3
    size_key = jnp.where(is_tie, size[None, :], POS_INF)
    best_mask = jnp.argmin(size_key, axis=-1).astype(jnp.int32)      # (N,)
    feasible = jnp.any(ok, axis=-1)
    return best_cost, best_mask, feasible


def _normalize(w: jax.Array, valid: jax.Array) -> jax.Array:
    """OpenStack weight normalization over the valid candidate set."""
    lo = jnp.min(jnp.where(valid, w, POS_INF))
    hi = jnp.max(jnp.where(valid, w, NEG_INF))
    span = hi - lo
    return jnp.where(span > 1e-12, (w - lo) / jnp.where(span > 1e-12, span, 1.0), 0.0)


@functools.partial(
    jax.jit,
    static_argnames=("use_pallas", "weigher_multipliers"),
)
def schedule_decision(
    state: SoAHostState,
    req_res: jax.Array,          # (D,)
    req_preemptible: jax.Array,  # () bool
    req_domain: jax.Array,       # () int32; -1 = any
    masks: jax.Array,            # (M, K)
    use_pallas: bool = False,
    weigher_multipliers: Tuple[float, float, float, float] = (1.0, 1.0, 0.0, 0.0),
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One scheduling decision.  Returns (host_idx, term_mask_idx, ok).

    ``weigher_multipliers`` = (overcommit, termination_cost, packing,
    straggler) — the first two reproduce the paper's evaluation policy.
    """
    # ---- phase 1: dual-view filtering (the paper's trick) -------------------
    view = jnp.where(req_preemptible, state.free_f, state.free_n)    # (N,D)
    fits = jnp.all(view >= req_res[None, :] - 1e-6, axis=-1)
    fits &= state.schedulable
    fits &= (req_domain < 0) | (state.domain == req_domain)

    # ---- phase 2+3 terms: Alg.5 enumeration (skipped for preemptible reqs) --
    if use_pallas:
        from repro.kernels.sched_weigh import sched_weigh as _sched_weigh

        best_cost, best_mask, any_feasible = _sched_weigh(
            state.free_f, state.inst_res, state.inst_cost,
            state.inst_valid, req_res, masks,
        )
    else:
        best_cost, best_mask, any_feasible = host_plan_terms(
            state.free_f, state.inst_res, state.inst_cost,
            state.inst_valid, req_res, masks,
        )
    # Preemptible requests never terminate others: empty plan, zero cost.
    best_cost = jnp.where(req_preemptible, 0.0, best_cost)
    best_mask = jnp.where(req_preemptible, 0, best_mask)
    feasible = jnp.where(req_preemptible, fits, any_feasible)

    valid = fits & feasible
    overcommitted = ~jnp.all(state.free_f >= req_res[None, :] - 1e-6, axis=-1)

    # ---- phase 2: normalized weighing on h_f --------------------------------
    m_over, m_term, m_pack, m_strag = weigher_multipliers
    omega = jnp.zeros(state.n_hosts)
    if m_over:
        omega += m_over * _normalize(jnp.where(overcommitted, -1.0, 0.0), valid)
    if m_term:
        omega += m_term * _normalize(-jnp.minimum(best_cost, POS_INF), valid)
    if m_pack:
        omega += m_pack * _normalize(-state.free_f.sum(-1), valid)
    if m_strag:
        omega += m_strag * _normalize(-state.slow, valid)
    omega = jnp.where(valid, omega, NEG_INF)

    # ---- argmax (first-index tie-break) --------------------------------------
    host_idx = jnp.argmax(omega).astype(jnp.int32)
    ok = omega[host_idx] > NEG_INF / 2
    return host_idx, best_mask[host_idx], ok


# ---------------------------------------------------------------------------
# Drop-in scheduler wrapper (same .schedule() contract as the python ones)
# ---------------------------------------------------------------------------


class JaxPreemptibleScheduler:
    """Beyond-paper vectorized scheduler with the python-class interface.

    For apples-to-apples latency benchmarks against the python schedulers it
    rebuilds device arrays from the python hosts per call unless the caller
    maintains the SoA state incrementally (``schedule_soa``).
    """

    def __init__(
        self,
        cost_fn: Optional[CostFunction] = None,
        k_slots: int = 8,
        use_pallas: bool = False,
        weigher_multipliers: Tuple[float, float, float, float] = (1.0, 1.0, 0.0, 0.0),
    ):
        self.cost_fn = cost_fn or PeriodCost()
        self.k_slots = k_slots
        self.use_pallas = use_pallas
        self.weigher_multipliers = weigher_multipliers
        self._masks = jnp.asarray(subset_masks(k_slots))

    # -- full pipeline from python objects ------------------------------------
    def schedule(
        self, req: Request, hosts: Sequence[Host], now: float
    ) -> ScheduleResult:
        state, slots = build_soa_state(
            hosts, now, cost_fn=self.cost_fn, k_slots=self.k_slots
        )
        domains = {h.domain: i for i, h in enumerate({h.domain: h for h in hosts}.values())}
        dom = -1
        if req.domain is not None:
            dom = domains.get(req.domain, -1)
        host_idx, mask_idx, ok = self.schedule_soa(
            state,
            jnp.asarray(req.resources.vec, jnp.float32),
            bool(req.preemptible),
            dom,
        )
        if not bool(ok):
            return ScheduleResult(request=req, host=None, passes=1)
        hi = int(host_idx)
        mask = int(mask_idx)
        victims = tuple(
            slots[hi][k] for k in range(len(slots[hi])) if (mask >> k) & 1
        )
        plan = (
            EMPTY_PLAN
            if not victims
            else TerminationPlan(
                instances=victims,
                cost=self.cost_fn.cost(victims, now),
                feasible=True,
            )
        )
        return ScheduleResult(request=req, host=hosts[hi].name, plan=plan, passes=1)

    # -- jit'd core (device arrays in/out) -------------------------------------
    def schedule_soa(self, state: SoAHostState, req_res, preemptible: bool, domain: int = -1):
        return schedule_decision(
            state,
            req_res,
            jnp.asarray(preemptible),
            jnp.asarray(domain, jnp.int32),
            self._masks,
            use_pallas=self.use_pallas,
            weigher_multipliers=self.weigher_multipliers,
        )
