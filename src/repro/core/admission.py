"""Streaming admission front end: device-resident wait queue + drain plane.

Every decision path below this module is one-shot: a request arrives, the
pipeline decides, and a rejection simply vanishes.  Real fleets live under
*continuous* demand — the paper's scheduler exists to keep an IaaS fleet full
— so this module adds the missing admission plane in front of the decision
pipeline:

* **Device-resident wait queue** (``AdmissionQueueState``): a fixed-capacity
  struct-of-arrays queue living next to ``SoAFleetState``.  Each entry
  carries the request's resource vector, flags, a **priority class** (0 =
  interactive, highest; ``n_classes - 1`` = batch, lowest), a monotone FIFO
  ticket (``seq``), its enqueue time, and a retry counter.  All transitions
  (``queue_push`` / ``queue_select`` / ``queue_pop``) are pure jnp — the
  queue never leaves the device between drains.
* **Drains** (``drain_queue`` / the fused ``_drain_entry``): one dispatch
  pushes the newly-accumulated arrivals, selects the top ``admit_batch``
  waiting entries by ``(class, seq)`` — strict priority order, FIFO within a
  class — runs them through the exact ``schedule_many`` scan body
  (``jax_scheduler._step_core``), and folds the outcomes back: placed
  entries leave the queue, failed entries stay for **backfill retry** (their
  ``tries`` counter increments; ``max_retries`` attempts total before the
  request is rejected).  Because the drain feeds the identical per-request
  arrays through the identical scan body, a drained queue's decisions are
  bit-exact against the unqueued oracle (tests/test_admission.py).
* **Interactive preempts batch** by construction, not by new machinery:
  interactive requests are the normal (non-preemptible) ones, so the
  existing preemption predicate in ``_decision_core`` — normal requests may
  evacuate preemptible instances — IS the cross-class preemption.  The
  queue adds the ordering half (interactive drains first); the decision
  pipeline supplies the eviction half unchanged.
* **Async double-buffered dispatch** (``AdmissionFrontEnd``): arrivals
  accumulate host-side into the next batch while the previous drain's
  device program is still running; JAX's async dispatch returns
  immediately, and because every transition donates its input buffers the
  in-place state update is safe.  Outcome absorption (the only host sync)
  is deferred until the result is actually needed — the next drain, a
  state-observing simulator event, or a stats read.

SLO discipline: the front end accumulates arrivals toward
``policy.admit_batch`` (throughput), but a drain is forced once the oldest
waiting arrival has waited ``policy.slo_target_s`` sim-seconds (latency).
``SoASimulator`` drives both triggers plus a third: a drain after any
capacity-freeing event (departure / host failure) while the queue is
non-empty — the backfill path.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .jax_scheduler import SoAFleetState, _step_core
from .policy import COST_KIND_IDS, SchedulerPolicy
from .screen_math import churn_stats
from .types import Request

#: Padding sentinel for untaken drain rows: a request no host can fit, so
#: the scan body no-ops it (``ok=False``).  Same value as
#: ``soa_fleet._PAD_RES`` (which re-exports this one).
PAD_RES = 1e30

#: Sort key for invalid queue entries — larger than any real class or seq,
#: so they sink to the back of every selection.
_BIG = jnp.int32(2**30)


# ---------------------------------------------------------------------------
# Queue state + pure transitions
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class AdmissionQueueState:
    """Fixed-capacity device-resident wait queue (struct-of-arrays).

    ``Q = policy.queue_capacity`` rows; a row is live iff ``valid``.  The
    ``(klass, seq)`` pair is the total drain order: strict priority by
    class, FIFO by the monotone ``seq`` ticket within a class.  ``tries``
    counts placement attempts already consumed (backfill retries).
    """

    res: jax.Array          # (Q, D) f32 request resource vectors
    preemptible: jax.Array  # (Q,)   bool
    domain: jax.Array       # (Q,)   i32; -1 = any
    cost_kind: jax.Array    # (Q,)   i32 kind id; -1 = policy default
    period: jax.Array       # (Q,)   f32 contract period; -1 = policy default
    exclude_zone: jax.Array  # (Q,)  i32 hard-excluded zone id; -1 = none
    klass: jax.Array        # (Q,)   i32 priority class; 0 = highest
    price: jax.Array        # (Q,)   f32
    enq_t: jax.Array        # (Q,)   f32 enqueue (arrival) time
    seq: jax.Array          # (Q,)   i32 FIFO ticket
    tries: jax.Array        # (Q,)   i32 failed placement attempts so far
    valid: jax.Array        # (Q,)   bool
    next_seq: jax.Array     # ()     i32 ticket counter

    @property
    def capacity(self) -> int:
        return self.res.shape[0]

    @property
    def depth(self) -> jax.Array:
        """Live entries (traced; host callers use the drain aux instead)."""
        return jnp.sum(self.valid).astype(jnp.int32)


def queue_init(capacity: int, n_dims: int) -> AdmissionQueueState:
    """Empty queue with ``capacity`` rows over ``n_dims`` resource dims."""
    q = int(capacity)
    return AdmissionQueueState(
        res=jnp.zeros((q, n_dims), jnp.float32),
        preemptible=jnp.zeros((q,), bool),
        domain=jnp.full((q,), -1, jnp.int32),
        cost_kind=jnp.full((q,), -1, jnp.int32),
        period=jnp.full((q,), -1.0, jnp.float32),
        exclude_zone=jnp.full((q,), -1, jnp.int32),
        klass=jnp.zeros((q,), jnp.int32),
        price=jnp.ones((q,), jnp.float32),
        enq_t=jnp.zeros((q,), jnp.float32),
        seq=jnp.zeros((q,), jnp.int32),
        tries=jnp.zeros((q,), jnp.int32),
        valid=jnp.zeros((q,), bool),
        next_seq=jnp.int32(0),
    )


def queue_push(
    q: AdmissionQueueState,
    res: jax.Array,          # (D,)
    preemptible: jax.Array,  # () bool
    domain: jax.Array,       # () i32
    cost_kind: jax.Array,    # () i32
    period: jax.Array,       # () f32; -1 = policy default
    exclude_zone: jax.Array,  # () i32; -1 = none
    klass: jax.Array,        # () i32
    enq_t: jax.Array,        # () f32
    price: jax.Array,        # () f32
    live: jax.Array = True,  # () bool — False = padding row, no-op
) -> Tuple[AdmissionQueueState, jax.Array, jax.Array]:
    """Enqueue one arrival into the first free row.

    Returns ``(q', slot, ok)``; ``ok=False`` (queue full, or ``live=False``)
    leaves the queue untouched — a full queue REJECTS at arrival, it never
    displaces a waiting entry.
    """
    free = ~q.valid
    ok = jnp.asarray(live) & jnp.any(free)
    slot = jnp.argmax(free).astype(jnp.int32)
    sel = (jnp.arange(q.capacity) == slot) & ok
    q = dataclasses.replace(
        q,
        res=jnp.where(sel[:, None], jnp.asarray(res, jnp.float32)[None, :], q.res),
        preemptible=jnp.where(sel, preemptible, q.preemptible),
        domain=jnp.where(sel, jnp.asarray(domain, jnp.int32), q.domain),
        cost_kind=jnp.where(sel, jnp.asarray(cost_kind, jnp.int32), q.cost_kind),
        period=jnp.where(sel, jnp.asarray(period, jnp.float32), q.period),
        exclude_zone=jnp.where(
            sel, jnp.asarray(exclude_zone, jnp.int32), q.exclude_zone
        ),
        klass=jnp.where(sel, jnp.asarray(klass, jnp.int32), q.klass),
        price=jnp.where(sel, jnp.asarray(price, jnp.float32), q.price),
        enq_t=jnp.where(sel, jnp.asarray(enq_t, jnp.float32), q.enq_t),
        seq=jnp.where(sel, q.next_seq, q.seq),
        tries=jnp.where(sel, 0, q.tries),
        valid=q.valid | sel,
        next_seq=q.next_seq + ok.astype(jnp.int32),
    )
    return q, slot, ok


def queue_select(
    q: AdmissionQueueState,
    batch: int,
    now: Optional[jax.Array] = None,
    aging_rate=0.0,
    n_classes: Optional[int] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Pick the next ``batch`` entries in drain order.

    Order is ``(klass asc, seq asc)`` — strict priority between classes,
    FIFO within a class; retries keep their original ticket, so a failed
    entry re-drains ahead of everything that arrived after it.  Returns
    ``(idx (B,), take (B,))``; rows with ``take=False`` gathered an invalid
    entry (queue shorter than the batch) and must be treated as padding.

    The two-key order is computed as ONE stable sort over a packed monotone
    uint32 key — effective class in the high ``cb = n_classes.bit_length()``
    bits, ``seq`` below, invalid rows pinned to the all-ones sentinel — so
    every drain pays a single sort pass instead of ``lexsort``'s two.  The
    packing is exact (bit-identical to the old lexsort order, pinned by
    tests/test_admission.py) because a valid key can never collide with the
    sentinel: classes are clipped to ``2**cb - 2`` and ``seq`` tickets must
    stay below ``2**(32 - cb)`` (~10^9 at the default two classes; callers
    with ``n_classes=None`` get an 8-bit class field and 2^24 tickets).

    With ``aging_rate > 0`` (``policy.aging_rate``, or a TRACED scalar on
    the scanned simulator's knob axis) an entry's *effective* class decays
    with its queue wait — ``max(0, klass - floor(aging_rate * (now -
    enq_t)))`` — so long-waiting batch entries eventually drain ahead of
    fresh interactive load instead of starving (and stop burning retries
    against a fleet that keeps serving class 0 first).  The secondary
    ``seq`` key is untouched: FIFO within an effective class, and
    ``aging_rate=0`` (static or traced) selects exactly the pre-aging
    order.
    """
    klass = q.klass
    if now is not None and (isinstance(aging_rate, jax.Array) or aging_rate):
        waited = jnp.maximum(jnp.asarray(now, jnp.float32) - q.enq_t, 0.0)
        decay = jnp.floor(
            jnp.asarray(aging_rate, jnp.float32) * waited
        ).astype(jnp.int32)
        klass = jnp.maximum(klass - decay, 0)
    cb = int(n_classes).bit_length() if n_classes else 8
    shift = 32 - cb
    packed = (
        jnp.clip(klass, 0, (1 << cb) - 2).astype(jnp.uint32) << shift
    ) | q.seq.astype(jnp.uint32)
    key = jnp.where(q.valid, packed, jnp.uint32(0xFFFFFFFF))
    order = jnp.argsort(key, stable=True)
    idx = order[: int(batch)].astype(jnp.int32)
    return idx, q.valid[idx]


def queue_pop(
    q: AdmissionQueueState,
    idx: jax.Array,     # (B,) rows a drain attempted
    take: jax.Array,    # (B,) which of them were real
    placed: jax.Array,  # (B,) which of those the pipeline placed
    max_retries: int,
) -> Tuple[AdmissionQueueState, jax.Array]:
    """Fold one drain's outcomes back into the queue.

    Placed entries leave; failed entries burn one retry and stay (backfill)
    until ``max_retries`` attempts are exhausted, at which point they are
    dropped.  Returns ``(q', dropped (B,))``.
    """
    fail = take & ~placed
    tries_new = q.tries[idx] + fail.astype(jnp.int32)
    dropped = fail & (tries_new >= int(max_retries))
    remove = placed | dropped
    q = dataclasses.replace(
        q,
        tries=q.tries.at[idx].set(jnp.where(take, tries_new, q.tries[idx])),
        valid=q.valid.at[idx].set(
            jnp.where(take, q.valid[idx] & ~remove, q.valid[idx])
        ),
    )
    return q, dropped


# ---------------------------------------------------------------------------
# The fused drain: push arrivals → select → decide (scan) → pop
# ---------------------------------------------------------------------------


def _drain_entry(
    fleet_state: SoAFleetState,
    q: AdmissionQueueState,
    new_res,     # (A, D) arrival buffer (padded)
    new_pre,     # (A,) bool
    new_dom,     # (A,) i32
    new_kind,    # (A,) i32
    new_period,  # (A,) f32; -1 = policy default
    new_excl,    # (A,) i32 excluded zone id; -1 = none
    new_cls,     # (A,) i32
    new_t,       # (A,) f32 arrival times
    new_price,   # (A,) f32
    new_live,    # (A,) bool — padding rows False
    now,         # () f32 drain time
    *,
    policy: SchedulerPolicy,
):
    """One admission drain, fully fused (one dispatch).

    Decisions run through the exact ``schedule_many`` scan body at a common
    ``now`` (the drain time), so a drained queue is bit-exact against
    feeding the same requests to the unqueued pipeline in drain order.
    Untaken rows carry the ``PAD_RES`` sentinel and no-op.

    Graceful degradation (``policy.storm_threshold``): when the fleet-wide
    observed churn rate ΣT/max(ΣU, eps) — read off the state's zone
    accumulators — exceeds the threshold, this drain's preemptible rows are
    demoted to non-preemptible *for this attempt* (spot capacity is being
    reclaimed fleet-wide, so handing out more spot placements just feeds
    the storm).  The demotion is reported per row (``degraded``) so the
    host mirror books the placement under the demoted request.
    """

    def push_body(qs, xs):
        qs, slot, ok = queue_push(qs, *xs)
        return qs, (slot, ok)

    q, (new_slot, pushed) = jax.lax.scan(
        push_body, q,
        (new_res, new_pre, new_dom, new_kind, new_period, new_excl, new_cls,
         new_t, new_price, new_live),
    )

    idx, take = queue_select(
        q, policy.admit_batch, now=now, aging_rate=policy.aging_rate,
        n_classes=policy.n_classes,
    )
    b = idx.shape[0]
    b_res = jnp.where(take[:, None], q.res[idx], PAD_RES)
    b_pre = jnp.where(take, q.preemptible[idx], False)
    b_dom = jnp.where(take, q.domain[idx], -1)
    b_kind = jnp.where(take, q.cost_kind[idx], -1)
    b_period = jnp.where(take, q.period[idx], -1.0)
    b_excl = jnp.where(take, q.exclude_zone[idx], -1)
    b_price = jnp.where(take, q.price[idx], 1.0)
    b_now = jnp.full((b,), now, jnp.float32)

    if policy.storm_threshold is not None:
        # fleet-wide rate = last entry of the shared fused churn reduction
        churn = churn_stats(fleet_state.zone_term, fleet_state.zone_up)[-1]
        storm = churn > jnp.float32(policy.storm_threshold)
        degraded = b_pre & storm
        b_pre = b_pre & ~storm
    else:
        degraded = jnp.zeros_like(b_pre)

    # The exclusion operand rides the scan only when the relocation plane is
    # on, so relocation-off policies compile the exact pre-relocation drain.
    excl_xs = b_excl if policy.relocation_on else jnp.full((b,), -1, jnp.int32)

    def body(st, xs):
        res, pre, dom, t, price, kind, period, excl = xs
        return _step_core(
            st, res, pre, dom, t, price, kind, period, policy,
            req_exclude=excl if policy.relocation_on else None,
        )

    fleet_state, (host_idx, slot, ok, kill, fell_back, margin) = jax.lax.scan(
        body, fleet_state,
        (b_res, b_pre, b_dom, b_now, b_price, b_kind, b_period, excl_xs),
    )
    placed = ok & take
    wait = jnp.where(placed, now - q.enq_t[idx], 0.0)
    q, dropped = queue_pop(q, idx, take, placed, policy.max_retries)
    return fleet_state, q, (
        new_slot, pushed, idx, take, placed, host_idx, slot, kill,
        fell_back, margin, wait, dropped, degraded, q.depth,
    )


_DRAIN_STATICS = ("policy",)
_drain_donated = functools.partial(
    jax.jit, static_argnames=_DRAIN_STATICS, donate_argnums=(0, 1)
)(_drain_entry)
_drain_kept = functools.partial(
    jax.jit, static_argnames=_DRAIN_STATICS
)(_drain_entry)


# ---------------------------------------------------------------------------
# Host-side mirror: stats, identity bookkeeping, async dispatch
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class AdmissionStats:
    """Counters + latency samples of one front end (host-side).

    Conservation invariant (pinned by tests/test_admission.py): every
    arrival is in exactly one bucket —
    ``arrivals == admitted + rejected_overflow + rejected_retry
    + queue_depth + pending``.
    """

    arrivals: int = 0
    admitted: int = 0
    rejected_overflow: int = 0
    rejected_retry: int = 0
    drains: int = 0
    retries: int = 0
    #: preemptible attempts demoted to non-preemptible by storm degradation
    degraded: int = 0
    queue_depth: int = 0
    #: sim-time admission latency (drain time - arrival time) per placement
    wait_s: List[float] = dataclasses.field(default_factory=list)
    #: wall-clock submit → outcome-absorbed latency per placement (seconds)
    wall_wait_s: List[float] = dataclasses.field(default_factory=list)

    @property
    def rejected(self) -> int:
        return self.rejected_overflow + self.rejected_retry

    @staticmethod
    def _pct(samples: Sequence[float], pct: float) -> float:
        if not samples:
            return 0.0
        # f32 on purpose: the waits themselves are f32 device differences,
        # and interpolating in f32 keeps this reader bit-identical to the
        # scanned engine's (``ScanResult.wait_percentiles``).
        return float(np.percentile(np.asarray(samples, np.float32), pct))

    def wait_percentiles(self) -> Dict[str, float]:
        """Sim-time queue-wait distribution (drain time − arrival time per
        admitted placement).  The waits are f32 differences computed by the
        device drain program itself, so the same reader over
        ``ScanResult.wait_s`` (the in-carry accumulator of the scanned
        simulator) returns bit-identical percentiles — the deterministic
        latency comparison the streaming parity suite pins."""
        return {
            "wait_p50_s": self._pct(self.wait_s, 50),
            "wait_p99_s": self._pct(self.wait_s, 99),
        }

    def summary(self) -> Dict[str, float]:
        return {
            "arrivals": self.arrivals,
            "admitted": self.admitted,
            "rejected_overflow": self.rejected_overflow,
            "rejected_retry": self.rejected_retry,
            "drains": self.drains,
            "retries": self.retries,
            "degraded": self.degraded,
            "queue_depth": self.queue_depth,
            "wait_p50_s": self._pct(self.wait_s, 50),
            "wait_p99_s": self._pct(self.wait_s, 99),
            "wall_p50_us": self._pct(self.wall_wait_s, 50) * 1e6,
            "wall_p99_us": self._pct(self.wall_wait_s, 99) * 1e6,
        }


@dataclasses.dataclass(frozen=True)
class DrainResult:
    """Host-side view of one absorbed drain."""

    now: float
    #: every attempted (request, placed) pair in service (drain) order —
    #: the exact decision sequence, for oracle replays
    attempts: Tuple[Tuple[Request, bool], ...]
    #: placed requests' outcomes, in service (drain) order
    outcomes: Tuple[object, ...]          # Tuple[SoAOutcome, ...]
    #: requests rejected by this drain (queue overflow or retries exhausted)
    rejected: Tuple[Request, ...]
    #: requests that failed placement but remain queued for backfill retry
    retried: Tuple[Request, ...]
    #: live queue entries after the drain
    queue_depth: int


@dataclasses.dataclass
class _Waiting:
    """One not-yet-admitted request (host mirror of a queue row)."""

    request: Request
    price: float
    klass: int
    enq_t: float
    submit_wall: float  # time.perf_counter() at submit


class AdmissionFrontEnd:
    """Async admission layer over one ``SoAFleet``.

    Arrivals ``submit()`` into a host-side accumulation buffer; ``drain()``
    flushes buffer + queue through the fused drain program.  With
    ``block=False`` the dispatch returns immediately (double-buffering:
    the host accumulates the next batch while the device runs this one);
    outcomes are absorbed lazily on the next drain / ``flush()`` / stats
    read.  The owning fleet's python mirror is updated through the same
    ``_absorb`` path as the direct entry points, so departures, failures
    and oracle rebuilds compose unchanged.
    """

    def __init__(self, fleet):
        policy = fleet.policy
        if policy.queue_capacity <= 0:
            raise ValueError(
                "AdmissionFrontEnd needs policy.queue_capacity > 0"
            )
        if policy.mesh is not None:
            raise NotImplementedError(
                "admission queue + sharded fleet state is future work; "
                "drop policy.mesh or policy.queue_capacity"
            )
        self.fleet = fleet
        self.policy = policy
        self.qstate = queue_init(policy.queue_capacity, len(fleet.spec.dims))
        #: queue row → waiting record (mirrors ``AdmissionQueueState.valid``)
        self.slots: List[Optional[_Waiting]] = [None] * policy.queue_capacity
        self._pending: List[_Waiting] = []
        #: relocation re-placements in flight: request id → (victim id,
        #: source zone).  The owning fleet settles each entry at the drain
        #: that decides it (make-before-break; see ``SoAFleet.relocate``).
        self._reloc: Dict[str, Tuple[str, str]] = {}
        self._inflight = None
        #: results absorbed as a side effect (a blocking drain flushing a
        #: previous non-blocking one) awaiting ``take_results``
        self._unclaimed: List[DrainResult] = []
        self.stats = AdmissionStats()

    # -- submission -----------------------------------------------------------
    def _klass_of(self, req: Request) -> int:
        nc = self.policy.n_classes
        if req.priority is None:
            return 0 if not req.preemptible else nc - 1
        k = int(req.priority)
        if not 0 <= k < nc:
            raise ValueError(
                f"request {req.id} priority {k} outside the policy's "
                f"{nc} classes"
            )
        return k

    def submit(self, req: Request, now: float, price: float = 1.0) -> None:
        """Accept one arrival into the accumulation buffer (never blocks)."""
        self.fleet._req_arrays(req)  # validate cost kind early, like direct paths
        self._pending.append(
            _Waiting(
                request=req, price=float(price), klass=self._klass_of(req),
                enq_t=float(now), submit_wall=time.perf_counter(),
            )
        )
        self.stats.arrivals += 1

    def submit_relocation(
        self, req: Request, victim_id: str, zone: str, now: float,
        price: float = 1.0,
    ) -> None:
        """Queue one relocation re-placement.  It rides the queue as a
        class-0 entry (drains with interactive traffic) but stays
        preemptible, so it can never displace user placements.  The victim
        keeps running until the drain that places this entry settles it
        (``SoAFleet._settle_relocation_placed``); a rejected entry leaves
        the victim untouched and backs the zone off."""
        self.submit(req, now, price=price)
        self._reloc[req.id] = (victim_id, zone)

    @property
    def pending(self) -> int:
        """Arrivals accumulated but not yet pushed to the device queue."""
        return len(self._pending)

    @property
    def waiting(self) -> int:
        """Everything not yet decided: buffer + live queue entries."""
        return len(self._pending) + sum(w is not None for w in self.slots)

    def batch_ready(self) -> bool:
        return len(self._pending) >= self.policy.admit_batch

    def oldest_enq_t(self) -> Optional[float]:
        ts = [w.enq_t for w in self._pending]
        ts += [w.enq_t for w in self.slots if w is not None]
        return min(ts) if ts else None

    def next_deadline(self) -> Optional[float]:
        """Sim time by which the SLO forces the next drain (None = idle)."""
        oldest = self.oldest_enq_t()
        return None if oldest is None else oldest + self.policy.slo_target_s

    # -- drains ---------------------------------------------------------------
    def drain(self, now: float, block: bool = True) -> Optional[DrainResult]:
        """Dispatch one drain at sim time ``now``.

        Absorbs any in-flight previous drain first (ordering; its result
        lands in ``take_results``), then pushes the pending buffer + runs
        one ``admit_batch`` selection.  Returns this drain's
        ``DrainResult`` when ``block``; with ``block=False`` returns None
        immediately and the result is absorbed later (``flush`` /
        ``take_results``).
        """
        self.sync()
        pend, self._pending = self._pending, []
        if not pend and not any(w is not None for w in self.slots):
            return DrainResult(
                now=float(now), attempts=(), outcomes=(), rejected=(),
                retried=(), queue_depth=0,
            ) if block else None

        a = max(4, 1 << (len(pend) - 1).bit_length()) if pend else 4
        d = len(self.fleet.spec.dims)
        res = np.full((a, d), PAD_RES, np.float32)
        pre = np.zeros((a,), bool)
        dom = np.full((a,), -1, np.int32)
        kind = np.full((a,), -1, np.int32)
        per = np.full((a,), -1.0, np.float32)
        exc = np.full((a,), -1, np.int32)
        cls = np.zeros((a,), np.int32)
        enq = np.zeros((a,), np.float32)
        price = np.ones((a,), np.float32)
        live = np.zeros((a,), bool)
        for i, w in enumerate(pend):
            r, p, dm, kd, pd, ex = self.fleet._req_arrays(w.request)
            res[i], pre[i], dom[i], kind[i], per[i], exc[i] = (
                r, p, dm, kd, pd, ex
            )
            cls[i], enq[i], price[i], live[i] = w.klass, w.enq_t, w.price, True

        policy = self.fleet._flush_policy()
        fn = _drain_donated if policy.donate else _drain_kept
        self.fleet.state, self.qstate, aux = fn(
            self.fleet.state, self.qstate,
            res, pre, dom, kind, per, exc, cls, enq, price, live,
            jnp.asarray(now, jnp.float32), policy=policy,
        )
        self._inflight = (pend, float(now), aux)
        self.stats.drains += 1
        return self.flush() if block else None

    def flush(self) -> Optional[DrainResult]:
        """Absorb the in-flight drain's outcomes (blocks on the device)."""
        if self._inflight is None:
            return None
        pend, now, aux = self._inflight
        self._inflight = None
        (new_slot, pushed, idx, take, placed, host_idx, slot, kill,
         fell_back, margin, wait, dropped, degraded, depth) = (
            np.asarray(x) for x in aux
        )
        wall_now = time.perf_counter()

        rejected: List[Request] = []
        # 1. arrivals → queue rows (or instant overflow rejection)
        for i, w in enumerate(pend):
            if pushed[i]:
                self.slots[int(new_slot[i])] = w
            else:
                self.stats.rejected_overflow += 1
                rejected.append(w.request)
                reloc = self._reloc.pop(w.request.id, None)
                if reloc is not None:  # overflow: victim keeps running
                    self.fleet._settle_relocation_rejected(
                        reloc[0], reloc[1], now
                    )
        # 2. attempted rows, in service order
        outcomes, retried, attempts = [], [], []
        for j in range(len(idx)):
            if not take[j]:
                continue
            row = int(idx[j])
            w = self.slots[row]
            assert w is not None, "drained an empty queue row"
            # Storm degradation demoted this attempt on device; mirror the
            # demotion so the python bookkeeping matches what actually ran.
            req = w.request
            if degraded[j]:
                req = dataclasses.replace(req, preemptible=False)
                self.stats.degraded += 1
            attempts.append((req, bool(placed[j])))
            if placed[j]:
                self.slots[row] = None
                out = self.fleet._absorb(
                    req, now, w.price, int(host_idx[j]), int(slot[j]),
                    True, kill[j],
                )
                outcomes.append(out)
                self.stats.admitted += 1
                self.stats.wait_s.append(float(wait[j]))
                self.stats.wall_wait_s.append(wall_now - w.submit_wall)
                reloc = self._reloc.pop(req.id, None)
                if reloc is not None:  # make-before-break: replacement is
                    # live — NOW the victim may die.
                    self.fleet._settle_relocation_placed(
                        reloc[0], reloc[1], out, now
                    )
            elif dropped[j]:
                self.slots[row] = None
                self.stats.rejected_retry += 1
                rejected.append(w.request)
                reloc = self._reloc.pop(req.id, None)
                if reloc is not None:  # victim keeps running; zone backs off
                    self.fleet._settle_relocation_rejected(
                        reloc[0], reloc[1], now
                    )
            else:
                self.stats.retries += 1
                retried.append(w.request)
        n_take = int(take.sum())
        if n_take:
            fb = fell_back[take]
            mg = margin[take]
            self.fleet._observe(int(fb.sum()), float(mg.min()), n_take)
        self.stats.queue_depth = int(depth)
        return DrainResult(
            now=now, attempts=tuple(attempts), outcomes=tuple(outcomes),
            rejected=tuple(rejected), retried=tuple(retried),
            queue_depth=int(depth),
        )

    def sync(self) -> None:
        """Absorb any in-flight drain, banking its result for
        ``take_results`` (safe to call anywhere the python mirror must be
        current — e.g. before a departure/failure event)."""
        prev = self.flush()
        if prev is not None:
            self._unclaimed.append(prev)

    def wait_percentiles(self) -> Dict[str, float]:
        """Sim-time queue-wait p50/p99 over every absorbed placement —
        the same reader ``ScanResult.wait_percentiles`` exposes for the
        scanned engine (bit-identical on a shared trace)."""
        self.sync()
        return self.stats.wait_percentiles()

    def take_results(self) -> List[DrainResult]:
        """Flush and return every drain result not yet handed to a caller
        (the non-blocking consumption pattern — see ``SoASimulator``)."""
        self.sync()
        out, self._unclaimed = self._unclaimed, []
        return out

    def drain_all(self, now: float) -> List[DrainResult]:
        """Drain until the queue is empty or every waiting entry has
        exhausted its retries (end-of-run / test epilogue)."""
        results: List[DrainResult] = []
        # Each failing entry burns one retry per drain, so this terminates
        # within ceil(Q/B) * max_retries + 1 rounds.
        cap = self.policy.queue_capacity
        limit = (
            -(-cap // self.policy.admit_batch) * self.policy.max_retries + 2
        )
        for _ in range(limit):
            if self.waiting == 0:
                break
            results.append(self.drain(now, block=True))
        return results
