"""repro.core — the paper's preemptible-aware scheduling, as a library.

Python reference implementation (oracle + paper-faithful):
    scheduler.FilterScheduler / RetryScheduler / PreemptibleScheduler
Vectorized beyond-paper implementation:
    jax_scheduler.JaxPreemptibleScheduler  (jit; optional Pallas hot path)
"""
from .admission import (
    AdmissionFrontEnd,
    AdmissionQueueState,
    AdmissionStats,
    DrainResult,
    queue_init,
    queue_pop,
    queue_push,
    queue_select,
)
from .cluster import Cluster, make_uniform_fleet
from .cost import CountCost, MixedCost, PeriodCost, RecomputeCost, RevenueCost
from .fleet_sharding import (
    fleet_mesh,
    merge_shortlists,
    pad_fleet_state,
    padded_hosts,
    padded_hosts_for,
    shard_fleet_state,
)
from .policy import (
    COST_KINDS,
    SchedulerPolicy,
)
from .preemption import PreemptAck, PreemptionController
from .scheduler import (
    FilterScheduler,
    PreemptibleScheduler,
    RetryScheduler,
    SCHEDULER_REGISTRY,
)
from .simulator import Simulator, SoASimulator, WorkloadSpec
from .soa_fleet import SoAFleet, SoAOutcome
from .types import (
    Flavor,
    Host,
    Instance,
    Request,
    ResourceSpec,
    Resources,
    ScheduleResult,
    TerminationPlan,
    TPU_SPEC,
    VM_SPEC,
)

__all__ = [
    "AdmissionFrontEnd", "AdmissionQueueState", "AdmissionStats",
    "DrainResult", "queue_init", "queue_pop", "queue_push", "queue_select",
    "Cluster", "make_uniform_fleet",
    "CountCost", "MixedCost", "PeriodCost", "RecomputeCost", "RevenueCost",
    "COST_KINDS", "SchedulerPolicy",
    "fleet_mesh", "merge_shortlists", "pad_fleet_state", "padded_hosts",
    "padded_hosts_for", "shard_fleet_state",
    "PreemptAck", "PreemptionController",
    "FilterScheduler", "PreemptibleScheduler", "RetryScheduler", "SCHEDULER_REGISTRY",
    "Simulator", "SoASimulator", "WorkloadSpec",
    "SoAFleet", "SoAOutcome",
    "Flavor", "Host", "Instance", "Request", "ResourceSpec", "Resources",
    "ScheduleResult", "TerminationPlan", "TPU_SPEC", "VM_SPEC",
]
