"""``SchedulerPolicy`` — the ONE static argument of the jit'd pipeline.

Every decision path (``schedule_decision``/``schedule_step``/``schedule_many``,
``SoAFleet``, ``SoASimulator``, ``JaxPreemptibleScheduler``, the sharded
screen) used to thread the same knob set — cost kind, billing period, weigher
multipliers, shortlist size, execution-backend switches — as loose static
kwargs through nine signatures that had to change in lockstep for every new
knob.  The policy object collapses that plumbing: one frozen, hashable
dataclass carried as a single ``static_argnames`` entry, validated once at
construction instead of mid-trace.

Contracts the jit'd paths rely on:

* **Frozen + hashable + value-equal.**  Two policies built from the same
  field values are ``==`` and hash alike, so they hit the SAME jit cache
  entry — constructing a fresh (equal) policy per call never retraces
  (pinned by tests/test_policy.py::test_equal_policies_share_compile_cache).
  Every field must therefore be hashable: tuples not lists, a
  ``jax.sharding.Mesh`` (hashable by device layout) not a device list.
* **Static.**  Policy fields select *which program is compiled* (multiplier
  gating, shortlist size, cost-kind table, screen backend); none of them is
  a traced value.  Changing any field compiles a new executable.
* **Decision-neutral execution knobs.**  ``use_pallas`` / ``fused_screen``
  / ``mesh`` / ``shortlist`` / ``donate`` select which path computes the
  answer, never the answer itself (the parity suites pin every combination
  bit-identical).  ``weigher_multipliers`` and the cost table DO define the
  answer — they are the provider's policy proper.

The **cost-kind table** (``cost_kind`` + ``cost_kinds``) is what makes mixed
payment models expressible on the fast path: a fleet may bill some instances
by partial period, others by count / lost revenue / recompute work, chosen
per instance via the ``inst_cost_kind`` column of ``SoAFleetState`` (see
``jax_scheduler.mixed_slot_costs`` and ``cost.MixedCost``, the python
oracle).  A single-kind policy compiles the exact pre-policy program — no
kind column is read and decisions are bit-identical to the old loose-kwarg
path.

The **admission knobs** (``queue_capacity``, ``admit_batch``,
``slo_target_s``, ``max_retries``, ``n_classes``) configure the streaming
admission front end (``core.admission``): a device-resident wait queue with
priority classes and backfill retries in front of the decision pipeline.
``queue_capacity=0`` (the default) disables the admission plane entirely —
every driver behaves exactly as before.

The pre-policy loose decision kwargs were removed one release after their
deprecation (the old ``resolve_policy`` shims and
``PolicyDeprecationWarning``); every entry point now takes ``policy=`` only.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from .cost import (
    BILL_PERIOD_S,
    CostFunction,
    CountCost,
    MixedCost,
    PeriodCost,
    RecomputeCost,
    RevenueCost,
)

#: Canonical device-resident cost kinds; position = the kind id stored in
#: ``SoAFleetState.inst_cost_kind`` (-1 there = "use the policy default").
COST_KINDS: Tuple[str, ...] = ("period", "count", "revenue", "recompute")
COST_KIND_IDS = {kind: i for i, kind in enumerate(COST_KINDS)}

#: Default stage-2 shortlist size when ``shortlist=None`` (auto).  Lives here
#: (not ``jax_scheduler``) so the policy can resolve its own ceiling without
#: an import cycle; ``jax_scheduler`` re-exports it.
DEFAULT_SHORTLIST = 64


def _is_pow2(x: int) -> bool:
    return x > 0 and (x & (x - 1)) == 0


@dataclasses.dataclass(frozen=True)
class SchedulerPolicy:
    """Frozen, hashable bundle of every static decision knob.

    Fields (see docs/api.md for the full table):

    * ``weigher_multipliers`` — (overcommit, termination_cost, packing,
      straggler); the first two reproduce the paper's evaluation policy.
    * ``cost_kind`` — the DEFAULT billing kind: used for every slot whose
      ``inst_cost_kind`` is -1, and recorded on new placements whose request
      carries no explicit kind.
    * ``cost_kinds`` — extra kinds instances of this fleet may carry
      (the mixed-payment table).  Empty = homogeneous fleet, which compiles
      the exact single-kind program (bit-identical to the pre-policy path).
    * ``period`` — billing quantum (seconds) of the ``period``/``revenue``
      kinds.
    * ``shortlist`` — stage-2 candidate count M (None = auto, 0 = full
      enumeration).
    * ``adaptive_shortlist`` / ``adaptive_bounds`` — host-side controller
      resizing M between flushes within [m_min, m_max] (powers of two).
    * ``use_pallas`` / ``fused_screen`` / ``mesh`` — execution backends
      (stage-2 kernel, stage-1 kernel, device sharding).  With both
      ``fused_screen=True`` and ``mesh`` set, the fused kernel runs *per
      shard* inside ``shard_map``.
    * ``donate`` — donate input state buffers on step/many (per-call
      ``donate=`` overrides).
    * ``queue_capacity`` — slots in the device-resident admission queue
      (0 = admission plane off; ``core.admission`` untouched).
    * ``admit_batch`` — decisions per drain (the ``schedule_many`` batch the
      front end accumulates toward).
    * ``slo_target_s`` — admission-latency SLO (sim-time seconds): a drain
      is forced once the oldest waiting arrival has waited this long.
    * ``max_retries`` — placement attempts per queued request before it is
      rejected (1 = no backfill retry).
    * ``n_classes`` — priority classes; class 0 (interactive) drains first,
      class ``n_classes - 1`` (batch) last.
    * ``churn_multiplier`` — weight of the failure-domain churn weigher:
      hosts in zones with a high learned churn rate ẑ = T/max(U, ε) are
      penalized.  0 (default) compiles the exact churn-blind program.
    * ``churn_threshold`` — hard steering: zones whose ẑ exceeds this are
      filtered out for PREEMPTIBLE placements (normal work still lands).
      ``None`` = off.
    * ``storm_threshold`` — graceful degradation in the admission front
      end: when the FLEET-WIDE churn rate exceeds this, pending preemptible
      requests are admitted as non-preemptible instead of being exposed to
      the storm.  ``None`` = off.
    * ``aging_rate`` — anti-starvation aging (classes per second of queue
      wait): a queued entry's effective class decays toward 0 the longer it
      waits, as one more ``queue_select`` lexsort column.  0 = strict
      (class, seq) order, the pre-aging program.
    * ``relocate_threshold`` — the relocation plane's arming threshold: a
      zone whose learned churn rate ẑ exceeds it becomes an evacuation
      target (``SoAFleet.relocate``).  ``None`` (default) = the relocation
      plane is off entirely and no zone-exclusion operand is compiled.
    * ``relocate_exit`` — hysteresis exit: an armed zone disarms only when
      ẑ drops BELOW this (must be < ``relocate_threshold``; ``None`` =
      half the arming threshold), so a zone oscillating around the arming
      threshold never thrashes.
    * ``relocate_cooldown_s`` — per-zone cooldown after a disarm before the
      zone may re-arm.
    * ``relocate_budget`` — max victims evacuated per zone per relocation
      pass (bounds migration storms).
    * ``relocate_backoff_s`` — base of the per-zone exponential backoff
      after a failed relocation (doubles per consecutive failure).
    * ``relocate_every_s`` — period of the simulator's relocation trigger.
    """

    weigher_multipliers: Tuple[float, float, float, float] = (1.0, 1.0, 0.0, 0.0)
    churn_multiplier: float = 0.0
    churn_threshold: Optional[float] = None
    storm_threshold: Optional[float] = None
    cost_kind: str = "period"
    cost_kinds: Tuple[str, ...] = ()
    period: float = BILL_PERIOD_S
    shortlist: Optional[int] = None
    adaptive_shortlist: bool = False
    adaptive_bounds: Tuple[int, int] = (16, 256)
    use_pallas: bool = False
    fused_screen: Optional[bool] = None
    mesh: object = None  # Optional[jax.sharding.Mesh]; hashable by layout
    donate: bool = True
    queue_capacity: int = 0
    admit_batch: int = 32
    slo_target_s: float = 60.0
    max_retries: int = 8
    n_classes: int = 2
    aging_rate: float = 0.0
    relocate_threshold: Optional[float] = None
    relocate_exit: Optional[float] = None
    relocate_cooldown_s: float = 300.0
    relocate_budget: int = 4
    relocate_backoff_s: float = 30.0
    relocate_every_s: float = 60.0

    def __post_init__(self):
        # Tuple-normalize sequence fields so list-passing callers still get a
        # hashable (and value-equal) policy instead of a mid-trace TypeError.
        mult = tuple(float(m) for m in self.weigher_multipliers)
        if len(mult) != 4:
            raise ValueError(
                f"weigher_multipliers needs 4 entries (overcommit, "
                f"termination_cost, packing, straggler); got {len(mult)}"
            )
        object.__setattr__(self, "weigher_multipliers", mult)
        object.__setattr__(self, "churn_multiplier", float(self.churn_multiplier))
        for name in ("churn_threshold", "storm_threshold", "relocate_threshold"):
            val = getattr(self, name)
            if val is not None:
                val = float(val)
                if not val > 0:
                    raise ValueError(f"{name} must be positive or None, got {val}")
                object.__setattr__(self, name, val)
        # -- relocation plane -------------------------------------------------
        if self.relocate_exit is not None:
            exit_val = float(self.relocate_exit)
            if self.relocate_threshold is None:
                raise ValueError(
                    "relocate_exit without relocate_threshold (the plane is "
                    "off); set relocate_threshold to arm evacuation"
                )
            if not 0 < exit_val < self.relocate_threshold:
                raise ValueError(
                    f"relocate_exit must sit in (0, relocate_threshold="
                    f"{self.relocate_threshold}) for hysteresis, got {exit_val}"
                )
            object.__setattr__(self, "relocate_exit", exit_val)
        for name in ("relocate_cooldown_s", "relocate_backoff_s",
                     "relocate_every_s"):
            val = float(getattr(self, name))
            if not val > 0:
                raise ValueError(f"{name} must be positive, got {val}")
            object.__setattr__(self, name, val)
        if int(self.relocate_budget) < 1:
            raise ValueError(
                f"relocate_budget must be >= 1, got {self.relocate_budget}"
            )
        object.__setattr__(self, "relocate_budget", int(self.relocate_budget))
        if float(self.aging_rate) < 0:
            raise ValueError(f"aging_rate must be >= 0, got {self.aging_rate}")
        object.__setattr__(self, "aging_rate", float(self.aging_rate))
        kinds = tuple(str(k) for k in self.cost_kinds)
        object.__setattr__(self, "cost_kinds", kinds)
        for kind in (self.cost_kind,) + kinds:
            if kind not in COST_KIND_IDS:
                raise ValueError(
                    f"unknown cost kind {kind!r}; device-resident kinds are "
                    f"{COST_KINDS} (others must use the rebuild path)"
                )
        if not self.period > 0:
            raise ValueError(f"period must be positive, got {self.period}")
        if self.shortlist is not None and int(self.shortlist) < 0:
            raise ValueError(f"shortlist must be >= 0 or None, got {self.shortlist}")
        if self.shortlist is not None:
            object.__setattr__(self, "shortlist", int(self.shortlist))
        lo, hi = (int(b) for b in self.adaptive_bounds)
        if not (_is_pow2(lo) and _is_pow2(hi)):
            raise ValueError(
                f"adaptive_bounds must be powers of two (M doubles/halves "
                f"between them), got {self.adaptive_bounds}"
            )
        if lo > hi:
            raise ValueError(f"adaptive_bounds m_min > m_max: {self.adaptive_bounds}")
        object.__setattr__(self, "adaptive_bounds", (lo, hi))
        if self.adaptive_shortlist and self.shortlist == 0:
            # The starting M itself may sit outside adaptive_bounds (the
            # pre-policy controller accepted that and clamps as it moves);
            # only the genuinely contradictory setting is rejected.
            raise ValueError(
                "adaptive_shortlist=True contradicts shortlist=0 (explicit "
                "full enumeration); pass shortlist=None or a starting M"
            )
        if self.fused_screen is not None and not isinstance(self.fused_screen, bool):
            raise ValueError("fused_screen must be None (auto) or a bool")
        if self.mesh is not None and len(getattr(self.mesh, "axis_names", ())) != 1:
            raise ValueError(
                "mesh must be a 1-D jax.sharding.Mesh (see fleet_sharding.fleet_mesh)"
            )
        # -- admission plane --------------------------------------------------
        qc, ab = int(self.queue_capacity), int(self.admit_batch)
        mr, nc = int(self.max_retries), int(self.n_classes)
        if qc < 0:
            raise ValueError(f"queue_capacity must be >= 0 (0 = off), got {qc}")
        if ab < 1:
            raise ValueError(f"admit_batch must be >= 1, got {ab}")
        if qc and ab > qc:
            raise ValueError(
                f"admit_batch ({ab}) cannot exceed queue_capacity ({qc}); a "
                "drain selects at most the whole queue"
            )
        if not float(self.slo_target_s) > 0:
            raise ValueError(
                f"slo_target_s must be positive, got {self.slo_target_s}"
            )
        if mr < 1:
            raise ValueError(f"max_retries must be >= 1, got {mr}")
        if nc < 1:
            raise ValueError(f"n_classes must be >= 1, got {nc}")
        if nc > 255:
            raise ValueError(
                f"n_classes must be <= 255, got {nc}: drain order sorts one "
                "packed uint32 key whose class field is at most 8 bits "
                "(see core/admission.py queue_select)"
            )
        object.__setattr__(self, "queue_capacity", qc)
        object.__setattr__(self, "admit_batch", ab)
        object.__setattr__(self, "slo_target_s", float(self.slo_target_s))
        object.__setattr__(self, "max_retries", mr)
        object.__setattr__(self, "n_classes", nc)

    # -- weigher multipliers ---------------------------------------------------
    @property
    def all_multipliers(self) -> Tuple[float, float, float, float, float]:
        """The public 4-tuple extended with the churn multiplier — the 5-slot
        form every screen backend consumes (``screen_math``)."""
        return self.weigher_multipliers + (self.churn_multiplier,)

    @property
    def churn_aware(self) -> bool:
        """True when decisions read the zone-churn plane at all (weigher or
        hard steering) — gates the extra stage-1 input statically."""
        return bool(self.churn_multiplier) or self.churn_threshold is not None

    # -- relocation plane -----------------------------------------------------
    @property
    def relocation_on(self) -> bool:
        """True when the hot-zone relocation plane is enabled — gates the
        per-request zone-exclusion operand statically, the same way
        ``churn_aware`` gates the churn row: relocation-off policies compile
        the exact pre-relocation program."""
        return self.relocate_threshold is not None

    @property
    def relocate_exit_threshold(self) -> float:
        """The resolved hysteresis exit (``relocate_exit`` or half the
        arming threshold).  Only meaningful when :attr:`relocation_on`."""
        if self.relocate_threshold is None:
            raise ValueError("relocation plane is off (relocate_threshold=None)")
        if self.relocate_exit is not None:
            return self.relocate_exit
        return self.relocate_threshold / 2.0

    # -- cost-kind table ------------------------------------------------------
    @property
    def kind_table(self) -> Tuple[str, ...]:
        """Distinct kinds this fleet may bill, default first."""
        extra = tuple(k for k in dict.fromkeys(self.cost_kinds) if k != self.cost_kind)
        return (self.cost_kind,) + extra

    @property
    def mixed(self) -> bool:
        """True when more than one billing kind is in play (the kind column
        is read; single-kind policies never touch it)."""
        return len(self.kind_table) > 1

    @property
    def default_kind_id(self) -> int:
        return COST_KIND_IDS[self.cost_kind]

    def max_shortlist(self) -> int:
        """Largest M a decision under this policy can run with — the adaptive
        ceiling when the controller is on; what sharded fleets pad for."""
        if self.adaptive_shortlist:
            return self.adaptive_bounds[1]
        return DEFAULT_SHORTLIST if self.shortlist is None else self.shortlist

    # -- python cost-module bridge --------------------------------------------
    @classmethod
    def for_cost(cls, cost_fn: Optional[CostFunction], **overrides) -> "SchedulerPolicy":
        """Build a policy whose cost table mirrors a python cost module
        (the inverse of :meth:`make_cost_fn`).  ``MixedCost`` maps to a
        multi-kind table; the four single-kind modules map to themselves."""
        cost_fn = cost_fn or PeriodCost()
        if isinstance(cost_fn, MixedCost):
            fields = dict(
                cost_kind=cost_fn.default,
                cost_kinds=tuple(cost_fn.kinds),
                period=cost_fn.period_s,
            )
        elif isinstance(cost_fn, PeriodCost):
            fields = dict(cost_kind="period", period=cost_fn.period_s)
        elif isinstance(cost_fn, CountCost):
            fields = dict(cost_kind="count")
        elif isinstance(cost_fn, RevenueCost):
            fields = dict(cost_kind="revenue", period=cost_fn.period_s)
        elif isinstance(cost_fn, RecomputeCost):
            fields = dict(cost_kind="recompute")
        else:
            raise ValueError(
                f"cost function {cost_fn.name!r} has no device-resident "
                "equivalent; use the rebuild path (build_soa_state + "
                "schedule_decision)"
            )
        fields.update(overrides)
        return cls(**fields)

    def make_cost_fn(self) -> CostFunction:
        """The python cost module equivalent to this policy's cost table —
        the oracle the parity tests rebuild states with."""
        if self.mixed:
            return MixedCost(
                default=self.cost_kind, kinds=self.cost_kinds, period_s=self.period
            )
        return {
            "period": lambda: PeriodCost(self.period),
            "count": CountCost,
            "revenue": lambda: RevenueCost(self.period),
            "recompute": RecomputeCost,
        }[self.cost_kind]()


def ensure_policy(
    policy: Optional[SchedulerPolicy],
    where: str,
    cost_fn: Optional[CostFunction] = None,
) -> SchedulerPolicy:
    """Validate/derive the policy an entry point will compile against.

    ``None`` derives a policy from ``cost_fn`` (or the all-defaults policy).
    An explicit policy passes through type-checked — and, when ``cost_fn``
    is ALSO given, checked for billing agreement: billing was historically
    derived from ``cost_fn``, so a policy that bills differently from an
    explicitly-passed cost module would silently reprice decisions — make
    the disagreement loud instead.
    """
    if policy is None:
        return SchedulerPolicy.for_cost(cost_fn)
    if not isinstance(policy, SchedulerPolicy):
        raise TypeError(f"{where}(): policy must be a SchedulerPolicy")
    if cost_fn is not None:
        derived = SchedulerPolicy.for_cost(cost_fn)
        if (
            derived.cost_kind != policy.cost_kind
            or set(derived.kind_table) != set(policy.kind_table)
            or derived.period != policy.period
        ):
            raise ValueError(
                f"{where}(): cost_fn={cost_fn.name!r} bills "
                f"{derived.kind_table} @ period={derived.period} but the "
                f"given policy bills {policy.kind_table} @ "
                f"period={policy.period}; drop cost_fn or build the "
                "policy with SchedulerPolicy.for_cost(cost_fn, ...)"
            )
    return policy
