"""Cluster state machine: the fleet of hosts plus instance lifecycle.

Applies ``ScheduleResult``s produced by a scheduler: evacuates the planned
preemptible instances (through the preemption protocol, which gives training
jobs a checkpoint window) and places the new instance.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Dict, Iterable, List, Optional, Sequence

from .scheduler import BaseScheduler
from .types import (
    Host,
    Instance,
    Request,
    Resources,
    ScheduleError,
    ScheduleResult,
)

PreemptHook = Callable[[Instance, float], None]


@dataclasses.dataclass
class ClusterStats:
    placed: int = 0
    failed: int = 0
    preemptions: int = 0
    #: provider-side cost paid to preemptions (per the active cost function).
    preemption_cost: float = 0.0


class Cluster:
    """Mutable fleet state + instance lifecycle."""

    def __init__(self, hosts: Iterable[Host]):
        self.hosts: Dict[str, Host] = {h.name: h for h in hosts}
        self.stats = ClusterStats()
        self._ids = itertools.count()
        #: hooks fired on preemption (checkpoint protocol, accounting, ...).
        self.preempt_hooks: List[PreemptHook] = []
        #: ids of preempted instances, for re-queueing (elasticity).
        self.preempted: List[Instance] = []

    # -- views ----------------------------------------------------------------
    def host_list(self) -> List[Host]:
        return list(self.hosts.values())

    def instances(self) -> List[Instance]:
        return [i for h in self.hosts.values() for i in h.instances.values()]

    def utilization(self) -> float:
        """Fraction of total capacity in use (first resource dim)."""
        cap = sum(h.capacity.vec[0] for h in self.hosts.values())
        used = sum(h.used().vec[0] for h in self.hosts.values())
        return used / cap if cap else 0.0

    def utilization_normal(self) -> float:
        cap = sum(h.capacity.vec[0] for h in self.hosts.values())
        used = sum(h.used(include_preemptible=False).vec[0] for h in self.hosts.values())
        return used / cap if cap else 0.0

    # -- lifecycle --------------------------------------------------------------
    def apply(
        self, result: ScheduleResult, now: float, price_rate: float = 1.0
    ) -> Optional[Instance]:
        """Apply a scheduling decision: evacuate the plan, place the instance."""
        if not result.ok:
            self.stats.failed += 1
            return None
        host = self.hosts[result.host]
        for victim in result.plan.instances:
            self.preempt(victim, now)
        inst = Instance(
            id=f"i{next(self._ids)}-{result.request.id}",
            resources=result.request.resources,
            preemptible=result.request.preemptible,
            host=host.name,
            start_time=now,
            user=result.request.user,
            price_rate=price_rate,
        )
        host.place(inst)
        self.stats.placed += 1
        self.stats.preemption_cost += result.plan.cost
        return inst

    def preempt(self, inst: Instance, now: float) -> None:
        """Terminate a preemptible instance (checkpoint hooks fire first)."""
        for hook in self.preempt_hooks:
            hook(inst, now)
        host = self.hosts[inst.host]
        host.remove(inst.id)
        self.stats.preemptions += 1
        self.preempted.append(inst)

    def terminate(self, inst: Instance) -> None:
        """Voluntary termination (end of lifetime) — no preemption hooks."""
        self.hosts[inst.host].remove(inst.id)

    def schedule_and_place(
        self,
        scheduler: BaseScheduler,
        req: Request,
        now: float,
    ) -> Optional[Instance]:
        result = scheduler.schedule(req, self.host_list(), now)
        return self.apply(result, now)

    @classmethod
    def from_fleet(cls, fleet) -> "Cluster":
        """Materialize a python ``Cluster`` from an incremental ``SoAFleet``
        (fast-path → python-tooling bridge; placement re-validates capacity)."""
        cluster = cls(fleet.sync_hosts())
        cluster.preempted = list(fleet.preempted)
        cluster.stats.preemptions = len(fleet.preempted)
        return cluster


def make_uniform_fleet(
    n_hosts: int,
    capacity: Resources,
    domain_size: int = 0,
    name_prefix: str = "host",
) -> List[Host]:
    """Build a uniform fleet; ``domain_size`` groups hosts into ICI domains."""
    hosts = []
    for i in range(n_hosts):
        dom = f"dom{i // domain_size}" if domain_size else "d0"
        hosts.append(Host(name=f"{name_prefix}-{i}", capacity=capacity, domain=dom))
    return hosts
