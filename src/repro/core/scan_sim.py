"""Fully on-device scanned simulator.

``SoASimulator`` already keeps the fleet state device-resident, but its event
loop is python: every event costs one dispatch (plus a host sync at sample
points), and at 10^5 hosts the host<->device ping-pong — not the decision
math — dominates end-to-end throughput.  This module folds the *entire*
event stream into one jitted ``lax.scan``:

* ``EventTrace`` — a struct-of-arrays trace encoding (the same trick as
  ``SoAFleetState.inst_cost_kind``): one i32 ``kind`` column plus payload
  columns (time / size / duration / priority / cost kind / period / zone /
  instance-id), so a whole simulation is a handful of device arrays.
* ``trace_from_workload`` — encoder replaying the exact rng draw order of
  ``SoASimulator`` (``_draw_request`` / ``_draw_lifetime``), so a trace is a
  faithful pre-materialization of the python simulator's event heap.
* ``simulate_scan(trace, policy, state)`` — the arrival / departure /
  failure / storm / checkpoint stream as ONE ``lax.scan`` over a
  ``_step_core``-compatible carry, ``lax.switch``-dispatching on the event
  kind, syncing to host ``SimMetrics`` only at configurable sample points.
* ``simulate_ensemble`` — ``vmap`` of the scan over a stacked-trace (seed)
  axis and optional stacked weigher-multiplier / admission-knob axes: one
  dispatch evaluates hundreds of fleet trajectories (the Monte-Carlo
  substrate for policy sweeps).

Streaming admission (``policy.queue_capacity > 0``) runs INSIDE the scan:
the ``AdmissionQueueState`` arrays ride the carry, arrivals ``queue_push``
instead of dispatching directly, and drains (``queue_select`` with aging →
batched ``_step_core`` → ``queue_pop``, storm degradation included) fire
behind predicate-gated ``lax.cond`` on the same triggers the python front
end uses — SLO deadline crossed (before the event), batch filled by an
arrival, capacity freed by a departure/failure/heal/storm (after it) —
with a ``drain_all``-mirroring ``fori_loop`` epilogue at the last
timestamp.  ``knobs`` traces ``(aging_rate, slo_target_s,
storm_threshold)`` so an admission-policy sweep shares one compiled
program (``storm_threshold=inf`` disables degradation numerically).

Parity contract (pinned by ``tests/test_scan_sim.py``): on integer-time /
integer-resource traces the scanned simulator is **bit-exact** against
``SoASimulator.run_trace`` — final fleet-state arrays, per-arrival
placement/rejection sequences, every ``SimMetrics`` counter, and (in
streaming mode) every admission counter, the final queue arrays, and the
per-placement sim-time wait distribution.  f32 sums
of integers below 2^24 are exact regardless of association, so the fused
device reductions here equal the python loop's sequential adds bitwise;
decisions run the same ``_step_core`` program on both sides, so even
non-integer billing costs (``revenue``) cannot diverge the placements.

Storm semantics are deterministic by construction (no rng inside the scan):
a ``zone_storm`` event kills the ``n`` lowest ``(host, slot)`` flat-indexed
live preemptible slots of the zone, ``n = min(max(1, round_f32(count *
frac)), count)`` — mirrored exactly by ``SoASimulator._trace_storm``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .admission import (
    PAD_RES,
    AdmissionQueueState,
    queue_init,
    queue_pop,
    queue_push,
    queue_select,
)
from .jax_scheduler import (
    SoAFleetState,
    _step_core,
    apply_departure,
    apply_host_failure,
    apply_termination,
    ensure_policy,
    set_schedulable,
)
from .policy import COST_KINDS, SchedulerPolicy
from .screen_math import churn_stats
from .simulator import SimMetrics, WorkloadSpec

# -- event kinds --------------------------------------------------------------
ARRIVAL = 0
DEPARTURE = 1
FAIL_HOST = 2
HEAL_HOST = 3
CHECKPOINT = 4
ZONE_STORM = 5
PAD = 6

KIND_NAMES: Tuple[str, ...] = (
    "arrival", "departure", "fail_host", "heal_host", "checkpoint",
    "zone_storm", "pad",
)
KIND_IDS: Dict[str, int] = {name: i for i, name in enumerate(KIND_NAMES)}

#: float payload columns checked for NaN at construction (column, per-row)
_FLOAT_COLS = ("time", "duration", "period", "price", "frac")


@dataclasses.dataclass
class TraceEvent:
    """One decoded trace row (``EventTrace.events`` / ``from_events``)."""

    kind: str
    time: float
    res: Optional[Tuple[float, ...]] = None   # arrival size vector
    preemptible: bool = False
    duration: float = -1.0                    # arrival lifetime (s)
    priority: int = -1
    cost_kind: int = -1                       # COST_KIND_IDS id, -1 = default
    period: float = -1.0
    price: float = 1.0
    domain: int = -1
    zone: int = -1                            # zone_storm target
    frac: float = 0.0                         # zone_storm kill fraction
    inst_id: int = -1                         # departure/checkpoint: arrival row
    host: int = -1                            # fail/heal target host index


@dataclasses.dataclass(frozen=True)
class EventTrace:
    """Struct-of-arrays event trace: ``kind`` i32 + payload columns.

    Rows are time-ordered (non-decreasing).  Non-applicable payloads hold
    sentinel defaults (-1 / 0 / 1.0) so every column is dense and the whole
    trace ships to the device as one pytree of arrays.
    """

    kind: np.ndarray          # (E,)   i32  event kind (KIND_NAMES index)
    time: np.ndarray          # (E,)   f32  event time (s)
    res: np.ndarray           # (E,D)  f32  arrival size vector
    preemptible: np.ndarray   # (E,)   bool arrival preemptible flag
    duration: np.ndarray      # (E,)   f32  arrival lifetime (-1 = n/a)
    priority: np.ndarray      # (E,)   i32  arrival priority (-1 = none)
    cost_kind: np.ndarray     # (E,)   i32  COST_KIND_IDS id (-1 = default)
    period: np.ndarray        # (E,)   f32  billing period (-1 = default)
    price: np.ndarray         # (E,)   f32  price rate
    domain: np.ndarray        # (E,)   i32  anti-affinity domain id (-1 = none)
    zone: np.ndarray          # (E,)   i32  storm target zone (-1 = n/a)
    frac: np.ndarray          # (E,)   f32  storm kill fraction
    inst_id: np.ndarray       # (E,)   i32  departure/checkpoint target =
                              #             ARRIVAL ROW INDEX (-1 = n/a)
    host: np.ndarray          # (E,)   i32  fail/heal target host (-1 = n/a)

    def __post_init__(self):
        coerce = {
            "kind": np.int32, "time": np.float32, "res": np.float32,
            "preemptible": np.bool_, "duration": np.float32,
            "priority": np.int32, "cost_kind": np.int32,
            "period": np.float32, "price": np.float32, "domain": np.int32,
            "zone": np.int32, "frac": np.float32, "inst_id": np.int32,
            "host": np.int32,
        }
        for name, dt in coerce.items():
            object.__setattr__(
                self, name, np.ascontiguousarray(getattr(self, name), dt)
            )
        e = self.kind.shape[0]
        for name in coerce:
            col = getattr(self, name)
            want = 2 if name == "res" else 1
            if col.ndim != want or col.shape[0] != e:
                raise ValueError(
                    f"trace column {name!r} has shape {col.shape}, expected "
                    f"{e} rows ({want}-d)"
                )
        bad = np.nonzero((self.kind < 0) | (self.kind > PAD))[0]
        if bad.size:
            i = int(bad[0])
            raise ValueError(
                f"unknown event kind {int(self.kind[i])} at row {i} "
                f"(valid: 0..{PAD} = {KIND_NAMES})"
            )
        if not np.all(np.isfinite(self.time)):
            i = int(np.nonzero(~np.isfinite(self.time))[0][0])
            raise ValueError(f"non-finite time at row {i}")
        if e and float(self.time[0]) < 0.0:
            raise ValueError("negative time at row 0")
        drop = np.nonzero(np.diff(self.time) < 0)[0]
        if drop.size:
            i = int(drop[0])
            raise ValueError(
                f"unsorted times: time[{i + 1}]={float(self.time[i + 1])!r} < "
                f"time[{i}]={float(self.time[i])!r}"
            )
        for name in _FLOAT_COLS[1:] + ("res",):
            col = getattr(self, name)
            nan = np.nonzero(np.isnan(col).reshape(e, -1).any(axis=1))[0]
            if nan.size:
                raise ValueError(
                    f"NaN payload in column {name!r} at row {int(nan[0])}"
                )
        bad = np.nonzero(
            (self.cost_kind < -1) | (self.cost_kind >= len(COST_KINDS))
        )[0]
        if bad.size:
            i = int(bad[0])
            raise ValueError(
                f"unknown cost kind id {int(self.cost_kind[i])} at row {i}"
            )
        arr = self.kind == ARRIVAL
        if np.any(arr & ~np.all(np.isfinite(self.res), axis=1)):
            i = int(np.nonzero(arr & ~np.all(np.isfinite(self.res), axis=1))[0][0])
            raise ValueError(f"non-finite arrival size at row {i}")
        if np.any(arr & (self.res < 0).any(axis=1)):
            i = int(np.nonzero(arr & (self.res < 0).any(axis=1))[0][0])
            raise ValueError(f"negative arrival size at row {i}")
        for k, what in ((DEPARTURE, "departure"), (CHECKPOINT, "checkpoint")):
            rows = np.nonzero(self.kind == k)[0]
            for i in rows:
                tgt = int(self.inst_id[i])
                if not 0 <= tgt < e or int(self.kind[tgt]) != ARRIVAL:
                    raise ValueError(
                        f"{what} at row {int(i)} targets inst_id={tgt}, "
                        f"which is not an arrival row"
                    )
                if float(self.time[tgt]) > float(self.time[i]):
                    raise ValueError(
                        f"{what} at row {int(i)} precedes its arrival "
                        f"(row {tgt})"
                    )
        for k, what in ((FAIL_HOST, "fail_host"), (HEAL_HOST, "heal_host")):
            rows = np.nonzero((self.kind == k) & (self.host < 0))[0]
            if rows.size:
                raise ValueError(
                    f"{what} at row {int(rows[0])} has no host index"
                )
        rows = np.nonzero(self.kind == ZONE_STORM)[0]
        for i in rows:
            if int(self.zone[i]) < 0:
                raise ValueError(f"zone_storm at row {int(i)} has no zone")
            f = float(self.frac[i])
            if not 0.0 < f <= 1.0:
                raise ValueError(
                    f"zone_storm at row {int(i)} has kill fraction {f!r} "
                    f"outside (0, 1]"
                )

    # -- views ----------------------------------------------------------------
    @property
    def n_events(self) -> int:
        return int(self.kind.shape[0])

    @property
    def n_dims(self) -> int:
        return int(self.res.shape[1])

    def events(self) -> List[TraceEvent]:
        """Decode to a python event list (inverse of ``from_events``)."""
        out = []
        for i in range(self.n_events):
            k = int(self.kind[i])
            out.append(TraceEvent(
                kind=KIND_NAMES[k],
                time=float(self.time[i]),
                res=tuple(float(v) for v in self.res[i]) if k == ARRIVAL else None,
                preemptible=bool(self.preemptible[i]),
                duration=float(self.duration[i]),
                priority=int(self.priority[i]),
                cost_kind=int(self.cost_kind[i]),
                period=float(self.period[i]),
                price=float(self.price[i]),
                domain=int(self.domain[i]),
                zone=int(self.zone[i]),
                frac=float(self.frac[i]),
                inst_id=int(self.inst_id[i]),
                host=int(self.host[i]),
            ))
        return out

    @classmethod
    def from_events(cls, events: Sequence[TraceEvent], n_dims: int) -> "EventTrace":
        """Encode a python event list (inverse of ``events``)."""
        e = len(events)
        cols = dict(
            kind=np.zeros(e, np.int32), time=np.zeros(e, np.float32),
            res=np.zeros((e, n_dims), np.float32),
            preemptible=np.zeros(e, bool),
            duration=np.full(e, -1.0, np.float32),
            priority=np.full(e, -1, np.int32),
            cost_kind=np.full(e, -1, np.int32),
            period=np.full(e, -1.0, np.float32),
            price=np.ones(e, np.float32),
            domain=np.full(e, -1, np.int32),
            zone=np.full(e, -1, np.int32),
            frac=np.zeros(e, np.float32),
            inst_id=np.full(e, -1, np.int32),
            host=np.full(e, -1, np.int32),
        )
        for i, ev in enumerate(events):
            if ev.kind not in KIND_IDS:
                raise ValueError(f"unknown event kind {ev.kind!r} at row {i}")
            cols["kind"][i] = KIND_IDS[ev.kind]
            cols["time"][i] = ev.time
            if ev.res is not None:
                cols["res"][i] = np.asarray(ev.res, np.float32)
            cols["preemptible"][i] = ev.preemptible
            cols["duration"][i] = ev.duration
            cols["priority"][i] = ev.priority
            cols["cost_kind"][i] = ev.cost_kind
            cols["period"][i] = ev.period
            cols["price"][i] = ev.price
            cols["domain"][i] = ev.domain
            cols["zone"][i] = ev.zone
            cols["frac"][i] = ev.frac
            cols["inst_id"][i] = ev.inst_id
            cols["host"][i] = ev.host
        return cls(**cols)

    def padded(self, to: int) -> "EventTrace":
        """Right-pad with PAD rows at the trace's final time (no-ops on both
        engines) so unequal-length traces can stack on an ensemble axis."""
        e = self.n_events
        if to < e:
            raise ValueError(f"cannot pad {e} events down to {to}")
        if to == e:
            return self
        tail = to - e
        t_last = float(self.time[-1]) if e else 0.0
        base = EventTrace.from_events(
            [TraceEvent(kind="pad", time=t_last)], self.n_dims
        )
        cols = {
            f.name: np.concatenate(
                [getattr(self, f.name),
                 np.repeat(getattr(base, f.name), tail, axis=0)]
            )
            for f in dataclasses.fields(self)
        }
        return EventTrace(**cols)


def stack_traces(traces: Sequence[EventTrace]) -> Dict[str, np.ndarray]:
    """Stack traces on a leading ensemble axis, right-padding with PAD rows."""
    if not traces:
        raise ValueError("stack_traces needs at least one trace")
    d = traces[0].n_dims
    if any(t.n_dims != d for t in traces):
        raise ValueError("traces disagree on resource dimensionality")
    emax = max(t.n_events for t in traces)
    padded = [t.padded(emax) for t in traces]
    return {
        f.name: np.stack([getattr(t, f.name) for t in padded])
        for f in dataclasses.fields(EventTrace)
    }


# -- workload encoder ---------------------------------------------------------
def trace_from_workload(
    workload: WorkloadSpec,
    duration_s: float,
    seed: int = 0,
    *,
    integer_times: bool = True,
    storms: Sequence[Tuple[float, int, float]] = (),
    failures: Sequence[Tuple[float, int, Optional[float]]] = (),
    checkpoint_every: int = 0,
    cost_kinds: Sequence[int] = (),
    priorities: Sequence[int] = (),
) -> EventTrace:
    """Pre-materialize a ``SoASimulator`` workload as an ``EventTrace``.

    Replays the simulator's exact rng draw order (initial inter-arrival
    exponential; per arrival: flavor choice, preemptible uniform, <=64
    truncated lifetime exponentials, next inter-arrival), then lowers the
    event heap into time-sorted rows:

    * arrivals carry size/preemptible/duration (+ optional round-robin
      ``cost_kinds`` / ``priorities`` assignment for mixed-billing traces);
    * each placed lifetime emits a ``departure`` row whose ``inst_id`` is
      the ARRIVAL ROW INDEX (resolved to a live instance at run time);
    * ``storms`` = (time, zone_id, kill_frac), ``failures`` = (time,
      host_idx, heal_after_s|None) inject fault rows;
    * ``checkpoint_every=k`` adds a mid-life checkpoint row for every k-th
      preemptible arrival.

    ``integer_times=True`` floors every event time and rounds lifetimes to
    whole seconds — the regime in which scanned-vs-python parity is bitwise
    (f32 integer sums are exact under any association).
    """
    if not workload.flavors:
        raise ValueError("trace_from_workload needs workload.flavors")
    rng = np.random.default_rng(seed)
    w = workload
    names = [f[0] for f in w.flavors]
    d = len(w.flavors[0][1].vec)

    def draw_lifetime() -> float:
        for _ in range(64):
            x = rng.exponential(w.lifetime_mean_s)
            if w.lifetime_min_s <= x <= w.lifetime_max_s:
                return x
        return float(np.clip(x, w.lifetime_min_s, w.lifetime_max_s))

    def q(t: float) -> float:
        return float(np.floor(t)) if integer_times else float(t)

    events: List[Tuple[float, int, TraceEvent]] = []
    seq = 0

    def emit(t: float, ev: TraceEvent) -> None:
        nonlocal seq
        ev.time = t
        events.append((t, seq, ev))
        seq += 1

    arrivals: List[TraceEvent] = []
    t = rng.exponential(1.0 / w.arrival_rate_per_s)
    n_arr = 0
    while t <= duration_s:
        now = q(t)
        idx = rng.choice(len(names), p=w.flavor_probs)
        _, res = w.flavors[idx]
        preempt = bool(rng.random() < w.preemptible_fraction)
        life = draw_lifetime()
        if integer_times:
            life = max(1.0, float(np.round(life)))
        ev = TraceEvent(
            kind="arrival", time=now,
            res=tuple(float(v) for v in res.vec32),
            preemptible=preempt, duration=life,
            cost_kind=(cost_kinds[n_arr % len(cost_kinds)] if cost_kinds else -1),
            priority=(priorities[n_arr % len(priorities)] if priorities else -1),
        )
        if ev.cost_kind == COST_KINDS.index("period"):
            ev.period = max(60.0, float(np.round(life / 4.0)))
        elif ev.cost_kind == COST_KINDS.index("revenue"):
            ev.period = 3600.0
        emit(now, ev)
        arrivals.append(ev)
        dep_t = now + life
        if dep_t <= duration_s:
            emit(dep_t, TraceEvent(kind="departure", time=dep_t))
            events[-1][2].inst_id = len(arrivals) - 1  # patched to row below
        if preempt and checkpoint_every and n_arr % checkpoint_every == 0:
            ck_t = q(now + life / 2.0)
            if ck_t <= min(dep_t, duration_s):
                emit(ck_t, TraceEvent(kind="checkpoint", time=ck_t))
                events[-1][2].inst_id = len(arrivals) - 1
        n_arr += 1
        t += rng.exponential(1.0 / w.arrival_rate_per_s)
    for at, zone, frac in storms:
        emit(q(at), TraceEvent(kind="zone_storm", time=q(at), zone=int(zone),
                               frac=float(frac)))
    for at, host, heal_after in failures:
        emit(q(at), TraceEvent(kind="fail_host", time=q(at), host=int(host)))
        if heal_after is not None:
            ht = q(at + heal_after)
            emit(ht, TraceEvent(kind="heal_host", time=ht, host=int(host)))
    events.sort(key=lambda x: (x[0], x[1]))
    # inst_id currently indexes `arrivals`; remap to sorted row indices
    row_of = {id(ev): i for i, (_, _, ev) in enumerate(events)}
    ordered = [ev for _, _, ev in events]
    for ev in ordered:
        if ev.kind in ("departure", "checkpoint") and ev.inst_id >= 0:
            ev.inst_id = row_of[id(arrivals[ev.inst_id])]
    return EventTrace.from_events(ordered, d)


# -- the scanned event loop ---------------------------------------------------
@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class _ScanCarry:
    state: SoAFleetState
    slot_owner: jax.Array    # (N, K) i32 arrival row occupying the slot (-1)
    ev_host: jax.Array       # (E+1,) i32 placement host per arrival row
    ev_slot: jax.Array       # (E+1,) i32 placement slot (-1 = normal)
    ev_live: jax.Array       # (E+1,) bool instance still running
    normal_res: jax.Array    # (N, D) f32 live NORMAL resources per host
    counters: jax.Array      # (7,) i32 [placed_n, placed_p, failed_n,
                             #           failed_p, preemptions, storms,
                             #           storm_kills]
    next_sample: jax.Array   # () f32
    n_samp: jax.Array        # () i32
    samp_t: jax.Array        # (E+1,) f32 sample times
    samp_f: jax.Array        # (E+1,) f32 free_f[:, 0] sums at samples
    samp_n: jax.Array        # (E+1,) f32 free_n[:, 0] sums at samples
    # -- streaming admission plane (policy.queue_capacity > 0; else None) ----
    qstate: Optional[AdmissionQueueState] = None  # the in-carry wait queue
    q_src: Optional[jax.Array] = None     # (Q,) i32 trace row per queue slot
    ev_ok: Optional[jax.Array] = None     # (E+1,) bool arrival row placed
    ev_kill: Optional[jax.Array] = None   # (E+1,) i32 victims of the placement
    ev_pre: Optional[jax.Array] = None    # (E+1,) bool EFFECTIVE (post-
                                          # degradation) preemptible flag
    ev_wait: Optional[jax.Array] = None   # (E+1,) f32 sim-time queue wait
                                          # at placement (-1 = never placed)
    adm: Optional[jax.Array] = None       # (7,) i32 admission counters
    next_deadline: Optional[jax.Array] = None  # () f32 earliest enq + SLO

    def tree_flatten(self):
        return tuple(getattr(self, f.name) for f in dataclasses.fields(self)), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


_C_PLACED_N, _C_PLACED_P, _C_FAILED_N, _C_FAILED_P = 0, 1, 2, 3
_C_PREEMPT, _C_STORMS, _C_STORM_KILLS = 4, 5, 6

_A_ARRIVALS, _A_ADMITTED, _A_REJ_OVER, _A_REJ_RETRY = 0, 1, 2, 3
_A_DRAINS, _A_RETRIES, _A_DEGRADED = 4, 5, 6
_ADM_NAMES = (
    "arrivals", "admitted", "rejected_overflow", "rejected_retry", "drains",
    "retries", "degraded",
)

_COL_ORDER = tuple(f.name for f in dataclasses.fields(EventTrace))


def _scan_impl(state, cols, normal_res0, sample_every, mult, knobs, policy,
               with_mult, with_knobs):
    (kind, time, res, pre, dur, prio, ck, per, price, dom, zone, frac,
     inst_id, host) = cols
    e_total = kind.shape[0]
    n, k = state.inst_valid.shape
    d = state.free_f.shape[1]
    slot_ids = jnp.arange(k)
    mult_val = tuple(mult[i] for i in range(len(policy.all_multipliers))) \
        if with_mult else None
    streaming = policy.queue_capacity > 0
    # Admission knobs: static policy floats by default; TRACED scalars on the
    # ensemble knob axis.  Traced neutral values (aging 0, storm inf) are
    # numerically inert, so the always-computed traced program is outcome-
    # bit-exact against the statically-gated one.
    if with_knobs:
        aging, slo, storm_thr = knobs[0], knobs[1], knobs[2]
    else:
        aging = policy.aging_rate
        slo = jnp.float32(policy.slo_target_s)
        storm_thr = (
            None if policy.storm_threshold is None
            else jnp.float32(policy.storm_threshold)
        )

    def record_sample(c, t):
        do = t >= c.next_sample
        si = c.n_samp
        f0 = jnp.sum(c.state.free_f[:, 0])
        n0 = jnp.sum(c.state.free_n[:, 0])
        return dataclasses.replace(
            c,
            samp_t=c.samp_t.at[si].set(jnp.where(do, t, c.samp_t[si])),
            samp_f=c.samp_f.at[si].set(jnp.where(do, f0, c.samp_f[si])),
            samp_n=c.samp_n.at[si].set(jnp.where(do, n0, c.samp_n[si])),
            n_samp=si + do.astype(jnp.int32),
            next_sample=jnp.where(do, t + sample_every, c.next_sample),
        )

    no_y = (jnp.int32(-1), jnp.int32(-1), jnp.asarray(False), jnp.int32(0))

    def ev_arrival(c, ev):
        e, t, r, p, pr, ckk, pd, pc, dm, zn, fr, tg, hs = ev
        if streaming:
            # Route through the in-carry wait queue instead of deciding
            # inline; the decision happens at the next drain boundary.
            klass = jnp.where(
                pr >= 0, pr,
                jnp.where(p, jnp.int32(policy.n_classes - 1), jnp.int32(0)),
            )
            q, slot, okp = queue_push(
                c.qstate, r, p, dm, ckk, pd, jnp.int32(-1), klass, t, pc,
            )
            adm = c.adm.at[_A_ARRIVALS].add(1)
            adm = adm.at[_A_REJ_OVER].add((~okp).astype(jnp.int32))
            counters = c.counters
            counters = counters.at[_C_FAILED_N].add(
                (~okp & ~p).astype(jnp.int32)
            )
            counters = counters.at[_C_FAILED_P].add(
                (~okp & p).astype(jnp.int32)
            )
            # queue_push's slot is garbage when the push was rejected — keep
            # the old source row in that case.
            q_src = c.q_src.at[slot].set(
                jnp.where(okp, e.astype(jnp.int32), c.q_src[slot])
            )
            nd = jnp.where(
                okp, jnp.minimum(c.next_deadline, t + slo), c.next_deadline
            )
            c = dataclasses.replace(
                c, qstate=q, q_src=q_src, adm=adm, counters=counters,
                next_deadline=nd,
            )
            return c, no_y
        st, (h, s, ok, kill, _fb, _mg) = _step_core(
            c.state, r, p, dm, t, pc, ckk, pd, policy,
            req_exclude=jnp.int32(-1), mult_val=mult_val,
        )
        n_kill = jnp.sum(kill.astype(jnp.int32))
        owner_row = c.slot_owner[h]
        dead = jnp.where(kill & (owner_row >= 0), owner_row, e_total)
        ev_live = c.ev_live.at[dead].set(False)
        placed_pre = ok & p
        owner_row = jnp.where(kill, -1, owner_row)
        owner_row = jnp.where(
            (slot_ids == s) & placed_pre, e.astype(jnp.int32), owner_row
        )
        r0 = jnp.where(ok & ~p, r, jnp.zeros_like(r))
        counters = c.counters
        counters = counters.at[_C_PLACED_N].add((ok & ~p).astype(jnp.int32))
        counters = counters.at[_C_PLACED_P].add(placed_pre.astype(jnp.int32))
        counters = counters.at[_C_FAILED_N].add((~ok & ~p).astype(jnp.int32))
        counters = counters.at[_C_FAILED_P].add((~ok & p).astype(jnp.int32))
        counters = counters.at[_C_PREEMPT].add(n_kill)
        c = dataclasses.replace(
            c, state=st,
            slot_owner=c.slot_owner.at[h].set(owner_row),
            ev_live=ev_live.at[e].set(ok),
            ev_host=c.ev_host.at[e].set(jnp.where(ok, h, -1)),
            ev_slot=c.ev_slot.at[e].set(jnp.where(placed_pre, s, -1)),
            normal_res=c.normal_res.at[h].add(r0),
            counters=counters,
        )
        y = (jnp.where(ok, h, -1).astype(jnp.int32),
             jnp.where(placed_pre, s, -1).astype(jnp.int32), ok, n_kill)
        return c, y

    def ev_departure(c, ev):
        e, t, r, p, pr, ckk, pd, pc, dm, zn, fr, tg, hs = ev
        tgc = jnp.clip(tg, 0, e_total)
        live = c.ev_live[tgc]
        h = jnp.maximum(c.ev_host[tgc], 0)
        s = jnp.clip(c.ev_slot[tgc], 0, k - 1)
        # Streaming: storm degradation may have demoted the placement to
        # NORMAL capacity — the trace's preemptible column lies; the carry's
        # EFFECTIVE flag is the truth.
        is_pre = c.ev_pre[tgc] if streaming else pre[tgc]
        mask = (slot_ids == s) & live & is_pre
        st = apply_termination(c.state, h, mask, now=t, involuntary=False)
        radd = res[tgc] * (live & ~is_pre).astype(jnp.float32)
        st = apply_departure(st, h, radd)
        owner_row = jnp.where(mask, -1, c.slot_owner[h])
        c = dataclasses.replace(
            c, state=st,
            slot_owner=c.slot_owner.at[h].set(owner_row),
            ev_live=c.ev_live.at[tgc].set(False),
            normal_res=c.normal_res.at[h].add(-radd),
        )
        return c, no_y

    def ev_fail(c, ev):
        e, t, r, p, pr, ckk, pd, pc, dm, zn, fr, tg, hs = ev
        h = jnp.clip(hs, 0, n - 1)
        st = apply_host_failure(c.state, h, c.normal_res[h], now=t)
        on_h = c.ev_live & (c.ev_host == h)
        c = dataclasses.replace(
            c, state=st,
            slot_owner=c.slot_owner.at[h].set(jnp.full((k,), -1, jnp.int32)),
            ev_live=c.ev_live & ~on_h,
            normal_res=c.normal_res.at[h].set(jnp.zeros((d,), jnp.float32)),
        )
        return c, no_y

    def ev_heal(c, ev):
        e, t, r, p, pr, ckk, pd, pc, dm, zn, fr, tg, hs = ev
        h = jnp.clip(hs, 0, n - 1)
        return dataclasses.replace(
            c, state=set_schedulable(c.state, h, jnp.asarray(True))
        ), no_y

    def ev_checkpoint(c, ev):
        e, t, r, p, pr, ckk, pd, pc, dm, zn, fr, tg, hs = ev
        tgc = jnp.clip(tg, 0, e_total)
        # fleet.checkpoint no-ops on normal instances, so a demoted
        # (effectively normal) streaming placement must not take one.
        live = c.ev_live[tgc] & (c.ev_pre[tgc] if streaming else pre[tgc])
        h = jnp.maximum(c.ev_host[tgc], 0)
        s = jnp.clip(c.ev_slot[tgc], 0, k - 1)
        row = jnp.where((slot_ids == s) & live, t, c.state.inst_ckpt[h])
        st = dataclasses.replace(
            c.state, inst_ckpt=c.state.inst_ckpt.at[h].set(row)
        )
        return dataclasses.replace(c, state=st), no_y

    def ev_storm(c, ev):
        e, t, r, p, pr, ckk, pd, pc, dm, zn, fr, tg, hs = ev
        st = c.state
        live = st.inst_valid & (st.host_zone[:, None] == zn)
        flat = live.reshape(-1)
        cnt = jnp.sum(flat.astype(jnp.int32))
        want = jnp.maximum(
            1, jnp.round(cnt.astype(jnp.float32) * fr).astype(jnp.int32)
        )
        n_kill = jnp.where(cnt > 0, jnp.minimum(want, cnt), 0)
        kill_flat = flat & (jnp.cumsum(flat.astype(jnp.int32)) <= n_kill)
        kill = kill_flat.reshape(n, k)
        freed = jnp.sum(jnp.where(kill[:, :, None], st.inst_res, 0.0), axis=1)
        up = jnp.sum(jnp.where(kill, t - st.inst_start, 0.0))
        zc = jnp.clip(zn, 0, st.zone_term.shape[0] - 1)
        st = dataclasses.replace(
            st,
            free_f=st.free_f + freed,
            inst_valid=st.inst_valid & ~kill,
            zone_term=st.zone_term.at[zc].add(n_kill.astype(jnp.float32)),
            zone_up=st.zone_up.at[zc].add(up),
        )
        owner_flat = c.slot_owner.reshape(-1)
        dead = jnp.where(kill_flat & (owner_flat >= 0), owner_flat, e_total)
        counters = c.counters.at[_C_STORMS].add(1)
        counters = counters.at[_C_STORM_KILLS].add(n_kill)
        c = dataclasses.replace(
            c, state=st,
            slot_owner=jnp.where(kill, -1, c.slot_owner),
            ev_live=c.ev_live.at[dead].set(False),
            counters=counters,
        )
        return c, no_y

    def ev_pad(c, ev):
        return c, no_y

    branches = (ev_arrival, ev_departure, ev_fail, ev_heal, ev_checkpoint,
                ev_storm, ev_pad)

    def drain(c, now):
        """One in-carry admission drain: select → ``_step_core`` scan → pop.

        The pure-transition mirror of ``admission._drain_entry`` (minus the
        push scan — arrivals were already pushed at their event rows), with
        the host mirror's bookkeeping (``AdmissionFrontEnd.flush``) folded
        into the carry arrays instead of python lists.
        """
        q = c.qstate
        idx, take = queue_select(
            q, policy.admit_batch, now=now, aging_rate=aging,
            n_classes=policy.n_classes,
        )
        b = idx.shape[0]
        b_res = jnp.where(take[:, None], q.res[idx], PAD_RES)
        b_pre = jnp.where(take, q.preemptible[idx], False)
        b_dom = jnp.where(take, q.domain[idx], -1)
        b_kind = jnp.where(take, q.cost_kind[idx], -1)
        b_period = jnp.where(take, q.period[idx], -1.0)
        b_price = jnp.where(take, q.price[idx], 1.0)
        b_now = jnp.full((b,), now, jnp.float32)
        src = jnp.where(take, c.q_src[idx], e_total).astype(jnp.int32)

        orig_pre = b_pre
        if storm_thr is None:
            degraded = jnp.zeros_like(b_pre)
        else:
            # storm_thr == +inf (the traced-knob "off" value) makes the
            # predicate constant-False: exactly the no-degradation program.
            churn = churn_stats(c.state.zone_term, c.state.zone_up)[-1]
            storm = churn > storm_thr
            degraded = b_pre & storm
            b_pre = b_pre & ~storm

        def attempt(cc, xs):
            src_e, r, p, dm, t_, pc_, kd_, pd_ = xs
            st, (h, s, ok, kill, _fb, _mg) = _step_core(
                cc.state, r, p, dm, t_, pc_, kd_, pd_, policy,
                req_exclude=None, mult_val=mult_val,
            )
            n_kill = jnp.sum(kill.astype(jnp.int32))
            owner_row = cc.slot_owner[h]
            dead = jnp.where(kill & (owner_row >= 0), owner_row, e_total)
            ev_live = cc.ev_live.at[dead].set(False)
            placed_pre = ok & p
            owner_row = jnp.where(kill, -1, owner_row)
            owner_row = jnp.where(
                (slot_ids == s) & placed_pre, src_e, owner_row
            )
            r0 = jnp.where(ok & ~p, r, jnp.zeros_like(r))
            counters = cc.counters
            counters = counters.at[_C_PLACED_N].add(
                (ok & ~p).astype(jnp.int32)
            )
            counters = counters.at[_C_PLACED_P].add(
                placed_pre.astype(jnp.int32)
            )
            counters = counters.at[_C_PREEMPT].add(n_kill)
            cc = dataclasses.replace(
                cc, state=st,
                slot_owner=cc.slot_owner.at[h].set(owner_row),
                ev_live=ev_live.at[src_e].set(ev_live[src_e] | ok),
                ev_host=cc.ev_host.at[src_e].set(
                    jnp.where(ok, h, cc.ev_host[src_e])
                ),
                ev_slot=cc.ev_slot.at[src_e].set(
                    jnp.where(placed_pre, s, cc.ev_slot[src_e])
                ),
                ev_ok=cc.ev_ok.at[src_e].set(cc.ev_ok[src_e] | ok),
                ev_kill=cc.ev_kill.at[src_e].add(n_kill),
                ev_pre=cc.ev_pre.at[src_e].set(
                    jnp.where(ok, p, cc.ev_pre[src_e])
                ),
                normal_res=cc.normal_res.at[h].add(r0),
                counters=counters,
            )
            return cc, ok

        c, ok_b = lax.scan(
            attempt, c,
            (src, b_res, b_pre, b_dom, b_now, b_price, b_kind, b_period),
        )
        placed = ok_b & take
        wait = jnp.where(placed, now - q.enq_t[idx], 0.0)
        ev_wait = c.ev_wait.at[src].set(
            jnp.where(placed, wait, c.ev_wait[src])
        )
        q2, dropped = queue_pop(q, idx, take, placed, policy.max_retries)
        # Rejections (retries exhausted) book as failures under the ORIGINAL
        # preemptible flag — the queue stores it; demotion is per-attempt.
        counters = c.counters
        counters = counters.at[_C_FAILED_N].add(
            jnp.sum((dropped & ~orig_pre).astype(jnp.int32))
        )
        counters = counters.at[_C_FAILED_P].add(
            jnp.sum((dropped & orig_pre).astype(jnp.int32))
        )
        adm = c.adm
        adm = adm.at[_A_ADMITTED].add(jnp.sum(placed.astype(jnp.int32)))
        adm = adm.at[_A_REJ_RETRY].add(jnp.sum(dropped.astype(jnp.int32)))
        adm = adm.at[_A_RETRIES].add(
            jnp.sum((take & ~placed & ~dropped).astype(jnp.int32))
        )
        adm = adm.at[_A_DEGRADED].add(jnp.sum(degraded.astype(jnp.int32)))
        adm = adm.at[_A_DRAINS].add(1)
        nd = jnp.min(
            jnp.where(q2.valid, q2.enq_t, jnp.float32(jnp.inf))
        ) + slo
        return dataclasses.replace(
            c, qstate=q2, ev_wait=ev_wait, adm=adm, counters=counters,
            next_deadline=nd,
        )

    def step(c, xs):
        kd = xs[0]
        ev = xs[1:]
        t = ev[1]
        c = record_sample(c, t)
        if not streaming:
            return lax.switch(jnp.clip(kd, 0, PAD), branches, c, ev)
        # SLO pre-drain: the incoming event's timestamp crossing the oldest
        # waiting entry's deadline forces (at most) one drain first.
        c = lax.cond(
            t >= c.next_deadline, lambda cc: drain(cc, t), lambda cc: cc, c
        )
        c, y = lax.switch(jnp.clip(kd, 0, PAD), branches, c, ev)
        # Post-event drain triggers: a full admit batch after an arrival, or
        # freed capacity (departure/failure/heal/storm) while entries wait.
        depth = c.qstate.depth
        freeing = (
            (kd == DEPARTURE) | (kd == FAIL_HOST) | (kd == HEAL_HOST)
            | (kd == ZONE_STORM)
        )
        fire = ((kd == ARRIVAL) & (depth >= policy.admit_batch)) \
            | (freeing & (depth > 0))
        c = lax.cond(fire, lambda cc: drain(cc, t), lambda cc: cc, c)
        return c, y

    s1 = e_total + 1
    carry0 = _ScanCarry(
        state=state,
        slot_owner=jnp.full((n, k), -1, jnp.int32),
        ev_host=jnp.full((s1,), -1, jnp.int32),
        ev_slot=jnp.full((s1,), -1, jnp.int32),
        ev_live=jnp.zeros((s1,), bool),
        normal_res=normal_res0,
        counters=jnp.zeros((7,), jnp.int32),
        next_sample=jnp.float32(0.0),
        n_samp=jnp.int32(0),
        samp_t=jnp.zeros((s1,), jnp.float32),
        samp_f=jnp.zeros((s1,), jnp.float32),
        samp_n=jnp.zeros((s1,), jnp.float32),
    )
    if streaming:
        carry0 = dataclasses.replace(
            carry0,
            qstate=queue_init(policy.queue_capacity, d),
            q_src=jnp.full((policy.queue_capacity,), e_total, jnp.int32),
            ev_ok=jnp.zeros((s1,), bool),
            ev_kill=jnp.zeros((s1,), jnp.int32),
            ev_pre=jnp.zeros((s1,), bool),
            ev_wait=jnp.full((s1,), -1.0, jnp.float32),
            adm=jnp.zeros((7,), jnp.int32),
            next_deadline=jnp.float32(jnp.inf),
        )
    xs = (kind, jnp.arange(e_total, dtype=jnp.int32), time, res, pre, prio,
          ck, per, price, dom, zone, frac, inst_id, host)
    carry, ys = lax.scan(step, carry0, xs)
    t_last = time[e_total - 1] if e_total else jnp.float32(0.0)
    stream = None
    if streaming:
        # End-of-run epilogue (``AdmissionFrontEnd.drain_all``): every
        # still-waiting entry gets its retries.  Each failing entry burns one
        # retry per drain, so ceil(Q/B) * max_retries + 2 rounds suffice.
        limit = (
            -(-policy.queue_capacity // policy.admit_batch)
            * policy.max_retries + 2
        )

        def _epilogue(_, cc):
            return lax.cond(
                cc.qstate.depth > 0, lambda c2: drain(c2, t_last),
                lambda c2: c2, cc,
            )

        carry = lax.fori_loop(0, limit, _epilogue, carry)
        # Per-arrival outcomes resolve at drain boundaries, not event rows —
        # read them off the final carry instead of the scan's ys.
        ys = (carry.ev_host[:e_total], carry.ev_slot[:e_total],
              carry.ev_ok[:e_total], carry.ev_kill[:e_total])
        stream = (carry.qstate, carry.adm, carry.ev_wait[:e_total],
                  carry.qstate.depth)
    # final host sample, mirroring the python loop's closing _sample()
    si = carry.n_samp
    return (
        carry.state,
        ys,
        carry.counters,
        (
            carry.samp_t.at[si].set(t_last),
            carry.samp_f.at[si].set(jnp.sum(carry.state.free_f[:, 0])),
            carry.samp_n.at[si].set(jnp.sum(carry.state.free_n[:, 0])),
            si + 1,
        ),
        stream,
    )


@functools.lru_cache(maxsize=64)
def _scan_fn(policy: SchedulerPolicy, with_mult: bool, with_knobs: bool):
    def run(state, cols, normal_res0, sample_every, mult, knobs):
        return _scan_impl(
            state, cols, normal_res0, sample_every, mult, knobs, policy,
            with_mult, with_knobs,
        )
    return jax.jit(run)


@functools.lru_cache(maxsize=64)
def _ensemble_fn(policy: SchedulerPolicy, with_mult: bool, with_knobs: bool):
    def run(state, cols, normal_res0, sample_every, mult, knobs):
        return _scan_impl(
            state, cols, normal_res0, sample_every, mult, knobs, policy,
            with_mult, with_knobs,
        )
    return jax.jit(
        jax.vmap(run, in_axes=(
            None, 0, None, None,
            0 if with_mult else None,
            0 if with_knobs else None,
        ))
    )


@dataclasses.dataclass
class ScanResult:
    """Host-side view of one scanned trajectory.

    Streaming-mode runs (``policy.queue_capacity > 0``) additionally carry
    the final queue arrays, the admission counter dict (the keys of
    ``AdmissionStats.summary()``'s integer counters), and the per-arrival
    sim-time queue wait (``-1`` = never placed); they are ``None`` on
    direct-mode runs.
    """

    state: SoAFleetState
    host: np.ndarray       # (E,) i32 winning host per arrival row (-1)
    slot: np.ndarray       # (E,) i32 winning slot (-1 = normal / rejected)
    ok: np.ndarray         # (E,) bool placement succeeded
    n_kill: np.ndarray     # (E,) i32 victims evacuated by the placement
    counters: Dict[str, int]
    sample_t: np.ndarray        # (S,) f32 sample times
    sample_free0: np.ndarray    # (S,) f32 sum(free_f[:, 0]) at each sample
    sample_free0_normal: np.ndarray  # (S,) f32 sum(free_n[:, 0])
    #: final wait-queue arrays (streaming mode only; numpy-materialized)
    queue: Optional[AdmissionQueueState] = None
    #: admission counters: arrivals / admitted / rejected_overflow /
    #: rejected_retry / drains / retries / degraded / queue_depth
    admission: Optional[Dict[str, int]] = None
    #: (E,) f32 sim-time enqueue→absorb wait per arrival row (-1 = never
    #: placed: rejected, or a non-arrival row)
    wait_s: Optional[np.ndarray] = None

    def wait_percentiles(self) -> Dict[str, float]:
        """Sim-time queue-wait p50/p99 over the placed arrivals — the same
        reader as ``AdmissionStats.wait_percentiles`` over the python front
        end, bit-identical on a shared trace (the waits are the same f32
        differences computed by the same drain program)."""
        if self.wait_s is None:
            return {"wait_p50_s": 0.0, "wait_p99_s": 0.0}
        w = np.asarray(self.wait_s)
        w = w[w >= 0.0]
        if not w.size:
            return {"wait_p50_s": 0.0, "wait_p99_s": 0.0}
        return {
            "wait_p50_s": float(np.percentile(w, 50)),
            "wait_p99_s": float(np.percentile(w, 99)),
        }

    def sim_metrics(self, cap0_total: float) -> SimMetrics:
        """Materialize ``SimMetrics`` exactly as the python loop would: the
        device ships raw f32 free-capacity sums; the utilization ratio is
        computed host-side in float64, bitwise-matching
        ``SoAFleet.utilization`` (which also sums on device and divides on
        host).  ``sched_latency_s`` is wall-clock-dependent and stays empty."""
        m = SimMetrics()
        for t, f, fn in zip(
            self.sample_t, self.sample_free0, self.sample_free0_normal
        ):
            m.t.append(float(t))
            if not cap0_total:
                m.utilization.append(0.0)
                m.utilization_normal.append(0.0)
            else:
                m.utilization.append((cap0_total - float(f)) / cap0_total)
                m.utilization_normal.append((cap0_total - float(fn)) / cap0_total)
        for name, val in self.counters.items():
            setattr(m, name, val)
        return m


_COUNTER_NAMES = (
    "placed_normal", "placed_preemptible", "failures_normal",
    "failures_preemptible", "preemptions", "storms", "storm_kills",
)


def _check_policy(policy: SchedulerPolicy, where: str) -> None:
    # Everything else — including the streaming admission plane
    # (queue_capacity > 0) — runs inside the scan; see
    # docs/scan_sim.md#which-planes-scan for the full support matrix.
    if policy.relocation_on:
        raise NotImplementedError(
            f"{where}: the relocation plane runs host-side passes between "
            f"events (victim identity bookkeeping) and is not folded into "
            f"the scanned loop; see docs/scan_sim.md#which-planes-scan"
        )
    if policy.mesh is not None:
        raise NotImplementedError(
            f"{where}: sharded fleet state is not supported under the scan; "
            f"see docs/scan_sim.md#which-planes-scan"
        )
    if policy.adaptive_shortlist:
        raise NotImplementedError(
            f"{where}: adaptive_shortlist mutates the policy between batches "
            f"(host-side controller) and cannot run inside one scan; see "
            f"docs/scan_sim.md#which-planes-scan"
        )


def _check_trace(trace: EventTrace, state: SoAFleetState,
                 policy: SchedulerPolicy) -> None:
    n = state.inst_valid.shape[0]
    n_zones = state.zone_term.shape[0]
    if trace.n_dims != state.free_f.shape[1]:
        raise ValueError(
            f"trace has {trace.n_dims} resource dims, fleet has "
            f"{state.free_f.shape[1]}"
        )
    fail = np.isin(trace.kind, (FAIL_HOST, HEAL_HOST))
    if np.any(fail & (trace.host >= n)):
        raise ValueError(f"fail/heal host index out of range (fleet has {n})")
    if np.any((trace.kind == ZONE_STORM) & (trace.zone >= n_zones)):
        raise ValueError(
            f"zone_storm zone index out of range (fleet has {n_zones} zones)"
        )
    table_ids = {-1} | {COST_KINDS.index(kname) for kname in policy.kind_table}
    arr = trace.kind == ARRIVAL
    bad = np.unique(trace.cost_kind[arr & ~np.isin(trace.cost_kind,
                                                   sorted(table_ids))])
    if bad.size:
        raise ValueError(
            f"trace bills by cost kind ids {bad.tolist()}, not in the "
            f"policy's kind table {policy.kind_table}"
        )
    if policy.queue_capacity:
        if np.any(arr & (trace.priority >= policy.n_classes)):
            i = int(np.nonzero(arr & (trace.priority >= policy.n_classes))[0][0])
            raise ValueError(
                f"arrival at row {i} has priority {int(trace.priority[i])} "
                f"outside the policy's {policy.n_classes} classes"
            )
        headroom = 1 << (32 - int(policy.n_classes).bit_length())
        if trace.n_events >= headroom:
            raise ValueError(
                f"trace has {trace.n_events} rows but the packed "
                f"queue_select key holds only {headroom} seq tickets at "
                f"n_classes={policy.n_classes}"
            )


def _check_mult(mult: np.ndarray, policy: SchedulerPolicy) -> np.ndarray:
    gates = policy.all_multipliers
    mult = np.asarray(mult, np.float32)
    if mult.shape[-1] != len(gates):
        raise ValueError(
            f"multiplier rows must have {len(gates)} entries "
            f"(weigher + churn), got shape {mult.shape}"
        )
    flat = mult.reshape(-1, len(gates))
    for i, g in enumerate(gates):
        if g == 0.0 and np.any(flat[:, i] != 0.0):
            raise ValueError(
                f"multiplier column {i} must be 0 everywhere: the policy's "
                f"static multiplier gates that term off at compile time"
            )
        if i == 1 and g != 0.0 and np.any(np.sign(flat[:, i]) != np.sign(g)):
            raise ValueError(
                "termination multipliers on the ensemble axis must keep the "
                "static multiplier's sign (the screening bound side is "
                "compiled from it)"
            )
    if np.any(~np.isfinite(mult)):
        raise ValueError("non-finite multiplier on the ensemble axis")
    return mult


def _check_knobs(knobs, policy: SchedulerPolicy) -> np.ndarray:
    """Validate a ``(..., 3)`` array of traced admission-knob rows:
    ``(aging_rate, slo_target_s, storm_threshold)``.  ``storm_threshold =
    np.inf`` disables degradation for that lane (the predicate ``churn >
    inf`` is constant-False)."""
    if not policy.queue_capacity:
        raise ValueError(
            "admission knobs need a streaming policy (queue_capacity > 0)"
        )
    knobs = np.asarray(knobs, np.float32)
    if knobs.shape[-1] != 3:
        raise ValueError(
            f"knob rows must be (aging_rate, slo_target_s, storm_threshold), "
            f"got shape {knobs.shape}"
        )
    flat = knobs.reshape(-1, 3)
    if np.any(~np.isfinite(flat[:, 0])) or np.any(flat[:, 0] < 0):
        raise ValueError("aging_rate knob must be finite and >= 0")
    if np.any(~np.isfinite(flat[:, 1])) or np.any(flat[:, 1] <= 0):
        raise ValueError("slo_target_s knob must be finite and > 0")
    if np.any(np.isnan(flat[:, 2])) or np.any(flat[:, 2] <= 0):
        raise ValueError(
            "storm_threshold knob must be > 0 (np.inf = degradation off)"
        )
    return knobs


def _device_cols(cols: Dict[str, np.ndarray]):
    return tuple(jnp.asarray(cols[name]) for name in _COL_ORDER)


def _lane_result(state, ys, counters, samples, stream=None) -> ScanResult:
    h, s, ok, n_kill = (np.asarray(y) for y in ys)
    samp_t, samp_f, samp_n, n_samp = samples
    n_samp = int(n_samp)
    queue = admission = wait_s = None
    if stream is not None:
        qstate, adm, ev_wait, depth = stream
        queue = jax.tree_util.tree_map(np.asarray, qstate)
        adm = np.asarray(adm)
        admission = {name: int(adm[i]) for i, name in enumerate(_ADM_NAMES)}
        admission["queue_depth"] = int(depth)
        wait_s = np.asarray(ev_wait)
    return ScanResult(
        state=state,
        host=h, slot=s, ok=ok, n_kill=n_kill,
        counters={
            name: int(np.asarray(counters)[i])
            for i, name in enumerate(_COUNTER_NAMES)
        },
        sample_t=np.asarray(samp_t)[:n_samp],
        sample_free0=np.asarray(samp_f)[:n_samp],
        sample_free0_normal=np.asarray(samp_n)[:n_samp],
        queue=queue, admission=admission, wait_s=wait_s,
    )


def simulate_scan(
    trace: EventTrace,
    policy: Optional[SchedulerPolicy],
    state: SoAFleetState,
    *,
    normal_res: Optional[np.ndarray] = None,
    sample_every_s: float = 300.0,
    mult: Optional[np.ndarray] = None,
    knobs: Optional[np.ndarray] = None,
) -> ScanResult:
    """Run ``trace`` against ``state`` as ONE jitted ``lax.scan`` dispatch.

    ``normal_res`` seeds the per-host live-normal-resource tracker (needed
    only when the starting state already hosts normal instances that a
    ``fail_host`` row may evacuate); defaults to zeros.  ``mult`` optionally
    substitutes TRACED weigher/churn multiplier values (same zero pattern
    and m_term sign as the policy's static ones — see ``simulate_ensemble``).

    With ``policy.queue_capacity > 0`` the run is in **streaming admission
    mode**: arrivals queue through the in-carry ``AdmissionQueueState`` and
    drains fire inside the scan (see docs/scan_sim.md), bit-exact against
    the python front end (``SoASimulator.run_trace`` streaming replay).
    ``knobs`` then optionally substitutes one TRACED ``(aging_rate,
    slo_target_s, storm_threshold)`` row for the policy's static values
    (``np.inf`` threshold = degradation off).

    Returns a ``ScanResult``: the final fleet state, the per-arrival
    placement/rejection sequence, metric counters, and the sample-point
    series (``.sim_metrics(cap0_total)`` materializes ``SimMetrics``).
    """
    policy = ensure_policy(policy, "simulate_scan")
    _check_policy(policy, "simulate_scan")
    _check_trace(trace, state, policy)
    n, d = state.free_f.shape
    if normal_res is None:
        normal_res = np.zeros((n, d), np.float32)
    with_mult = mult is not None
    if with_mult:
        mult = _check_mult(mult, policy)
        if mult.ndim != 1:
            raise ValueError("simulate_scan takes one multiplier row; use "
                             "simulate_ensemble for a stacked axis")
    else:
        mult = np.zeros((len(policy.all_multipliers),), np.float32)
    with_knobs = knobs is not None
    if with_knobs:
        knobs = _check_knobs(knobs, policy)
        if knobs.ndim != 1:
            raise ValueError("simulate_scan takes one knob row; use "
                             "simulate_ensemble for a stacked axis")
    else:
        knobs = np.zeros((3,), np.float32)
    cols = {name: getattr(trace, name) for name in _COL_ORDER}
    out_state, ys, counters, samples, stream = _scan_fn(
        policy, with_mult, with_knobs
    )(
        state, _device_cols(cols), jnp.asarray(normal_res, jnp.float32),
        jnp.float32(sample_every_s), jnp.asarray(mult), jnp.asarray(knobs),
    )
    return _lane_result(out_state, ys, counters, samples, stream)


def simulate_ensemble(
    traces: Sequence[EventTrace],
    policy: Optional[SchedulerPolicy],
    state: SoAFleetState,
    *,
    mults: Optional[np.ndarray] = None,
    knobs: Optional[np.ndarray] = None,
    normal_res: Optional[np.ndarray] = None,
    sample_every_s: float = 300.0,
) -> List[ScanResult]:
    """Monte-Carlo harness: ``vmap`` the scanned loop over a stacked-trace
    (seed) axis and, optionally, stacked weigher-multiplier and
    admission-knob axes.

    ``traces`` are right-padded with no-op PAD rows and stacked; ``mults``
    is a ``(P, len(policy.all_multipliers))`` array of TRACED multiplier
    values zipped lane-for-lane with the traces; ``knobs`` (streaming
    policies only) is a ``(P, 3)`` array of TRACED ``(aging_rate,
    slo_target_s, storm_threshold)`` rows — a whole admission-policy sweep
    in one dispatch.  Any axis of length 1 broadcasts against the others.
    Each lane is bitwise identical to the corresponding single
    ``simulate_scan`` dispatch on integer-cost traces (pinned by
    tests/test_scan_sim.py).

    Multiplier rows must preserve the static policy's zero pattern and
    m_term sign: zeros gate terms out at COMPILE time (``consts_of`` folds),
    and the screening bound side is compiled from ``sign(m_term)`` — traced
    values may change magnitudes, never structure.  Knob rows have no such
    structural constraint (``storm_threshold=np.inf`` turns degradation off
    numerically, not structurally).
    """
    policy = ensure_policy(policy, "simulate_ensemble")
    _check_policy(policy, "simulate_ensemble")
    if policy.use_pallas or policy.fused_screen:
        raise NotImplementedError(
            "simulate_ensemble: the pallas/fused stage-1 kernels do not "
            "support the ensemble batch axis; use the jnp path"
        )
    if policy.fused_screen is None:
        policy = dataclasses.replace(policy, fused_screen=False)
    traces = list(traces)
    if not traces:
        raise ValueError("simulate_ensemble needs at least one trace")
    with_mult = mults is not None
    if with_mult:
        mults = _check_mult(mults, policy)
        if mults.ndim != 2:
            raise ValueError("mults must be (P, n_multipliers)")
    with_knobs = knobs is not None
    if with_knobs:
        knobs = _check_knobs(knobs, policy)
        if knobs.ndim != 2:
            raise ValueError(
                "knobs must be (P, 3) rows of (aging_rate, slo_target_s, "
                "storm_threshold)"
            )
    n_lanes = max(
        len(traces),
        mults.shape[0] if with_mult else 1,
        knobs.shape[0] if with_knobs else 1,
    )
    if len(traces) == 1 and n_lanes > 1:
        traces = traces * n_lanes
    if with_mult and mults.shape[0] == 1 and n_lanes > 1:
        mults = np.repeat(mults, n_lanes, axis=0)
    if with_knobs and knobs.shape[0] == 1 and n_lanes > 1:
        knobs = np.repeat(knobs, n_lanes, axis=0)
    if with_mult and mults.shape[0] != len(traces):
        raise ValueError(
            f"{len(traces)} traces vs {mults.shape[0]} multiplier rows"
        )
    if with_knobs and knobs.shape[0] != len(traces):
        raise ValueError(
            f"{len(traces)} traces vs {knobs.shape[0]} knob rows"
        )
    if not with_mult:
        mults = np.zeros(
            (len(traces), len(policy.all_multipliers)), np.float32
        )
    if not with_knobs:
        knobs = np.zeros((len(traces), 3), np.float32)
    for t in traces:
        _check_trace(t, state, policy)
    n, d = state.free_f.shape
    if normal_res is None:
        normal_res = np.zeros((n, d), np.float32)
    stacked = stack_traces(traces)
    out_state, ys, counters, samples, stream = _ensemble_fn(
        policy, with_mult, with_knobs
    )(
        state, _device_cols(stacked), jnp.asarray(normal_res, jnp.float32),
        jnp.float32(sample_every_s), jnp.asarray(mults), jnp.asarray(knobs),
    )
    lanes = []
    n_lanes = len(traces)
    state_np = jax.tree_util.tree_map(np.asarray, out_state)
    stream_np = (
        None if stream is None
        else jax.tree_util.tree_map(np.asarray, stream)
    )
    for i in range(n_lanes):
        e = traces[i].n_events
        lane_state = jax.tree_util.tree_map(lambda a: a[i], state_np)
        lane_stream = None
        if stream_np is not None:
            qst, adm, ev_wait, depth = stream_np
            lane_stream = (
                jax.tree_util.tree_map(lambda a: a[i], qst),
                adm[i], ev_wait[i, :e], depth[i],
            )
        lanes.append(_lane_result(
            lane_state,
            tuple(np.asarray(y)[i, :e] for y in ys),
            np.asarray(counters)[i],
            tuple(np.asarray(s)[i] for s in samples),
            lane_stream,
        ))
    return lanes
