"""Modular host filters (phase 1 of the paper's Alg. 2).

A filter sees the *view-appropriate* free resources: for a normal request the
scheduler passes ``h_n`` (free_normal), for a preemptible request ``h_f``
(free_full) — that single switch is the paper's core trick, removing the
retry cycle.
"""
from __future__ import annotations

import abc
from typing import List, Sequence

from .types import Host, Request, Resources


class Filter(abc.ABC):
    """Boolean predicate over (host, request, view-free-resources)."""

    name: str = "filter"

    @abc.abstractmethod
    def host_passes(self, host: Host, req: Request, free: Resources) -> bool:
        ...


class SchedulableFilter(Filter):
    """Drops hosts that are draining / failed (fault-tolerance hook)."""

    name = "schedulable"

    def host_passes(self, host: Host, req: Request, free: Resources) -> bool:
        return host.schedulable


class ResourceFilter(Filter):
    """The paper's RAM/CPU fit filter, generalized to the resource vector."""

    name = "resource_fit"

    def host_passes(self, host: Host, req: Request, free: Resources) -> bool:
        return req.resources.fits_in(free)


class DomainFilter(Filter):
    """TPU adaptation: jobs pinned to an ICI domain only match hosts in it."""

    name = "domain"

    def host_passes(self, host: Host, req: Request, free: Resources) -> bool:
        return req.domain is None or host.domain == req.domain


class AntiAffinityFilter(Filter):
    """Rejects hosts already running an instance of the same user when the
    request carries ``anti_affinity=True`` (paper §2.1 'direct user input')."""

    name = "anti_affinity"

    def host_passes(self, host: Host, req: Request, free: Resources) -> bool:
        if not req.metadata.get("anti_affinity"):
            return True
        return all(i.user != req.user for i in host.instances.values())


DEFAULT_FILTERS: Sequence[Filter] = (
    SchedulableFilter(),
    DomainFilter(),
    AntiAffinityFilter(),
    ResourceFilter(),
)


def run_filters(
    filters: Sequence[Filter], host: Host, req: Request, free: Resources
) -> bool:
    return all(f.host_passes(host, req, free) for f in filters)
