"""Optimizers in pure JAX pytrees: AdamW (default) and Adafactor (factored
second moment — the memory-frugal choice for the 480B MoE).

State layout mirrors the param tree so parameter PartitionSpecs apply to the
optimizer state unchanged (ZeRO-1 falls out of FSDP param sharding for free).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jax.Array
    mu: Any            # AdamW: first moment | Adafactor: None
    nu: Any            # AdamW: second moment | Adafactor: (row, col) factors


@dataclasses.dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable[[Any], OptState]
    update: Callable[[Any, OptState, Any, jax.Array], Tuple[Any, OptState]]
    #: PartitionSpec tree factory: given param specs, produce state specs.
    state_specs: Callable[[Any], Any]


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def clip_by_global_norm(tree: Any, max_norm: float) -> Tuple[Any, jax.Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale, tree), norm


def cosine_schedule(
    base_lr: float, warmup: int, total: int, min_frac: float = 0.1
) -> Callable[[jax.Array], jax.Array]:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * jnp.minimum(1.0, step / max(1, warmup))
        t = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
        cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, base_lr * cos)

    return lr


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def adamw_init(params: Any) -> OptState:
    zeros = lambda t: jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), t)
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros(params), nu=zeros(params))


def adamw_update(
    grads: Any,
    state: OptState,
    params: Any,
    lr: jax.Array,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
) -> Tuple[Any, OptState]:
    step = state.step + 1
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        update = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        update = update + weight_decay * p.astype(jnp.float32)
        return m, v, (-lr * update).astype(p.dtype)

    flat = jax.tree.map(upd, grads, state.mu, state.nu, params)
    mu = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
    nu = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
    delta = jax.tree.map(lambda t: t[2], flat, is_leaf=lambda x: isinstance(x, tuple))
    return delta, OptState(step=step, mu=mu, nu=nu)


# ---------------------------------------------------------------------------
# Adafactor (factored second moment; no first moment)
# ---------------------------------------------------------------------------


def _factored(shape) -> bool:
    return len(shape) >= 2


def adafactor_init(params: Any) -> OptState:
    def nu_init(p):
        if _factored(p.shape):
            row = jnp.zeros(p.shape[:-1], jnp.float32)
            col = jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            return (row, col)
        return jnp.zeros(p.shape, jnp.float32)

    return OptState(
        step=jnp.zeros((), jnp.int32),
        mu=None,
        nu=jax.tree.map(nu_init, params),
    )


def adafactor_update(
    grads: Any,
    state: OptState,
    params: Any,
    lr: jax.Array,
    decay: float = 0.8,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
    weight_decay: float = 0.0,
) -> Tuple[Any, OptState]:
    step = state.step + 1
    beta = 1.0 - (step.astype(jnp.float32) + 1.0) ** (-decay)

    def upd(g, nu, p):
        g = g.astype(jnp.float32)
        g2 = jnp.square(g) + eps
        if _factored(g.shape):
            row, col = nu
            row = beta * row + (1 - beta) * jnp.mean(g2, axis=-1)
            col = beta * col + (1 - beta) * jnp.mean(g2, axis=-2)
            row_mean = jnp.mean(row, axis=-1, keepdims=True)
            vhat = (row / row_mean)[..., None] * col[..., None, :]
            new_nu = (row, col)
        else:
            vhat = beta * nu + (1 - beta) * g2
            new_nu = vhat
        update = g * jax.lax.rsqrt(vhat + eps)
        rms = jnp.sqrt(jnp.mean(jnp.square(update)) + 1e-12)
        update = update / jnp.maximum(1.0, rms / clip_threshold)
        if weight_decay:
            update = update + weight_decay * p.astype(jnp.float32)
        return new_nu, (-lr * update).astype(p.dtype)

    is_nu_leaf = lambda x: isinstance(x, tuple) and len(x) == 2 and not isinstance(x[0], tuple)
    flat = jax.tree.map(upd, grads, state.nu, params, is_leaf=None)
    # flat leaves are (nu, delta) tuples; nu may itself be a (row,col) tuple.
    two = lambda x: isinstance(x, tuple) and len(x) == 2
    nu = jax.tree.map(lambda t: t[0], flat, is_leaf=two)
    delta = jax.tree.map(lambda t: t[1], flat, is_leaf=two)
    return delta, OptState(step=step, mu=None, nu=nu)


def apply_updates(params: Any, delta: Any) -> Any:
    return jax.tree.map(lambda p, d: p + d.astype(p.dtype), params, delta)


# ---------------------------------------------------------------------------
# factory
# ---------------------------------------------------------------------------


def make_optimizer(name: str, **kw) -> Optimizer:
    if name == "adamw":
        def state_specs(pspecs):
            from jax.sharding import PartitionSpec

            return OptState(step=PartitionSpec(), mu=pspecs, nu=pspecs)

        return Optimizer(
            name="adamw",
            init=adamw_init,
            update=functools.partial(adamw_update, **kw),
            state_specs=state_specs,
        )
    if name == "adafactor":
        def state_specs(pspecs):
            from jax.sharding import PartitionSpec as P

            def nu_spec(spec):
                # row factor drops the last axis, col factor the second-last.
                parts = list(spec) if spec else []
                if len(parts) >= 2:
                    return (P(*parts[:-1]), P(*(parts[:-2] + parts[-1:])))
                return spec

            return OptState(
                step=P(),
                mu=None,
                nu=jax.tree.map(nu_spec, pspecs,
                                is_leaf=lambda s: isinstance(s, P)),
            )

        return Optimizer(
            name="adafactor",
            init=adafactor_init,
            update=functools.partial(adafactor_update, **kw),
            state_specs=state_specs,
        )
    raise ValueError(name)
