from .optimizers import (
    OptState,
    adafactor_init,
    adamw_init,
    apply_updates,
    cosine_schedule,
    global_norm,
    make_optimizer,
)

__all__ = [
    "OptState",
    "adafactor_init",
    "adamw_init",
    "apply_updates",
    "cosine_schedule",
    "global_norm",
    "make_optimizer",
]
