"""zamba2-7b [arXiv:2411.15242] — 81 Mamba2 layers + ONE shared attention
block applied every 6 layers (weights shared across its 13 applications)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
    d_ff=14336, vocab_size=32000,
    block_pattern="zamba_hybrid", ssm_state=64, shared_attn_every=6,
)
