"""qwen2-1.5b [arXiv:2407.10671] — dense GQA with QKV bias."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-1.5b", family="dense",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
    d_ff=8960, vocab_size=151936,
    mlp_type="swiglu", qkv_bias=True, tie_embeddings=True,
)
