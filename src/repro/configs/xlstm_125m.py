"""xlstm-125m [arXiv:2405.04517] — alternating sLSTM / mLSTM blocks, no FFN.

Assumption (config tier: unverified): sLSTM every 4th block (xLSTM-paper
ratios are 7:1 / 1:0 depending on variant; the 125M table is mLSTM-heavy).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304,
    mlp_type="none", block_pattern="xlstm", slstm_every=4,
    scan_layers=False,  # heterogeneous blocks; 12 layers — unrolled is fine
)
