"""seamless-m4t-medium [arXiv:2308.11596] — enc-dec; audio frontend STUB:
input_specs() provides precomputed frame embeddings (B, S, d_model)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="audio",
    n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab_size=256206,
    encoder_decoder=True, n_encoder_layers=12,
    modality="audio_stub",
)
