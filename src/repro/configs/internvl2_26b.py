"""internvl2-26b [arXiv:2404.16821] — InternViT (STUB) + InternLM2 backbone.

The vision frontend is a stub per the assignment: input_specs() provides
precomputed patch embeddings (B, 1024, d_model) prepended to the text tokens.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b", family="vlm",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab_size=92553,
    modality="vision_stub", n_prefix_tokens=1024,
)
