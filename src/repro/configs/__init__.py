"""Architecture registry: ``get_config("<arch-id>")`` / ``--arch <id>``."""
from typing import Dict

from .base import ModelConfig, ShapeConfig, SHAPES, applicable_shapes

_MODULES = {
    "phi3-medium-14b": "phi3_medium_14b",
    "qwen2-1.5b": "qwen2_1_5b",
    "yi-9b": "yi_9b",
    "gemma-2b": "gemma_2b",
    "arctic-480b": "arctic_480b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "xlstm-125m": "xlstm_125m",
    "internvl2-26b": "internvl2_26b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "zamba2-7b": "zamba2_7b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    import importlib

    mod = importlib.import_module(f".{_MODULES[arch]}", __package__)
    return mod.CONFIG


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests: same block structure,
    shrunken dimensions.  Full configs are exercised only via the dry run."""
    import dataclasses

    small = dict(
        n_layers=min(cfg.n_layers, 4 if cfg.block_pattern != "zamba_hybrid" else 8),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=32,
        d_ff=0 if cfg.d_ff == 0 else 256,
        vocab_size=512,
        n_experts=min(cfg.n_experts, 8) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        n_encoder_layers=min(cfg.n_encoder_layers, 2),
        n_prefix_tokens=16 if cfg.n_prefix_tokens else 0,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_head_dim=16,
        ssm_chunk=16,
        shared_attn_every=3,
        slstm_every=cfg.slstm_every,
        remat="none",
        params_dtype="float32",
        dtype="float32",
    )
    small.update(overrides)
    return dataclasses.replace(cfg, **small)


__all__ = [
    "ARCH_IDS",
    "ModelConfig",
    "SHAPES",
    "ShapeConfig",
    "applicable_shapes",
    "get_config",
    "reduced",
]
