"""Model / shape configuration system.

One ``ModelConfig`` dataclass covers all six assigned families
(dense / moe / ssm / vlm / audio / hybrid); per-arch modules under
``repro.configs`` instantiate the exact published hyperparameters and a
``reduced()`` variant for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | vlm | audio | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None   # default d_model // n_heads

    # --- block flavour -------------------------------------------------------
    mlp_type: str = "swiglu"         # swiglu | geglu | none
    qkv_bias: bool = False
    block_pattern: str = "attention" # attention | xlstm | zamba_hybrid
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # --- MoE -----------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    moe_dense_residual: bool = False # arctic: dense FFN ∥ MoE branch
    capacity_factor: float = 1.25

    # --- SSM / hybrid ---------------------------------------------------------
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 128
    shared_attn_every: int = 6       # zamba: shared attn block cadence
    slstm_every: int = 4             # xlstm: sLSTM block cadence (rest mLSTM)

    # --- encoder-decoder -------------------------------------------------------
    encoder_decoder: bool = False
    n_encoder_layers: int = 0

    # --- modality stubs ---------------------------------------------------------
    modality: str = "text"           # text | vision_stub | audio_stub
    n_prefix_tokens: int = 0         # precomputed patch/frame embeddings length

    # --- numerics / training -----------------------------------------------------
    dtype: str = "bfloat16"
    params_dtype: str = "float32"    # master copy; "bfloat16" for huge MoE
    remat: str = "full"              # none | full | dots
    attention_impl: str = "reference"  # reference | blocked | flash
    optimizer: str = "adamw"         # adamw | adafactor
    scan_layers: bool = True
    #: Megatron-style sequence parallelism: the residual stream between
    #: blocks is sharded over the model axis on the sequence dim, turning
    #: per-block activation all-reduces into reduce-scatter/all-gather pairs
    #: (half the link bytes) and shrinking resident activations TP-fold.
    sequence_parallel: bool = False
    #: Tensor-parallel attention.  False replicates the (small) attention
    #: weights and computes attention purely data-parallel — the right call
    #: when n_heads doesn't divide the TP degree (GSPMD pads 8→16 heads on
    #: gemma: 2x attention waste + per-layer gathers) and attn params are
    #: a small fraction of the model.
    attn_tp: bool = True
    #: decode KV cache layout: "stacked" (one (L,B,S,G,hd) array — required
    #: by the scanned decode path) or "per_layer" (L separate buffers —
    #: serving mode: in-place DUS aliasing is trivially provable per buffer;
    #: implies scan_layers=False for decode).  See EXPERIMENTS.md §Perf E.
    decode_cache_layout: str = "stacked"

    # -------------------------------------------------------------------------
    @property
    def vocab_padded(self) -> int:
        """Embedding/LM-head table size: vocab rounded up to a multiple of
        256 so the vocab axis shards evenly on any mesh (MaxText-style).
        Logits for pad ids train toward -inf; decode slices them off."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, hd = self.d_model, self.resolved_head_dim
        n_q = self.n_heads * hd
        n_kv = self.n_kv_heads * hd
        emb = self.vocab_size * d
        head = 0 if self.tie_embeddings else d * self.vocab_size
        per_layer = 0
        if self.block_pattern == "attention" or self.family in ("vlm", "audio"):
            attn = d * n_q + 2 * d * n_kv + n_q * d
            per_layer += attn + 2 * d  # norms
            if self.mlp_type in ("swiglu", "geglu"):
                per_layer += 3 * d * self.d_ff
            if self.is_moe:
                per_layer += d * self.n_experts + self.n_experts * 3 * d * self.d_ff
                if self.moe_dense_residual:
                    per_layer += 3 * d * self.d_ff
        elif self.block_pattern == "xlstm":
            di = self.ssm_expand * d
            per_layer += 4 * d * di + 2 * d  # rough: in/out proj + gates
        elif self.block_pattern == "zamba_hybrid":
            di = self.ssm_expand * d
            nh = di // self.ssm_head_dim
            per_layer += d * (2 * di + 2 * self.ssm_state + nh) + di * d + 2 * d
        total = emb + head + self.n_layers * per_layer
        if self.encoder_decoder:
            attn = d * n_q + 2 * d * n_kv + n_q * d
            enc_layer = attn + 3 * d * self.d_ff + 2 * d
            dec_cross = attn + d
            total += self.n_encoder_layers * enc_layer + self.n_layers * dec_cross
        if self.block_pattern == "zamba_hybrid":
            # one shared attention+mlp block
            total += d * n_q * 2 + 2 * d * n_kv + 3 * d * self.d_ff
        return int(total)

    def active_param_count(self) -> int:
        """MoE: parameters touched per token (6·N_active·D roofline term)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        full = self.param_count()
        all_experts = self.n_layers * self.n_experts * 3 * d * self.d_ff
        active = self.n_layers * self.top_k * 3 * d * self.d_ff
        return int(full - all_experts + active)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

#: archs with sub-quadratic token mixing — the only ones that run long_500k.
SUBQUADRATIC = ("ssm", "hybrid")


def applicable_shapes(cfg: ModelConfig) -> List[Tuple[ShapeConfig, Optional[str]]]:
    """(shape, skip_reason) for all four shapes; skip_reason=None → run."""
    out: List[Tuple[ShapeConfig, Optional[str]]] = []
    for s in SHAPES.values():
        reason = None
        if s.name == "long_500k" and cfg.family not in SUBQUADRATIC:
            reason = (
                "pure full-attention arch: 524k dense-KV decode is the "
                "quadratic regime long_500k excludes (DESIGN.md §6)"
            )
        out.append((s, reason))
    return out
