"""arctic-480b [hf:Snowflake/snowflake-arctic-base] — dense residual + MoE
128 experts top-2.  bf16 params + adafactor so optimizer state fits the pod
(DESIGN.md §7 / EXPERIMENTS.md memory notes)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=4864, vocab_size=32000,
    n_experts=128, top_k=2, moe_dense_residual=True,
    params_dtype="bfloat16", optimizer="adafactor",
)
