"""Backfill-utilization study (paper §1/§5 motivation) + the fast-path
speedup study at fleet scale.

Part 1 — the same workload on the same fleet, with and without preemptible
backfill.  Without preemptible instances the provider must keep headroom for
on-demand requests (utilization stays low); with them the fleet saturates
while normal requests still succeed by evacuating spot capacity — the paper's
core value proposition, quantified by the event-driven simulator.

Part 2 — the same dynamics at 4096 hosts, rebuild path vs fast path:
``Simulator`` + ``JaxPreemptibleScheduler`` pays an O(N·K) python→device
array rebuild per scheduling call; ``SoASimulator`` keeps the fleet state
device-resident and applies O(K·D) incremental transitions (batching runs of
arrivals through one ``lax.scan``).  Emits the end-to-end speedup.
"""
from __future__ import annotations

import time

from repro.core.cluster import Cluster, make_uniform_fleet
from repro.core.cost import PeriodCost
from repro.core.jax_scheduler import JaxPreemptibleScheduler
from repro.core.scheduler import FilterScheduler, PreemptibleScheduler
from repro.core.simulator import Simulator, SoASimulator, WorkloadSpec

from .common import NODE_CAP, SIZES, TINY, emit, write_bench_json


def _spec(preemptible_fraction: float) -> WorkloadSpec:
    return WorkloadSpec(
        arrival_rate_per_s=1 / 30.0,
        preemptible_fraction=preemptible_fraction,
        flavors=tuple(SIZES.items()),
        flavor_probs=(0.3, 0.5, 0.2),
    )


def run() -> None:
    # ---- part 1: backfill value proposition ---------------------------------
    duration = (6 * 3600.0) if TINY else (3 * 24 * 3600.0)
    n_hosts = 16 if TINY else 48
    for name, sched_cls, frac in (
        ("ondemand_only", FilterScheduler, 0.0),
        ("with_backfill", PreemptibleScheduler, 0.5),
    ):
        cluster = Cluster(make_uniform_fleet(n_hosts, NODE_CAP))
        sim = Simulator(cluster, sched_cls(cost_fn=PeriodCost()), _spec(frac), seed=7)
        t0 = time.perf_counter()
        metrics = sim.run(duration)
        wall_us = (time.perf_counter() - t0) * 1e6
        s = metrics.summary()
        emit(
            f"sim_{name}", wall_us / max(1, len(metrics.sched_latency_s)),
            f"util={s['mean_utilization']:.3f};util_normal={s['mean_utilization_normal']:.3f};"
            f"fail_normal={s['failures_normal']:.0f};preemptions={s['preemptions']:.0f};"
            f"p50_lat_us={s['p50_sched_latency_us']:.0f}",
        )

    # ---- part 2: incremental fast path vs per-call rebuild ------------------
    # Only medium flavors → ≤ 4 preemptible slots/host, so K=4 (2^4 subsets)
    # is exact; the rebuild comparison uses the same K.
    n_big = 128 if TINY else 4096
    dur_big = 1200.0 if TINY else 2 * 3600.0
    spec = WorkloadSpec(
        arrival_rate_per_s=1 / 10.0,
        preemptible_fraction=0.5,
        flavors=(("medium", SIZES["medium"]),),
    )

    t0 = time.perf_counter()
    fast = SoASimulator(
        make_uniform_fleet(n_big, NODE_CAP), spec, seed=7,
        cost_fn=PeriodCost(), k_slots=4,
    )
    m_fast = fast.run(dur_big)
    t_fast = time.perf_counter() - t0

    t0 = time.perf_counter()
    slow = Simulator(
        Cluster(make_uniform_fleet(n_big, NODE_CAP)),
        JaxPreemptibleScheduler(cost_fn=PeriodCost(), k_slots=4),
        spec, seed=7,
    )
    m_slow = slow.run(dur_big)
    t_slow = time.perf_counter() - t0

    placed_fast = m_fast.placed_normal + m_fast.placed_preemptible
    placed_slow = m_slow.placed_normal + m_slow.placed_preemptible
    emit(
        f"sim_fastpath_n{n_big}",
        t_fast * 1e6 / max(1, len(m_fast.sched_latency_s)),
        f"wall_s={t_fast:.2f};placed={placed_fast};"
        f"util={m_fast.summary()['mean_utilization']:.3f}",
    )
    emit(
        f"sim_rebuild_n{n_big}",
        t_slow * 1e6 / max(1, len(m_slow.sched_latency_s)),
        f"wall_s={t_slow:.2f};placed={placed_slow};"
        f"speedup_fastpath={t_slow / t_fast:.1f}x",
    )
    write_bench_json("sim_utilization")


if __name__ == "__main__":
    run()
