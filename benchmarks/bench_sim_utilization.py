"""Backfill-utilization study (paper §1/§5 motivation): the same workload on
the same fleet, with and without preemptible backfill.

Without preemptible instances the provider must keep headroom for on-demand
requests (utilization stays low); with them the fleet saturates while normal
requests still succeed by evacuating spot capacity — the paper's core value
proposition, quantified by the event-driven simulator.
"""
from __future__ import annotations

import time

from repro.core.cluster import Cluster, make_uniform_fleet
from repro.core.cost import PeriodCost
from repro.core.scheduler import FilterScheduler, PreemptibleScheduler
from repro.core.simulator import Simulator, WorkloadSpec

from .common import NODE_CAP, SIZES, emit


def _spec(preemptible_fraction: float) -> WorkloadSpec:
    return WorkloadSpec(
        arrival_rate_per_s=1 / 30.0,
        preemptible_fraction=preemptible_fraction,
        flavors=tuple(SIZES.items()),
        flavor_probs=(0.3, 0.5, 0.2),
    )


def run() -> None:
    duration = 3 * 24 * 3600.0  # three simulated days
    for name, sched_cls, frac in (
        ("ondemand_only", FilterScheduler, 0.0),
        ("with_backfill", PreemptibleScheduler, 0.5),
    ):
        cluster = Cluster(make_uniform_fleet(48, NODE_CAP))
        sim = Simulator(cluster, sched_cls(cost_fn=PeriodCost()), _spec(frac), seed=7)
        t0 = time.perf_counter()
        metrics = sim.run(duration)
        wall_us = (time.perf_counter() - t0) * 1e6
        s = metrics.summary()
        emit(
            f"sim_{name}", wall_us / max(1, len(metrics.sched_latency_s)),
            f"util={s['mean_utilization']:.3f};util_normal={s['mean_utilization_normal']:.3f};"
            f"fail_normal={s['failures_normal']:.0f};preemptions={s['preemptions']:.0f};"
            f"p50_lat_us={s['p50_sched_latency_us']:.0f}",
        )


if __name__ == "__main__":
    run()
