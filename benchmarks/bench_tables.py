"""Paper Tables 3–6: correctness scenarios, timed.

Each function rebuilds the exact published snapshot, runs one scheduling
call, asserts the paper's expected victim set, and reports the call latency.
"""
from __future__ import annotations

from repro.core.cost import PeriodCost
from repro.core.scheduler import PreemptibleScheduler
from repro.core.types import Host, Instance, Request

from .common import NODE_CAP, NOW, SIZES, emit, time_call, write_bench_json


def _host(name, instances):
    h = Host(name=name, capacity=NODE_CAP)
    for iid, size, minutes, pre in instances:
        h.place(Instance(id=iid, resources=SIZES[size], preemptible=pre,
                         host=name, start_time=NOW - minutes * 60.0))
    return h


TABLES = {
    "table3": (
        "medium", "host-B", {"BP1"},
        lambda: [
            _host("host-A", [("A1", "medium", 272, False), ("A2", "medium", 172, False),
                             ("AP1", "medium", 96, True), ("AP2", "medium", 207, True)]),
            _host("host-B", [("B1", "medium", 136, False), ("B2", "medium", 200, False),
                             ("BP1", "medium", 71, True), ("BP2", "medium", 91, True)]),
            _host("host-C", [("C1", "medium", 97, False), ("C2", "medium", 275, False),
                             ("CP1", "medium", 210, True), ("CP2", "medium", 215, True)]),
            _host("host-D", [("D1", "medium", 16, False), ("DP1", "medium", 85, True),
                             ("DP2", "medium", 199, True), ("DP3", "medium", 152, True)]),
        ],
    ),
    "table4": (
        "medium", "host-C", {"CP1"},
        lambda: [
            _host("host-A", [("AP1", "medium", 247, True), ("AP2", "medium", 463, True),
                             ("AP3", "medium", 403, True), ("AP4", "medium", 410, True)]),
            _host("host-B", [("B1", "medium", 388, False), ("B2", "medium", 103, False),
                             ("BP1", "medium", 344, True), ("BP2", "medium", 476, True)]),
            _host("host-C", [("C1", "medium", 481, False), ("C2", "medium", 177, False),
                             ("CP1", "medium", 181, True), ("CP2", "medium", 160, True)]),
            _host("host-D", [("D1", "medium", 173, False), ("DP1", "medium", 384, True),
                             ("DP2", "medium", 168, True), ("DP3", "medium", 232, True)]),
        ],
    ),
    "table5": (
        "large", "host-A", {"AP2", "AP3", "AP4"},
        lambda: [
            _host("host-A", [("AP1", "large", 298, True), ("AP2", "medium", 278, True),
                             ("AP3", "small", 190, True), ("AP4", "small", 187, True)]),
            _host("host-B", [("B1", "large", 494, False), ("BP1", "large", 178, True)]),
            _host("host-C", [("CP1", "large", 297, True), ("CP2", "medium", 296, True),
                             ("CP3", "small", 296, True)]),
            _host("host-D", [("D1", "medium", 176, False), ("D2", "medium", 200, False),
                             ("D3", "large", 116, False)]),
        ],
    ),
    "table6": (
        "medium", "host-B", {"BP3"},
        lambda: [
            _host("host-A", [("A1", "large", 234, False), ("A2", "medium", 122, False),
                             ("AP1", "medium", 172, True)]),
            _host("host-B", [("BP1", "large", 272, True), ("BP2", "medium", 212, True),
                             ("BP3", "small", 380, True)]),
            _host("host-C", [("C1", "small", 182, False), ("C2", "medium", 120, False),
                             ("C3", "large", 116, False)]),
            _host("host-D", [("DP1", "large", 232, True), ("DP2", "small", 213, True),
                             ("DP3", "medium", 324, True), ("DP4", "small", 314, True)]),
        ],
    ),
}


def run() -> None:
    sched = PreemptibleScheduler(cost_fn=PeriodCost())
    for name, (size, want_host, want_victims, mk) in TABLES.items():
        hosts = mk()
        req = Request(id="new", resources=SIZES[size], preemptible=False)
        res = sched.schedule(req, hosts, NOW)
        assert res.host == want_host and set(res.plan.ids) == want_victims, (
            name, res.host, res.plan.ids)
        t = time_call(lambda: sched.schedule(req, mk(), NOW), repeats=20)
        emit(f"paper_{name}", t.mean_us,
             f"host={res.host};victims={'+'.join(sorted(res.plan.ids))};"
             f"cost_min={res.plan.cost/60:.0f}", p50_us=t.p50_us)
    write_bench_json("tables")


if __name__ == "__main__":
    run()
