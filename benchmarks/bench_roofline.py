"""Roofline table from the dry-run artifacts (deliverable (g)): one row per
compiled (arch × shape × mesh) cell.  us_per_call = the dominant roofline
term (the modeled step-time lower bound on v5e)."""
from __future__ import annotations

import glob
import json
import os

from .common import emit, write_bench_json

ART_DIRS = ("artifacts/dryrun",)


def run() -> None:
    rows = []
    for d in ART_DIRS:
        for path in sorted(glob.glob(os.path.join(d, "*.json"))):
            r = json.load(open(path))
            if r.get("status") != "ok":
                continue
            rows.append(r)
    if not rows:
        print("# no dry-run artifacts found — run: "
              "PYTHONPATH=src python -m repro.launch.dryrun --all")
        return
    for r in rows:
        t_dom = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        emit(
            f"roofline_{r['mesh']}_{r['arch']}_{r['shape']}",
            t_dom * 1e6,
            f"dominant={r['dominant']};frac={r['roofline_fraction']:.3f};"
            f"useful={r['useful_ratio']:.2f};"
            f"tc={r['t_compute_s']:.3f};tm={r['t_memory_s']:.3f};"
            f"tx={r['t_collective_s']:.3f}",
        )
    write_bench_json("roofline")


if __name__ == "__main__":
    run()
