"""Shared benchmark fixtures: the paper's testbed geometry, fleet builders,
timing helpers, and the machine-readable results sink.

Every ``emit()`` row is printed as the historical ``name,us,derived`` CSV AND
recorded in-process; each benchmark module flushes its rows to
``$REPRO_BENCH_OUT/BENCH_<module>.json`` (default ``bench_out/``) with
per-config mean/p50 latency, so CI can archive results as artifacts and
regressions are diffable without parsing stdout.
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable, List, NamedTuple, Optional

import numpy as np

from repro.core.types import VM_SPEC, Host, Instance

#: CI smoke mode: shrink every fleet/duration so ``python -m benchmarks.run``
#: exercises all entrypoints in seconds rather than minutes.
TINY = os.environ.get("REPRO_BENCH_TINY", "") not in ("", "0")

#: Where BENCH_*.json files land (created on demand).
OUT_DIR = os.environ.get("REPRO_BENCH_OUT", "bench_out")

SIZES = {
    "small": VM_SPEC.make(vcpus=1, ram_mb=2000, disk_gb=20),
    "medium": VM_SPEC.make(vcpus=2, ram_mb=4000, disk_gb=40),
    "large": VM_SPEC.make(vcpus=4, ram_mb=8000, disk_gb=80),
}
#: paper Table 1 nodes (disk non-binding; see tests/test_scheduler_correctness)
NODE_CAP = VM_SPEC.make(vcpus=8, ram_mb=16000, disk_gb=10_000)
#: double-size nodes for the K>8 oversubscription sweep (up to 16 small slots)
BIG_NODE_CAP = VM_SPEC.make(vcpus=16, ram_mb=32000, disk_gb=10_000)
NOW = 1_000_000.0


def empty_fleet(n: int) -> List[Host]:
    return [Host(name=f"h{i}", capacity=NODE_CAP) for i in range(n)]


def saturated_fleet(n: int, seed: int = 0, preemptible_frac: float = 0.5,
                    k_max: int = 4) -> List[Host]:
    """Hosts filled with medium instances, mixed normal/preemptible, integer
    run-time minutes (paper §4.4.1 conditions)."""
    rng = np.random.default_rng(seed)
    hosts = []
    iid = 0
    for i in range(n):
        h = Host(name=f"h{i}", capacity=NODE_CAP)
        n_pre = 0
        for _ in range(4):  # 4 medium slots per node
            pre = bool(rng.random() < preemptible_frac) and n_pre < k_max
            n_pre += int(pre)
            h.place(Instance(
                id=f"x{iid}", resources=SIZES["medium"], preemptible=pre,
                host=h.name, start_time=NOW - float(rng.integers(10, 500)) * 60.0,
            ))
            iid += 1
        if n_pre == 0:  # guarantee evacuability somewhere
            inst = next(iter(h.instances.values()))
            inst.preemptible = True
        hosts.append(h)
    return hosts


class Timing(NamedTuple):
    mean_us: float
    std_us: float
    p50_us: float


def time_call(fn: Callable, repeats: int = 30, warmup: int = 3) -> Timing:
    """Mean/std/median latency of fn() in microseconds."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append((time.perf_counter() - t0) * 1e6)
    return Timing(float(np.mean(ts)), float(np.std(ts)), float(np.median(ts)))


#: rows emitted since the last ``write_bench_json`` flush
_RECORDS: List[dict] = []


def emit(name: str, us: float, derived: str, p50_us: Optional[float] = None) -> None:
    """Print the historical CSV row and record it for the JSON sink."""
    print(f"{name},{us:.1f},{derived}")
    row = {"name": name, "mean_us": round(float(us), 3), "derived": derived}
    if p50_us is not None:
        row["p50_us"] = round(float(p50_us), 3)
    _RECORDS.append(row)


def write_bench_json(module: str) -> Optional[str]:
    """Flush rows recorded since the previous call to BENCH_<module>.json.

    Returns the path written (None when nothing was recorded — e.g. the
    roofline table with no dry-run artifacts present)."""
    global _RECORDS
    rows, _RECORDS = _RECORDS, []
    if not rows:
        return None
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"BENCH_{module}.json")
    with open(path, "w") as f:
        json.dump({"module": module, "tiny": TINY, "rows": rows}, f, indent=1)
    return path
