"""Shared benchmark fixtures: the paper's testbed geometry + fleet builders."""
from __future__ import annotations

import os
import time
from typing import Callable, List, Tuple

import numpy as np

from repro.core.types import VM_SPEC, Host, Instance, Request

#: CI smoke mode: shrink every fleet/duration so ``python -m benchmarks.run``
#: exercises all entrypoints in seconds rather than minutes.
TINY = os.environ.get("REPRO_BENCH_TINY", "") not in ("", "0")

SIZES = {
    "small": VM_SPEC.make(vcpus=1, ram_mb=2000, disk_gb=20),
    "medium": VM_SPEC.make(vcpus=2, ram_mb=4000, disk_gb=40),
    "large": VM_SPEC.make(vcpus=4, ram_mb=8000, disk_gb=80),
}
#: paper Table 1 nodes (disk non-binding; see tests/test_scheduler_correctness)
NODE_CAP = VM_SPEC.make(vcpus=8, ram_mb=16000, disk_gb=10_000)
NOW = 1_000_000.0


def empty_fleet(n: int) -> List[Host]:
    return [Host(name=f"h{i}", capacity=NODE_CAP) for i in range(n)]


def saturated_fleet(n: int, seed: int = 0, preemptible_frac: float = 0.5,
                    k_max: int = 4) -> List[Host]:
    """Hosts filled with medium instances, mixed normal/preemptible, integer
    run-time minutes (paper §4.4.1 conditions)."""
    rng = np.random.default_rng(seed)
    hosts = []
    iid = 0
    for i in range(n):
        h = Host(name=f"h{i}", capacity=NODE_CAP)
        n_pre = 0
        for _ in range(4):  # 4 medium slots per node
            pre = bool(rng.random() < preemptible_frac) and n_pre < k_max
            n_pre += int(pre)
            h.place(Instance(
                id=f"x{iid}", resources=SIZES["medium"], preemptible=pre,
                host=h.name, start_time=NOW - float(rng.integers(10, 500)) * 60.0,
            ))
            iid += 1
        if n_pre == 0:  # guarantee evacuability somewhere
            inst = next(iter(h.instances.values()))
            inst.preemptible = True
        hosts.append(h)
    return hosts


def time_call(fn: Callable, repeats: int = 30, warmup: int = 3) -> Tuple[float, float]:
    """(mean_us, std_us) of fn()."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.mean(ts)), float(np.std(ts))


def emit(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.1f},{derived}")
