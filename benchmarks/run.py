"""Benchmark harness — one module per paper table/figure (+ beyond-paper).
Prints ``name,us_per_call,derived`` CSV rows."""
from __future__ import annotations

import sys


def main() -> None:
    from . import (
        bench_fig2_latency,
        bench_jax_vs_python,
        bench_roofline,
        bench_screen,
        bench_sim_utilization,
        bench_tables,
    )

    print("name,us_per_call,derived")
    bench_tables.run()            # paper Tables 3-6 (correctness + latency)
    bench_fig2_latency.run()      # paper Fig. 2 (3 schedulers x scenarios)
    bench_screen.run()            # stage-1 screen microbenchmark (PR 3)
    bench_jax_vs_python.run()     # beyond-paper vectorized scheduler
    bench_sim_utilization.run()   # backfill utilization (paper motivation)
    bench_roofline.run()          # dry-run roofline table (deliverable g)


if __name__ == "__main__":
    main()
