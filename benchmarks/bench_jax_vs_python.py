"""Beyond-paper: vectorized JAX scheduler vs the python reference at fleet
scale, including the Pallas-kernel hot path (interpret mode on CPU — the
structural win is visible; on TPU the kernel path is the deployed one).

The decision arrays are pre-staged (``schedule_soa``) — the production mode
where the cluster state machine maintains SoA mirrors incrementally — so the
measurement isolates the scheduling decision itself.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cost import PeriodCost
from repro.core.jax_scheduler import JaxPreemptibleScheduler, build_soa_state
from repro.core.policy import SchedulerPolicy
from repro.core.scheduler import PreemptibleScheduler
from repro.core.types import Request

from .common import NOW, SIZES, TINY, emit, saturated_fleet, time_call, write_bench_json


def run() -> None:
    req = Request(id="r", resources=SIZES["medium"], preemptible=False)
    req_vec = jnp.asarray(req.resources.vec, jnp.float32)
    py = PreemptibleScheduler(cost_fn=PeriodCost())
    for n_hosts in (100,) if TINY else (100, 1000, 10_000):
        hosts = saturated_fleet(n_hosts)
        t_py = time_call(lambda: py.schedule(req, hosts, NOW),
                         repeats=5 if n_hosts >= 10_000 else 10)
        emit(f"sched_python_n{n_hosts}", t_py.mean_us, "reference",
             p50_us=t_py.p50_us)

        variants = (
            (False, 0, "jnp"),
            (False, 64, "jnp_shortlist"),
            (True, 0, "pallas_interpret"),
        )
        for use_pallas, shortlist, tag in variants:
            if use_pallas and n_hosts > 1000:
                continue  # interpret mode is a correctness harness, not speed
            jx = JaxPreemptibleScheduler(
                cost_fn=PeriodCost(),
                policy=SchedulerPolicy(
                    use_pallas=use_pallas, shortlist=shortlist
                ),
            )
            state, _ = build_soa_state(hosts, NOW, jx.cost_fn, k_slots=jx.k_slots)

            def call():
                h, m, ok = jx.schedule_soa(state, req_vec, False, -1)
                jax.block_until_ready(h)

            t_jx = time_call(call, repeats=10)
            emit(f"sched_jax_{tag}_n{n_hosts}", t_jx.mean_us,
                 f"speedup_vs_python={t_py.mean_us / t_jx.mean_us:.1f}x",
                 p50_us=t_jx.p50_us)
    write_bench_json("jax_vs_python")


if __name__ == "__main__":
    run()
