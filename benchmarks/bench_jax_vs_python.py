"""Beyond-paper: vectorized JAX scheduler vs the python reference at fleet
scale, including the Pallas-kernel hot path (interpret mode on CPU — the
structural win is visible; on TPU the kernel path is the deployed one).

The decision arrays are pre-staged (``schedule_soa``) — the production mode
where the cluster state machine maintains SoA mirrors incrementally — so the
measurement isolates the scheduling decision itself.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cost import PeriodCost
from repro.core.jax_scheduler import JaxPreemptibleScheduler, build_soa_state
from repro.core.scheduler import PreemptibleScheduler
from repro.core.types import Request

from .common import NOW, SIZES, TINY, emit, saturated_fleet, time_call


def run() -> None:
    req = Request(id="r", resources=SIZES["medium"], preemptible=False)
    req_vec = jnp.asarray(req.resources.vec, jnp.float32)
    py = PreemptibleScheduler(cost_fn=PeriodCost())
    for n_hosts in (100,) if TINY else (100, 1000, 10_000):
        hosts = saturated_fleet(n_hosts)
        us_py, _ = time_call(lambda: py.schedule(req, hosts, NOW),
                             repeats=5 if n_hosts >= 10_000 else 10)
        emit(f"sched_python_n{n_hosts}", us_py, "reference")

        for use_pallas, tag in ((False, "jnp"), (True, "pallas_interpret")):
            if use_pallas and n_hosts > 1000:
                continue  # interpret mode is a correctness harness, not speed
            jx = JaxPreemptibleScheduler(cost_fn=PeriodCost(), use_pallas=use_pallas)
            state, _ = build_soa_state(hosts, NOW, jx.cost_fn, k_slots=jx.k_slots)

            def call():
                h, m, ok = jx.schedule_soa(state, req_vec, False, -1)
                jax.block_until_ready(h)

            us_jx, _ = time_call(call, repeats=10)
            emit(f"sched_jax_{tag}_n{n_hosts}", us_jx,
                 f"speedup_vs_python={us_py / us_jx:.1f}x")


if __name__ == "__main__":
    run()
