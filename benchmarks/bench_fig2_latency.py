"""Paper Fig. 2: scheduling-call latency, three schedulers × scenarios.

Scenarios (paper §4.5):
  * empty        — normal request, empty infrastructure;
  * empty-spot   — preemptible request, empty infrastructure;
  * saturated    — normal request on a full fleet ⇒ every call triggers the
                   select-and-terminate path (retry pays a second full cycle).

The paper's testbed is 24 compute nodes; we additionally run 240 and 2400 to
show the scaling trend the paper anticipates ("numbers are expected to become
larger as the infrastructure grows in size").
"""
from __future__ import annotations

import jax

from repro.core.cost import PeriodCost
from repro.core.jax_scheduler import schedule_step
from repro.core.scheduler import FilterScheduler, PreemptibleScheduler, RetryScheduler
from repro.core.soa_fleet import SoAFleet
from repro.core.types import Request

from .common import SIZES, NOW, TINY, empty_fleet, emit, saturated_fleet, time_call

SCHEDULERS = {
    "default": FilterScheduler,
    "retry": RetryScheduler,
    "preemptible": PreemptibleScheduler,
}


def _bench_incremental(n_hosts: int) -> None:
    """The fast path on the same scenarios: the fleet state is persistent and
    device-resident, so a scheduling call is one fused jit dispatch — no
    python→device rebuild.  The decision is applied to a throwaway state copy
    each call (the transition is pure), keeping repeats identical."""
    import numpy as np

    req_vec = np.asarray(SIZES["medium"].vec, np.float32)
    for scenario, fleet_fn in (("empty", empty_fleet), ("saturated", saturated_fleet)):
        fleet = SoAFleet(fleet_fn(n_hosts), cost_fn=PeriodCost(), k_slots=4)
        for kind, pre in (("normal", False), ("spot", True)):
            if scenario == "saturated" and pre:
                continue  # mirrors the python scheduler rows

            def call():
                _, (h, _, ok, _) = schedule_step(
                    fleet.state, req_vec, pre, -1, NOW, 1.0, fleet.masks,
                    cost_kind=fleet.cost_kind, period=fleet.period,
                )
                jax.block_until_ready(h)

            us, sd = time_call(call, repeats=15)
            emit(f"fig2_jax_incr_{kind}_{scenario}_n{n_hosts}", us, f"std={sd:.1f}")


def run() -> None:
    for n_hosts in (24,) if TINY else (24, 240, 2400):
        fleets = {
            "empty": empty_fleet(n_hosts),
            "saturated": saturated_fleet(n_hosts),
        }
        for sname, cls in SCHEDULERS.items():
            sched = cls(cost_fn=PeriodCost())
            # --- empty fleet, normal + preemptible requests
            for kind, pre in (("normal", False), ("spot", True)):
                if sname == "default" and pre:
                    continue  # baseline scheduler has no spot notion
                req = Request(id="r", resources=SIZES["medium"], preemptible=pre)
                us, sd = time_call(
                    lambda: sched.schedule(req, fleets["empty"], NOW), repeats=15
                )
                emit(f"fig2_{sname}_{kind}_empty_n{n_hosts}", us, f"std={sd:.1f}")
            # --- saturated fleet: the termination-triggering path
            req = Request(id="r", resources=SIZES["medium"], preemptible=False)
            res = sched.schedule(req, fleets["saturated"], NOW)
            us, sd = time_call(
                lambda: sched.schedule(req, fleets["saturated"], NOW), repeats=15
            )
            derived = f"std={sd:.1f};ok={res.ok};passes={res.passes};victims={len(res.plan.ids)}"
            emit(f"fig2_{sname}_normal_saturated_n{n_hosts}", us, derived)
        _bench_incremental(n_hosts)


if __name__ == "__main__":
    run()
