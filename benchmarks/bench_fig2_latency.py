"""Paper Fig. 2: scheduling-call latency, three schedulers × scenarios —
plus the beyond-paper K-sweep of the two-stage shortlist pipeline.

Scenarios (paper §4.5):
  * empty        — normal request, empty infrastructure;
  * empty-spot   — preemptible request, empty infrastructure;
  * saturated    — normal request on a full fleet ⇒ every call triggers the
                   select-and-terminate path (retry pays a second full cycle).

The paper's testbed is 24 compute nodes; we additionally run 240 and 2400 to
show the scaling trend the paper anticipates ("numbers are expected to become
larger as the infrastructure grows in size").

K-sweep: decision latency at K ∈ {4, 8, 10, 12} slots/host with the stage-2
shortlist on (M=64) and off (full 2^K × N enumeration), on an every-host-
oversubscribed fleet where each decision must terminate 2 of K slots.  The
shortlist path is bit-exact with the full one (tests/test_shortlist_parity),
so these rows measure pure speedup — and make K=12 at 10^5 hosts affordable,
which the full enumeration cannot reach (its (N, 2^K) feasibility tensor
alone is ~1.6 GB).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cost import PeriodCost
from repro.core.fleet_sharding import (
    fleet_mesh,
    pad_fleet_state,
    padded_hosts,
    shard_fleet_state,
)
from repro.core.jax_scheduler import SoAFleetState, schedule_step
from repro.core.policy import SchedulerPolicy
from repro.core.scheduler import FilterScheduler, PreemptibleScheduler, RetryScheduler
from repro.core.soa_fleet import SoAFleet
from repro.core.types import VM_SPEC, Request

from .common import (
    BIG_NODE_CAP, NOW, SIZES, TINY, emit, empty_fleet, saturated_fleet,
    time_call, write_bench_json,
)

SCHEDULERS = {
    "default": FilterScheduler,
    "retry": RetryScheduler,
    "preemptible": PreemptibleScheduler,
}


def _bench_incremental(n_hosts: int) -> None:
    """The fast path on the same scenarios: the fleet state is persistent and
    device-resident, so a scheduling call is one fused jit dispatch — no
    python→device rebuild.  The decision is applied to a throwaway state copy
    each call (``donate=False`` keeps the input alive), so repeats are
    identical."""
    req_vec = np.asarray(SIZES["medium"].vec, np.float32)
    for scenario, fleet_fn in (("empty", empty_fleet), ("saturated", saturated_fleet)):
        fleet = SoAFleet(fleet_fn(n_hosts), cost_fn=PeriodCost(), k_slots=4)
        for kind, pre in (("normal", False), ("spot", True)):
            if scenario == "saturated" and pre:
                continue  # mirrors the python scheduler rows

            def call():
                _, (h, *_rest) = schedule_step(
                    fleet.state, req_vec, pre, -1, NOW, 1.0,
                    policy=fleet.policy, donate=False,
                )
                jax.block_until_ready(h)

            t = time_call(call, repeats=15)
            emit(f"fig2_jax_incr_{kind}_{scenario}_n{n_hosts}", t.mean_us,
                 f"std={t.std_us:.1f}", p50_us=t.p50_us)


def _packed_state(n: int, k: int, seed: int = 0):
    """Synthetic ``SoAFleetState`` for the K-sweep, built directly as arrays
    (a python-``Host`` build of 10^5 hosts × 12 instances would dwarf the
    measurement): double-size nodes, k preemptible small slots each,
    randomized integer-minute start times.  Returns (state, request_vec) with
    the request sized so every decision evacuates exactly 2 of the k slots."""
    cap = np.asarray(BIG_NODE_CAP.vec, np.float32)
    small = np.asarray(SIZES["small"].vec, np.float32)
    rng = np.random.default_rng(seed)
    free_f = np.broadcast_to(cap - k * small, (n, 3)).copy()
    state = SoAFleetState(
        free_f=jnp.asarray(free_f),
        free_n=jnp.asarray(np.broadcast_to(cap, (n, 3)).copy()),
        schedulable=jnp.ones((n,), bool),
        domain=jnp.zeros((n,), jnp.int32),
        slow=jnp.ones((n,), jnp.float32),
        inst_res=jnp.asarray(np.broadcast_to(small, (n, k, 3)).copy()),
        inst_start=jnp.asarray(
            NOW - rng.integers(10, 500, (n, k)).astype(np.float32) * 60.0
        ),
        inst_price=jnp.ones((n, k), jnp.float32),
        inst_ckpt=jnp.zeros((n, k), jnp.float32),
        inst_cost_kind=jnp.full((n, k), -1, jnp.int32),
        inst_period=jnp.full((n, k), -1.0, jnp.float32),
        inst_valid=jnp.ones((n, k), bool),
        host_zone=jnp.zeros((n,), jnp.int32),
        zone_term=jnp.zeros((1,), jnp.float32),
        zone_up=jnp.zeros((1,), jnp.float32),
    )
    free_vcpus = int(cap[0]) - k * int(small[0])
    req = VM_SPEC.make(
        vcpus=free_vcpus + 2 * int(small[0]),
        ram_mb=int(free_f[0, 1]) + 2 * int(small[1]),
        disk_gb=40,
    )
    return state, np.asarray(req.vec, np.float32)


def _bench_k_sweep() -> None:
    """K × shortlist grid.  ``shortlist=0`` = single-stage full enumeration
    (the pre-shortlist baseline); ``shortlist=64`` = the two-stage pipeline.

    The ``fused`` column runs the same two-stage decision with stage 1 in
    the fused Pallas screen kernel.  On TPU backends that is the production
    fast path (one HBM pass + on-chip top-M); on CPU the kernel only exists
    as an interpreter emulation, so the fused rows run at small N (tiny
    mode) to keep the entrypoint exercised — their latency measures the
    interpreter, not the kernel.

    The ``sharded`` column (multi-device runs only) runs the same decision
    end-to-end with the fleet partitioned host-major across every visible
    device (``mesh=``) — decide + apply on sharded buffers, bit-exact with
    the unsharded rows."""
    on_tpu = jax.default_backend() == "tpu"
    n_dev = jax.device_count()
    if TINY:
        grid = [(k, 512, (0, 64)) for k in (4, 8, 10, 12)]
        repeats = 3
    else:
        grid = [
            (4, 65536, (0, 64)),
            (8, 65536, (0, 64)),      # acceptance baseline: ≥5x at K=8
            (10, 100_000, (64,)),
            (12, 100_000, (64,)),     # full enumeration infeasible here
        ]
        repeats = 5
    for k, n, shortlists in grid:
        state, req_vec = _packed_state(n, k)
        for m in shortlists:
            fused_cols = ((False, ""),)
            if m and (on_tpu or n <= 2048):
                fused_cols = ((False, ""), (True, "_fused"))
            for fused, suffix in fused_cols:
                def call():
                    _, (h, *_rest) = schedule_step(
                        state, req_vec, False, -1, NOW, 1.0,
                        policy=SchedulerPolicy(
                            shortlist=m, fused_screen=fused
                        ),
                        donate=False,
                    )
                    jax.block_until_ready(h)

                t = time_call(call, repeats=repeats, warmup=2)
                tag = (f"shortlist{m}" if m else "full") + suffix
                emit(f"fig2_ksweep_k{k}_n{n}_{tag}", t.mean_us,
                     f"std={t.std_us:.1f};masks={1 << k}", p50_us=t.p50_us)
            if m and n_dev > 1:
                mesh = fleet_mesh()
                st_sh = shard_fleet_state(
                    pad_fleet_state(
                        state, padded_hosts(n, mesh.size, m_keep=m + 1)
                    ),
                    mesh,
                )

                def call_sharded():
                    _, (h, *_rest) = schedule_step(
                        st_sh, req_vec, False, -1, NOW, 1.0,
                        policy=SchedulerPolicy(shortlist=m, mesh=mesh),
                        donate=False,
                    )
                    jax.block_until_ready(h)

                t = time_call(call_sharded, repeats=repeats, warmup=2)
                emit(f"fig2_ksweep_k{k}_n{n}_shortlist{m}_sharded",
                     t.mean_us,
                     f"std={t.std_us:.1f};masks={1 << k};shards={mesh.size}",
                     p50_us=t.p50_us)
                del st_sh


def run() -> None:
    for n_hosts in (24,) if TINY else (24, 240, 2400):
        fleets = {
            "empty": empty_fleet(n_hosts),
            "saturated": saturated_fleet(n_hosts),
        }
        for sname, cls in SCHEDULERS.items():
            sched = cls(cost_fn=PeriodCost())
            # --- empty fleet, normal + preemptible requests
            for kind, pre in (("normal", False), ("spot", True)):
                if sname == "default" and pre:
                    continue  # baseline scheduler has no spot notion
                req = Request(id="r", resources=SIZES["medium"], preemptible=pre)
                t = time_call(
                    lambda: sched.schedule(req, fleets["empty"], NOW), repeats=15
                )
                emit(f"fig2_{sname}_{kind}_empty_n{n_hosts}", t.mean_us,
                     f"std={t.std_us:.1f}", p50_us=t.p50_us)
            # --- saturated fleet: the termination-triggering path
            req = Request(id="r", resources=SIZES["medium"], preemptible=False)
            res = sched.schedule(req, fleets["saturated"], NOW)
            t = time_call(
                lambda: sched.schedule(req, fleets["saturated"], NOW), repeats=15
            )
            derived = f"std={t.std_us:.1f};ok={res.ok};passes={res.passes};victims={len(res.plan.ids)}"
            emit(f"fig2_{sname}_normal_saturated_n{n_hosts}", t.mean_us, derived,
                 p50_us=t.p50_us)
        _bench_incremental(n_hosts)
    _bench_k_sweep()
    write_bench_json("fig2_latency")


if __name__ == "__main__":
    run()
