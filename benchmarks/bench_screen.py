"""Stage-1 screen microbenchmark: the O(N·K) per-decision work in isolation.

Rows (emitted to BENCH_screen.json via the common REPRO_BENCH_OUT sink):

  * ``screen_slot_costs_*``   — the per-slot termination-cost derivation
                                (the floor-mod fast path; fmod was ~30x
                                slower on XLA CPU and dominated the whole
                                decision before PR 3);
  * ``screen_terms_*``        — the shared bounds math (Batcher-network
                                sorted-prefix feasibility + cost bounds);
  * ``screen_stage1_*``       — the full jnp stage-1: slot costs + screen +
                                weigher normalization + omega_ub + top_k(65)
                                (what the fused Pallas kernel replaces);
  * ``screen_fused_*``        — the fused Pallas kernel.  Compiled on TPU
                                backends; in interpret mode (CPU) it is an
                                emulation — those rows validate the
                                entrypoint and record interpreter overhead,
                                NOT kernel speed, and only run at small N;
  * ``screen_sharded_*``      — the device-sharded stage-1 (shard_map screen
                                + cross-shard shortlist merge) on S-device
                                meshes: a strong-scaling sweep at fixed
                                N ≥ 10^6 hosts and a weak-scaling sweep at
                                fixed hosts/shard.  Only emitted when more
                                than one device is visible — on CPU force
                                XLA_FLAGS=--xla_force_host_platform_device_count=8
                                (device "shards" then share the physical
                                cores, so treat CPU rows as a scaling-shape
                                smoke, not per-device speedup);
  * ``screen_slot_costs_mixed_*`` — the heterogeneous kind-table selection
                                (``fleet_slot_costs`` under a 4-kind
                                ``SchedulerPolicy``) vs the single-kind
                                column above — the mixed-payment overhead is
                                the extra elementwise selects only;
  * ``screen_sustained_*``    — the streaming admission front end under a
                                sustained arrival stream: requests flow
                                through ``submit`` → double-buffered
                                non-blocking ``drain`` at admit_batch B.
                                ``mean_us``/``p50_us`` are the wall-clock
                                admission latency per request (submit →
                                outcome absorbed); the derived field records
                                decisions/sec (``dps=``) and the tail
                                (``p99_us=``).  Uncontended fleet — this
                                measures the admission plane's overhead, not
                                retry/backfill behavior;
  * ``screen_storm_*``        — the failure-domain study: the same seeded
                                preemption-storm workload (one hot zone
                                driven by a Markov churn regime) run
                                churn-blind vs churn-aware
                                (``churn_multiplier`` + ``churn_threshold``)
                                at equal fleet size.  The row value is the
                                per-decision latency; the note records storm
                                kills, utilization, and placements — the
                                aware row must show FEWER kills at
                                equivalent utilization (asserted), which is
                                the whole point of learning ẑ online;
  * ``screen_adaptive_*``     — the AdaptiveShortlist workload study: a
                                fallback-heavy fleet (loose stage-1 bounds
                                on every host, so small M cannot certify its
                                winner) and a calm skewed fleet (a cheap
                                pool far ahead of the field, so margins are
                                wide) swept over (grow_after, shrink_after)
                                controller thresholds; the note records
                                decisions / fallbacks / final M / grows /
                                shrinks.  See ``AdaptiveShortlist`` for the
                                defaults this study picked.

K sweeps {4, 8, 12} on the packed oversubscribed fleet geometry from
``bench_fig2_latency`` so the sorted-prefix bounds do real work.
"""
from __future__ import annotations

import dataclasses
import functools
import time

import jax
import jax.numpy as jnp

import numpy as np

from repro.core.fleet_sharding import (
    fleet_mesh,
    merge_shortlists,
    pad_fleet_state,
    shard_fleet_state,
)
from repro.core.jax_scheduler import (
    _sharded_screen,
    fleet_slot_costs,
    screen_terms,
    slot_costs,
)
from repro.core.policy import SchedulerPolicy
from repro.core.simulator import SoASimulator, WorkloadSpec
from repro.core.screen_math import (
    base_from_consts,
    consts_of,
    inv_span,
    omega_of,
    raw_base_terms,
)
from repro.core.soa_fleet import SoAFleet
from repro.core.types import VM_SPEC, Host, Instance, Request

from .bench_fig2_latency import _packed_state
from .common import (
    NODE_CAP, NOW, SIZES, TINY, emit, time_call, write_bench_json,
)

MULT = (1.0, 1.0, 0.0, 0.0)
M_KEEP = 65
#: all four kinds in one table — the mixed-payment fleet the tentpole added
MIXED_POLICY = SchedulerPolicy(cost_kinds=("count", "revenue", "recompute"))


@functools.partial(jax.jit, static_argnames=("m_keep",))
def _stage1_jnp(state, req_res, m_keep):
    """The full jnp stage-1 assembly (mirrors ``_decision_core``: top_k(M)
    + masked argmax witness — top_k must stay ≤ 64 for XLA CPU's TopK
    custom-call; beyond that it silently becomes a full fleet sort)."""
    inst_cost = slot_costs(
        "period", state.inst_start, state.inst_price, NOW, 3600.0,
        inst_ckpt=state.inst_ckpt, inst_res=state.inst_res,
    )
    fits = jnp.all(state.free_n >= req_res[None, :] - 1e-6, axis=-1)
    fits &= state.schedulable
    feas, over, lb, ub = screen_terms(
        state.free_f, state.inst_res, inst_cost, state.inst_valid, req_res
    )
    valid = fits & feas
    raw = raw_base_terms(jnp.sum(state.free_f, axis=-1), state.slow, over)
    consts = consts_of(MULT, valid, lb, ub, *raw)
    base = base_from_consts(MULT, *raw, consts)
    omega_ub = omega_of(
        lb, base, valid, consts, inv_span(consts.c_lo, consts.c_hi), MULT[1]
    )
    _, cand = jax.lax.top_k(omega_ub, m_keep - 1)
    in_short = jnp.zeros(omega_ub.shape, bool).at[cand].set(True)
    out_ub = jnp.where(in_short, -1e30, omega_ub)
    return cand, jnp.max(out_ub), jnp.argmax(out_ub)


@functools.partial(jax.jit, static_argnames=("mesh", "m_cand"))
def _stage1_sharded(state, req_res, mesh, m_cand):
    """The sharded stage-1: per-shard screen under shard_map + the
    cross-shard shortlist/consts merge (what ``_decision_core`` runs before
    stage 2 when ``mesh`` is set)."""
    inst_cost = slot_costs(
        "period", state.inst_start, state.inst_price, NOW, 3600.0,
        inst_ckpt=state.inst_ckpt, inst_res=state.inst_res,
    )
    all_s, all_i, consts = _sharded_screen(
        mesh,
        state.free_f, state.free_n, state.schedulable, state.domain,
        state.slow, state.inst_res, inst_cost, state.inst_valid,
        req_res, jnp.asarray(False), jnp.asarray(-1, jnp.int32),
        MULT, True, m_cand,
    )
    cand, u, j_u = merge_shortlists(all_s, all_i, m_cand)
    return cand, u, j_u, consts


def _bench_sharded(k: int, repeats: int) -> None:
    """Weak/strong scaling of the sharded stage-1 across device subsets.

    Strong: fixed N (≥ 10^6 hosts in full mode) over 1..S-device meshes —
    the single-shard row is the sharded-path overhead baseline.  Weak:
    fixed hosts/shard, fleet grows with the mesh."""
    n_dev = jax.device_count()
    if n_dev < 2:
        return
    shard_counts = [s for s in (1, 2, 4, 8, 16) if s <= n_dev]
    n_strong = 2048 if TINY else 1 << 20        # 1,048,576 hosts
    per_shard_weak = 256 if TINY else 1 << 17   # 131,072 hosts/shard
    m_cand = 64
    req = jnp.asarray(_packed_state(4, k)[1])   # same request geometry

    def row(tag, n, s, state, mesh):
        t = time_call(
            lambda: jax.block_until_ready(
                _stage1_sharded(state, req, mesh, m_cand)
            ),
            repeats=repeats, warmup=2,
        )
        emit(f"screen_sharded_{tag}_k{k}_n{n}_s{s}", t.mean_us,
             f"std={t.std_us:.1f};hosts_per_shard={n // s};m={m_cand}",
             p50_us=t.p50_us)

    # strong scaling: ONE fleet (built once — ~130 MB at 2^20 hosts), more
    # shards; only the device placement changes per row.
    strong_base, _ = _packed_state(n_strong, k)
    for s in shard_counts:
        mesh = fleet_mesh(s)
        row("strong", n_strong, s, shard_fleet_state(strong_base, mesh), mesh)
        # weak scaling: fleet grows with the mesh
        n_weak = per_shard_weak * s
        if n_weak != n_strong:
            state, _ = _packed_state(n_weak, k)
            state = shard_fleet_state(pad_fleet_state(state, n_weak), mesh)
            row("weak", n_weak, s, state, mesh)
            del state


CAP = VM_SPEC.make(vcpus=8, ram_mb=16000, disk_gb=10_000)
MEDIUM = VM_SPEC.make(vcpus=2, ram_mb=4000, disk_gb=40)


def _loose_bound_fleet(n: int):
    """Every host's stage-1 cost lower bound undershoots its true optimum:
    two cheap slots cover one resource dim each (m* = 1 ⇒ lb = one cheap
    slot), but any feasible plan pays both — so a small shortlist can never
    certify its winner against the outside bounds and EVERY decision pays
    the admissibility fallback.  The worst case the adaptive controller's
    grow path exists for."""
    a = VM_SPEC.make(vcpus=4, ram_mb=0, disk_gb=20)
    b = VM_SPEC.make(vcpus=0, ram_mb=8000, disk_gb=20)
    c = VM_SPEC.make(vcpus=4, ram_mb=8000, disk_gb=40)
    hosts = []
    for i in range(n):
        h = Host(name=f"h{i}", capacity=CAP)
        for j, (res, mins) in enumerate(((a, 10), (b, 10), (c, 50))):
            h.place(Instance(
                id=f"x{i}-{j}", resources=res, preemptible=True, host=h.name,
                start_time=NOW - mins * 60.0,
            ))
        hosts.append(h)
    return hosts, VM_SPEC.make(vcpus=4, ram_mb=8000, disk_gb=40)


def _calm_skewed_fleet(n: int, rng):
    """Sparse feasibility: only ~64 hosts can admit the request at all (one
    evacuable slot + normal-view room); the rest are full of normal
    instances.  The whole viable pool fits inside the default shortlist, so
    the best *non-shortlisted* bound is NEG_INF and the admissibility
    margin is effectively infinite — the regime where a small M provably
    suffices and the controller should shrink toward the floor."""
    filler = MEDIUM
    step = max(n // 64, 1)
    hosts = []
    for i in range(n):
        h = Host(name=f"h{i}", capacity=CAP)
        feasible = i % step == 0
        if feasible:
            h.place(Instance(
                id=f"p{i}", resources=filler, preemptible=True, host=h.name,
                start_time=NOW - float(rng.integers(5, 56)) * 60.0,
            ))
        n_fill = 3 if feasible else 4
        for j in range(n_fill):
            h.place(Instance(
                id=f"n{i}-{j}", resources=filler, preemptible=False,
                host=h.name, start_time=NOW - 3600.0,
            ))
        hosts.append(h)
    return hosts, MEDIUM


def _bench_adaptive(repeats: int) -> None:
    """AdaptiveShortlist workload study: how the controller's thresholds
    trade fallback cost against shortlist size on the two extreme synthetic
    workloads, and what per-decision latency each configuration lands at.
    The (grow_after=2, shrink_after=8) row is the shipped default — see the
    ``AdaptiveShortlist`` docstring for the conclusions."""
    n = 256 if TINY else 4096
    flushes = 6 if TINY else 12
    batch = 8
    rng = np.random.default_rng(0)
    workloads = {
        "fallback_heavy": _loose_bound_fleet(n),
        "calm": _calm_skewed_fleet(n, rng),
    }
    for g, s in ((1, 4), (2, 8), (4, 16)):
        for name, (hosts, req_res) in workloads.items():
            fleet = SoAFleet(
                hosts, k_slots=4,
                policy=SchedulerPolicy(
                    shortlist=64, adaptive_shortlist=True,
                    adaptive_bounds=(16, 256),
                ),
            )
            fleet.adaptive.grow_after = g
            fleet.adaptive.shrink_after = s

            def flush(i):
                fleet.schedule_batch([
                    (
                        Request(id=f"r{i}-{j}", resources=req_res,
                                preemptible=False),
                        NOW + 60.0 * (i * batch + j),
                        1.0,
                    )
                    for j in range(batch)
                ])

            flush(0)  # compile + first controller observation
            ts = []
            for i in range(1, flushes + 1):
                t0 = time.perf_counter()
                flush(i)
                ts.append((time.perf_counter() - t0) * 1e6)
            st = fleet.shortlist_stats
            emit(
                f"screen_adaptive_{name}_g{g}_s{s}_n{n}",
                float(np.mean(ts)) / batch,
                (
                    f"per_decision;decisions={st['decisions']};"
                    f"fallbacks={st['fallbacks']};final_m={st['shortlist']};"
                    f"grows={st['grows']};shrinks={st['shrinks']}"
                ),
                p50_us=float(np.median(ts)) / batch,
            )


def _bench_sustained() -> None:
    """Sustained throughput of the streaming admission plane: wall-clock
    submit→absorbed latency and decisions/sec through the double-buffered
    non-blocking drain path, at two batch sizes.

    The fleet is large enough that every request admits on its first
    attempt — these rows price the admission machinery itself (queue push,
    lexicographic select, the ``_step_core`` scan, outcome absorption), not
    retry/backfill churn.  One warmup pass on a throwaway fleet compiles
    both drain shapes; equal policies share the compile cache, so the
    measured fleet starts hot."""
    n = 256 if TINY else 4096
    n_reqs = 64 if TINY else 1024

    def stream(fleet, b):
        rng = np.random.default_rng(7)
        now = NOW
        for i0 in range(0, n_reqs, b):
            for j in range(i0, min(i0 + b, n_reqs)):
                now += 1.0
                fleet.submit(
                    Request(
                        id=f"s{j}", resources=SIZES["medium"],
                        preemptible=bool(rng.random() < 0.5),
                    ),
                    now,
                )
            fleet.drain(now, block=False)  # double-buffered dispatch
        fleet.drain_all(now + 1.0)
        fleet.admission.sync()

    for b in (16, 64):
        policy = SchedulerPolicy(
            queue_capacity=4 * b, admit_batch=b, max_retries=4
        )
        hosts = [Host(name=f"h{i}", capacity=CAP) for i in range(n)]
        stream(SoAFleet(hosts, k_slots=8, policy=policy), b)  # warmup/compile
        hosts = [Host(name=f"h{i}", capacity=CAP) for i in range(n)]
        fleet = SoAFleet(hosts, k_slots=8, policy=policy)
        t0 = time.perf_counter()
        stream(fleet, b)
        elapsed_s = time.perf_counter() - t0
        st = fleet.admission.stats
        assert st.admitted == n_reqs, "sustained bench must stay uncontended"
        wall_us = np.asarray(st.wall_wait_s) * 1e6
        dps = st.admitted / elapsed_s
        emit(
            f"screen_sustained_n{n}_b{b}",
            float(wall_us.mean()),
            f"dps={dps:.0f};p99_us={float(np.percentile(wall_us, 99)):.1f};"
            f"reqs={n_reqs};admitted={st.admitted}",
            p50_us=float(np.percentile(wall_us, 50)),
        )


def _bench_storm() -> None:
    """Failure-domain study: does learning ẑ online actually save instances?

    One zone of three is hot — a Markov churn regime fires ``kill_frac=0.5``
    reclaim waves while "on", seeded identically for every run.  The blind
    policy spreads preemptible work uniformly (ties → lowest index), so a
    third of the fleet's instances sit in the blast radius at every storm;
    the aware policy reads the learned per-zone ẑ after the first wave and
    steers subsequent placements to the calm zones (weigher penalty) or
    refuses the hot zone outright (threshold — learned rates are per-second,
    so the gate sits at 1e-4, well under any stormed zone's ẑ and above the
    exact 0.0 of a calm one).  The arrival rate keeps steady-state occupancy
    under the calm zones' capacity, so avoidance costs no placements.

    The evacuated policy (PR 8) adds the relocation plane on top of aware:
    steering only protects placements made AFTER ẑ is learned, while the
    instances already sitting in the hot zone keep eating storms — the
    relocation passes move those out too, so the only kills left are the
    first-storm ones no online learner can see coming.  Emits the
    ``screen_storm_{blind,aware}`` rows (the PR 7 schema, unchanged) plus
    ``screen_relocate_{blind,aware,evacuated}`` rows with the relocation
    ledger in ``derived``."""
    n = 12 if TINY else 48
    duration = 1500.0 if TINY else 7200.0
    # steady state ≈ rate × mean lifetime, kept under the CALM zones'
    # capacity (2/3 · n · 4 mediums/host) so zone avoidance is free
    spec = WorkloadSpec(
        arrival_rate_per_s=(1 / 25.0 if TINY else 1 / 20.0),
        lifetime_min_s=(300.0 if TINY else 600.0),
        lifetime_mean_s=(600.0 if TINY else 1800.0),
        lifetime_max_s=(1200.0 if TINY else 3600.0),
        preemptible_fraction=1.0,   # storms are the only kill source
        flavors=(("medium", MEDIUM),),
    )

    def run_one(policy):
        hosts = [
            Host(name=f"h{i}", capacity=CAP, zone=f"z{i % 3}")
            for i in range(n)
        ]
        sim = SoASimulator(hosts, spec, seed=11, k_slots=8, policy=policy)
        # early one-shot wave seeds the learning; the regime keeps storming
        sim.inject_zone_storm("z2", at_s=duration * 0.05, kill_frac=0.8)
        sim.inject_churn_regime(
            "z2", until_s=duration, mean_on_s=duration / 8.0,
            mean_off_s=duration / 8.0, storm_every_s=duration / 50.0,
            kill_frac=0.5, start_s=0.0,
        )
        m = sim.run(duration, sample_every_s=duration / 24.0)
        return sim, m

    results = {}
    policies = (
        ("blind", SchedulerPolicy()),
        ("aware", SchedulerPolicy(churn_multiplier=2.0, churn_threshold=1e-4)),
        (
            "evacuated",
            SchedulerPolicy(
                churn_multiplier=2.0, churn_threshold=1e-4,
                relocate_threshold=1e-4, relocate_every_s=duration / 100.0,
                relocate_budget=8,
            ),
        ),
    )
    for tag, policy in policies:
        sim, m = run_one(policy)
        s = m.summary()
        lat = np.asarray(m.sched_latency_s) * 1e6
        if tag != "evacuated":  # the PR 7 rows keep their schema
            emit(
                f"screen_storm_{tag}_n{n}",
                float(lat.mean()),
                (
                    f"per_decision;kills={m.storm_kills};storms={m.storms};"
                    f"util={s['mean_utilization']:.3f};"
                    f"placed={m.placed_preemptible};"
                    f"failed={m.failures_preemptible};"
                    f"fleet_churn={sim.fleet.fleet_churn_rate():.2e}"
                ),
                p50_us=float(np.percentile(lat, 50)) if lat.size else 0.0,
            )
        emit(
            f"screen_relocate_{tag}_n{n}",
            float(lat.mean()),
            (
                f"per_decision;kills={m.storm_kills};storms={m.storms};"
                f"relocs={m.relocations};"
                f"reloc_failed={m.relocation_failed};"
                f"util={s['mean_utilization']:.3f};"
                f"placed={m.placed_preemptible};"
                f"failed={m.failures_preemptible + m.failures_normal}"
            ),
            p50_us=float(np.percentile(lat, 50)) if lat.size else 0.0,
        )
        results[tag] = m
    assert results["aware"].storm_kills < results["blind"].storm_kills, (
        "churn-aware policy must take fewer storm kills than churn-blind "
        f"(aware={results['aware'].storm_kills}, "
        f"blind={results['blind'].storm_kills})"
    )
    assert (
        results["evacuated"].storm_kills <= results["aware"].storm_kills
    ), (
        "evacuation must never lose MORE instances than staying put "
        f"(evacuated={results['evacuated'].storm_kills}, "
        f"aware={results['aware'].storm_kills})"
    )
    assert results["evacuated"].failures_preemptible == 0, (
        "evacuation must not steal capacity from user placements "
        f"(failed={results['evacuated'].failures_preemptible})"
    )
    if not TINY:
        # At full scale the aware run strands first-storm survivors in the
        # hot zone; the relocation plane must move them out and beat aware
        # strictly.  (The tiny fleet's steering alone keeps the hot zone
        # empty, so there is legitimately nothing to relocate.)
        assert results["evacuated"].relocations > 0, "no relocations ran"
        assert (
            results["evacuated"].storm_kills < results["aware"].storm_kills
        ), (
            "evacuation must save instances steering alone cannot "
            f"(evacuated={results['evacuated'].storm_kills}, "
            f"aware={results['aware'].storm_kills})"
        )


def _fused(state, req_res, m_keep, interpret):
    from repro.kernels.sched_screen import sched_screen

    inst_cost = slot_costs(
        "period", state.inst_start, state.inst_price, NOW, 3600.0,
        inst_ckpt=state.inst_ckpt, inst_res=state.inst_res,
    )
    return sched_screen(
        state.free_f, state.free_n, state.schedulable, state.domain,
        state.slow, state.inst_res, inst_cost, state.inst_valid,
        req_res, jnp.asarray(False), jnp.asarray(-1, jnp.int32),
        weigher_multipliers=MULT, require_free_slot=True,
        m_keep=m_keep, interpret=interpret,
    )


def _bench_scan() -> None:
    """Scanned-simulator study: the whole event loop as ONE ``lax.scan``
    dispatch (``core.scan_sim``) vs the python ``SoASimulator`` loop on the
    identical ``EventTrace``, end to end.  Emits:

      * ``screen_scan_python_n{N}`` / ``screen_scan_device_n{N}`` — wall
        time for the same trace through both engines at 4096 and 65536
        hosts (``eps=`` events/sec in derived).  The scanned engine must
        be at least as fast at 4096 hosts (asserted when not TINY) —
        the whole point of removing the per-event host<->device ping-pong;
      * ``screen_scan_ensemble_n{N}_s{S}`` — the vmap Monte-Carlo harness:
        S seeded trajectories in ONE dispatch (``tps=`` trajectories/sec).

    Every run starts by checking the two engines agree exactly (counters +
    placement sequence) on the smallest size — the bench doubles as the
    tiny parity smoke CI runs with TINY=1."""
    import time as _time

    from repro.core.scan_sim import (
        simulate_ensemble, simulate_scan, trace_from_workload,
    )

    policy = SchedulerPolicy()
    spec = WorkloadSpec(
        arrival_rate_per_s=1 / 8.0,
        lifetime_min_s=300.0, lifetime_mean_s=1200.0, lifetime_max_s=2400.0,
        preemptible_fraction=0.6,
        flavors=tuple((f"f{i}", s) for i, s in enumerate(SIZES.values())),
    )
    duration = 800.0 if TINY else 3200.0
    trace = trace_from_workload(
        spec, duration, seed=7,
        storms=((duration * 0.5, 0, 0.5),),
        failures=((duration * 0.4, 1, duration * 0.2),),
        checkpoint_every=4,
    )
    eps_by_n = {}
    sizes = (128, 256) if TINY else (4096, 65536)
    for i, n in enumerate(sizes):
        hosts = [
            Host(name=f"h{j}", capacity=NODE_CAP, zone=f"z{j % 3}")
            for j in range(n)
        ]
        sim = SoASimulator(hosts, spec, seed=7, k_slots=8, policy=policy)
        cap0 = sim.fleet._cap0_total
        state0 = jax.tree_util.tree_map(
            lambda a: jnp.asarray(np.asarray(a)), sim.fleet.state
        )
        t0 = _time.perf_counter()
        m_py = sim.run_trace(trace)
        py_us = (_time.perf_counter() - t0) * 1e6
        res = simulate_scan(trace, policy, state0)  # compile + first run
        t0 = _time.perf_counter()
        res = simulate_scan(trace, policy, state0)
        dev_us = (_time.perf_counter() - t0) * 1e6
        if i == 0:
            # the tiny differential smoke: both engines, same trace, equal
            m_dev = res.sim_metrics(cap0)
            for f in ("placed_normal", "placed_preemptible", "preemptions",
                      "storms", "storm_kills"):
                assert getattr(m_py, f) == getattr(m_dev, f), (
                    f, getattr(m_py, f), getattr(m_dev, f)
                )
            assert np.array_equal(
                np.stack([res.host, res.slot, res.ok.astype(np.int64),
                          res.n_kill], axis=1),
                sim.trace_outcomes,
            ), "scanned-vs-python placement sequence diverged"
        e = trace.n_events
        eps_py, eps_dev = e / (py_us / 1e6), e / (dev_us / 1e6)
        eps_by_n[n] = (eps_py, eps_dev)
        emit(f"screen_scan_python_n{n}", py_us,
             f"end_to_end;events={e};eps={eps_py:.0f}")
        emit(f"screen_scan_device_n{n}", dev_us,
             f"end_to_end;events={e};eps={eps_dev:.0f};"
             f"speedup={eps_dev / eps_py:.2f}")
    if not TINY:
        eps_py, eps_dev = eps_by_n[4096]
        assert eps_dev >= eps_py, (
            f"scanned loop slower than python at 4096 hosts: "
            f"{eps_dev:.0f} < {eps_py:.0f} events/s"
        )

    # the Monte-Carlo harness: S seeds, ONE dispatch
    n = 128 if TINY else 1024
    seeds = 8 if TINY else 32
    hosts = [
        Host(name=f"h{j}", capacity=NODE_CAP, zone=f"z{j % 3}")
        for j in range(n)
    ]
    sim = SoASimulator(hosts, spec, seed=0, k_slots=8, policy=policy)
    ens_duration = 400.0 if TINY else 1200.0
    traces = [
        trace_from_workload(spec, ens_duration, seed=s,
                            storms=((ens_duration * 0.5, s % 3, 0.5),))
        for s in range(seeds)
    ]
    lanes = simulate_ensemble(traces, policy, sim.fleet.state)  # compile
    t0 = _time.perf_counter()
    lanes = simulate_ensemble(traces, policy, sim.fleet.state)
    ens_us = (_time.perf_counter() - t0) * 1e6
    e_max = max(t.n_events for t in traces)
    emit(
        f"screen_scan_ensemble_n{n}_s{seeds}", ens_us,
        f"one_dispatch;seeds={seeds};events={e_max};"
        f"tps={seeds / (ens_us / 1e6):.2f};"
        f"placed={sum(l.counters['placed_preemptible'] for l in lanes)}",
    )


def _bench_scan_stream() -> None:
    """In-scan streaming admission vs the python front-end loop, end to
    end on the identical trace.  Emits:

      * ``screen_scan_stream_python_n{N}`` / ``screen_scan_stream_device_n{N}``
        — the same streaming trace (queue, SLO batching, retries) through
        ``SoASimulator.run_trace`` (one fused drain dispatch per trigger,
        host loop between events) and through ``simulate_scan`` with the
        queue arrays riding the scan carry (ONE dispatch total).  The
        in-scan path must be ≥5× faster at 4096 hosts (asserted when not
        TINY — the committed acceptance row);
      * ``screen_scan_stream_knobs_n{N}_l{L}`` — the admission-knob sweep:
        L traced ``(aging_rate, slo_target_s, storm_threshold)`` rows over
        one trace in ONE vmapped dispatch (``tps=`` lanes/sec).

    The smallest size doubles as a parity smoke: placement sequence and
    every admission counter must agree exactly before anything is timed."""
    import time as _time

    from repro.core.scan_sim import (
        simulate_ensemble, simulate_scan, trace_from_workload,
    )

    policy = SchedulerPolicy(
        # admit_batch=4 is the low-latency admission config: the python
        # loop pays one fused drain dispatch per 4 admissions, which is
        # exactly the per-trigger overhead the in-carry queue removes.
        queue_capacity=64, admit_batch=4, slo_target_s=120.0,
        max_retries=4, n_classes=3, aging_rate=0.005, storm_threshold=0.05,
    )
    spec = WorkloadSpec(
        arrival_rate_per_s=1 / 8.0,
        lifetime_min_s=300.0, lifetime_mean_s=1200.0, lifetime_max_s=2400.0,
        preemptible_fraction=0.6,
        flavors=tuple((f"f{i}", s) for i, s in enumerate(SIZES.values())),
    )
    duration = 800.0 if TINY else 3200.0
    trace = trace_from_workload(
        spec, duration, seed=7,
        storms=((duration * 0.5, 0, 0.5),),
        failures=((duration * 0.4, 1, duration * 0.2),),
        checkpoint_every=4,
        priorities=(-1, 0, 1, 2),
    )
    eps_by_n = {}
    sizes = (128, 256) if TINY else (4096, 65536)
    for i, n in enumerate(sizes):
        hosts = [
            Host(name=f"h{j}", capacity=NODE_CAP, zone=f"z{j % 3}")
            for j in range(n)
        ]
        sim = SoASimulator(hosts, spec, seed=7, k_slots=8, policy=policy)
        state0 = jax.tree_util.tree_map(
            lambda a: jnp.asarray(np.asarray(a)), sim.fleet.state
        )
        t0 = _time.perf_counter()
        m_py = sim.run_trace(trace)
        py_us = (_time.perf_counter() - t0) * 1e6
        res = simulate_scan(trace, policy, state0)  # compile + first run
        t0 = _time.perf_counter()
        res = simulate_scan(trace, policy, state0)
        dev_us = (_time.perf_counter() - t0) * 1e6
        if i == 0:
            # parity smoke: outcomes + every admission counter, exact
            front = sim.fleet.admission
            st = front.stats
            want = {k: getattr(st, k) for k in (
                "arrivals", "admitted", "rejected_overflow",
                "rejected_retry", "drains", "retries", "degraded",
            )}
            want["queue_depth"] = front.waiting
            assert res.admission == want, (res.admission, want)
            assert np.array_equal(
                np.stack([res.host, res.slot, res.ok.astype(np.int64),
                          res.n_kill], axis=1),
                sim.trace_outcomes,
            ), "streaming scan-vs-python placement sequence diverged"
            assert m_py.placed_normal + m_py.placed_preemptible == (
                st.admitted
            )
        e = trace.n_events
        eps_py, eps_dev = e / (py_us / 1e6), e / (dev_us / 1e6)
        eps_by_n[n] = (eps_py, eps_dev)
        adm = res.admission
        emit(f"screen_scan_stream_python_n{n}", py_us,
             f"end_to_end;events={e};eps={eps_py:.0f};"
             f"admitted={adm['admitted']}")
        emit(f"screen_scan_stream_device_n{n}", dev_us,
             f"end_to_end;events={e};eps={eps_dev:.0f};"
             f"admitted={adm['admitted']};"
             f"speedup={eps_dev / eps_py:.2f}")
    if not TINY:
        eps_py, eps_dev = eps_by_n[4096]
        assert eps_dev >= 5.0 * eps_py, (
            f"in-scan streaming admission must be >=5x the python loop at "
            f"4096 hosts: {eps_dev:.0f} vs {eps_py:.0f} events/s"
        )

    # the admission-knob sweep: L lanes, ONE dispatch
    n = 128 if TINY else 1024
    lanes_n = 8 if TINY else 32
    hosts = [
        Host(name=f"h{j}", capacity=NODE_CAP, zone=f"z{j % 3}")
        for j in range(n)
    ]
    sim = SoASimulator(hosts, spec, seed=0, k_slots=8, policy=policy)
    ens_duration = 400.0 if TINY else 1200.0
    ktrace = trace_from_workload(
        spec, ens_duration, seed=3,
        storms=((ens_duration * 0.5, 0, 0.5),),
        priorities=(-1, 0, 1, 2),
    )
    rng = np.random.default_rng(42)
    knob_rows = np.column_stack([
        rng.uniform(0.0, 0.05, lanes_n),
        rng.uniform(30.0, 300.0, lanes_n),
        np.where(rng.random(lanes_n) < 0.5, np.inf,
                 rng.uniform(0.005, 0.5, lanes_n)),
    ]).astype(np.float32)
    lanes = simulate_ensemble(
        [ktrace], policy, sim.fleet.state, knobs=knob_rows
    )  # compile
    t0 = _time.perf_counter()
    lanes = simulate_ensemble(
        [ktrace], policy, sim.fleet.state, knobs=knob_rows
    )
    ens_us = (_time.perf_counter() - t0) * 1e6
    emit(
        f"screen_scan_stream_knobs_n{n}_l{lanes_n}", ens_us,
        f"one_dispatch;lanes={lanes_n};events={ktrace.n_events};"
        f"tps={lanes_n / (ens_us / 1e6):.2f};"
        f"admitted={sum(l.admission['admitted'] for l in lanes)}",
    )


def run() -> None:
    on_tpu = jax.default_backend() == "tpu"
    n = 512 if TINY else 65536
    repeats = 3 if TINY else 10
    for k in (4, 8, 12):
        state, req_vec = _packed_state(n, k)
        req = jnp.asarray(req_vec)

        costs_j = jax.jit(
            lambda st: slot_costs(
                "period", st.inst_start, st.inst_price, NOW, 3600.0,
                inst_ckpt=st.inst_ckpt, inst_res=st.inst_res,
            )
        )
        t = time_call(
            lambda: jax.block_until_ready(costs_j(state)), repeats=repeats
        )
        emit(f"screen_slot_costs_k{k}_n{n}", t.mean_us,
             f"std={t.std_us:.1f}", p50_us=t.p50_us)

        # Heterogeneous kind-table selection (the mixed-payment fast path):
        # same column, each slot billed by its own kind through the
        # branchless 4-way select.
        rng = np.random.default_rng(k)
        mixed_state = dataclasses.replace(
            state,
            inst_cost_kind=jnp.asarray(
                rng.integers(-1, 4, (n, k)).astype(np.int32)
            ),
        )
        mixed_j = jax.jit(
            lambda st: fleet_slot_costs(st, jnp.float32(NOW), MIXED_POLICY)
        )
        t = time_call(
            lambda: jax.block_until_ready(mixed_j(mixed_state)),
            repeats=repeats,
        )
        emit(f"screen_slot_costs_mixed_k{k}_n{n}", t.mean_us,
             f"std={t.std_us:.1f};kinds=4", p50_us=t.p50_us)

        inst_cost = costs_j(state)
        screen_j = jax.jit(screen_terms)
        t = time_call(
            lambda: jax.block_until_ready(screen_j(
                state.free_f, state.inst_res, inst_cost, state.inst_valid, req
            )),
            repeats=repeats,
        )
        emit(f"screen_terms_k{k}_n{n}", t.mean_us,
             f"std={t.std_us:.1f}", p50_us=t.p50_us)

        m_keep = min(M_KEEP, n)
        t = time_call(
            lambda: jax.block_until_ready(_stage1_jnp(state, req, m_keep)),
            repeats=repeats,
        )
        emit(f"screen_stage1_k{k}_n{n}", t.mean_us,
             f"std={t.std_us:.1f};m_keep={m_keep}", p50_us=t.p50_us)

        # Fused kernel: real speed on TPU; interpreter-overhead smoke on CPU
        # (small N only — emulating 2×N/128 grid steps at 10^5 hosts tells
        # you nothing about the kernel and takes minutes).
        if on_tpu or n <= 2048:
            t = time_call(
                lambda: jax.block_until_ready(
                    _fused(state, req, m_keep, interpret=not on_tpu)
                ),
                repeats=repeats,
            )
            mode = "tpu" if on_tpu else "interpret"
            emit(f"screen_fused_k{k}_n{n}_{mode}", t.mean_us,
                 f"std={t.std_us:.1f};m_keep={m_keep}", p50_us=t.p50_us)
    # Device-sharded stage-1 scaling (multi-device runs only): K=8, the
    # acceptance geometry, swept over shard counts at ≥10^6 hosts.
    _bench_sharded(k=8, repeats=repeats)
    # Adaptive-shortlist workload study (fallback-heavy vs calm fleets).
    _bench_adaptive(repeats=repeats)
    # Streaming admission sustained-throughput rows (PR 6).
    _bench_sustained()
    # Failure-domain storm study: churn-aware vs churn-blind (PR 7).
    _bench_storm()
    _bench_scan()
    # In-scan streaming admission vs the python front-end loop (PR 10).
    _bench_scan_stream()
    write_bench_json("screen")


if __name__ == "__main__":
    run()
