"""Stage-1 screen microbenchmark: the O(N·K) per-decision work in isolation.

Rows (emitted to BENCH_screen.json via the common REPRO_BENCH_OUT sink):

  * ``screen_slot_costs_*``   — the per-slot termination-cost derivation
                                (the floor-mod fast path; fmod was ~30x
                                slower on XLA CPU and dominated the whole
                                decision before PR 3);
  * ``screen_terms_*``        — the shared bounds math (Batcher-network
                                sorted-prefix feasibility + cost bounds);
  * ``screen_stage1_*``       — the full jnp stage-1: slot costs + screen +
                                weigher normalization + omega_ub + top_k(65)
                                (what the fused Pallas kernel replaces);
  * ``screen_fused_*``        — the fused Pallas kernel.  Compiled on TPU
                                backends; in interpret mode (CPU) it is an
                                emulation — those rows validate the
                                entrypoint and record interpreter overhead,
                                NOT kernel speed, and only run at small N.

K sweeps {4, 8, 12} on the packed oversubscribed fleet geometry from
``bench_fig2_latency`` so the sorted-prefix bounds do real work.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.jax_scheduler import screen_terms, slot_costs
from repro.core.screen_math import (
    base_from_consts,
    consts_of,
    inv_span,
    omega_of,
    raw_base_terms,
)

from .bench_fig2_latency import _packed_state
from .common import NOW, TINY, emit, time_call, write_bench_json

MULT = (1.0, 1.0, 0.0, 0.0)
M_KEEP = 65


@functools.partial(jax.jit, static_argnames=("m_keep",))
def _stage1_jnp(state, req_res, m_keep):
    """The full jnp stage-1 assembly (mirrors ``_decision_core``: top_k(M)
    + masked argmax witness — top_k must stay ≤ 64 for XLA CPU's TopK
    custom-call; beyond that it silently becomes a full fleet sort)."""
    inst_cost = slot_costs(
        "period", state.inst_start, state.inst_price, NOW, 3600.0,
        inst_ckpt=state.inst_ckpt, inst_res=state.inst_res,
    )
    fits = jnp.all(state.free_n >= req_res[None, :] - 1e-6, axis=-1)
    fits &= state.schedulable
    feas, over, lb, ub = screen_terms(
        state.free_f, state.inst_res, inst_cost, state.inst_valid, req_res
    )
    valid = fits & feas
    raw = raw_base_terms(jnp.sum(state.free_f, axis=-1), state.slow, over)
    consts = consts_of(MULT, valid, lb, ub, *raw)
    base = base_from_consts(MULT, *raw, consts)
    omega_ub = omega_of(
        lb, base, valid, consts, inv_span(consts.c_lo, consts.c_hi), MULT[1]
    )
    _, cand = jax.lax.top_k(omega_ub, m_keep - 1)
    in_short = jnp.zeros(omega_ub.shape, bool).at[cand].set(True)
    out_ub = jnp.where(in_short, -1e30, omega_ub)
    return cand, jnp.max(out_ub), jnp.argmax(out_ub)


def _fused(state, req_res, m_keep, interpret):
    from repro.kernels.sched_screen import sched_screen

    inst_cost = slot_costs(
        "period", state.inst_start, state.inst_price, NOW, 3600.0,
        inst_ckpt=state.inst_ckpt, inst_res=state.inst_res,
    )
    return sched_screen(
        state.free_f, state.free_n, state.schedulable, state.domain,
        state.slow, state.inst_res, inst_cost, state.inst_valid,
        req_res, jnp.asarray(False), jnp.asarray(-1, jnp.int32),
        weigher_multipliers=MULT, require_free_slot=True,
        m_keep=m_keep, interpret=interpret,
    )


def run() -> None:
    on_tpu = jax.default_backend() == "tpu"
    n = 512 if TINY else 65536
    repeats = 3 if TINY else 10
    for k in (4, 8, 12):
        state, req_vec = _packed_state(n, k)
        req = jnp.asarray(req_vec)

        costs_j = jax.jit(
            lambda st: slot_costs(
                "period", st.inst_start, st.inst_price, NOW, 3600.0,
                inst_ckpt=st.inst_ckpt, inst_res=st.inst_res,
            )
        )
        t = time_call(
            lambda: jax.block_until_ready(costs_j(state)), repeats=repeats
        )
        emit(f"screen_slot_costs_k{k}_n{n}", t.mean_us,
             f"std={t.std_us:.1f}", p50_us=t.p50_us)

        inst_cost = costs_j(state)
        screen_j = jax.jit(screen_terms)
        t = time_call(
            lambda: jax.block_until_ready(screen_j(
                state.free_f, state.inst_res, inst_cost, state.inst_valid, req
            )),
            repeats=repeats,
        )
        emit(f"screen_terms_k{k}_n{n}", t.mean_us,
             f"std={t.std_us:.1f}", p50_us=t.p50_us)

        m_keep = min(M_KEEP, n)
        t = time_call(
            lambda: jax.block_until_ready(_stage1_jnp(state, req, m_keep)),
            repeats=repeats,
        )
        emit(f"screen_stage1_k{k}_n{n}", t.mean_us,
             f"std={t.std_us:.1f};m_keep={m_keep}", p50_us=t.p50_us)

        # Fused kernel: real speed on TPU; interpreter-overhead smoke on CPU
        # (small N only — emulating 2×N/128 grid steps at 10^5 hosts tells
        # you nothing about the kernel and takes minutes).
        if on_tpu or n <= 2048:
            t = time_call(
                lambda: jax.block_until_ready(
                    _fused(state, req, m_keep, interpret=not on_tpu)
                ),
                repeats=repeats,
            )
            mode = "tpu" if on_tpu else "interpret"
            emit(f"screen_fused_k{k}_n{n}_{mode}", t.mean_us,
                 f"std={t.std_us:.1f};m_keep={m_keep}", p50_us=t.p50_us)
    write_bench_json("screen")


if __name__ == "__main__":
    run()
