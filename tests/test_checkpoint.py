"""Checkpointer: roundtrip exactness, async durability, atomicity, GC."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer


def tree():
    return {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "b": {"w": jnp.ones((5,), jnp.bfloat16) * 1.5,
              "s": jnp.asarray(7, jnp.int32)},
    }


def test_roundtrip_exact(tmp_path):
    ck = Checkpointer(str(tmp_path))
    t = tree()
    ck.save(3, t, extra={"note": "x"}, blocking=True)
    restored, meta = ck.restore(jax.tree.map(lambda x: x, t))
    assert meta.step == 3 and meta.extra["note"] == "x"
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_compression_fallback_shard_naming(tmp_path):
    """Without the optional ``zstandard`` wheel, shards are plain ``.npz``
    (and still restore); with it they are ``.npz.zst``.  Either way the seed
    suite must not require the wheel (it broke test collection once)."""
    from repro.checkpoint import checkpointer as cp

    ck = Checkpointer(str(tmp_path))
    ck.save(1, tree(), blocking=True)
    shards = [
        n for n in os.listdir(tmp_path / "step_1") if n.startswith("shard_")
    ]
    assert shards
    want = ".npz" if cp.zstandard is None else ".npz.zst"
    assert all(n.endswith(want) for n in shards)
    restored, _ = ck.restore(jax.tree.map(lambda x: x, tree()))
    np.testing.assert_array_equal(
        np.asarray(restored["a"]), np.asarray(tree()["a"])
    )


def test_async_save_then_wait(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, tree())
    ck.wait()
    assert ck.latest_step() == 1


def test_latest_pointer_flips_only_on_complete_write(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, tree(), blocking=True)
    ck.save(2, tree(), blocking=True)
    assert ck.latest_step() == 2
    # a torn step_3 directory must not be visible via LATEST
    os.makedirs(tmp_path / "step_3.tmp", exist_ok=True)
    assert ck.latest_step() == 2


def test_gc_keeps_latest_k(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, tree(), blocking=True)
    kept = sorted(n for n in os.listdir(tmp_path) if n.startswith("step_"))
    assert kept == ["step_3", "step_4"]


def test_restore_into_shape_structs(tmp_path):
    ck = Checkpointer(str(tmp_path))
    t = tree()
    ck.save(5, t, blocking=True)
    template = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    restored, meta = ck.restore(template)
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(t["a"]))


def test_shape_mismatch_rejected(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"a": jnp.zeros((2, 2))}, blocking=True)
    with pytest.raises(AssertionError):
        ck.restore({"a": jnp.zeros((3, 3))})
