"""Failure-domain plane: zone churn accumulators, churn-aware decisions,
correlated preemption storms, and graceful degradation.

The contract under test, end to end:

  * the device-resident per-zone accumulators (``zone_term``/``zone_up``)
    track EXACTLY the python-side definition of involuntary churn — kills
    over accrued uptime — under any interleaving of placements with
    evacuations, out-of-band preemptions, voluntary departures, and host
    failures (integer times keep every f32 sum exact, so equality is strict);
  * churn-aware decisions (nonzero ``churn_multiplier`` / a
    ``churn_threshold``) taken on the incremental state are bit-identical to
    the rebuild-from-python oracle seeded with the same accumulators;
  * a hot zone's learned rate steers preemptible placements away (threshold)
    and penalizes all placements (weigher term);
  * storm injection is deterministic given the seed, conserves instances,
    and charges only the zone it hits;
  * queue aging (``aging_rate``) un-starves low-priority entries under
    sustained high-priority load;
  * fleet-wide storms demote pending preemptible placements to
    non-preemptible (``storm_threshold`` graceful degradation).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cost import PeriodCost
from repro.core.jax_scheduler import build_fleet_state, schedule_step
from repro.core.policy import SchedulerPolicy
from repro.core.screen_math import CHURN_EPS
from repro.core.simulator import SoASimulator, WorkloadSpec
from repro.core.soa_fleet import SoAFleet
from repro.core.types import VM_SPEC, Host, Instance, Request

CAP = VM_SPEC.make(vcpus=8, ram_mb=16000, disk_gb=160)
SIZES = [
    VM_SPEC.make(vcpus=1, ram_mb=2000, disk_gb=20),
    VM_SPEC.make(vcpus=2, ram_mb=4000, disk_gb=40),
    VM_SPEC.make(vcpus=4, ram_mb=8000, disk_gb=80),
]
K = 8


def _zoned_hosts(n: int, n_zones: int = 3):
    return [
        Host(
            name=f"h{i}", capacity=CAP, domain=f"dom{i % 2}",
            zone=f"z{i % n_zones}",
        )
        for i in range(n)
    ]


# ---------------------------------------------------------------------------
# 1. accumulator parity vs a pure-python churn oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_zone_accumulators_match_python_oracle(seed):
    """Randomized lifecycle events vs hand-tracked per-zone (T, U): every
    involuntary kill adds 1 to its zone's T and the victim's accrued uptime
    to U; voluntary departures add uptime only (diluting ẑ); normal
    instances never touch the accumulators.  Integer event times make the
    f32 sums exact, so equality is strict."""
    rng = np.random.default_rng(seed)
    n_hosts, n_zones, n_events = 12, 3, 350
    hosts = _zoned_hosts(n_hosts, n_zones)
    fleet = SoAFleet(hosts, cost_fn=PeriodCost(), k_slots=K)
    T = np.zeros((n_zones,), np.float64)
    U = np.zeros((n_zones,), np.float64)
    #: live instances we know about: id -> (zone index, start, preemptible)
    live = {}
    now = 0.0

    for step in range(n_events):
        now += float(rng.integers(1, 90))
        roll = rng.random()
        if roll < 0.55:  # -------------------------------------------- arrival
            req = Request(
                id=f"r{step}",
                resources=SIZES[int(rng.integers(3))],
                preemptible=bool(rng.random() < 0.6),
            )
            out = fleet.schedule_request(req, now)
            if out.ok:
                z = fleet.zone_ids[fleet.zones[fleet.index[out.host]]]
                # scheduler evacuations are involuntary churn in the
                # chosen host's zone
                for v in out.victims:
                    T[z] += 1.0
                    U[z] += now - v.start_time
                    del live[v.id]
                live[out.instance.id] = (z, now, req.preemptible)
        elif roll < 0.75 and live:  # ------------------------------- departure
            iid = sorted(live)[int(rng.integers(len(live)))]
            z, start, pre = live.pop(iid)
            assert fleet.depart(iid, now=now)
            if pre:  # voluntary exit: uptime credit only
                U[z] += now - start
        elif roll < 0.90:  # ------------------------- out-of-band preemption
            pre_ids = [i for i, (_, _, p) in live.items() if p]
            if pre_ids:
                iid = sorted(pre_ids)[int(rng.integers(len(pre_ids)))]
                z, start, _ = live.pop(iid)
                assert fleet.preempt_instance(iid, now=now)
                T[z] += 1.0
                U[z] += now - start
        else:  # ------------------------------------------------ host failure
            name = f"h{rng.integers(n_hosts)}"
            host_idx = fleet.index[name]
            z = fleet.zone_ids[fleet.zones[host_idx]]
            for iid in [
                i for i, (h, _) in fleet.locator.items() if h == host_idx
            ]:
                zz, start, pre = live.pop(iid)
                if pre:  # only slot instances feed the zone accumulators
                    T[z] += 1.0
                    U[z] += now - start
            fleet.fail_host(name, now=now)
            fleet.heal_host(name)

        np.testing.assert_array_equal(
            np.asarray(fleet.state.zone_term), T.astype(np.float32),
            err_msg=f"event {step}: zone_term",
        )
        np.testing.assert_array_equal(
            np.asarray(fleet.state.zone_up), U.astype(np.float32),
            err_msg=f"event {step}: zone_up",
        )

    assert T.sum() > 0 and U.sum() > 0, "degenerate run: no churn observed"
    # the reader derives the same ẑ the device decision consumes
    rates = fleet.zone_rates()
    for z, i in fleet.zone_ids.items():
        np.testing.assert_allclose(
            rates[z],
            np.float32(T[i]) / max(np.float32(U[i]), CHURN_EPS),
            rtol=1e-6,
        )
    np.testing.assert_allclose(
        fleet.fleet_churn_rate(),
        np.float32(T.sum()) / max(np.float32(U.sum()), CHURN_EPS),
        rtol=1e-6,
    )


# ---------------------------------------------------------------------------
# 2. churn-aware decision parity: incremental state vs rebuild oracle
# ---------------------------------------------------------------------------


def test_churn_aware_decisions_match_rebuild_oracle():
    """With a nonzero churn multiplier AND a churn threshold, every decision
    on the incrementally-maintained state is bit-identical to one taken on a
    state rebuilt from the python hosts and seeded with the live zone
    accumulators — the 4-path parity contract extended to the churn plane."""
    rng = np.random.default_rng(11)
    n_hosts, n_events = 16, 300
    hosts = _zoned_hosts(n_hosts, n_zones=4)
    by_name = {h.name: h for h in hosts}
    policy = SchedulerPolicy(
        weigher_multipliers=(1.0, 1.0, 0.05, 0.0),
        churn_multiplier=2.0,
        churn_threshold=0.5,
        cost_kind="period",
    )
    fleet = SoAFleet(hosts, k_slots=K, policy=policy)
    now = 0.0
    live = []  # departable ids

    def mirror_place(out):
        host = by_name[out.host]
        for v in out.victims:
            host.remove(v.id)
        inst = out.instance
        host.place(
            Instance(
                id=inst.id, resources=inst.resources,
                preemptible=inst.preemptible, host=host.name,
                start_time=inst.start_time, price_rate=inst.price_rate,
                cost_kind=inst.cost_kind, period=inst.period,
            )
        )

    for step in range(n_events):
        now += float(rng.integers(1, 90))
        roll = rng.random()
        if roll < 0.60:  # -------------------------------------------- arrival
            req = Request(
                id=f"r{step}",
                resources=SIZES[int(rng.integers(3))],
                preemptible=bool(rng.random() < 0.6),
            )
            price = float(rng.integers(1, 5))
            oracle, _ = build_fleet_state(
                hosts, k_slots=K, domain_ids=fleet.domain_ids,
                slot_assignment=fleet.slot_assignment(),
                zone_ids=fleet.zone_ids,
                zone_term=fleet.state.zone_term,
                zone_up=fleet.state.zone_up,
            )
            res, pre, dom, kind, period, _excl = fleet._req_arrays(req)
            _, (oh, oslot, ook, okill, _fb, _mg) = schedule_step(
                oracle, res, pre, dom, now, price,
                policy=fleet.policy, req_cost_kind=kind, req_period=period,
            )
            expect_victims = set()
            if bool(ook) and not req.preemptible:
                expect_victims = {
                    fleet.slot_ids[int(oh)][k]
                    for k in np.flatnonzero(np.asarray(okill))
                } - {None}
            out = fleet.schedule_request(req, now, price=price)
            assert bool(ook) == out.ok, f"event {step}: ok mismatch"
            if out.ok:
                assert fleet.names[int(oh)] == out.host, f"event {step}"
                assert {v.id for v in out.victims} == expect_victims
                mirror_place(out)
                live.append(out.instance.id)
        elif roll < 0.78 and live:  # ------------------------------- departure
            iid = live.pop(int(rng.integers(len(live))))
            if fleet.depart(iid, now=now):
                for h in hosts:
                    if iid in h.instances:
                        h.remove(iid)
        elif roll < 0.92:  # -------------------------------- storm preemption
            pre_ids = sorted(
                i for i, (_, s) in fleet.locator.items() if s is not None
            )
            if pre_ids:
                iid = pre_ids[int(rng.integers(len(pre_ids)))]
                assert fleet.preempt_instance(iid, now=now)
                for h in hosts:
                    if iid in h.instances:
                        h.remove(iid)
        else:  # ------------------------------------------------- fail / heal
            name = f"h{rng.integers(n_hosts)}"
            host = by_name[name]
            if host.schedulable:
                fleet.fail_host(name, now=now)
                host.schedulable = False
                host.instances.clear()
            else:
                fleet.heal_host(name)
                host.schedulable = True

    assert float(np.asarray(fleet.state.zone_term).sum()) > 0


# ---------------------------------------------------------------------------
# 3. hot-zone steering: threshold gate + churn weigher
# ---------------------------------------------------------------------------


def _two_zone_fleet(policy, hot_term=10.0):
    """Two empty hosts, h0 in the HOT zone (ẑ=0.1), h1 cold (ẑ=0)."""
    hosts = [
        Host(name="h0", capacity=CAP, zone="z_hot"),
        Host(name="h1", capacity=CAP, zone="z_cold"),
    ]
    fleet = SoAFleet(hosts, k_slots=K, policy=policy)
    fleet.state = dataclasses.replace(
        fleet.state,
        zone_term=jnp.asarray([hot_term, 0.0], jnp.float32),
        zone_up=jnp.asarray([100.0, 100.0], jnp.float32),
    )
    return fleet


def test_churn_threshold_steers_preemptible_off_hot_zone():
    small = SIZES[0]
    # baseline (churn-blind): the tie resolves to the first host — h0 (hot)
    blind = _two_zone_fleet(SchedulerPolicy(cost_kind="period"))
    out = blind.schedule_request(
        Request(id="p", resources=small, preemptible=True), now=10.0
    )
    assert out.ok and out.host == "h0"

    # threshold below the hot zone's ẑ=0.1: preemptible placements are
    # gated off h0 entirely
    gated = _two_zone_fleet(
        SchedulerPolicy(cost_kind="period", churn_threshold=0.05)
    )
    out = gated.schedule_request(
        Request(id="p", resources=small, preemptible=True), now=10.0
    )
    assert out.ok and out.host == "h1"
    # normal placements are NOT gated (only spot capacity rides churn risk)
    out = gated.schedule_request(
        Request(id="n", resources=small, preemptible=False), now=11.0
    )
    assert out.ok and out.host == "h0"
    # a hot fleet with nowhere cold to go: preemptible is rejected, not
    # silently placed into the hot zone
    all_hot = SoAFleet(
        [Host(name="h0", capacity=CAP, zone="z_hot")],
        k_slots=K,
        policy=SchedulerPolicy(cost_kind="period", churn_threshold=0.05),
    )
    all_hot.state = dataclasses.replace(
        all_hot.state,
        zone_term=jnp.asarray([10.0], jnp.float32),
        zone_up=jnp.asarray([100.0], jnp.float32),
    )
    out = all_hot.schedule_request(
        Request(id="p", resources=small, preemptible=True), now=10.0
    )
    assert not out.ok


def test_churn_weigher_penalizes_hot_zone():
    """A positive churn multiplier steers ALL placements toward the cold
    zone (soft penalty, not a gate)."""
    small = SIZES[0]
    weighed = _two_zone_fleet(
        SchedulerPolicy(cost_kind="period", churn_multiplier=2.0)
    )
    for rid, pre in (("p", True), ("n", False)):
        out = weighed.schedule_request(
            Request(id=rid, resources=small, preemptible=pre),
            now=10.0 + (rid == "n"),
        )
        assert out.ok and out.host == "h1", f"{rid} landed {out.host}"


# ---------------------------------------------------------------------------
# 4. storm injection: determinism, conservation, zone isolation
# ---------------------------------------------------------------------------


def _storm_sim(seed=3):
    medium = VM_SPEC.make(vcpus=2, ram_mb=4000, disk_gb=40)
    spec = WorkloadSpec(
        arrival_rate_per_s=1 / 20.0,
        preemptible_fraction=1.0,  # storms are the ONLY kill source
        flavors=(("medium", medium),),
    )
    sim = SoASimulator(
        _zoned_hosts(12, 3), spec, seed=seed, cost_fn=PeriodCost(), k_slots=4
    )
    sim.inject_zone_storm("z1", at_s=1500.0, kill_frac=0.5)
    sim.inject_churn_regime(
        "z2", until_s=4000.0, mean_on_s=300.0, mean_off_s=800.0,
        storm_every_s=100.0, kill_frac=0.3, start_s=0.0,
    )
    return sim


def test_zone_storms_deterministic_and_conserving():
    sim = _storm_sim()
    m = sim.run(4000.0)
    assert m.storms >= 1 and m.storm_kills >= 1
    # conservation: with an all-preemptible workload and no failures, every
    # preempted record traces back to a storm kill (and nothing else)
    assert len(sim.fleet.preempted) == m.storm_kills
    assert m.preemptions == 0  # no scheduler-driven evacuations fired
    # zone isolation: involuntary terminations land only in the hit zones
    term = np.asarray(sim.fleet.state.zone_term)
    assert term[sim.fleet.zone_ids["z0"]] == 0.0
    assert term.sum() == float(m.storm_kills)
    # every storm victim's host really is in a stormed zone
    for inst in sim.fleet.preempted:
        assert sim.fleet.zones[sim.fleet.index[inst.host]] in ("z1", "z2")

    # determinism: same seed, same injections → identical trajectories
    # (latency percentiles are wall-clock measurements, so compare the
    # simulation-state keys only)
    sim2 = _storm_sim()
    rerun = sim2.run(4000.0)
    skip = {"p50_sched_latency_us", "p99_sched_latency_us"}
    assert {k: v for k, v in rerun.summary().items() if k not in skip} == {
        k: v for k, v in m.summary().items() if k not in skip
    }
    np.testing.assert_array_equal(
        np.asarray(sim2.fleet.state.zone_term), term
    )


def test_zone_storm_validates_inputs():
    sim = _storm_sim()
    with pytest.raises(ValueError, match="unknown zone"):
        sim.inject_zone_storm("z9", at_s=10.0)
    with pytest.raises(ValueError, match="kill_frac"):
        sim.inject_zone_storm("z1", at_s=10.0, kill_frac=0.0)
    with pytest.raises(ValueError, match="unknown zone"):
        sim.inject_churn_regime("z9", until_s=100.0)


# ---------------------------------------------------------------------------
# 5. queue aging: no starvation under sustained high-priority load
# ---------------------------------------------------------------------------


def _aging_run(aging_rate):
    """One preemptible (class-1) arrival at t=0, then two fresh normal
    (class-0) arrivals per drain with ``admit_batch=2`` — without aging the
    fresh pairs monopolize every batch forever."""
    small = SIZES[0]
    policy = SchedulerPolicy(
        cost_kind="period", queue_capacity=32, admit_batch=2,
        n_classes=2, aging_rate=aging_rate, slo_target_s=1e9,
    )
    fleet = SoAFleet(_zoned_hosts(4, 2), k_slots=K, policy=policy)
    fleet.submit(
        Request(id="starved", resources=small, preemptible=True), now=0.0
    )
    attempts = []
    for i in range(1, 6):
        t = 60.0 * i
        fleet.submit(
            Request(id=f"a{i}", resources=small, preemptible=False), now=t
        )
        fleet.submit(
            Request(id=f"b{i}", resources=small, preemptible=False), now=t
        )
        result = fleet.drain(t)
        if result is not None:
            attempts.extend(result.attempts)
    return fleet, attempts


def test_aging_unstarves_batch_class_under_sustained_load():
    # aging off: the class-1 entry never makes a batch
    fleet, attempts = _aging_run(aging_rate=0.0)
    assert all(req.id != "starved" for req, _ in attempts)
    assert fleet.admission.waiting >= 1

    # one class per 30 s waited: by the first drain (60 s) the entry reads
    # as class 0 with the oldest seq, so it leads the very next batch
    fleet, attempts = _aging_run(aging_rate=1 / 30.0)
    placed = {req.id: ok for req, ok in attempts}
    assert placed.get("starved") is True
    assert "starved" not in {
        w.request.id
        for w in fleet.admission.slots + fleet.admission._pending
        if w is not None
    }


# ---------------------------------------------------------------------------
# 6. graceful degradation: fleet-wide storms demote preemptible placements
# ---------------------------------------------------------------------------


def _degradation_fleet(hot: bool):
    policy = SchedulerPolicy(
        cost_kind="period", queue_capacity=8, admit_batch=4,
        storm_threshold=0.05,
    )
    fleet = SoAFleet(_zoned_hosts(2, 2), k_slots=K, policy=policy)
    if hot:  # fleet churn ΣT/ΣU = 10/100 = 0.1 > storm_threshold
        fleet.state = dataclasses.replace(
            fleet.state,
            zone_term=jnp.asarray([5.0, 5.0], jnp.float32),
            zone_up=jnp.asarray([50.0, 50.0], jnp.float32),
        )
    return fleet


def test_storm_threshold_demotes_preemptible_to_normal():
    small = SIZES[0]
    fleet = _degradation_fleet(hot=True)
    fleet.submit(
        Request(id="p", resources=small, preemptible=True), now=10.0
    )
    result = fleet.drain(10.0)
    (out,) = result.outcomes
    assert out.ok
    # placed, but demoted: a durable (non-preemptible) instance in the
    # python mirror, the locator, and the device free_n view
    assert out.instance.preemptible is False
    assert fleet.locator[out.instance.id][1] is None
    assert fleet.admission.stats.degraded == 1
    used_n = float(
        np.asarray(fleet.state.free_n).sum()
    )
    cap_n = float(np.asarray(CAP.vec).sum()) * 2
    assert used_n < cap_n  # free_n paid for the durable placement

    # calm fleet (ẑ = 0): the same arrival stays preemptible
    fleet = _degradation_fleet(hot=False)
    fleet.submit(
        Request(id="p", resources=small, preemptible=True), now=10.0
    )
    result = fleet.drain(10.0)
    (out,) = result.outcomes
    assert out.ok
    assert out.instance.preemptible is True
    assert fleet.locator[out.instance.id][1] is not None
    assert fleet.admission.stats.degraded == 0
