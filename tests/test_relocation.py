"""Relocation plane: hot-zone evacuation with hysteresis, bounded budgets,
checkpoint-aware victim selection, and the never-worse guarantee.

The contract under test, end to end:

  * the zone-exclusion operand (``Request.exclude_zone`` →
    ``req_exclude_zone``) yields BIT-IDENTICAL decisions across every
    screen backend — pure jnp, fused Pallas (interpret mode), sharded
    shard_map, and the sharded+fused split-phase kernel — all pinned to
    the rebuild-from-python oracle, and never places into the excluded
    zone;
  * arming is hysteretic: a zone arms when its learned ẑ crosses
    ``relocate_threshold``, disarms (entering a cooldown) only below the
    lower ``relocate_exit_threshold``, and cannot re-arm inside the
    cooldown window — no thrash;
  * victim selection is checkpoint-aware: at most ``relocate_budget``
    victims per zone per pass, highest expected loss (recompute since the
    last checkpoint + remaining billing period) first;
  * never-worse: a failed re-placement leaves its victim running,
    backs the zone off exponentially, and counts as ``failed`` — and the
    fleet conserves instances (nothing lost, duplicated, or double-billed)
    after EVERY event of a randomized chaos schedule mixing churn regimes,
    storms, streaming admission, and relocation passes.

CI treats a skip of this file as a failure (see .github/workflows/ci.yml,
multi-device job): the parity sweep below is the acceptance gate for the
relocation plane's decision operand.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fleet_sharding import (
    fleet_mesh,
    pad_fleet_state,
    padded_hosts,
    shard_fleet_state,
)
from repro.core.jax_scheduler import build_fleet_state, schedule_step
from repro.core.policy import SchedulerPolicy
from repro.core.screen_math import CHURN_EPS
from repro.core.simulator import SoASimulator, WorkloadSpec
from repro.core.soa_fleet import SoAFleet
from repro.core.types import VM_SPEC, Host, Instance, Request

NOW = 500_000.0
CAP = VM_SPEC.make(vcpus=8, ram_mb=16000, disk_gb=160)
SIZES = [
    VM_SPEC.make(vcpus=1, ram_mb=2000, disk_gb=20),
    VM_SPEC.make(vcpus=2, ram_mb=4000, disk_gb=40),
    VM_SPEC.make(vcpus=4, ram_mb=8000, disk_gb=80),
]
K = 8
N_ZONES = 3


def _zoned_hosts(n: int, n_zones: int = N_ZONES):
    return [
        Host(
            name=f"h{i}", capacity=CAP, domain=f"dom{i % 2}",
            zone=f"z{i % n_zones}",
        )
        for i in range(n)
    ]


def _reloc_policy(**kw):
    kw.setdefault("cost_kind", "period")
    kw.setdefault("relocate_threshold", 0.05)
    return SchedulerPolicy(**kw)


def _seed_churn(fleet, term, up):
    """Overwrite the zone accumulators (ẑ = T / max(U, eps)) in place."""
    fleet.state = dataclasses.replace(
        fleet.state,
        zone_term=jnp.asarray(term, jnp.float32),
        zone_up=jnp.asarray(up, jnp.float32),
    )


# ---------------------------------------------------------------------------
# 1. exclusion-operand parity: jnp / fused / sharded / sharded+fused screens
# ---------------------------------------------------------------------------


def _filled_zoned_hosts(rng, n_hosts, fill=0.8):
    hosts = _zoned_hosts(n_hosts)
    iid = 0
    for h in hosts:
        while h.used().vec[0] < fill * CAP.vec[0]:
            size = SIZES[int(rng.integers(3))]
            if not size.fits_in(h.free_full):
                break
            pre = (
                bool(rng.random() < 0.6)
                and len(h.preemptible_instances()) < K
            )
            h.place(
                Instance(
                    id=f"x{iid}", resources=size, preemptible=pre,
                    host=h.name,
                    start_time=NOW - float(rng.integers(10, 500)) * 60.0,
                )
            )
            iid += 1
    return hosts


@pytest.mark.parametrize("seed", [0, 1])
def test_exclusion_decisions_bit_exact_across_screens(seed):
    """The relocation operand through all four screen backends: for every
    excluded zone (and the -1 no-exclusion sentinel) the full 6-tuple
    decision — host, slot, ok, kill mask, shortlist-health signals — is
    bitwise equal between the pure-jnp screen, the fused Pallas kernel
    (interpret mode), the sharded shard_map screen, and the sharded screen
    running the split-phase kernel per shard; a placed host is never in
    the excluded zone; and with the sentinel the relocation-ON program
    reproduces the relocation-OFF one bit-exactly (static gating)."""
    rng = np.random.default_rng(seed)
    n_hosts, m = 37, 8
    hosts = _filled_zoned_hosts(rng, n_hosts)
    zone_ids = {f"z{i}": i for i in range(N_ZONES)}
    mesh = fleet_mesh()
    state, _ = build_fleet_state(hosts, k_slots=K, zone_ids=zone_ids)
    padded = pad_fleet_state(
        state, padded_hosts(n_hosts, mesh.size, m_keep=m + 1)
    )
    sharded = shard_fleet_state(padded, mesh)
    host_zone = np.asarray(padded.host_zone)

    knobs = dict(cost_kind="period", shortlist=m, relocate_threshold=0.05)
    paths = {
        "jnp": (padded, SchedulerPolicy(**knobs, fused_screen=False)),
        "fused": (padded, SchedulerPolicy(**knobs, fused_screen=True)),
        "sharded": (sharded, SchedulerPolicy(**knobs, mesh=mesh)),
        "split": (
            sharded,
            SchedulerPolicy(**knobs, mesh=mesh, fused_screen=True),
        ),
    }
    off_policy = SchedulerPolicy(cost_kind="period", shortlist=m,
                                 fused_screen=False)

    step = 0
    for excl in (-1, 0, 1, 2):
        for pre in (True, False):
            req = np.asarray(SIZES[step % 3].vec, np.float32)
            now = NOW + 60.0 * step
            outs = {}
            for name, (st, pol) in paths.items():
                _, outs[name] = schedule_step(
                    st, req, pre, np.int32(-1), now, 1.0,
                    policy=pol, donate=False,
                    req_exclude_zone=np.int32(excl),
                )
            ref = outs["jnp"]
            for name, got in outs.items():
                for a, b in zip(ref, got):
                    np.testing.assert_array_equal(
                        np.asarray(a), np.asarray(b),
                        err_msg=f"excl={excl} pre={pre}: {name} != jnp",
                    )
            h, _, ok = int(ref[0]), ref[1], bool(ref[2])
            if ok and excl >= 0:
                assert host_zone[h] != excl, (
                    f"excl={excl} pre={pre}: placed into the excluded zone"
                )
            if excl < 0:
                _, off = schedule_step(
                    padded, req, pre, np.int32(-1), now, 1.0,
                    policy=off_policy, donate=False,
                )
                for a, b in zip(ref, off):
                    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            step += 1


def test_split_phase_kernel_parity_with_exclusion():
    """Kernel level: the split screen (``sched_screen_consts`` +
    ``sched_screen_topm``) with the zone operands emits exactly the fused
    single-kernel shortlist — scores, indices, and packed constants."""
    from repro.kernels.sched_screen import (
        sched_screen,
        sched_screen_consts,
        sched_screen_topm,
    )

    rng = np.random.default_rng(7)
    n, k, d = 150, K, 3
    a = dict(
        free_f=rng.integers(0, 9, (n, d)).astype(np.float32),
        free_n=rng.integers(2, 12, (n, d)).astype(np.float32),
        schedulable=rng.random(n) < 0.9,
        domain=rng.integers(0, 3, (n,)).astype(np.int32),
        slow=rng.integers(1, 5, (n,)).astype(np.float32),
        inst_res=rng.integers(0, 5, (n, k, d)).astype(np.float32),
        inst_cost=(rng.integers(0, 60, (n, k)) * 60).astype(np.float32),
        inst_valid=rng.random((n, k)) < 0.7,
    )
    host_zone = rng.integers(0, N_ZONES, (n,)).astype(np.int32)
    args = (
        a["free_f"], a["free_n"], a["schedulable"], a["domain"], a["slow"],
        a["inst_res"], a["inst_cost"], a["inst_valid"],
        np.asarray(SIZES[1].vec, np.float32), jnp.asarray(True),
        jnp.asarray(-1, jnp.int32),
    )
    for excl in (-1, 0, 2):
        kw = dict(
            weigher_multipliers=(1.0, 1.0, 0.0, 0.0),
            require_free_slot=True, interpret=True,
            host_zone=host_zone, exclude_zone=np.int32(excl),
        )
        ref_s, ref_i, ref_c = sched_screen(*args, m_keep=33, **kw)
        consts = sched_screen_consts(*args, **kw)
        np.testing.assert_array_equal(np.asarray(consts), np.asarray(ref_c))
        s, i = sched_screen_topm(*args, consts=consts, m_keep=33, **kw)
        np.testing.assert_array_equal(np.asarray(s), np.asarray(ref_s))
        np.testing.assert_array_equal(np.asarray(i), np.asarray(ref_i))
        if excl >= 0:
            live = np.asarray(ref_s) > -1e29
            assert not np.any(host_zone[np.asarray(ref_i)[live]] == excl)


# ---------------------------------------------------------------------------
# 2. hysteresis: arm above threshold, disarm below exit, cooldown gates re-arm
# ---------------------------------------------------------------------------


def test_hysteresis_arm_disarm_cooldown():
    policy = _reloc_policy(relocate_threshold=0.05, relocate_cooldown_s=300.0)
    assert policy.relocate_exit_threshold == pytest.approx(0.025)
    fleet = SoAFleet(_zoned_hosts(4, 2), k_slots=K, policy=policy)
    st = fleet.relocation

    # hot z0 (ẑ = 0.1): arms on the first pass
    _seed_churn(fleet, [10.0, 0.0], [100.0, 100.0])
    fleet.relocate(10.0)
    assert st.arms == 1 and fleet._reloc_zone["z0"].armed

    # ẑ = 0.04 — between exit (0.025) and threshold (0.05): stays armed
    _seed_churn(fleet, [4.0, 0.0], [100.0, 100.0])
    fleet.relocate(20.0)
    assert st.disarms == 0 and fleet._reloc_zone["z0"].armed

    # ẑ = 0.01 < exit: disarms and starts the cooldown
    _seed_churn(fleet, [1.0, 0.0], [100.0, 100.0])
    fleet.relocate(30.0)
    z = fleet._reloc_zone["z0"]
    assert st.disarms == 1 and not z.armed
    assert z.cooldown_until == pytest.approx(330.0)

    # hot again INSIDE the cooldown: must not re-arm (no thrash)
    _seed_churn(fleet, [10.0, 0.0], [100.0, 100.0])
    fleet.relocate(100.0)
    assert st.arms == 1 and not z.armed

    # past the cooldown: re-arms
    fleet.relocate(400.0)
    assert st.arms == 2 and fleet._reloc_zone["z0"].armed

    # the plane refuses to run on an off-policy (explicit, not silent)
    off = SoAFleet(
        _zoned_hosts(2, 2), k_slots=K,
        policy=SchedulerPolicy(cost_kind="period"),
    )
    with pytest.raises(RuntimeError, match="relocation plane is off"):
        off.relocate(0.0)


# ---------------------------------------------------------------------------
# 3. checkpoint-aware victim selection + per-pass budget
# ---------------------------------------------------------------------------


def _hot_cold_fleet(policy, n_hot=2, n_cold=2):
    """n_hot hosts in z0 (hot), n_cold in z1 (cold, empty)."""
    hosts = [
        Host(name=f"hot{i}", capacity=CAP, zone="z0") for i in range(n_hot)
    ] + [
        Host(name=f"cold{i}", capacity=CAP, zone="z1") for i in range(n_cold)
    ]
    return SoAFleet(hosts, k_slots=K, policy=policy)


def test_victims_ranked_by_expected_loss():
    """Budget 1 must take the victim whose reclaim would cost the most —
    the one whose last durable checkpoint is furthest behind."""
    fleet = _hot_cold_fleet(_reloc_policy(relocate_budget=1))
    ids = []
    for i in range(2):
        out = fleet.schedule_request(
            Request(id=f"p{i}", resources=SIZES[0], preemptible=True),
            now=0.0,
        )
        assert out.ok and out.host.startswith("hot")  # z0 wins the tie order
        ids.append(out.instance.id)
    # p0 checkpointed recently; p1 has 2000 s of unsaved work
    assert fleet.checkpoint(ids[0], 1000.0)
    _seed_churn(fleet, [10.0, 0.0], [100.0, 100.0])
    fleet.relocate(2000.0)
    assert fleet.relocation.relocated == 1
    assert ids[1] in fleet.relocated_ids  # the stale-checkpoint victim moved
    assert ids[0] in fleet.instances      # the fresh one stayed


def test_budget_bounds_evacuations_per_pass():
    fleet = _hot_cold_fleet(_reloc_policy(relocate_budget=2), n_hot=2, n_cold=4)
    for i in range(6):
        out = fleet.schedule_request(
            Request(
                id=f"p{i}", resources=SIZES[0], preemptible=True,
                # pin arrivals onto the hot zone so the fixture is exact
                metadata={},
            ),
            now=0.0,
        )
        assert out.ok
    in_hot = sum(
        1 for iid, (h, s) in fleet.locator.items()
        if s is not None and fleet.zones[h] == "z0"
    )
    assert in_hot >= 4  # enough victims that the budget binds
    _seed_churn(fleet, [10.0, 0.0], [100.0, 100.0])
    fleet.relocate(100.0)
    assert fleet.relocation.attempted == 2  # ≤ relocate_budget per pass
    assert fleet.relocation.relocated == 2
    # a second pass takes the next two — bounded, not starved
    fleet.relocate(200.0)
    assert fleet.relocation.attempted == 4


# ---------------------------------------------------------------------------
# 4. never-worse: failed re-placement leaves the victim, exponential backoff
# ---------------------------------------------------------------------------


def test_failed_replacement_leaves_victim_and_backs_off():
    """All hosts share the hot zone, so every re-placement is rejected
    (the source zone is hard-excluded): victims keep running, ``failed``
    counts every attempt, and the zone's retry gate doubles per pass."""
    policy = _reloc_policy(relocate_budget=1, relocate_backoff_s=30.0)
    fleet = SoAFleet(
        [Host(name=f"h{i}", capacity=CAP, zone="z0") for i in range(2)],
        k_slots=K, policy=policy,
    )
    out = fleet.schedule_request(
        Request(id="p", resources=SIZES[0], preemptible=True), now=0.0
    )
    assert out.ok
    iid = out.instance.id
    _seed_churn(fleet, [10.0], [100.0])
    st = fleet.relocation

    fleet.relocate(100.0)
    assert st.attempted == 1 and st.failed == 1 and st.relocated == 0
    assert iid in fleet.instances  # never-worse: the victim still runs
    z = fleet._reloc_zone["z0"]
    assert z.retry_at == pytest.approx(130.0)  # 100 + 30·2⁰

    # inside the backoff window: the armed zone does NOT retry
    fleet.relocate(110.0)
    assert st.attempted == 1

    # past the gate: retries, fails again, and the backoff doubles
    fleet.relocate(130.0)
    assert st.attempted == 2 and st.failed == 2
    assert fleet._reloc_zone["z0"].retry_at == pytest.approx(190.0)  # 30·2¹

    # checkpoint-before-place really ran (the never-worse ordering):
    # the surviving victim's recompute clock was reset at the latest attempt
    assert float(np.asarray(fleet.state.inst_ckpt).max()) == 130.0
    # and the fleet still conserves: one live instance, nothing preempted
    assert set(fleet.instances) == {iid} and not fleet.preempted


def test_preempt_instance_contract():
    """Out-of-band reclaim: already-gone ids are benign (False — storms and
    relocations race); a live NORMAL instance is a caller bug (raise)."""
    fleet = SoAFleet(_zoned_hosts(2, 2), k_slots=K,
                     policy=SchedulerPolicy(cost_kind="period"))
    assert fleet.preempt_instance("never-existed", now=1.0) is False
    out = fleet.schedule_request(
        Request(id="n", resources=SIZES[0], preemptible=False), now=0.0
    )
    assert out.ok
    with pytest.raises(ValueError, match="not preemptible"):
        fleet.preempt_instance(out.instance.id, now=1.0)
    assert out.instance.id in fleet.instances  # untouched by the refusal


def test_churn_snapshot_single_reader_matches_wrappers():
    """``churn_snapshot`` is ONE fused device reduction; its two halves are
    exactly what the legacy per-reader wrappers report."""
    fleet = SoAFleet(_zoned_hosts(6, 3), k_slots=K,
                     policy=SchedulerPolicy(cost_kind="period"))
    _seed_churn(fleet, [3.0, 0.0, 7.0], [60.0, 0.0, 140.0])
    rates, fleet_rate = fleet.churn_snapshot()
    assert rates == fleet.zone_rates()
    assert fleet_rate == fleet.fleet_churn_rate()
    np.testing.assert_allclose(rates["z0"], 3.0 / 60.0, rtol=1e-6)
    np.testing.assert_allclose(
        rates["z1"], np.float32(0.0) / CHURN_EPS, rtol=1e-6
    )
    np.testing.assert_allclose(fleet_rate, 10.0 / 200.0, rtol=1e-6)


# ---------------------------------------------------------------------------
# 5. chaos: conservation after every event, direct + streaming admission
# ---------------------------------------------------------------------------


def _assert_conserved(fleet):
    """No instance lost, duplicated, or double-billed: the python mirror,
    the locator, and the slot map agree; nothing is simultaneously live and
    preempted; and materializing hosts re-places every instance without a
    capacity violation (``Host.place`` raises on overflow)."""
    assert set(fleet.instances) == set(fleet.locator)
    slot_listed = {}
    for h, row in enumerate(fleet.slot_ids):
        for s, iid in enumerate(row):
            if iid is not None:
                assert iid not in slot_listed, f"{iid} in two slots"
                slot_listed[iid] = (h, s)
    pre_located = {
        iid: loc for iid, loc in fleet.locator.items() if loc[1] is not None
    }
    assert slot_listed == pre_located
    assert not {i.id for i in fleet.preempted} & set(fleet.instances)
    fleet.sync_hosts()


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_relocation_chaos_conserves_after_every_event(seed):
    """Randomized direct-mode chaos: arrivals (some carrying their own
    ``exclude_zone``), departures, out-of-band storm preemptions, host
    fail/heal, and periodic relocation passes — the conservation invariant
    holds after EVERY event, excluded zones are honored, and the relocation
    ledger balances (attempted = relocated + failed + lost + stale)."""
    rng = np.random.default_rng(seed)
    policy = _reloc_policy(
        relocate_threshold=0.005, relocate_budget=3, relocate_backoff_s=20.0,
        relocate_cooldown_s=100.0,
    )
    fleet = SoAFleet(_zoned_hosts(12, 3), k_slots=4, policy=policy)
    now, live = 0.0, []
    for step in range(250):
        now += float(rng.integers(1, 60))
        roll = rng.random()
        if roll < 0.5:  # --------------------------------------------- arrival
            excl = (
                f"z{rng.integers(N_ZONES)}" if rng.random() < 0.2 else None
            )
            out = fleet.schedule_request(
                Request(
                    id=f"r{step}", resources=SIZES[int(rng.integers(3))],
                    preemptible=bool(rng.random() < 0.7),
                    exclude_zone=excl,
                ),
                now,
            )
            if out.ok:
                if excl is not None:
                    assert fleet.zones[fleet.index[out.host]] != excl
                live.append(out.instance.id)
        elif roll < 0.62 and live:  # ------------------------------- departure
            iid = live.pop(int(rng.integers(len(live))))
            fleet.depart(iid, now=now)  # may be already gone — idempotent
        elif roll < 0.8:  # -------------------------- zone-correlated storm
            zone = f"z{rng.integers(N_ZONES)}"
            pre_ids = sorted(
                i for i, (h, s) in fleet.locator.items()
                if s is not None and fleet.zones[h] == zone
            )
            for iid in pre_ids[: int(rng.integers(1, 4))]:
                assert fleet.preempt_instance(iid, now=now)
        elif roll < 0.88:  # ---------------------------------------- fail/heal
            name = f"h{rng.integers(12)}"
            if bool(np.asarray(fleet.state.schedulable)[fleet.index[name]]):
                fleet.fail_host(name, now=now)
            else:
                fleet.heal_host(name)
        else:  # ------------------------------------------------ relocation
            fleet.relocate(now)
        _assert_conserved(fleet)

    st = fleet.relocation
    assert st.pending == 0  # direct mode settles synchronously
    assert st.attempted == st.relocated + st.failed + st.lost_victims + st.stale
    # the chaos actually exercised the plane
    assert st.passes > 0 and st.attempted > 0
    # every completed move is tracked for departure-id chasing
    assert len(fleet.relocated_ids) >= st.relocated > 0


def _storm_sim(relocate: bool, streaming: bool, seed: int = 11):
    """PR 7's seeded storm regime: z2 oscillates through churn storms
    (teaching ẑ), then one big storm sweeps it — with and without the
    evacuation plane on top of the churn-aware policy."""
    knobs = dict(
        cost_kind="period", churn_multiplier=2.0, churn_threshold=1e-4,
    )
    if streaming:
        knobs.update(queue_capacity=64, admit_batch=8, slo_target_s=30.0)
    if relocate:
        knobs.update(
            relocate_threshold=1e-4, relocate_every_s=60.0,
            relocate_budget=8, relocate_cooldown_s=600.0,
        )
    medium = VM_SPEC.make(vcpus=2, ram_mb=4000, disk_gb=40)
    spec = WorkloadSpec(
        arrival_rate_per_s=1 / 20.0,
        preemptible_fraction=1.0,
        flavors=(("medium", medium),),
    )
    sim = SoASimulator(
        _zoned_hosts(12, 3), spec, seed=seed, k_slots=4,
        policy=SchedulerPolicy(**knobs),
    )
    sim.inject_churn_regime(
        "z2", until_s=4000.0, mean_on_s=300.0, mean_off_s=800.0,
        storm_every_s=100.0, kill_frac=0.3, start_s=0.0,
    )
    sim.inject_zone_storm("z2", at_s=3500.0, kill_frac=1.0)
    return sim


@pytest.mark.parametrize("streaming", [False, True])
def test_evacuation_reduces_storm_kills(streaming):
    """Under the seeded storm regime the evacuated run loses no more
    instances to storms than the aware-but-stationary one, actually moves
    instances, never fails a user placement it would otherwise have made,
    and conserves the fleet — in both direct and streaming admission
    modes."""
    base = _storm_sim(relocate=False, streaming=streaming)
    m0 = base.run(4000.0)
    evac = _storm_sim(relocate=True, streaming=streaming)
    m1 = evac.run(4000.0)

    assert m1.relocations > 0 and m1.relocation_passes > 0
    assert m1.storm_kills <= m0.storm_kills
    assert m1.failures_normal == 0
    # with an all-preemptible workload and no host failures, storms are the
    # only involuntary kill source: every preempted record is a storm kill
    # (relocation moves are voluntary departures, never preemptions)
    assert len(evac.fleet.preempted) == m1.storm_kills
    _assert_conserved(evac.fleet)
    st = evac.fleet.relocation
    assert st.pending == 0  # the epilogue drain settled every in-flight move
    assert st.attempted == st.relocated + st.failed + st.lost_victims + st.stale
    # metrics fold mirrors the fleet ledger
    assert m1.relocations == st.relocated
    assert m1.relocation_failed == st.failed
    assert m1.relocation_lost == st.lost_victims


# ---------------------------------------------------------------------------
# 7. batched victim re-placement: one fused dispatch, decisions bit-exact
# ---------------------------------------------------------------------------
def test_batched_evacuation_one_dispatch_bit_exact(monkeypatch):
    """Direct-mode evacuation must run the whole victim batch as ONE
    ``relocate_many`` dispatch (no per-victim ``schedule_request``), and
    the fused scan's decisions must be bit-identical to the old
    per-victim checkpoint → re-place → terminate loop replayed
    sequentially on a clone fleet."""
    import repro.core.soa_fleet as sf

    policy = _reloc_policy(relocate_budget=4)

    def build():
        fleet = _hot_cold_fleet(policy, n_hot=2, n_cold=4)
        ids = []
        for i in range(6):
            out = fleet.schedule_request(
                Request(id=f"p{i}", resources=SIZES[i % 2], preemptible=True),
                now=0.0,
            )
            assert out.ok
            ids.append(out.instance.id)
        # stagger checkpoints so the loss ranking is nontrivial
        assert fleet.checkpoint(ids[0], 900.0)
        assert fleet.checkpoint(ids[2], 400.0)
        _seed_churn(fleet, [10.0, 0.0], [100.0, 100.0])
        return fleet, ids

    fleet, _ = build()
    calls = {"batch": 0, "per_victim": 0}
    real_many = sf.relocate_many

    def counting_many(*a, **kw):
        calls["batch"] += 1
        return real_many(*a, **kw)

    real_sr = SoAFleet.schedule_request

    def counting_sr(self, *a, **kw):
        calls["per_victim"] += 1
        return real_sr(self, *a, **kw)

    monkeypatch.setattr(sf, "relocate_many", counting_many)
    monkeypatch.setattr(SoAFleet, "schedule_request", counting_sr)
    now = 2000.0
    fleet.relocate(now)
    monkeypatch.undo()
    assert calls["batch"] == 1, "evacuation must be one fused dispatch"
    assert calls["per_victim"] == 0, "no per-victim dispatches allowed"
    assert fleet.relocation.attempted == 4
    assert fleet.relocation.relocated > 0

    # sequential oracle: the old loop, one victim at a time
    oracle, _ = build()
    hosts, slots, valid = sf._relocation_victims(
        oracle.state, jnp.int32(oracle.zone_ids["z0"]), jnp.float32(now),
        jnp.float32(policy.period), budget=4,
    )
    moved = {}
    for h, s, v in zip(np.asarray(hosts), np.asarray(slots), np.asarray(valid)):
        if not v:
            continue
        iid = oracle.slot_ids[int(h)][int(s)]
        inst = oracle.instances[iid]
        assert oracle.checkpoint(iid, now)
        out = oracle.schedule_request(
            Request(
                id=f"reloc-{iid}", resources=inst.resources, preemptible=True,
                user=inst.user, cost_kind=inst.cost_kind, period=inst.period,
                priority=0, exclude_zone="z0",
            ),
            now, price=inst.price_rate,
        )
        if out.ok:
            assert oracle.depart(iid, now=now)
            moved[iid] = out.instance.metadata.get("slot")

    # device state arrays bitwise equal between fused batch and oracle loop
    for f in dataclasses.fields(oracle.state):
        a = np.asarray(getattr(oracle.state, f.name))
        b = np.asarray(getattr(fleet.state, f.name))
        assert np.array_equal(a, b), f"state column {f.name} diverged"
    # and the move ledger agrees victim-for-victim
    assert set(fleet.relocated_ids) == set(moved)
    for iid, new_id in fleet.relocated_ids.items():
        h, s = fleet.locator[new_id]
        assert s == moved[iid]
    _assert_conserved(fleet)
