"""Interpret-mode validation of the ``sched_weigh`` Pallas kernel against the
pure-jnp oracle (``host_plan_terms``), swept over slot counts K∈{4,10,12},
host counts that are NOT multiples of the 128-host tile, and the gathered
shortlist entry point.

Inputs are integer-valued (the paper's workload regime) so f32 arithmetic is
exact and every comparison can be strict.
"""
from __future__ import annotations

import numpy as np
import pytest

import importlib

from repro.core import jax_scheduler
from repro.core.jax_scheduler import host_plan_terms, subset_masks
from repro.kernels.ops import TIE_EPS
from repro.kernels.sched_weigh import sched_weigh, sched_weigh_gathered


def _rand_soa(rng, n, k, d=3):
    """Random integer-valued SoA arrays: free space, padded slot rows, costs
    in whole minutes (all exactly representable in f32)."""
    free_f = rng.integers(0, 9, (n, d)).astype(np.float32)
    inst_res = rng.integers(1, 5, (n, k, d)).astype(np.float32)
    inst_valid = rng.random((n, k)) < 0.7
    inst_cost = (rng.integers(0, 60, (n, k)) * 60).astype(np.float32)
    req = rng.integers(2, 14, (d,)).astype(np.float32)
    return free_f, inst_res, inst_cost, inst_valid, req


@pytest.mark.parametrize("k", [4, 10, 12])
@pytest.mark.parametrize("n", [1, 37, 100, 130])
def test_sched_weigh_matches_oracle(k, n):
    if k == 12 and n > 100:
        n = 100  # keep the 4096-mask interpret sweep quick
    rng = np.random.default_rng(k * 1000 + n)
    free_f, inst_res, inst_cost, inst_valid, req = _rand_soa(rng, n, k)
    masks = subset_masks(k)

    ref_cost, ref_mask, ref_feas = host_plan_terms(
        free_f, inst_res, inst_cost, inst_valid, req, masks
    )
    k_cost, k_mask, k_feas = sched_weigh(
        free_f, inst_res, inst_cost, inst_valid, req, masks, interpret=True
    )
    np.testing.assert_array_equal(np.asarray(ref_feas), np.asarray(k_feas))
    np.testing.assert_array_equal(np.asarray(ref_mask), np.asarray(k_mask))
    feas = np.asarray(ref_feas)
    np.testing.assert_array_equal(
        np.asarray(k_cost)[feas], np.asarray(ref_cost)[feas]
    )


@pytest.mark.parametrize("k", [4, 10])
@pytest.mark.parametrize("m", [1, 5, 16, 33])
def test_gathered_entry_matches_oracle(k, m):
    """The shortlist entry point (small gathered candidate sets, sub-128
    tiles) must agree with the oracle exactly, like the full-fleet path."""
    rng = np.random.default_rng(k * 100 + m)
    free_f, inst_res, inst_cost, inst_valid, req = _rand_soa(rng, 200, k)
    cand = rng.choice(200, size=m, replace=False)
    masks = subset_masks(k)

    ref = host_plan_terms(
        free_f[cand], inst_res[cand], inst_cost[cand], inst_valid[cand],
        req, masks,
    )
    got = sched_weigh_gathered(
        free_f[cand], inst_res[cand], inst_cost[cand], inst_valid[cand],
        req, masks, interpret=True,
    )
    np.testing.assert_array_equal(np.asarray(ref[2]), np.asarray(got[2]))
    np.testing.assert_array_equal(np.asarray(ref[1]), np.asarray(got[1]))
    feas = np.asarray(ref[2])
    np.testing.assert_array_equal(
        np.asarray(got[0])[feas], np.asarray(ref[0])[feas]
    )


def test_tie_epsilon_single_source():
    """The enumeration tie-break epsilon is ONE constant in kernels/ops.py:
    the Pallas kernel and the jnp oracle must reference it, not private
    copies that can drift."""
    # the function re-export shadows the submodule on the package, so
    # resolve the module object explicitly
    sched_weigh_mod = importlib.import_module("repro.kernels.sched_weigh")
    assert sched_weigh_mod.TIE_EPS is TIE_EPS
    assert jax_scheduler.TIE_EPS is TIE_EPS


@pytest.mark.parametrize("gap_frac,want_mask", [(0.5, 0b001), (2.0, 0b110)])
def test_tie_epsilon_boundary_identical_on_both_paths(gap_frac, want_mask):
    """A cost gap just INSIDE the epsilon makes the 1-slot plan tie with the
    cheaper 2-slot plan and win on size; just OUTSIDE, the cheap 2-slot plan
    wins outright.  Kernel and oracle must flip at the same boundary.

    Geometry (D=1, K=3): req needs 4; slot 0 frees 4 alone (cost 10+gap),
    slots {1, 2} free 4 together (cost 5+5=10, the minimum)."""
    masks = subset_masks(3)
    free_f = np.zeros((1, 1), np.float32)
    inst_res = np.array([[[4.0], [2.0], [2.0]]], np.float32)
    inst_valid = np.ones((1, 3), bool)
    req = np.array([4.0], np.float32)
    inst_cost = np.array([[10.0 + gap_frac * TIE_EPS, 5.0, 5.0]], np.float32)

    ref_cost, ref_mask, ref_feas = host_plan_terms(
        free_f, inst_res, inst_cost, inst_valid, req, masks
    )
    k_cost, k_mask, k_feas = sched_weigh(
        free_f, inst_res, inst_cost, inst_valid, req, masks, interpret=True
    )
    assert bool(ref_feas[0]) and bool(k_feas[0])
    assert float(ref_cost[0]) == float(k_cost[0]) == 10.0
    assert int(ref_mask[0]) == int(k_mask[0]) == want_mask


def test_all_slots_invalid_host():
    """Hosts with zero valid slots are feasible iff the request fits as-is."""
    k = 4
    free_f = np.array([[4.0, 4.0, 4.0], [1.0, 1.0, 1.0]], np.float32)
    inst_res = np.zeros((2, k, 3), np.float32)
    inst_cost = np.zeros((2, k), np.float32)
    inst_valid = np.zeros((2, k), bool)
    req = np.array([2.0, 2.0, 2.0], np.float32)
    masks = subset_masks(k)
    cost, mask, feas = sched_weigh(
        free_f, inst_res, inst_cost, inst_valid, req, masks, interpret=True
    )
    np.testing.assert_array_equal(np.asarray(feas), [True, False])
    assert float(cost[0]) == 0.0 and int(mask[0]) == 0
