"""Unit tests for the loop-aware HLO cost parser (the roofline's foundation)."""
from __future__ import annotations

import pytest

from repro.launch.hlo_stats import HloCost

HLO = """\
HloModule test

%body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
  %w = f32[16,16]{1,0} constant({...})
  %mm = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16]{1,0} all-reduce(%mm), replica_groups=[16,16]<=[256], to_apply=%sum
  ROOT %t = (s32[], f32[8,16]) tuple(%i, %ar)
}

%cond (p: (s32[], f32[8,16])) -> pred[] {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[8,16]) -> f32[8,16] {
  %a = f32[8,16]{1,0} parameter(0)
  %init = (s32[], f32[8,16]) tuple(%a)
  %w2 = (s32[], f32[8,16]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
  %ag = f32[128,16]{1,0} all-gather(%a), replica_groups=[16,16]<=[256], dimensions={0}
  ROOT %out = f32[8,16]{1,0} get-tuple-element(%w2), index=1
}
"""


def test_while_body_scaled_by_trip_count():
    t = HloCost(HLO, 256).total()
    # dot: 2 * 8*16 * 16 = 4096 flops, x10 trips
    assert t.flops == pytest.approx(4096 * 10)


def test_collective_conventions_and_scaling():
    t = HloCost(HLO, 256).total()
    # all-reduce in the loop: 2*(16-1)/16 * 8*16*4 bytes, x10
    ar = 2 * 15 / 16 * 8 * 16 * 4 * 10
    # all-gather outside: (16-1)/16 * result(128*16*4)
    ag = 15 / 16 * 128 * 16 * 4
    assert t.coll_by_kind["all-reduce"] == pytest.approx(ar)
    assert t.coll_by_kind["all-gather"] == pytest.approx(ag)
    assert t.coll_counts["all-reduce"] == 10
    assert t.collective_bytes == pytest.approx(ar + ag)


def test_replica_group_iota_parsing():
    from repro.launch.hlo_stats import _group_size

    assert _group_size("replica_groups=[16,16]<=[256]", 999) == 16
    assert _group_size("replica_groups={{0,1,2,3}}", 999) == 4
    assert _group_size("no groups here", 7) == 7


def test_memory_traffic_counts_top_level_only():
    t = HloCost(HLO, 256).total()
    # loop body: dot (result 512B + operands 512+1024) + all-reduce result
    # (512) per trip; entry: all-gather result + while init tuple is
    # no-traffic (tuple), GTE/parameter skipped.
    assert t.bytes > 0
    per_trip = (512 + 512 + 1024) + 512
    assert t.bytes >= per_trip * 10
