"""Device-sharded screen parity: decisions taken with the fleet partitioned
host-major across a device mesh (``mesh=`` on ``schedule_decision`` /
``schedule_step`` / ``schedule_many`` / ``SoAFleet``) must be BIT-IDENTICAL
to the unsharded oracle — including fleets whose host count does not divide
the shard count (padding), fallback-triggering fleets (the ``lax.cond`` full
enumeration on sharded arrays), and mass-tied fleets where everything rides
on the cross-shard merge reproducing ``lax.top_k``'s tie ordering.

Run with forced host devices to exercise real sharding on CPU:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m pytest tests/test_sharded_parity.py

CI's multi-device job does exactly that and treats any skip as a failure
(see .github/workflows/ci.yml); on a single-device run the shard_map cases
skip and only the pure-math merge tests run.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fleet_sharding import (
    fleet_mesh,
    merge_shortlists,
    pad_fleet_state,
    padded_hosts,
    shard_fleet_state,
)
from repro.core.jax_scheduler import (
    build_fleet_state,
    build_soa_state,
    schedule_decision,
    schedule_many,
    schedule_step,
)
from repro.core.cost import MixedCost, PeriodCost, RevenueCost
from repro.core.policy import SchedulerPolicy
from repro.core.screen_math import NEG_INF
from repro.core.soa_fleet import SoAFleet
from repro.core.types import VM_SPEC, Host, Instance, Request

NOW = 500_000.0
CAP = VM_SPEC.make(vcpus=8, ram_mb=16000, disk_gb=160)
SIZES = [
    VM_SPEC.make(vcpus=1, ram_mb=2000, disk_gb=20),
    VM_SPEC.make(vcpus=2, ram_mb=4000, disk_gb=40),
    VM_SPEC.make(vcpus=4, ram_mb=8000, disk_gb=80),
]

multi_device = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs >1 device (XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)


def _random_fleet(rng, n_hosts, fill=0.85, k_max=8):
    hosts = []
    iid = 0
    for i in range(n_hosts):
        h = Host(name=f"h{i}", capacity=CAP)
        while h.used().vec[0] < fill * CAP.vec[0]:
            size = SIZES[int(rng.integers(3))]
            if not size.fits_in(h.free_full):
                break
            pre = bool(rng.random() < 0.6) and len(h.preemptible_instances()) < k_max
            h.place(
                Instance(
                    id=f"x{iid}",
                    resources=size,
                    preemptible=pre,
                    host=h.name,
                    start_time=NOW - float(rng.integers(10, 500)) * 60.0,
                )
            )
            iid += 1
        hosts.append(h)
    return hosts


def _sharded_pair(hosts, m, k_slots=8):
    """(padded unsharded state, sharded state, mesh) for the full mesh."""
    mesh = fleet_mesh()
    state, _ = build_fleet_state(hosts, k_slots=k_slots)
    padded = pad_fleet_state(
        state, padded_hosts(len(hosts), mesh.size, m_keep=m + 1)
    )
    return padded, shard_fleet_state(padded, mesh), mesh


# ---------------------------------------------------------------------------
# Cross-shard merge vs lax.top_k — pure array math, runs on any device count
# ---------------------------------------------------------------------------


def _forward_shards(omega: np.ndarray, n_shards: int, m: int):
    """What each shard emits (exactly ``_sharded_screen``'s per-shard logic,
    replayed in numpy): local top-M via lax.top_k + the masked-argmax
    witness, tagged with global indices."""
    t = len(omega) // n_shards
    scores, idxs = [], []
    for s in range(n_shards):
        blk = omega[s * t : (s + 1) * t]
        s_loc, p_loc = jax.lax.top_k(jnp.asarray(blk), m)
        s_loc, p_loc = np.asarray(s_loc), np.asarray(p_loc)
        mask = np.zeros(t, bool)
        mask[p_loc] = True
        out = np.where(mask, np.float32(NEG_INF), blk)
        scores.append(np.concatenate([s_loc, [out.max()]]))
        idxs.append(np.concatenate([p_loc, [out.argmax()]]) + s * t)
    return (
        np.concatenate(scores).astype(np.float32),
        np.concatenate(idxs).astype(np.int32),
    )


def _oracle(omega: np.ndarray, m: int):
    """The unsharded selection: lax.top_k shortlist + masked-argmax witness."""
    _, cand = jax.lax.top_k(jnp.asarray(omega), m)
    cand = np.asarray(cand)
    mask = np.zeros(len(omega), bool)
    mask[cand] = True
    out = np.where(mask, np.float32(NEG_INF), omega)
    return cand, np.float32(out.max()), np.int32(out.argmax())


@pytest.mark.parametrize("seed", range(8))
@pytest.mark.parametrize("n_shards,m", [(2, 4), (4, 8), (8, 16)])
def test_merge_preserves_topk_tie_ordering(seed, n_shards, m):
    """Regression: the merged shortlist must list hosts in exactly
    ``lax.top_k``'s order — value descending, ties by ascending index —
    and yield the identical (u, j_u) witness.  Scores are drawn from a
     4-value set so ties dominate (the regime where a sloppy merge breaks)."""
    rng = np.random.default_rng(seed)
    t = max(m + 1, 12)
    omega = rng.choice(
        np.asarray([NEG_INF, 0.25, 0.5, 1.0], np.float32), n_shards * t
    )
    scores, idxs = _forward_shards(omega, n_shards, m)
    cand, u, j_u = merge_shortlists(jnp.asarray(scores), jnp.asarray(idxs), m)
    ref_cand, ref_u, ref_ju = _oracle(omega, m)
    np.testing.assert_array_equal(np.asarray(cand), ref_cand)
    assert np.float32(u) == ref_u
    # j_u is decision-relevant only when u is a real score (see
    # _decision_core's admissibility predicate): at u == NEG_INF the
    # unsharded masked argmax may surface an in-shortlist index while the
    # merge returns the best true outsider — both inert.
    if ref_u > NEG_INF / 2:
        assert int(j_u) == ref_ju


def test_merge_drops_duplicate_witness():
    """A shard whose hosts ALL sit in its local top-M re-emits one of them
    (at NEG_INF) as its witness; the dedup pass must drop the duplicate so
    the merged shortlist stays duplicate-free like lax.top_k's."""
    omega = np.asarray([NEG_INF] * 4 + [1.0, 0.5, NEG_INF, NEG_INF], np.float32)
    scores, idxs = _forward_shards(omega, n_shards=2, m=4)
    assert len(np.unique(idxs)) < len(idxs)  # the degenerate shard duplicated
    cand, _, _ = merge_shortlists(jnp.asarray(scores), jnp.asarray(idxs), 4)
    cand = np.asarray(cand)
    assert len(np.unique(cand)) == len(cand)
    np.testing.assert_array_equal(cand, _oracle(omega, 4)[0])


# ---------------------------------------------------------------------------
# Padding invariance — single device is enough
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("preemptible", [False, True])
def test_padded_state_decisions_unchanged(preemptible):
    """All-zero padding rows are invalid everywhere, so decisions on a padded
    state are bit-identical to the unpadded ones (the property that makes
    N-not-divisible-by-S fleets shardable at all)."""
    rng = np.random.default_rng(3)
    hosts = _random_fleet(rng, 21)
    state, _ = build_soa_state(hosts, NOW, PeriodCost(), k_slots=8)
    padded = pad_fleet_state(state, 40)
    req = jnp.asarray(SIZES[1].vec, jnp.float32)
    for m in (0, 4, 16):
        pol = SchedulerPolicy(shortlist=m)
        a = schedule_decision(state, req, preemptible, -1, policy=pol)
        b = schedule_decision(padded, req, preemptible, -1, policy=pol)
        assert tuple(map(int, a)) == tuple(map(int, b))


# ---------------------------------------------------------------------------
# Sharded vs unsharded decisions — shard_map across forced host devices
# ---------------------------------------------------------------------------


@multi_device
@pytest.mark.parametrize("n_hosts", [37, 64, 101])  # 37/101 ∤ any shard count
@pytest.mark.parametrize("m", [8, 16])
@pytest.mark.parametrize("fused", [False, True])
def test_sharded_step_parity(n_hosts, m, fused):
    """schedule_step: all six outputs (decision + kill mask + health
    signals) bit-equal between the sharded and unsharded screens, across
    fleets whose size does and does not divide the mesh.  ``fused=True``
    runs the per-shard screen through the split Pallas kernel (interpret
    mode on CPU) — the kernel+mesh combination that used to be mutually
    exclusive."""
    rng = np.random.default_rng(n_hosts)
    padded, sharded, mesh = _sharded_pair(_random_fleet(rng, n_hosts), m)
    for step, pre in ((0, False), (1, True), (2, False)):
        req = np.asarray(SIZES[step % 3].vec, np.float32)
        _, ref = schedule_step(
            padded, req, pre, np.int32(-1), NOW + 60.0 * step, 1.0,
            policy=SchedulerPolicy(shortlist=m), donate=False,
        )
        _, got = schedule_step(
            sharded, req, pre, np.int32(-1), NOW + 60.0 * step, 1.0,
            policy=SchedulerPolicy(
                shortlist=m, mesh=mesh, fused_screen=fused or None
            ),
            donate=False,
        )
        for a, b in zip(ref, got):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@multi_device
def test_sharded_many_parity_and_state():
    """schedule_many: the scan carries the sharded state through decide +
    apply; outputs AND the final state arrays must match the unsharded run
    bitwise (the transitions run on sharded buffers via GSPMD)."""
    rng = np.random.default_rng(17)
    padded, sharded, mesh = _sharded_pair(_random_fleet(rng, 50), 8)
    b = 12
    res = np.stack(
        [np.asarray(SIZES[i % 3].vec, np.float32) for i in range(b)]
    )
    pre = np.asarray([i % 2 == 0 for i in range(b)])
    dom = np.full((b,), -1, np.int32)
    now = NOW + 60.0 * np.arange(b, dtype=np.float32)
    price = np.ones((b,), np.float32)
    ref_state, ref = schedule_many(
        padded, res, pre, dom, now, price,
        policy=SchedulerPolicy(shortlist=8), donate=False,
    )
    got_state, got = schedule_many(
        sharded, res, pre, dom, now, price,
        policy=SchedulerPolicy(shortlist=8, mesh=mesh), donate=False,
    )
    for a, c in zip(ref, got):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))
    for a, c in zip(
        jax.tree_util.tree_leaves(ref_state),
        jax.tree_util.tree_leaves(got_state),
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))


@multi_device
def test_sharded_fallback_parity():
    """The loose-bound fixture from test_shortlist_parity, sharded: host A's
    cost lower bound undershoots (cheap slots conflict across dims), a
    1-candidate shortlist picks A optimistically, and the admissibility
    check must take the lax.cond full-enumeration branch — on SHARDED
    arrays — landing on the true winner B."""
    mesh = fleet_mesh()
    from repro.core.jax_scheduler import SoAHostState

    free_f = np.zeros((2, 2), np.float32)
    free_n = np.full((2, 2), 4.0, np.float32)
    inst_res = np.array(
        [[[4, 0], [0, 4], [4, 4]], [[4, 4], [0, 0], [0, 0]]], np.float32
    )
    inst_cost = np.array([[10, 10, 50], [15, 0, 0]], np.float32)
    inst_valid = np.array([[1, 1, 1], [1, 0, 0]], bool)
    state = SoAHostState(
        free_f=jnp.asarray(free_f),
        free_n=jnp.asarray(free_n),
        schedulable=jnp.ones((2,), bool),
        domain=jnp.zeros((2,), jnp.int32),
        slow=jnp.ones((2,), jnp.float32),
        inst_res=jnp.asarray(inst_res),
        inst_cost=jnp.asarray(inst_cost),
        inst_valid=jnp.asarray(inst_valid),
    )
    padded = pad_fleet_state(state, padded_hosts(2, mesh.size, m_keep=2))
    sharded = shard_fleet_state(padded, mesh)
    req = jnp.asarray([4.0, 4.0], jnp.float32)
    ref = schedule_decision(
        padded, req, False, -1, policy=SchedulerPolicy(shortlist=1)
    )
    for fused in (None, True):
        got = schedule_decision(
            sharded, req, False, -1,
            policy=SchedulerPolicy(shortlist=1, mesh=mesh, fused_screen=fused),
        )
        assert tuple(map(int, got)) == tuple(map(int, ref)), f"fused={fused}"
    assert int(ref[0]) == 1 and bool(ref[2])  # B's single 15-cost slot wins


@multi_device
def test_sharded_fleet_end_to_end():
    """SoAFleet(mesh=...): padding + placement at build, sharded decisions,
    donation, and python bookkeeping — outcome-for-outcome equal to the
    unsharded fleet over a mixed schedule/depart/fail/batch run.  Also
    exercises non-integer slot costs (RevenueCost) where the admissibility
    tolerance is live."""
    rng = np.random.default_rng(23)
    hosts = _random_fleet(rng, 43)
    plain = SoAFleet(
        hosts, cost_fn=RevenueCost(), k_slots=8,
        policy=SchedulerPolicy.for_cost(RevenueCost(), shortlist=8),
    )
    sharded = SoAFleet(
        _random_fleet(np.random.default_rng(23), 43),
        cost_fn=RevenueCost(), k_slots=8,
        policy=SchedulerPolicy.for_cost(
            RevenueCost(), shortlist=8, mesh=fleet_mesh()
        ),
    )
    assert sharded.state.n_hosts % sharded.mesh.size == 0

    def drive(fleet):
        log = []
        out = fleet.schedule_batch(
            [
                (
                    Request(
                        id=f"r{i}", resources=SIZES[i % 3],
                        preemptible=bool(i % 2),
                    ),
                    NOW + 60.0 * i,
                    1.0,
                )
                for i in range(10)
            ]
        )
        log += [(o.host, o.ok, tuple(v.id for v in o.victims)) for o in out]
        placed = next(o for o in out if o.ok)
        fleet.depart(placed.instance.id)
        fleet.fail_host("h3")
        o = fleet.schedule_request(
            Request(id="rx", resources=SIZES[2], preemptible=False),
            NOW + 3600.0,
        )
        log.append((o.host, o.ok, tuple(v.id for v in o.victims)))
        log.append(round(fleet.utilization(), 6))
        return log

    assert drive(plain) == drive(sharded)


@multi_device
@pytest.mark.parametrize("fused", [False, True])
def test_sharded_mixed_cost_parity(fused):
    """Heterogeneous billing on the sharded path: a fleet mixing all four
    cost kinds (per-instance ``cost_kind``) must make bit-identical
    decisions sharded vs unsharded — the kind-table select runs upstream of
    the screen, so sharding (and the per-shard fused kernel) must be
    transparent to it."""
    kinds = ("period", "count", "revenue", "recompute")
    rng = np.random.default_rng(77)
    hosts = _random_fleet(rng, 41)
    for h in hosts:
        for inst in h.preemptible_instances():
            inst.cost_kind = kinds[int(rng.integers(4))]
            inst.last_checkpoint = inst.start_time + 120.0
    policy = SchedulerPolicy.for_cost(
        MixedCost(default="period", kinds=kinds), shortlist=8
    )
    mesh = fleet_mesh()
    state, _ = build_fleet_state(hosts, k_slots=8)
    padded = pad_fleet_state(state, padded_hosts(41, mesh.size, m_keep=9))
    sharded = shard_fleet_state(padded, mesh)
    for step, pre in ((0, False), (1, True), (2, False)):
        req = np.asarray(SIZES[step % 3].vec, np.float32)
        kind = np.int32(step % 4)
        _, ref = schedule_step(
            padded, req, pre, np.int32(-1), NOW + 60.0 * step, 1.0,
            policy=policy, req_cost_kind=kind, donate=False,
        )
        _, got = schedule_step(
            sharded, req, pre, np.int32(-1), NOW + 60.0 * step, 1.0,
            policy=dataclasses.replace(
                policy, mesh=mesh, fused_screen=fused or None
            ),
            req_cost_kind=kind, donate=False,
        )
        for a, b in zip(ref, got):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the mixed column actually varies (otherwise this test is vacuous)
    col = np.asarray(padded.inst_cost_kind)[np.asarray(padded.inst_valid)]
    assert len(np.unique(col)) == 4


@multi_device
@pytest.mark.parametrize("fused", [False, True])
def test_sharded_churn_parity(fused):
    """Failure-domain plane, sharded: zone accumulators are replicated
    across the mesh while ``host_zone`` shards host-major, and churn-aware
    decisions (weigher term + hot-zone threshold) must stay bit-identical
    to the unsharded screen — including the per-shard churn-normalization
    folds crossing the pmin/pmax merge."""
    rng = np.random.default_rng(29)
    hosts = _random_fleet(rng, 39)  # 39 does not divide the mesh
    for i, h in enumerate(hosts):
        h.zone = f"z{i % 3}"
    mesh = fleet_mesh()
    # seeded accumulator history: z0 cold, z1 warm, z2 hot (ẑ = 0.5)
    state, _ = build_fleet_state(
        hosts, k_slots=8,
        zone_term=np.asarray([0.0, 8.0, 32.0], np.float32),
        zone_up=np.asarray([64.0, 64.0, 64.0], np.float32),
    )
    padded = pad_fleet_state(state, padded_hosts(39, mesh.size, m_keep=9))
    sharded = shard_fleet_state(padded, mesh)
    np.testing.assert_array_equal(  # zone plane survives pad + shard
        np.asarray(sharded.zone_term), np.asarray(state.zone_term)
    )
    policy = SchedulerPolicy(
        shortlist=8, churn_multiplier=2.0, churn_threshold=0.25
    )
    for step, pre in ((0, False), (1, True), (2, False)):
        req = np.asarray(SIZES[step % 3].vec, np.float32)
        _, ref = schedule_step(
            padded, req, pre, np.int32(-1), NOW + 60.0 * step, 1.0,
            policy=policy, donate=False,
        )
        _, got = schedule_step(
            sharded, req, pre, np.int32(-1), NOW + 60.0 * step, 1.0,
            policy=dataclasses.replace(
                policy, mesh=mesh, fused_screen=fused or None
            ),
            donate=False,
        )
        for a, b in zip(ref, got):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b), err_msg=f"step {step}"
            )


@multi_device
def test_sharded_simulator_smoke():
    """SoASimulator(mesh=...) runs the whole event loop on the sharded state
    and produces identical metrics to the unsharded simulator (same seed ⇒
    same rng stream ⇒ decisions must agree for the runs to align)."""
    from repro.core import SoASimulator, WorkloadSpec, make_uniform_fleet

    node = VM_SPEC.make(vcpus=8, ram_mb=16000, disk_gb=10_000)
    workload = WorkloadSpec(
        arrival_rate_per_s=0.05,
        preemptible_fraction=0.6,
        flavors=(("small", SIZES[0]), ("medium", SIZES[1])),
        flavor_probs=(0.5, 0.5),
    )
    runs = []
    for mesh in (None, fleet_mesh()):
        sim = SoASimulator(
            make_uniform_fleet(44, node), workload, seed=5,
            cost_fn=PeriodCost(), k_slots=8,
            policy=SchedulerPolicy(shortlist=8, mesh=mesh),
        )
        summary = sim.run(1800.0).summary()
        # sched_latency_* are wall-clock timings — everything else is a pure
        # function of the decisions and must match exactly.
        runs.append(
            {k: v for k, v in summary.items() if "latency" not in k}
        )
    assert runs[0] == runs[1]
