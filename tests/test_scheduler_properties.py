"""Property-based tests (hypothesis) for the scheduler's invariants."""
from __future__ import annotations

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.cluster import Cluster
from repro.core.cost import CountCost, PeriodCost
from repro.core.scheduler import PreemptibleScheduler, RetryScheduler
from repro.core.select_terminate import best_plan
from repro.core.types import VM_SPEC, Host, Instance, Request

NOW = 1_000_000.0
CAP = VM_SPEC.make(vcpus=8, ram_mb=16000, disk_gb=160)
FLAVORS = [
    VM_SPEC.make(vcpus=1, ram_mb=2000, disk_gb=20),
    VM_SPEC.make(vcpus=2, ram_mb=4000, disk_gb=40),
    VM_SPEC.make(vcpus=4, ram_mb=8000, disk_gb=80),
]


@st.composite
def fleets(draw, max_hosts=8):
    n = draw(st.integers(1, max_hosts))
    hosts = []
    iid = 0
    for i in range(n):
        h = Host(name=f"h{i}", capacity=CAP)
        for _ in range(draw(st.integers(0, 5))):
            fl = FLAVORS[draw(st.integers(0, 2))]
            if not fl.fits_in(h.free_full):
                break
            h.place(Instance(
                id=f"x{iid}",
                resources=fl,
                preemptible=draw(st.booleans()),
                host=h.name,
                start_time=NOW - draw(st.integers(1, 500)) * 60.0,
            ))
            iid += 1
        hosts.append(h)
    return hosts


@st.composite
def requests(draw):
    return Request(
        id="q", resources=FLAVORS[draw(st.integers(0, 2))],
        preemptible=draw(st.booleans()),
    )


@given(fleets(), requests())
@settings(max_examples=60, deadline=None)
def test_success_iff_view_fits(hosts, req):
    """The paper's dual-state guarantee: a request is schedulable exactly
    when it fits the view-appropriate free resources of some host."""
    sched = PreemptibleScheduler(cost_fn=PeriodCost())
    res = sched.schedule(req, hosts, NOW)
    view = (lambda h: h.free_full) if req.preemptible else (lambda h: h.free_normal)
    expected = any(req.resources.fits_in(view(h)) for h in hosts)
    assert res.ok == expected


@given(fleets(), requests())
@settings(max_examples=60, deadline=None)
def test_plan_only_contains_preemptible_from_winner(hosts, req):
    sched = PreemptibleScheduler(cost_fn=PeriodCost())
    res = sched.schedule(req, hosts, NOW)
    if not res.ok:
        return
    winner = next(h for h in hosts if h.name == res.host)
    for inst in res.plan.instances:
        assert inst.preemptible
        assert inst.id in winner.instances


@given(fleets(), requests())
@settings(max_examples=60, deadline=None)
def test_apply_never_overcommits(hosts, req):
    """After evacuation + placement, no host has negative free resources."""
    cluster = Cluster(hosts)
    sched = PreemptibleScheduler(cost_fn=PeriodCost())
    cluster.schedule_and_place(sched, req, NOW)
    for h in cluster.hosts.values():
        assert not h.free_full.any_negative()


@given(fleets(), requests())
@settings(max_examples=40, deadline=None)
def test_retry_agrees_with_single_pass_on_feasibility(hosts, req):
    """The retry design reaches the same feasibility verdict — it just pays
    a second cycle for it (the paper's Fig. 2 point)."""
    a = PreemptibleScheduler(cost_fn=PeriodCost()).schedule(req, hosts, NOW)
    b = RetryScheduler(cost_fn=PeriodCost()).schedule(req, hosts, NOW)
    assert a.ok == b.ok


@given(fleets())
@settings(max_examples=40, deadline=None)
def test_dual_state_dominance(hosts):
    """h_n free resources always dominate h_f (preemptible usage ≥ 0)."""
    for h in hosts:
        assert h.free_full <= h.free_normal


@given(fleets(), requests())
@settings(max_examples=40, deadline=None)
def test_best_plan_is_cost_minimal(hosts, req):
    """Alg. 5 exact enumeration returns THE minimum-cost feasible subset
    (verified against an independent brute force)."""
    import itertools

    cost_fn = PeriodCost()
    for h in hosts:
        plan = best_plan(h, req, cost_fn, NOW)
        pre = h.preemptible_instances()
        # brute force
        best = None
        free = h.free_full
        if req.resources.fits_in(free):
            best = 0.0
        else:
            need = np.maximum((req.resources - free).vec, 0.0)
            for r in range(1, len(pre) + 1):
                for combo in itertools.combinations(pre, r):
                    freed = np.sum([i.resources.vec for i in combo], axis=0)
                    if np.all(freed >= need - 1e-9):
                        c = cost_fn.cost(combo, NOW)
                        if best is None or c < best - 1e-9:
                            best = c
        if best is None:
            assert not plan.feasible
        else:
            assert plan.feasible
            assert plan.cost == pytest.approx(best, abs=1e-6)


@given(fleets(), requests())
@settings(max_examples=30, deadline=None)
def test_count_cost_minimizes_cardinality(hosts, req):
    """With CountCost, the plan terminates the fewest possible instances."""
    for h in hosts:
        plan = best_plan(h, req, CountCost(), NOW)
        if plan.feasible and plan.instances:
            assert plan.cost == len(plan.instances)
