"""Per-architecture smoke tests: reduced config, one train forward/backward
step and two decode steps on CPU — asserts shapes and finiteness (no NaNs).
Full configs are exercised only via the dry run (ShapeDtypeStructs).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduced
from repro.models.model import (
    decode_step,
    forward_train,
    init_decode_state,
    init_params,
)

B, S = 2, 32


def make_batch(cfg, key):
    kt, kl = jax.random.split(key)
    batch = {
        "tokens": jax.random.randint(kt, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(kl, (B, S), 0, cfg.vocab_size),
    }
    if cfg.modality == "vision_stub":
        batch["patch_embeds"] = jax.random.normal(
            kt, (B, cfg.n_prefix_tokens, cfg.d_model), jnp.float32
        )
    if cfg.encoder_decoder:
        batch["frame_embeds"] = jax.random.normal(
            kt, (B, S, cfg.d_model), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = reduced(get_config(arch))
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = make_batch(cfg, jax.random.PRNGKey(1))

    def loss_fn(p):
        loss, metrics = forward_train(cfg, p, batch)
        return loss, metrics

    (loss, metrics), grads = jax.jit(jax.value_and_grad(loss_fn, has_aux=True))(params)
    assert np.isfinite(float(loss)), f"{arch}: loss={loss}"
    assert float(loss) > 0
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    )
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0, f"{arch}: grad norm {gnorm}"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step_smoke(arch):
    cfg = reduced(get_config(arch))
    params = init_params(cfg, jax.random.PRNGKey(0))
    state = init_decode_state(cfg, batch=B, max_len=S, dtype=jnp.float32, enc_len=S)
    if cfg.encoder_decoder:  # prime cross-attention caches with stub encoder KV
        from repro.models.attention import encode_cross_kv
        from repro.models.model import _cast, _encoder_stack

        pc = _cast(params, cfg)
        enc_out = _encoder_stack(
            jax.random.normal(jax.random.PRNGKey(3), (B, S, cfg.d_model)), pc, cfg
        )
        ks, vs = [], []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda x: x[i], pc["layers"])
            k, v = encode_cross_kv(enc_out, lp["cross"], cfg)
            ks.append(k)
            vs.append(v)
        state = state._replace(
            cross_k=jnp.stack(ks).astype(jnp.float32),
            cross_v=jnp.stack(vs).astype(jnp.float32),
        )

    step = jax.jit(lambda t, s: decode_step(cfg, params, t, s))
    tok = jnp.zeros((B, 1), jnp.int32)
    logits1, state = step(tok, state)
    logits2, state = step(jnp.argmax(logits1[:, -1:], -1).astype(jnp.int32), state)
    assert logits2.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2, np.float32)).all(), arch
    assert int(state.length) == 2
