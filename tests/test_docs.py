"""Docs must not rot: every relative link in docs/*.md and README.md must
resolve to a real file (and in-file anchors to a real heading), and every
backticked ``repro.*`` dotted name or repo path they mention must exist in
the codebase.  Run by the tier-1 suite and by CI's multi-device job.
"""
from __future__ import annotations

import importlib
import pathlib
import re

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent
DOC_FILES = sorted(
    list((REPO / "docs").glob("*.md")) + [REPO / "README.md"]
)

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
CODE_RE = re.compile(r"`([^`\n]+)`")
#: dotted python name rooted at the package, e.g. repro.core.soa_fleet.SoAFleet
SYMBOL_RE = re.compile(r"^repro(\.\w+)+$")
#: repo-relative path, e.g. src/repro/core/screen_math.py or docs/api.md
PATH_RE = re.compile(r"^[\w./-]+\.(py|md|json|yml)$")


def _headings(md: str):
    """GitHub-style anchor slugs of every heading in the file."""
    slugs = set()
    for line in md.splitlines():
        m = re.match(r"#+\s+(.*)", line)
        if m:
            text = re.sub(r"`", "", m.group(1)).strip().lower()
            text = re.sub(r"[^\w\- ]", "", text)
            slugs.add(re.sub(r" ", "-", text))
    return slugs


def test_doc_files_exist():
    assert (REPO / "docs" / "architecture.md").is_file()
    assert (REPO / "docs" / "api.md").is_file()
    assert (REPO / "docs" / "admission.md").is_file()
    assert (REPO / "docs" / "failure_domains.md").is_file()
    assert (REPO / "docs" / "relocation.md").is_file()
    assert (REPO / "docs" / "scan_sim.md").is_file()
    assert (REPO / "docs" / "tpu_validation.md").is_file()


@pytest.mark.parametrize("doc", DOC_FILES, ids=lambda p: p.name)
def test_links_resolve(doc):
    """Relative markdown links point at real files; same-file anchors point
    at real headings (external URLs are out of scope)."""
    text = doc.read_text()
    bad = []
    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path, _, anchor = target.partition("#")
        if path:
            resolved = (doc.parent / path).resolve()
            if not resolved.exists():
                bad.append(target)
        elif anchor and anchor not in _headings(text):
            bad.append(target)
    assert not bad, f"{doc.name}: broken links {bad}"


def _resolve_symbol(name: str) -> bool:
    """Import the longest module prefix, then getattr the rest."""
    parts = name.split(".")
    for cut in range(len(parts), 0, -1):
        try:
            obj = importlib.import_module(".".join(parts[:cut]))
        except ImportError:
            continue
        try:
            for attr in parts[cut:]:
                obj = getattr(obj, attr)
        except AttributeError:
            return False
        return True
    return False


@pytest.mark.parametrize("doc", DOC_FILES, ids=lambda p: p.name)
def test_referenced_symbols_and_paths_resolve(doc):
    """Backticked ``repro.*`` dotted names import/getattr cleanly, and
    backticked repo paths exist (also tried under src/)."""
    bad = []
    for token in CODE_RE.findall(doc.read_text()):
        token = token.strip()
        if SYMBOL_RE.match(token):
            if not _resolve_symbol(token):
                bad.append(token)
        elif PATH_RE.match(token) and "/" in token:
            if not (
                (REPO / token).exists()
                or (REPO / "src" / "repro" / token).exists()
                or (REPO / "src" / token).exists()
            ):
                bad.append(token)
    assert not bad, f"{doc.name}: unresolved references {bad}"
