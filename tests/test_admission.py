"""Queue invariants + drained-queue parity for the streaming admission plane.

Property tests (hypothesis when installed, seeded sweeps otherwise — the
suite itself never skips, it is gated fail-on-skip in CI):

* **conservation** — every arrival lands in exactly one bucket:
  admitted + rejected (overflow / retries) + still queued + still pending;
* **FIFO-within-class** — admitted order within a priority class is the
  submission order of that class (and ``queue_select`` returns exactly the
  ``(class, seq)``-lexicographic top-B against a python model queue);
* **priority preemption only evicts lower classes** — every eviction victim
  is preemptible and of a strictly lower-priority class than the evictor;
* **drained-queue bit-exactness** — replaying each drain's attempt sequence
  through the rebuild-from-python oracle (``build_fleet_state`` +
  ``schedule_step``, and ``JaxPreemptibleScheduler`` at the decision level)
  reproduces every decision bit-for-bit, and the fleet state after each
  drain equals the oracle rebuild.

Event times, resources and prices are integer-valued so f32 arithmetic is
exact and equality can be strict (same regime as tests/test_soa_incremental).
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.admission import queue_init, queue_pop, queue_push, queue_select
from repro.core.jax_scheduler import (
    JaxPreemptibleScheduler,
    build_fleet_state,
    schedule_step,
)
from repro.core.policy import SchedulerPolicy
from repro.core.simulator import SoASimulator, WorkloadSpec
from repro.core.soa_fleet import SoAFleet
from repro.core.types import VM_SPEC, Host, Instance, Request

try:  # hypothesis is optional: fall back to a seeded sweep, never skip
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def seeded_property(n_fallback: int = 10, max_examples: int = 20):
    """Run a ``fn(seed)`` property via hypothesis when available, else over
    ``range(n_fallback)`` fixed seeds."""
    if HAVE_HYPOTHESIS:
        def deco(fn):
            return settings(max_examples=max_examples, deadline=None)(
                given(seed=st.integers(min_value=0, max_value=2**31 - 1))(fn)
            )
        return deco
    return pytest.mark.parametrize("seed", range(n_fallback))


CAP = VM_SPEC.make(vcpus=8, ram_mb=16000, disk_gb=160)
SIZES = [
    VM_SPEC.make(vcpus=1, ram_mb=2000, disk_gb=20),
    VM_SPEC.make(vcpus=2, ram_mb=4000, disk_gb=40),
    VM_SPEC.make(vcpus=4, ram_mb=8000, disk_gb=80),
]
K = 8


def _hosts(n):
    return [Host(name=f"h{i}", capacity=CAP) for i in range(n)]


def _stream(rng, n, n_classes=2, explicit_priority=False):
    """Random request stream; class derives from preemptible unless
    ``explicit_priority`` assigns one uniformly."""
    reqs = []
    for i in range(n):
        pre = bool(rng.random() < 0.5)
        prio = None
        if explicit_priority:
            prio = int(rng.integers(n_classes))
            # interactive classes must ride the preemption machinery: only
            # the lowest class is preemptible (the batch tier)
            pre = prio == n_classes - 1
        reqs.append(
            Request(
                id=f"r{i}", resources=SIZES[int(rng.integers(3))],
                preemptible=pre, priority=prio,
            )
        )
    return reqs


def _klass(req, n_classes=2):
    if req.priority is not None:
        return req.priority
    return 0 if not req.preemptible else n_classes - 1


# ---------------------------------------------------------------------------
# Pure-transition level: push/select/pop vs a python model queue
# ---------------------------------------------------------------------------


@seeded_property()
def test_queue_select_is_lexicographic_top_b(seed):
    rng = np.random.default_rng(seed)
    cap, batch, d = 16, 4, 3
    q = queue_init(cap, d)
    model = {}  # slot -> (klass, seq)
    next_seq = 0
    for _ in range(40):
        if rng.random() < 0.7 and len(model) < cap:  # push
            klass = int(rng.integers(3))
            q, slot, ok = queue_push(
                q, np.ones((d,), np.float32), False, -1, -1, -1.0, -1, klass,
                float(next_seq), 1.0,
            )
            assert bool(ok)
            model[int(slot)] = (klass, next_seq)
            next_seq += 1
        # select must equal the model's (class, seq)-sorted head
        idx, take = queue_select(q, batch)
        idx, take = np.asarray(idx), np.asarray(take)
        want = sorted(model.items(), key=lambda kv: kv[1])[:batch]
        got = [int(idx[j]) for j in range(batch) if take[j]]
        assert got == [slot for slot, _ in want]
        if got and rng.random() < 0.4:  # pop some of the selected rows
            b = len(got)
            takev = np.zeros((batch,), bool)
            takev[:b] = True
            placed = np.asarray(rng.random(batch) < 0.5) & takev
            q, dropped = queue_pop(
                q, np.asarray(idx, np.int32), takev, placed, max_retries=2
            )
            dropped = np.asarray(dropped)
            for j in range(b):
                if placed[j] or dropped[j]:
                    del model[int(idx[j])]


def test_queue_push_overflow_rejects_not_displaces():
    q = queue_init(2, 1)
    for i in range(2):
        q, _, ok = queue_push(q, np.zeros((1,), np.float32), False, -1, -1,
                              -1.0, -1, 0, float(i), 1.0)
        assert bool(ok)
    before = np.asarray(q.seq).copy()
    q, _, ok = queue_push(q, np.zeros((1,), np.float32), False, -1, -1,
                          -1.0, -1, 0, 99.0, 1.0)
    assert not bool(ok)  # full queue rejects the arrival…
    np.testing.assert_array_equal(np.asarray(q.seq), before)  # …untouched


# ---------------------------------------------------------------------------
# Conservation: admitted + rejected + queued + pending == arrivals
# ---------------------------------------------------------------------------


@seeded_property()
def test_conservation(seed):
    rng = np.random.default_rng(seed)
    # tiny queue + tiny fleet + few retries exercises every bucket:
    # overflow rejections, retry rejections, placements, leftovers
    policy = SchedulerPolicy(queue_capacity=8, admit_batch=4, max_retries=2)
    fleet = SoAFleet(_hosts(3), k_slots=K, policy=policy)
    front = fleet.admission
    now = 0.0
    for i, req in enumerate(_stream(rng, 40)):
        now += float(rng.integers(1, 30))
        fleet.submit(req, now)
        if rng.random() < 0.4:
            fleet.drain(now)
        st_ = front.stats
        assert st_.arrivals == (
            st_.admitted + st_.rejected + st_.queue_depth + front.pending
        ), f"conservation broken at arrival {i}"
    fleet.drain_all(now + 1.0)
    st_ = front.stats
    assert front.waiting == 0 or st_.queue_depth > 0  # drain_all converged
    assert st_.arrivals == st_.admitted + st_.rejected + st_.queue_depth
    assert st_.arrivals == 40


# ---------------------------------------------------------------------------
# FIFO within a class / strict priority between classes
# ---------------------------------------------------------------------------


@seeded_property()
def test_fifo_within_class_admission_order(seed):
    rng = np.random.default_rng(seed)
    # ample fleet + queue: every request admits, so the admitted order per
    # class must BE the submission order of that class
    policy = SchedulerPolicy(queue_capacity=128, admit_batch=8, n_classes=3)
    fleet = SoAFleet(_hosts(32), k_slots=K, policy=policy)
    reqs = _stream(rng, 48, n_classes=3, explicit_priority=True)
    now, admitted = 0.0, []
    for i, req in enumerate(reqs):
        now += 1.0
        fleet.submit(req, now)
        if (i + 1) % int(rng.integers(3, 10)) == 0:
            dr = fleet.drain(now)
            admitted += [o.request for o in dr.outcomes]
    for dr in fleet.drain_all(now + 1.0):
        admitted += [o.request for o in dr.outcomes]
    assert len(admitted) == len(reqs)
    for klass in range(3):
        submitted_k = [r.id for r in reqs if _klass(r, 3) == klass]
        admitted_k = [r.id for r in admitted if _klass(r, 3) == klass]
        assert admitted_k == submitted_k, f"class {klass} broke FIFO"


@seeded_property(n_fallback=6, max_examples=10)
def test_higher_class_always_drains_first(seed):
    rng = np.random.default_rng(seed)
    policy = SchedulerPolicy(queue_capacity=64, admit_batch=4, n_classes=2)
    fleet = SoAFleet(_hosts(16), k_slots=K, policy=policy)
    reqs = _stream(rng, 24)
    for i, req in enumerate(reqs):
        fleet.submit(req, float(i + 1))
    # every drain's attempts must be class-sorted, and no batch entry may be
    # attempted while an older interactive entry still waits
    waiting = {r.id: _klass(r) for r in reqs}
    now = 100.0
    for dr in fleet.drain_all(now):
        classes = [_klass(r) for r, _ in dr.attempts]
        assert classes == sorted(classes), "drain not in priority order"
        if dr.attempts and _klass(dr.attempts[0][0]) == 1:
            assert not any(k == 0 for k in waiting.values())
        for r, _ in dr.attempts:
            waiting.pop(r.id, None)
        for r in dr.rejected:
            waiting.pop(r.id, None)


# ---------------------------------------------------------------------------
# Priority preemption: evictions only ever hit strictly lower classes
# ---------------------------------------------------------------------------


@seeded_property()
def test_preemption_only_evicts_lower_classes(seed):
    rng = np.random.default_rng(seed)
    # small saturated fleet so interactive arrivals must evict batch work
    policy = SchedulerPolicy(queue_capacity=64, admit_batch=8)
    fleet = SoAFleet(_hosts(3), k_slots=K, policy=policy)
    reqs = _stream(rng, 60)
    klass_of = {r.id: _klass(r) for r in reqs}
    now, evictions = 0.0, 0
    for i, req in enumerate(reqs):
        now += float(rng.integers(1, 20))
        fleet.submit(req, now)
        if (i + 1) % 6 == 0:
            for dr in [fleet.drain(now)]:
                for out in dr.outcomes:
                    for victim in out.victims:
                        evictions += 1
                        assert victim.preemptible, "evicted a normal instance"
                        vid = victim.id.split("-", 1)[1]
                        assert klass_of[out.request.id] < klass_of[vid], (
                            "eviction across equal/higher class"
                        )
    assert evictions > 0, "workload never exercised preemption"


def test_interactive_preempts_batch_composition():
    """The ordering half (queue) composes with the paper's eviction half
    (decision pipeline): batch work fills the fleet, then one interactive
    arrival drains first AND evicts batch instances to fit."""
    big = VM_SPEC.make(vcpus=6, ram_mb=12000, disk_gb=120)
    policy = SchedulerPolicy(queue_capacity=16, admit_batch=4)
    fleet = SoAFleet(_hosts(1), k_slots=K, policy=policy)
    for i in range(4):  # 4×2 vcpus of batch work on an 8-vcpu host
        fleet.submit(Request(id=f"b{i}", resources=SIZES[1], preemptible=True),
                     now=float(i + 1))
    dr = fleet.drain(10.0)
    assert len(dr.outcomes) == 4
    fleet.submit(Request(id="interactive", resources=big), now=11.0)
    fleet.submit(Request(id="b-late", resources=SIZES[1], preemptible=True),
                 now=11.0)
    dr = fleet.drain(12.0)
    # interactive drains before the later batch arrival and evicts batch work
    assert dr.attempts[0][0].id == "interactive"
    out = dr.outcomes[0]
    assert out.request.id == "interactive" and len(out.victims) >= 2
    assert all(v.preemptible for v in out.victims)


# ---------------------------------------------------------------------------
# Backfill retries
# ---------------------------------------------------------------------------


def test_backfill_retry_then_placement_after_capacity_frees():
    policy = SchedulerPolicy(queue_capacity=8, admit_batch=2, max_retries=8)
    fleet = SoAFleet(_hosts(1), k_slots=K, policy=policy)
    blocker = fleet.schedule_request(
        Request(id="blocker", resources=CAP), now=1.0
    )
    assert blocker.ok
    fleet.submit(Request(id="waiter", resources=SIZES[0]), now=2.0)
    dr = fleet.drain(3.0)
    assert dr.outcomes == () and [r.id for r in dr.retried] == ["waiter"]
    assert fleet.admission.stats.retries == 1
    fleet.depart(blocker.instance.id)  # capacity frees → backfill succeeds
    dr = fleet.drain(4.0)
    assert [o.request.id for o in dr.outcomes] == ["waiter"]


def test_retry_exhaustion_rejects():
    policy = SchedulerPolicy(queue_capacity=8, admit_batch=2, max_retries=3)
    fleet = SoAFleet(_hosts(1), k_slots=K, policy=policy)
    assert fleet.schedule_request(
        Request(id="blocker", resources=CAP), now=1.0
    ).ok
    fleet.submit(Request(id="doomed", resources=SIZES[0]), now=2.0)
    for t in (3.0, 4.0):
        dr = fleet.drain(t)
        assert [r.id for r in dr.retried] == ["doomed"]
    dr = fleet.drain(5.0)  # third (= max_retries) attempt drops it
    assert [r.id for r in dr.rejected] == ["doomed"]
    assert fleet.admission.stats.rejected_retry == 1
    assert fleet.drain(6.0).attempts == ()  # queue is empty now


def test_queue_overflow_rejects_at_drain():
    policy = SchedulerPolicy(queue_capacity=4, admit_batch=4, max_retries=1)
    fleet = SoAFleet(_hosts(1), k_slots=K, policy=policy)
    assert fleet.schedule_request(
        Request(id="blocker", resources=CAP), now=1.0
    ).ok
    for i in range(7):  # 7 arrivals into a 4-slot queue
        fleet.submit(Request(id=f"r{i}", resources=SIZES[0]), now=2.0)
    dr = fleet.drain(3.0)
    # 4 queued (then dropped: max_retries=1 and the host is full), 3 overflow
    assert fleet.admission.stats.rejected_overflow == 3
    assert fleet.admission.stats.rejected_retry == 4
    assert len(dr.rejected) == 7


# ---------------------------------------------------------------------------
# Drained-queue decisions are bit-exact vs the unqueued oracle
# ---------------------------------------------------------------------------


def _assert_states_equal(state, oracle, msg=""):
    valid = np.asarray(state.inst_valid)
    np.testing.assert_array_equal(valid, np.asarray(oracle.inst_valid), err_msg=msg)
    for field in ("free_f", "free_n", "schedulable", "domain", "slow"):
        np.testing.assert_array_equal(
            np.asarray(getattr(state, field)),
            np.asarray(getattr(oracle, field)),
            err_msg=f"{msg}: {field}",
        )
    for field in ("inst_start", "inst_price", "inst_ckpt", "inst_cost_kind"):
        np.testing.assert_array_equal(
            np.asarray(getattr(state, field)) * valid,
            np.asarray(getattr(oracle, field)) * valid,
            err_msg=f"{msg}: {field}",
        )
    np.testing.assert_array_equal(
        np.asarray(state.inst_res) * valid[..., None],
        np.asarray(oracle.inst_res) * valid[..., None],
        err_msg=f"{msg}: inst_res",
    )


class _PyMirror:
    def __init__(self, hosts):
        self.hosts = hosts
        self.by_name = {h.name: h for h in hosts}

    def apply(self, outcome):
        host = self.by_name[outcome.host]
        for victim in outcome.victims:
            host.remove(victim.id)
        host.place(
            Instance(
                id=outcome.instance.id,
                resources=outcome.instance.resources,
                preemptible=outcome.instance.preemptible,
                host=host.name,
                start_time=outcome.instance.start_time,
                price_rate=outcome.instance.price_rate,
                cost_kind=outcome.instance.cost_kind,
            )
        )


@seeded_property(n_fallback=4, max_examples=8)
def test_drained_queue_bit_exact_vs_oracle(seed):
    """Replay every drain's attempt sequence against (a) ``schedule_step``
    on the rebuilt-from-python state and (b) the ``JaxPreemptibleScheduler``
    rebuild oracle; decisions must match bit-for-bit and the fleet state
    after each drain must equal the oracle rebuild."""
    rng = np.random.default_rng(seed)
    hosts = _hosts(12)
    py = _PyMirror(hosts)
    policy = SchedulerPolicy(queue_capacity=32, admit_batch=4)
    # k_slots > capacity/min-size: a host can never run out of free slots,
    # so the drain path (require_free_slot=True) and the rebuild oracle
    # (require_free_slot=False) face identical feasibility everywhere
    k = 12
    fleet = SoAFleet(hosts, k_slots=k, policy=policy)
    oracle = JaxPreemptibleScheduler(k_slots=k, policy=policy)
    reqs = _stream(rng, 36)
    now = 0.0
    for i, req in enumerate(reqs):
        now += float(rng.integers(1, 60))
        fleet.submit(req, now)
        if (i + 1) % int(rng.integers(2, 7)) != 0:
            continue
        dr = fleet.drain(now)
        outs = iter(dr.outcomes)
        for areq, placed in dr.attempts:
            # (a) one step on the oracle state rebuilt from the mirror
            ostate, _ = build_fleet_state(
                py.hosts, k_slots=k, domain_ids=fleet.domain_ids,
                slot_assignment=fleet.slot_assignment(),
            )
            res, pre, dom, kind, period, _excl = fleet._req_arrays(areq)
            _, (oh, oslot, ook, okill, _fb, _mg) = schedule_step(
                ostate, res, pre, dom, dr.now, 1.0,
                policy=policy, req_cost_kind=kind, req_period=period,
                donate=False,
            )
            assert bool(ook) == placed, f"oracle ok mismatch for {areq.id}"
            # (b) the rebuild-per-call scheduler agrees at decision level
            sched = oracle.schedule(areq, py.hosts, dr.now)
            assert sched.ok == placed, f"rebuild oracle mismatch {areq.id}"
            if not placed:
                continue
            out = next(outs)
            assert out.host == fleet.names[int(oh)] == sched.host
            assert set(sched.plan.ids) == {v.id for v in out.victims}
            py.apply(out)
        # state parity after the whole drain
        ostate, _ = build_fleet_state(
            py.hosts, k_slots=k, domain_ids=fleet.domain_ids,
            slot_assignment=fleet.slot_assignment(),
        )
        _assert_states_equal(fleet.state, ostate, msg=f"after drain @{now}")


# ---------------------------------------------------------------------------
# Double-buffered (non-blocking) dispatch delivers identical results
# ---------------------------------------------------------------------------


def test_nonblocking_drains_match_blocking():
    def run(block):
        policy = SchedulerPolicy(queue_capacity=32, admit_batch=4)
        fleet = SoAFleet(_hosts(4), k_slots=K, policy=policy)
        rng = np.random.default_rng(123)
        results = []  # blocking drains return directly; async ones bank
        for i, req in enumerate(_stream(rng, 24)):
            fleet.submit(req, float(i + 1))
            if (i + 1) % 4 == 0:
                dr = fleet.drain(float(i + 1), block=block)
                if dr is not None:
                    results.append(dr)
        dr = fleet.drain(100.0, block=block)
        if dr is not None:
            results.append(dr)
        results += fleet.admission.take_results()
        placed = [
            (o.request.id, o.host) for dr in results for o in dr.outcomes
        ]
        st_ = fleet.admission.stats
        return placed, (st_.admitted, st_.rejected, st_.queue_depth)

    assert run(block=True) == run(block=False)


# ---------------------------------------------------------------------------
# Streaming simulator mode
# ---------------------------------------------------------------------------


def _streaming_sim(seed=11):
    medium = VM_SPEC.make(vcpus=2, ram_mb=4000, disk_gb=40)
    spec = WorkloadSpec(
        arrival_rate_per_s=1 / 20.0,
        preemptible_fraction=0.5,
        flavors=(("medium", medium),),
    )
    policy = SchedulerPolicy(
        queue_capacity=64, admit_batch=8, slo_target_s=120.0
    )
    return SoASimulator(_hosts(16), spec, seed=seed, policy=policy)


def test_streaming_simulator_conserves_and_is_deterministic():
    runs = []
    for _ in range(2):
        sim = _streaming_sim()
        m = sim.run(12 * 3600.0, sample_every_s=900.0)
        st_ = sim.fleet.admission.stats
        assert st_.arrivals == st_.admitted + st_.rejected + st_.queue_depth
        assert st_.admitted == m.placed_normal + m.placed_preemptible
        assert st_.rejected == m.failures_normal + m.failures_preemptible
        assert st_.admitted > 50
        runs.append(
            (m.placed_normal, m.placed_preemptible, m.failures_normal,
             m.failures_preemptible, m.preemptions, tuple(m.utilization))
        )
    assert runs[0] == runs[1]


def test_streaming_simulator_respects_slo_deadline():
    """With a lazy batch size, the SLO tick still forces timely drains: no
    placed request waits (in sim time) much past slo_target_s."""
    sim = _streaming_sim()
    sim.run(12 * 3600.0)
    st_ = sim.fleet.admission.stats
    slo = sim.fleet.policy.slo_target_s
    assert st_.wait_s, "nothing was admitted"
    # drains happen AT the deadline tick; waits may exceed the target only
    # by the retry/backfill path, never for first-attempt admissions
    waits = np.asarray(st_.wait_s)
    assert float(np.percentile(waits, 50)) <= slo + 1e-6


# ---------------------------------------------------------------------------
# Packed-key drain order: one fused sort == the old two-pass lexsort
# ---------------------------------------------------------------------------


@seeded_property()
def test_queue_select_packed_key_matches_lexsort(seed):
    """``queue_select`` now sorts ONE packed uint32 key; it must reproduce
    the two-key ``lexsort((seq, effective_klass))`` order bit-exactly —
    including aged and retried entries — at several class counts/batches."""
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    for n_classes, batch in ((2, 4), (3, 8), (8, 5), (255, 16), (None, 6)):
        nc = n_classes if n_classes else 255
        cap, d = 32, 3
        q = queue_init(cap, d)
        occupied = set()
        t = 0.0
        for i in range(64):
            t += float(rng.integers(0, 40))
            if rng.random() < 0.75 and len(occupied) < cap:
                q, slot, ok = queue_push(
                    q, np.ones((d,), np.float32), False, -1, -1, -1.0, -1,
                    int(rng.integers(nc)), t, 1.0,
                )
                assert bool(ok)
                occupied.add(int(slot))
            elif occupied:  # burn retries on a few random rows (tries += 1,
                # seq ticket KEPT) without ever dropping them
                rows = rng.choice(sorted(occupied), size=1)
                idxv = np.full((4,), rows[0], np.int32)
                takev = np.zeros((4,), bool)
                takev[0] = True
                q, dropped = queue_pop(
                    q, idxv, takev, np.zeros((4,), bool), max_retries=10**6
                )
                assert not np.asarray(dropped).any()
            aging = float(rng.choice([0.0, 0.002, 0.05]))
            now = jnp.float32(t)
            idx, take = queue_select(
                q, batch, now=now, aging_rate=aging, n_classes=n_classes
            )
            # reference: the pre-packing two-pass order
            klass = np.asarray(q.klass)
            if aging:
                waited = np.maximum(t - np.asarray(q.enq_t), 0.0)
                decay = np.floor(
                    np.float32(aging) * waited.astype(np.float32)
                ).astype(np.int32)
                klass = np.maximum(klass - decay, 0)
            valid = np.asarray(q.valid)
            eff = np.where(valid, klass, np.iinfo(np.int32).max)
            ref = np.asarray(
                jnp.lexsort((jnp.asarray(np.asarray(q.seq)),
                             jnp.asarray(eff)))
            )[:batch]
            # compare the VALID prefix (padding rows gather arbitrary
            # invalid entries; both sorts place them strictly last)
            idx, take = np.asarray(idx), np.asarray(take)
            assert np.array_equal(take, valid[ref]), (
                f"take mask diverged (n_classes={n_classes}, batch={batch})"
            )
            assert np.array_equal(idx[take], ref[valid[ref]]), (
                f"packed-key order diverged from lexsort "
                f"(n_classes={n_classes}, batch={batch}, aging={aging})"
            )


def test_wait_percentile_readers_agree():
    """The front end's sim-time p50/p99 reader interpolates in f32 —
    bit-identical to ``ScanResult.wait_percentiles`` over the same waits."""
    sim = _streaming_sim()
    sim.run(6 * 3600.0)
    front = sim.fleet.admission
    pct = front.wait_percentiles()
    assert set(pct) == {"wait_p50_s", "wait_p99_s"}
    w = np.asarray(front.stats.wait_s, np.float32)
    assert pct["wait_p50_s"] == float(np.percentile(w, 50))
    assert pct["wait_p99_s"] == float(np.percentile(w, 99))
    assert pct["wait_p50_s"] <= pct["wait_p99_s"]
    # summary() exposes the same sim-time percentiles
    summ = front.stats.summary()
    assert summ["wait_p50_s"] == pct["wait_p50_s"]
    assert summ["wait_p99_s"] == pct["wait_p99_s"]
