"""Paper-fidelity divergence tests: places where the paper's PROSE
contradicts its own EVALUATION, demonstrated executably (DESIGN.md §1).
"""
from __future__ import annotations

import pytest

from repro.core.cost import PeriodCost
from repro.core.scheduler import PreemptibleScheduler
from repro.core.types import VM_SPEC, Host, Instance, Request
from repro.core.weighers import OvercommitRank, PeriodRank, TerminationCostRank

NOW = 1_000_000.0
SIZES = {
    "small": VM_SPEC.make(vcpus=1, ram_mb=2000, disk_gb=20),
    "medium": VM_SPEC.make(vcpus=2, ram_mb=4000, disk_gb=40),
    "large": VM_SPEC.make(vcpus=4, ram_mb=8000, disk_gb=80),
}
CAP = VM_SPEC.make(vcpus=8, ram_mb=16000, disk_gb=10_000)


def table5_hosts():
    def mk(name, instances):
        h = Host(name=name, capacity=CAP)
        for iid, size, minutes, pre in instances:
            h.place(Instance(id=iid, resources=SIZES[size], preemptible=pre,
                             host=name, start_time=NOW - minutes * 60.0))
        return h

    return [
        mk("host-A", [("AP1", "large", 298, True), ("AP2", "medium", 278, True),
                      ("AP3", "small", 190, True), ("AP4", "small", 187, True)]),
        mk("host-B", [("B1", "large", 494, False), ("BP1", "large", 178, True)]),
        mk("host-C", [("CP1", "large", 297, True), ("CP2", "medium", 296, True),
                      ("CP3", "small", 296, True)]),
        mk("host-D", [("D1", "medium", 176, False), ("D2", "medium", 200, False),
                      ("D3", "large", 116, False)]),
    ]


def test_literal_alg4_contradicts_papers_table5():
    """The paper's PROSE Alg. 4 ranks hosts by the sum of partial periods of
    ALL preemptible instances: A=113, B=58, C=169 minutes → it would pick
    host-B.  The paper's own Table 5 terminates AP2-4 on host-A (min-cost
    subset 55 < 58 < 57).  This test pins the divergence."""
    req = Request(id="q", resources=SIZES["large"], preemptible=False)
    literal = PreemptibleScheduler(
        cost_fn=PeriodCost(), weighers=(OvercommitRank(), PeriodRank())
    )
    res = literal.schedule(req, table5_hosts(), NOW)
    assert res.host == "host-B"            # literal Alg. 4's (different) choice

    faithful = PreemptibleScheduler(
        cost_fn=PeriodCost(), weighers=(OvercommitRank(), TerminationCostRank())
    )
    res = faithful.schedule(req, table5_hosts(), NOW)
    assert res.host == "host-A"            # the paper's published outcome
    assert set(res.plan.ids) == {"AP2", "AP3", "AP4"}


def test_alg5_pseudocode_ignores_free_resources_but_table6_needs_them():
    """Alg. 5's literal feasibility (Σ freed > req) would reject {BP3} on
    Table 6's host-B (a small frees only 1 vCPU for a 2-vCPU request); the
    published outcome uses the host's existing free slot.  Our
    implementation follows the evaluation: free_full + Σ freed ≥ req."""
    h = Host(name="host-B", capacity=CAP)
    h.place(Instance(id="BP1", resources=SIZES["large"], preemptible=True,
                     host="host-B", start_time=NOW - 272 * 60))
    h.place(Instance(id="BP2", resources=SIZES["medium"], preemptible=True,
                     host="host-B", start_time=NOW - 212 * 60))
    h.place(Instance(id="BP3", resources=SIZES["small"], preemptible=True,
                     host="host-B", start_time=NOW - 380 * 60))
    from repro.core.select_terminate import best_plan

    req = Request(id="q", resources=SIZES["medium"], preemptible=False)
    plan = best_plan(h, req, PeriodCost(), NOW)
    assert plan.feasible and plan.ids == ("BP3",)
    # literal pseudocode check: Σ freed alone does NOT cover the request
    assert not req.resources.fits_in(SIZES["small"])


def test_run_time_modulo_costs_zero_at_exact_periods():
    """§4.2's example: among 120/119/61-minute instances, the 120-minute one
    is terminated (remainder 0)."""
    h = Host(name="h", capacity=CAP)
    for iid, minutes in (("a", 120), ("b", 119), ("c", 61)):
        h.place(Instance(id=iid, resources=SIZES["medium"], preemptible=True,
                         host="h", start_time=NOW - minutes * 60))
    h.place(Instance(id="n", resources=SIZES["medium"], preemptible=False,
                     host="h", start_time=NOW - 10 * 60))
    from repro.core.select_terminate import best_plan

    req = Request(id="q", resources=SIZES["medium"], preemptible=False)
    plan = best_plan(h, req, PeriodCost(), NOW)
    assert plan.ids == ("a",) and plan.cost == 0.0
