"""End-to-end fault tolerance: preempt → checkpoint → resume is EXACT.

The strongest guarantee the preemption protocol offers: a training job that
is preempted mid-run and later resumed (fresh Trainer, as after an
evacuation) produces bit-identical parameters to an uninterrupted run —
params, optimizer state and data cursor all restore exactly.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.core.preemption import PreemptAck, PreemptionController
from repro.data.pipeline import DataConfig, SyntheticLMDataset
from repro.training import Trainer, TrainerConfig, TrainSettings


def make_trainer(tmpdir, seed=0):
    cfg = reduced(get_config("qwen2-1.5b"))
    data = SyntheticLMDataset(
        DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4, seed=seed)
    )
    return Trainer(
        cfg,
        TrainSettings(total_steps=50, warmup_steps=2, learning_rate=1e-3),
        TrainerConfig(ckpt_dir=str(tmpdir), ckpt_every=1000, log_every=1),
        data=data,
    )


def _params_vec(trainer):
    return np.concatenate(
        [np.asarray(x, np.float32).ravel() for x in jax.tree.leaves(trainer.params)]
    )


def test_preempt_resume_is_bit_exact(tmp_path):
    # uninterrupted reference: 10 steps
    ref = make_trainer(tmp_path / "ref")
    ref.run(10)
    ref_vec = _params_vec(ref)

    # preempted run: 6 steps → preempt (checkpoint) → fresh trainer → 4 more
    t1 = make_trainer(tmp_path / "pre")
    t1.run(6)
    ack = t1.on_preempt(now=0.0, deadline=60.0)
    assert ack is PreemptAck.DRAINED

    t2 = make_trainer(tmp_path / "pre")
    t2.init_or_restore()
    assert t2.step == 6
    t2.run(until_step=10)
    np.testing.assert_array_equal(ref_vec, _params_vec(t2))


def test_hard_kill_loses_only_since_last_checkpoint(tmp_path):
    t1 = make_trainer(tmp_path / "hk")
    t1.tcfg.ckpt_every = 5
    t1.run(8)          # periodic checkpoint at step 5; steps 6-8 volatile
    t1.ckpt.wait()
    # hard kill: no drain — simply start a fresh trainer from disk
    t2 = make_trainer(tmp_path / "hk")
    t2.init_or_restore()
    assert t2.step == 5  # lost exactly steps 6-8, not the whole run
    t2.run(until_step=10)
    assert t2.step == 10


def test_controller_records_lost_work(tmp_path):
    from repro.core.types import TPU_SPEC, Instance

    ctrl = PreemptionController(notice_s=60.0)
    trainer = make_trainer(tmp_path / "rec")
    trainer.run(3)
    inst = Instance(
        id="i0", resources=TPU_SPEC.make(chips=4, hbm_gb=32, host_ram_gb=16),
        preemptible=True, host="h0", start_time=0.0,
    )
    ctrl.register("i0", trainer)
    ctrl(inst, now=100.0)
    assert ctrl.records[-1].ack is PreemptAck.DRAINED
    assert ctrl.records[-1].lost_work_s == 0.0
    assert ctrl.drain_rate == 1.0
