"""Incremental-state parity: the persistent device-resident fleet vs the
rebuild-from-python oracle.

The contract under test: after ANY interleaving of placements, preemptions,
voluntary departures, host failures/heals, and straggler updates, the
incrementally-maintained ``SoAFleetState`` is bit-identical to the state
rebuilt from the python ``Host`` objects (``build_fleet_state`` with the
mirror's slot layout), and scheduling decisions taken on either state are
bit-identical too.  Event times and resources are integer-valued so float32
arithmetic is exact and equality can be strict.
"""
from __future__ import annotations

import jax.numpy as jnp
import jax.tree_util
import numpy as np
import pytest

from repro.core.cost import PeriodCost, RecomputeCost, RevenueCost
from repro.core.jax_scheduler import (
    build_fleet_state,
    schedule_many,
    schedule_step,
)
from repro.core.simulator import Simulator, SoASimulator, WorkloadSpec
from repro.core.soa_fleet import SoAFleet
from repro.core.cluster import Cluster, make_uniform_fleet
from repro.core.jax_scheduler import JaxPreemptibleScheduler
from repro.core.types import VM_SPEC, Host, Instance, Request

CAP = VM_SPEC.make(vcpus=8, ram_mb=16000, disk_gb=160)
SIZES = [
    VM_SPEC.make(vcpus=1, ram_mb=2000, disk_gb=20),
    VM_SPEC.make(vcpus=2, ram_mb=4000, disk_gb=40),
    VM_SPEC.make(vcpus=4, ram_mb=8000, disk_gb=80),
]
K = 8


def _assert_states_equal(state, oracle, msg=""):
    """Strict equality; slot payloads compared only where a slot is valid."""
    valid = np.asarray(state.inst_valid)
    np.testing.assert_array_equal(valid, np.asarray(oracle.inst_valid), err_msg=msg)
    for field in (
        "free_f", "free_n", "schedulable", "domain", "slow",
        "host_zone", "zone_term", "zone_up",
    ):
        np.testing.assert_array_equal(
            np.asarray(getattr(state, field)),
            np.asarray(getattr(oracle, field)),
            err_msg=f"{msg}: {field}",
        )
    for field in (
        "inst_start", "inst_price", "inst_ckpt", "inst_cost_kind",
        "inst_period",
    ):
        np.testing.assert_array_equal(
            np.asarray(getattr(state, field)) * valid,
            np.asarray(getattr(oracle, field)) * valid,
            err_msg=f"{msg}: {field}",
        )
    np.testing.assert_array_equal(
        np.asarray(state.inst_res) * valid[..., None],
        np.asarray(oracle.inst_res) * valid[..., None],
        err_msg=f"{msg}: inst_res",
    )


class _PyMirror:
    """Plain python ``Host`` objects mutated in lockstep with the fast path —
    the ground truth the oracle state is rebuilt from."""

    def __init__(self, n_hosts: int):
        self.hosts = [
            Host(name=f"h{i}", capacity=CAP, domain=f"dom{i % 2}")
            for i in range(n_hosts)
        ]
        self.by_name = {h.name: h for h in self.hosts}

    def apply(self, outcome):
        if not outcome.ok:
            return
        host = self.by_name[outcome.host]
        for victim in outcome.victims:
            host.remove(victim.id)
        host.place(
            Instance(
                id=outcome.instance.id,
                resources=outcome.instance.resources,
                preemptible=outcome.instance.preemptible,
                host=host.name,
                start_time=outcome.instance.start_time,
                price_rate=outcome.instance.price_rate,
                cost_kind=outcome.instance.cost_kind,
            )
        )


@pytest.mark.parametrize(
    "seed,cost_fn",
    [(0, PeriodCost()), (1, PeriodCost()), (2, RevenueCost()), (3, RecomputeCost())],
)
def test_incremental_matches_rebuild_over_randomized_events(seed, cost_fn):
    """≥1k randomized events; after every event the arrays must equal the
    oracle rebuild, and every arrival's decision must be bit-identical when
    taken on the incremental state vs the rebuilt state."""
    rng = np.random.default_rng(seed)
    n_hosts, n_events = 24, 1100
    py = _PyMirror(n_hosts)
    fleet = SoAFleet(py.hosts, cost_fn=cost_fn, k_slots=K)
    now = 0.0
    live_departable = []  # ids we may voluntarily depart

    for step in range(n_events):
        now += float(rng.integers(1, 90))
        roll = rng.random()
        if roll < 0.65:  # -------------------------------------------- arrival
            req = Request(
                id=f"r{step}",
                resources=SIZES[int(rng.integers(3))],
                preemptible=bool(rng.random() < 0.6),
                domain=f"dom{rng.integers(2)}" if rng.random() < 0.3 else None,
            )
            price = float(rng.integers(1, 5))
            # oracle decision on the rebuilt state must match bit-for-bit
            oracle, _ = build_fleet_state(
                py.hosts, k_slots=K, domain_ids=fleet.domain_ids,
                slot_assignment=fleet.slot_assignment(),
                zone_term=fleet.state.zone_term, zone_up=fleet.state.zone_up,
            )
            res, pre, dom, kind, period, _excl = fleet._req_arrays(req)
            _, (oh, oslot, ook, okill, _fb, _mg) = schedule_step(
                oracle, res, pre, dom, now, price,
                policy=fleet.policy, req_cost_kind=kind, req_period=period,
            )
            # victims the oracle decision implies, read from the slot map
            # BEFORE the fast path mutates it
            expect_victims = set()
            if bool(ook) and not req.preemptible:
                expect_victims = {
                    fleet.slot_ids[int(oh)][k]
                    for k in np.flatnonzero(np.asarray(okill))
                } - {None}
            out = fleet.schedule_request(req, now, price=price)
            assert bool(ook) == out.ok, f"event {step}: ok mismatch"
            if out.ok:
                assert fleet.names[int(oh)] == out.host, f"event {step}"
                assert {v.id for v in out.victims} == expect_victims, f"event {step}"
                py.apply(out)
                live_departable.append(out.instance.id)
        elif roll < 0.85 and live_departable:  # -------------------- departure
            iid = live_departable.pop(int(rng.integers(len(live_departable))))
            was_live = fleet.depart(iid)
            if was_live:
                host = py.by_name[fleet_host_of(py, iid)]
                host.remove(iid)
        elif roll < 0.90:  # ------------------------------------- checkpoint
            pre_ids = [
                iid for iid, (_, slot) in fleet.locator.items() if slot is not None
            ]
            if pre_ids:
                iid = pre_ids[int(rng.integers(len(pre_ids)))]
                assert fleet.checkpoint(iid, now)
                py.by_name[fleet_host_of(py, iid)].instances[iid].last_checkpoint = now
        elif roll < 0.95:  # -------------------------------------- fail / heal
            name = f"h{rng.integers(n_hosts)}"
            host = py.by_name[name]
            if host.schedulable:
                fleet.fail_host(name)
                host.schedulable = False
                host.instances.clear()
            else:
                fleet.heal_host(name)
                host.schedulable = True
        else:  # ------------------------------------------------- straggler
            name = f"h{rng.integers(n_hosts)}"
            factor = float(rng.integers(1, 6))
            fleet.set_slow(name, factor)
            py.by_name[name].slow_factor = factor

        oracle, _ = build_fleet_state(
            py.hosts, k_slots=K, domain_ids=fleet.domain_ids,
            slot_assignment=fleet.slot_assignment(),
            zone_term=fleet.state.zone_term, zone_up=fleet.state.zone_up,
        )
        _assert_states_equal(fleet.state, oracle, msg=f"event {step}")

    # the mirror's own Host materialization agrees with the ground truth
    synced = {h.name: h for h in fleet.sync_hosts()}
    for h in py.hosts:
        assert set(synced[h.name].instances) == set(h.instances)


def fleet_host_of(py: _PyMirror, iid: str) -> str:
    for h in py.hosts:
        if iid in h.instances:
            return h.name
    raise KeyError(iid)


def test_schedule_many_bit_identical_to_sequential_steps():
    """One lax.scan over a batch == the same requests through schedule_step
    one by one: identical outputs AND identical final state."""
    rng = np.random.default_rng(7)
    hosts = [Host(name=f"h{i}", capacity=CAP) for i in range(16)]
    fleet = SoAFleet(hosts, cost_fn=PeriodCost(), k_slots=4)
    b, d = 32, len(CAP.spec.dims)
    res = np.stack(
        [SIZES[int(rng.integers(3))].vec for _ in range(b)]
    ).astype(np.float32)
    pre = rng.random(b) < 0.5
    dom = np.full((b,), -1, np.int32)
    now = np.cumsum(rng.integers(1, 60, size=b)).astype(np.float32)
    price = np.ones((b,), np.float32)

    # schedule_step donates its input state, so run the sequential chain on
    # an independent deep copy and keep fleet.state for the scan.
    state_seq = jax.tree_util.tree_map(jnp.array, fleet.state)
    outs = []
    for i in range(b):
        state_seq, o = schedule_step(
            state_seq, res[i], bool(pre[i]), dom[i], float(now[i]),
            float(price[i]),
            policy=fleet.policy,
        )
        outs.append([np.asarray(x) for x in o])

    state_scan, (h, s, ok, kill, _fb, _mg) = schedule_many(
        fleet.state, res, pre, dom, now, price,
        policy=fleet.policy,
    )
    np.testing.assert_array_equal(np.asarray(h), [o[0] for o in outs])
    np.testing.assert_array_equal(np.asarray(ok), [o[2] for o in outs])
    np.testing.assert_array_equal(np.asarray(kill), [o[3] for o in outs])
    # slots only meaningful for successful preemptible placements
    slot_scan, slot_seq = np.asarray(s), np.asarray([o[1] for o in outs])
    sel = np.asarray(ok) & pre
    np.testing.assert_array_equal(slot_scan[sel], slot_seq[sel])
    _assert_states_equal(state_scan, state_seq, msg="scan vs sequential")


def test_preemptible_requires_free_slot():
    """A host whose K slots are all occupied rejects further preemptible
    requests even though raw capacity is free (the rebuild path would
    overflow ``k_slots`` instead)."""
    small = SIZES[0]
    host = Host(name="h0", capacity=CAP)
    for i in range(2):
        host.place(
            Instance(id=f"p{i}", resources=small, preemptible=True,
                     host="h0", start_time=0.0)
        )
    fleet = SoAFleet([host], cost_fn=PeriodCost(), k_slots=2)
    out = fleet.schedule_request(
        Request(id="q", resources=small, preemptible=True), now=100.0
    )
    assert not out.ok
    # a normal request still lands (dual view sees through the spot slots)
    out = fleet.schedule_request(
        Request(id="q2", resources=small, preemptible=False), now=101.0
    )
    assert out.ok


def test_soa_simulator_matches_rebuild_simulator_metrics():
    """End-to-end: the fast-path simulator and the per-call-rebuild simulator
    under the same workload land in the same utilization regime."""
    node = VM_SPEC.make(vcpus=8, ram_mb=16000, disk_gb=10_000)
    medium = VM_SPEC.make(vcpus=2, ram_mb=4000, disk_gb=40)
    spec = WorkloadSpec(
        arrival_rate_per_s=1 / 40.0,
        preemptible_fraction=0.5,
        flavors=(("medium", medium),),
    )
    fast = SoASimulator(
        make_uniform_fleet(16, node), spec, seed=5, cost_fn=PeriodCost(),
        k_slots=4,
    )
    m_fast = fast.run(24 * 3600.0)
    slow = Simulator(
        Cluster(make_uniform_fleet(16, node)),
        JaxPreemptibleScheduler(cost_fn=PeriodCost(), k_slots=4),
        spec, seed=5,
    )
    m_slow = slow.run(24 * 3600.0)
    assert m_fast.placed_normal + m_fast.placed_preemptible > 100
    assert np.isclose(
        np.mean(m_fast.utilization), np.mean(m_slow.utilization), atol=0.1
    )
    # the fleet state at the end is internally consistent
    hosts = fast.fleet.sync_hosts()
    assert sum(len(h.instances) for h in hosts) == len(fast.fleet.instances)


def test_soa_simulator_is_deterministic():
    node = VM_SPEC.make(vcpus=8, ram_mb=16000, disk_gb=10_000)
    medium = VM_SPEC.make(vcpus=2, ram_mb=4000, disk_gb=40)
    spec = WorkloadSpec(
        arrival_rate_per_s=1 / 20.0,
        preemptible_fraction=0.5,
        flavors=(("medium", medium),),
    )

    def go():
        sim = SoASimulator(
            make_uniform_fleet(12, node), spec, seed=11, cost_fn=PeriodCost(),
            k_slots=4,
        )
        sim.inject_host_failure("host-2", at_s=3600.0, heal_after_s=3600.0)
        m = sim.run(12 * 3600.0)
        return m

    a, b = go(), go()
    assert a.placed_normal == b.placed_normal
    assert a.placed_preemptible == b.placed_preemptible
    assert a.preemptions == b.preemptions
    assert a.utilization == b.utilization


def test_apply_placement_matches_rebuild():
    """The standalone placement transition (used to re-apply recorded
    decisions) produces the same state as placing on the python Host and
    rebuilding."""
    from repro.core.jax_scheduler import apply_placement

    hosts = [Host(name="h0", capacity=CAP), Host(name="h1", capacity=CAP)]
    fleet = SoAFleet(hosts, cost_fn=PeriodCost(), k_slots=4)
    state = fleet.state
    placements = [
        ("n0", 0, SIZES[1], False, 100.0, 1.0),
        ("p0", 0, SIZES[0], True, 160.0, 2.0),
        ("p1", 1, SIZES[2], True, 220.0, 3.0),
    ]
    for iid, hi, res, pre, t, price in placements:
        state, slot = apply_placement(
            state, hi, res.vec32, pre, t, price
        )
        hosts[hi].place(
            Instance(id=iid, resources=res, preemptible=pre, host=hosts[hi].name,
                     start_time=t, price_rate=price)
        )
        if pre:  # slot table must track the placement for the oracle rebuild
            fleet.slot_ids[hi][int(slot)] = iid
    oracle, _ = build_fleet_state(
        hosts, k_slots=4, domain_ids=fleet.domain_ids,
        slot_assignment=fleet.slot_assignment(),
        zone_term=state.zone_term, zone_up=state.zone_up,
    )
    _assert_states_equal(state, oracle, msg="apply_placement")


def test_host_failure_frees_everything_and_heals():
    rng = np.random.default_rng(3)
    py = _PyMirror(4)
    fleet = SoAFleet(py.hosts, cost_fn=PeriodCost(), k_slots=K)
    for i in range(20):
        out = fleet.schedule_request(
            Request(
                id=f"r{i}", resources=SIZES[int(rng.integers(3))],
                preemptible=bool(i % 2),
            ),
            now=float(10 + i),
        )
        py.apply(out)
    n_pre, n_norm = fleet.fail_host("h1")
    assert n_pre + n_norm == len(py.by_name["h1"].instances)
    py.by_name["h1"].schedulable = False
    py.by_name["h1"].instances.clear()
    oracle, _ = build_fleet_state(
        py.hosts, k_slots=K, domain_ids=fleet.domain_ids,
        slot_assignment=fleet.slot_assignment(),
        zone_term=fleet.state.zone_term, zone_up=fleet.state.zone_up,
    )
    _assert_states_equal(fleet.state, oracle, msg="after failure")
    free = np.asarray(fleet.state.free_f)[1]
    np.testing.assert_array_equal(free, CAP.vec.astype(np.float32))

    fleet.heal_host("h1")
    py.by_name["h1"].schedulable = True
    oracle, _ = build_fleet_state(
        py.hosts, k_slots=K, domain_ids=fleet.domain_ids,
        slot_assignment=fleet.slot_assignment(),
        zone_term=fleet.state.zone_term, zone_up=fleet.state.zone_up,
    )
    _assert_states_equal(fleet.state, oracle, msg="after heal")
