"""MoE layer correctness: dispatch/combine vs a dense per-token reference,
capacity dropping semantics, aux-loss sanity."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models.layers import materialize
from repro.models.moe import _local_moe, moe_defs, moe_ffn


def setup(e=8, k=2, d=32, f=64, cf=16.0):
    cfg = dataclasses.replace(
        reduced(get_config("moonshot-v1-16b-a3b"), d_model=d, d_ff=f),
        n_experts=e, top_k=k, capacity_factor=cf,
    )
    params = materialize(moe_defs(cfg), jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, d), jnp.float32)
    return cfg, params, x


def dense_reference(x, p, cfg):
    """Per-token dense reference: run EVERY expert, combine top-k."""
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    logits = xf @ p["router"]
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_p, top_e = jax.lax.top_k(probs, cfg.top_k)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    gate = jnp.einsum("td,edf->tef", xf, p["wg"])
    up = jnp.einsum("td,edf->tef", xf, p["wu"])
    out_all = jnp.einsum("tef,efd->ted", jax.nn.silu(gate) * up, p["wd"])
    y = jnp.zeros_like(xf)
    for j in range(cfg.top_k):
        y = y + jnp.take_along_axis(
            out_all, top_e[:, j][:, None, None], axis=1
        )[:, 0, :] * top_p[:, j][:, None]
    return y.reshape(b, s, d)


def test_dispatch_matches_dense_reference():
    cfg, params, x = setup()
    y, aux = moe_ffn(x, params, cfg)
    ref = dense_reference(x, params, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-4, rtol=1e-4)


def test_capacity_drops_tokens_when_tight():
    cfg, params, x = setup(cf=16.0)
    y_full, _ = moe_ffn(x, params, cfg)
    tight = dataclasses.replace(cfg, capacity_factor=0.25)
    y_tight, _ = moe_ffn(x, params, tight)
    # tight capacity must change (drop) some token outputs, not NaN them
    assert np.isfinite(np.asarray(y_tight)).all()
    assert not np.allclose(np.asarray(y_full), np.asarray(y_tight))
    # dropped tokens produce zero contribution, never garbage
    norms = np.linalg.norm(np.asarray(y_tight), axis=-1)
    assert (norms <= np.linalg.norm(np.asarray(y_full), axis=-1).max() * 2).all()


def test_aux_loss_positive_and_order_one():
    cfg, params, x = setup()
    _, aux = moe_ffn(x, params, cfg)
    # Switch aux loss is ≥1 at balance (E * Σ f_e·p_e with Σf=Σp=1)
    assert 0.5 <= float(aux) < float(cfg.n_experts)


def test_moe_is_differentiable_through_dispatch():
    cfg, params, x = setup()

    def loss(p):
        y, aux = moe_ffn(x, p, cfg)
        return jnp.sum(jnp.square(y)) + 0.01 * aux

    grads = jax.grad(loss)(params)
    gnorm = float(
        jnp.sqrt(sum(jnp.sum(jnp.square(g)) for g in jax.tree.leaves(grads)))
    )
    assert np.isfinite(gnorm) and gnorm > 0
    # router receives gradient through the combine weights + aux loss
    assert float(jnp.sum(jnp.abs(grads["router"]))) > 0


def test_local_moe_peer_split_matches_single_peer():
    """The a2a-sharded math (n_peers>1) must equal the single-shard math.
    Simulated here by checking the n_peers=1 path against the dense ref and
    relying on tests/test_jax_scheduler-style shard_map equivalence (the
    shard_map path reuses _local_moe verbatim)."""
    cfg, params, x = setup(e=8, k=2)
    y1, _ = _local_moe(
        x, params["router"], params["wg"], params["wu"], params["wd"],
        cfg=cfg, n_peers=1, tp=1,
    )
    ref = dense_reference(x, params, cfg)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(ref), atol=1e-4, rtol=1e-4)
