"""Vectorized (jnp + Pallas) scheduler vs the python reference oracle.

The python ``PreemptibleScheduler`` is the paper-faithful implementation
already validated against the paper's Tables 3-6; here we require the JAX
reformulation to make identical decisions on randomized fleets.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.cost import PeriodCost
from repro.core.jax_scheduler import (
    JaxPreemptibleScheduler,
    build_soa_state,
    host_plan_terms,
    subset_masks,
)
from repro.core.scheduler import PreemptibleScheduler
from repro.core.types import VM_SPEC, Host, Instance, Request

NOW = 500_000.0

SIZES = {
    "small": VM_SPEC.make(vcpus=1, ram_mb=2000, disk_gb=20),
    "medium": VM_SPEC.make(vcpus=2, ram_mb=4000, disk_gb=40),
    "large": VM_SPEC.make(vcpus=4, ram_mb=8000, disk_gb=80),
}
CAP = VM_SPEC.make(vcpus=8, ram_mb=16000, disk_gb=160)


def random_fleet(rng, n_hosts: int, fill: float = 0.8):
    """Random hosts with mixed normal/preemptible instances; integer-minute
    run times so float32 cost arithmetic is exact."""
    hosts = []
    names = list(SIZES)
    iid = 0
    for i in range(n_hosts):
        h = Host(name=f"h{i}", capacity=CAP)
        while h.used().vec[0] < fill * CAP.vec[0]:
            size = SIZES[names[rng.integers(3)]]
            if not size.fits_in(h.free_full):
                break
            h.place(
                Instance(
                    id=f"x{iid}",
                    resources=size,
                    preemptible=bool(rng.random() < 0.5),
                    host=h.name,
                    start_time=NOW - float(rng.integers(10, 500)) * 60.0,
                )
            )
            iid += 1
        hosts.append(h)
    return hosts


@pytest.mark.parametrize("seed", range(6))
@pytest.mark.parametrize("preemptible", [False, True])
def test_jax_matches_python_reference(seed, preemptible):
    rng = np.random.default_rng(seed)
    hosts = random_fleet(rng, n_hosts=13)
    req = Request(
        id="q", resources=SIZES[["small", "medium", "large"][seed % 3]],
        preemptible=preemptible,
    )
    py = PreemptibleScheduler(cost_fn=PeriodCost())
    py._rng = np.random.default_rng(0)  # ties broken by argmax-first anyway
    jx = JaxPreemptibleScheduler(cost_fn=PeriodCost(), k_slots=8)

    r_py = py.schedule(req, hosts, NOW)
    r_jx = jx.schedule(req, hosts, NOW)

    assert r_py.ok == r_jx.ok
    if r_py.ok:
        # Decisions must agree on cost; host may differ only on exact ties.
        assert r_jx.plan.cost == pytest.approx(r_py.plan.cost, abs=1e-2)
        if abs(r_py.plan.cost - r_jx.plan.cost) < 1e-6 and r_py.host != r_jx.host:
            pass  # tie between hosts — both optimal
        else:
            assert set(r_jx.plan.ids) == set(r_py.plan.ids)


@pytest.mark.parametrize("seed", range(3))
def test_pallas_kernel_matches_jnp_oracle(seed):
    rng = np.random.default_rng(seed + 100)
    hosts = random_fleet(rng, n_hosts=37)
    state, _ = build_soa_state(hosts, NOW, PeriodCost(), k_slots=8)
    masks = subset_masks(8)
    req = np.asarray(SIZES["large"].vec, np.float32)

    ref_cost, ref_mask, ref_feas = host_plan_terms(
        state.free_f, state.inst_res, state.inst_cost, state.inst_valid,
        req, masks,
    )
    from repro.kernels.sched_weigh import sched_weigh

    k_cost, k_mask, k_feas = sched_weigh(
        state.free_f, state.inst_res, state.inst_cost, state.inst_valid,
        req, masks, interpret=True,
    )
    np.testing.assert_array_equal(np.asarray(ref_feas), np.asarray(k_feas))
    # costs: exact where feasible (integer-minute inputs)
    feas = np.asarray(ref_feas)
    np.testing.assert_allclose(
        np.asarray(k_cost)[feas], np.asarray(ref_cost)[feas], rtol=1e-6
    )
    np.testing.assert_array_equal(np.asarray(k_mask)[feas], np.asarray(ref_mask)[feas])


def test_pallas_end_to_end_decision():
    from repro.core.policy import SchedulerPolicy

    rng = np.random.default_rng(7)
    hosts = random_fleet(rng, n_hosts=20)
    req = Request(id="q", resources=SIZES["medium"], preemptible=False)
    jx = JaxPreemptibleScheduler(
        cost_fn=PeriodCost(), k_slots=8,
        policy=SchedulerPolicy(use_pallas=False),
    )
    jp = JaxPreemptibleScheduler(
        cost_fn=PeriodCost(), k_slots=8,
        policy=SchedulerPolicy(use_pallas=True),
    )
    a = jx.schedule(req, hosts, NOW)
    b = jp.schedule(req, hosts, NOW)
    assert a.ok == b.ok and a.host == b.host and a.plan.ids == b.plan.ids
