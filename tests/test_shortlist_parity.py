"""Shortlist-pruning parity: the two-stage O(N·K + M·2^K) pipeline must make
decisions BIT-IDENTICAL to the single-stage O(N·2^K) full enumeration, for
every shortlist size M — including M far below the feasible-host count, where
the admissibility check must detect uncertain prunes and fall back.

Inputs are integer-valued (resources, minutes, prices) — the regime where the
screen's bounds hold bitwise and parity is unconditional.  The "revenue" and
fallback cases additionally exercise non-dyadic slot costs (``/period``),
where the admissibility check's ulp margin keeps the paths aligned.

CI treats a skip of this file as a failure (see .github/workflows/ci.yml):
the hypothesis-based cases below are the acceptance gate for the pruned path.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cost import CountCost, PeriodCost, RecomputeCost, RevenueCost
from repro.core.jax_scheduler import (
    SoAHostState,
    build_soa_state,
    schedule_decision,
    schedule_step,
)
from repro.core.policy import SchedulerPolicy
from repro.core.soa_fleet import AdaptiveShortlist, SoAFleet
from repro.core.types import VM_SPEC, Host, Instance, Request

NOW = 500_000.0
CAP = VM_SPEC.make(vcpus=8, ram_mb=16000, disk_gb=160)
SIZES = [
    VM_SPEC.make(vcpus=1, ram_mb=2000, disk_gb=20),
    VM_SPEC.make(vcpus=2, ram_mb=4000, disk_gb=40),
    VM_SPEC.make(vcpus=4, ram_mb=8000, disk_gb=80),
]


def _random_fleet(rng, n_hosts, fill=0.85, k_max=8):
    hosts = []
    iid = 0
    for i in range(n_hosts):
        h = Host(name=f"h{i}", capacity=CAP)
        while h.used().vec[0] < fill * CAP.vec[0]:
            size = SIZES[int(rng.integers(3))]
            if not size.fits_in(h.free_full):
                break
            pre = bool(rng.random() < 0.6) and len(h.preemptible_instances()) < k_max
            h.place(
                Instance(
                    id=f"x{iid}",
                    resources=size,
                    preemptible=pre,
                    host=h.name,
                    start_time=NOW - float(rng.integers(10, 500)) * 60.0,
                )
            )
            iid += 1
        hosts.append(h)
    return hosts


def _decide(state, req_vec, preemptible, shortlist, multipliers=(1.0, 1.0, 0.0, 0.0)):
    h, m, ok = schedule_decision(
        state,
        jnp.asarray(req_vec, jnp.float32),
        jnp.asarray(preemptible),
        jnp.asarray(-1, jnp.int32),
        policy=SchedulerPolicy(
            weigher_multipliers=multipliers, shortlist=shortlist
        ),
    )
    return int(h), int(m), bool(ok)


@pytest.mark.parametrize("k", [4, 8, 10])
@pytest.mark.parametrize("seed", range(3))
def test_shortlist_matches_full_enumeration(k, seed):
    """Randomized fleets, normal+preemptible requests, M ∈ {1, 4, 16} (all
    below the host count): decisions identical to shortlist=0."""
    rng = np.random.default_rng(1000 * k + seed)
    hosts = _random_fleet(rng, n_hosts=int(rng.integers(18, 40)), k_max=k)
    state, _ = build_soa_state(hosts, NOW, PeriodCost(), k_slots=k)
    for preemptible in (False, True):
        for size in SIZES:
            full = _decide(state, size.vec, preemptible, shortlist=0)
            for m in (1, 4, 16):
                assert _decide(state, size.vec, preemptible, shortlist=m) == full, (
                    f"k={k} seed={seed} pre={preemptible} M={m}"
                )


@pytest.mark.parametrize(
    "cost_fn", [PeriodCost(), CountCost(), RevenueCost(), RecomputeCost()]
)
def test_shortlist_parity_on_fleet_state_step(cost_fn):
    """Same contract on the persistent-state path (schedule_step), across
    every device-resident cost kind."""
    rng = np.random.default_rng(7)
    hosts = _random_fleet(rng, 32)
    fleet = SoAFleet(hosts, cost_fn=cost_fn, k_slots=8)
    for step in range(12):
        now = NOW + 60.0 * step
        pre = bool(step % 3 == 0)
        req = np.asarray(SIZES[step % 3].vec, np.float32)
        _, full = schedule_step(
            fleet.state, req, pre, np.int32(-1), now, 1.0,
            policy=dataclasses.replace(fleet.policy, shortlist=0),
            donate=False,
        )
        for m in (2, 8):
            _, got = schedule_step(
                fleet.state, req, pre, np.int32(-1), now, 1.0,
                policy=dataclasses.replace(fleet.policy, shortlist=m),
                donate=False,
            )
            # decision outputs only — the trailing (fell_back, margin)
            # health signals differ between shortlist settings by design
            for a, b in zip(full[:4], got[:4]):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # advance the fleet so later steps see occupied/terminated slots
        fleet.schedule_request(
            Request(id=f"r{step}", resources=SIZES[step % 3], preemptible=pre),
            now,
        )


def _decide_churn(state, req_vec, preemptible, shortlist,
                  churn_multiplier=2.0, churn_threshold=None):
    h, m, ok = schedule_decision(
        state,
        jnp.asarray(req_vec, jnp.float32),
        jnp.asarray(preemptible),
        jnp.asarray(-1, jnp.int32),
        policy=SchedulerPolicy(
            shortlist=shortlist,
            churn_multiplier=churn_multiplier,
            churn_threshold=churn_threshold,
        ),
    )
    return int(h), int(m), bool(ok)


@pytest.mark.parametrize("seed", range(3))
def test_shortlist_matches_full_enumeration_churn_aware(seed):
    """Churn-aware decisions (nonzero churn multiplier, with and without the
    hot-zone threshold) prune identically: the churn term shifts which hosts
    win, never whether the shortlist reproduces the full enumeration."""
    rng = np.random.default_rng(4000 + seed)
    hosts = _random_fleet(rng, n_hosts=int(rng.integers(18, 36)))
    for i, h in enumerate(hosts):
        h.zone = f"z{i % 3}"
    # the rebuild oracle's frozen ẑ column (dyadic rates stay f32-exact)
    zone_rates = {"z0": 0.0, "z1": 0.25, "z2": 0.75}
    state, _ = build_soa_state(
        hosts, NOW, PeriodCost(), k_slots=8, zone_rates=zone_rates
    )
    assert state.churn is not None
    for preemptible in (False, True):
        for thr in (None, 0.5):
            full = _decide_churn(
                state, SIZES[1].vec, preemptible, shortlist=0,
                churn_threshold=thr,
            )
            for m in (1, 4, 16):
                got = _decide_churn(
                    state, SIZES[1].vec, preemptible, shortlist=m,
                    churn_threshold=thr,
                )
                assert got == full, (
                    f"seed={seed} pre={preemptible} thr={thr} M={m}"
                )


def test_fallback_on_loose_bound():
    """Deterministic fallback exercise: the cost lower bound (m* cheapest
    slots) undershoots the true optimum on host A (its cheap slots conflict
    across dims), so a 1-candidate shortlist picks A optimistically and the
    admissibility check must fall back to pick the true winner B."""
    free_f = np.zeros((2, 2), np.float32)
    free_n = np.full((2, 2), 4.0, np.float32)
    inst_res = np.array(
        [
            [[4, 0], [0, 4], [4, 4]],    # A: cheap slots cover one dim each
            [[4, 4], [0, 0], [0, 0]],    # B: one slot covers both
        ],
        np.float32,
    )
    inst_cost = np.array([[10, 10, 50], [15, 0, 0]], np.float32)
    inst_valid = np.array([[1, 1, 1], [1, 0, 0]], bool)
    state = SoAHostState(
        free_f=jnp.asarray(free_f),
        free_n=jnp.asarray(free_n),
        schedulable=jnp.ones((2,), bool),
        domain=jnp.zeros((2,), jnp.int32),
        slow=jnp.ones((2,), jnp.float32),
        inst_res=jnp.asarray(inst_res),
        inst_cost=jnp.asarray(inst_cost),
        inst_valid=jnp.asarray(inst_valid),
    )
    req = np.array([4.0, 4.0], np.float32)
    full = _decide(state, req, False, shortlist=0)
    assert full[0] == 1 and full[2]      # B's single 15-cost slot wins
    assert _decide(state, req, False, shortlist=1) == full


# ---------------------------------------------------------------------------
# Adaptive shortlist: the host-side controller over the jit'd paths
# ---------------------------------------------------------------------------


def test_adaptive_controller_grow_and_shrink():
    """Grow ×2 after a fallback streak; shrink ÷2 only after a calm streak
    WITH wide margins; both clamped to [m_min, m_max]."""
    c = AdaptiveShortlist(m=32, m_min=16, m_max=64, grow_after=2,
                          shrink_after=3, wide_margin=0.1)
    c.update(1, 0.0)
    assert c.m == 32                      # one fallback flush: not yet
    c.update(3, 0.0)
    assert c.m == 64 and c.grows == 1     # streak of 2 → grow
    c.update(1, 0.0)
    c.update(1, 0.0)
    assert c.m == 64                      # clamped at m_max
    for _ in range(3):
        c.update(0, 0.05)
    assert c.m == 64                      # calm but margins tight: no shrink
    for _ in range(3):
        c.update(0, 5.0)
    assert c.m == 32 and c.shrinks == 1   # calm + wide → shrink
    for _ in range(6):
        c.update(0, 5.0)
    assert c.m == 16                      # floor
    for _ in range(3):
        c.update(0, 5.0)
    assert c.m == 16                      # clamped at m_min


def test_adaptive_fleet_decisions_and_counters():
    """The adaptive fleet makes the SAME decisions as a static fleet (M
    never changes correctness — only which path computes it) and exposes the
    fallback/decision counters through shortlist_stats."""
    rng = np.random.default_rng(11)
    hosts = _random_fleet(rng, 24)
    static = SoAFleet(
        hosts, cost_fn=PeriodCost(), k_slots=8,
        policy=SchedulerPolicy(shortlist=4),
    )
    # starting M below adaptive_bounds is legal (pre-policy behavior: the
    # controller clamps as it moves)
    adaptive = SoAFleet(
        hosts, cost_fn=PeriodCost(), k_slots=8,
        policy=SchedulerPolicy(shortlist=4, adaptive_shortlist=True),
    )
    assert adaptive.effective_shortlist == 4
    items = [
        (Request(id=f"r{i}", resources=SIZES[i % 3],
                 preemptible=bool(i % 2)), NOW + 60.0 * i, 1.0)
        for i in range(6)
    ]
    out_s = static.schedule_batch(list(items))
    out_a = adaptive.schedule_batch(list(items))
    assert [(o.host, o.ok) for o in out_s] == [(o.host, o.ok) for o in out_a]
    stats = adaptive.shortlist_stats
    assert stats["decisions"] == 6
    assert stats["fallbacks"] >= 0
    assert set(stats) == {"decisions", "fallbacks", "shortlist", "grows", "shrinks"}
    # single-step path feeds the same counters
    adaptive.schedule_request(
        Request(id="rx", resources=SIZES[0], preemptible=False), NOW + 1e4
    )
    assert adaptive.shortlist_stats["decisions"] == 7


# ---------------------------------------------------------------------------
# Property-based sweep (hypothesis): arbitrary integer fleets and requests.
# Guarded per-test (NOT importorskip) so the deterministic parity cases above
# always run; the leftover skip is what the CI gate turns into a failure.
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @st.composite
    def soa_states(draw):
        n = draw(st.integers(2, 24))
        k = draw(st.sampled_from([4, 8, 10]))
        d = 2
        rng = np.random.default_rng(draw(st.integers(0, 2**31 - 1)))
        state = SoAHostState(
            free_f=jnp.asarray(rng.integers(0, 7, (n, d)).astype(np.float32)),
            free_n=jnp.asarray(rng.integers(2, 10, (n, d)).astype(np.float32)),
            schedulable=jnp.asarray(rng.random(n) < 0.9),
            domain=jnp.zeros((n,), jnp.int32),
            slow=jnp.asarray(rng.integers(1, 5, (n,)).astype(np.float32)),
            inst_res=jnp.asarray(rng.integers(0, 5, (n, k, d)).astype(np.float32)),
            inst_cost=jnp.asarray(
                (rng.integers(0, 60, (n, k)) * 60).astype(np.float32)
            ),
            inst_valid=jnp.asarray(rng.random((n, k)) < 0.65),
        )
        return state, rng

    @given(
        soa_states(),
        st.integers(1, 8),
        st.booleans(),
        st.sampled_from(
            [(1.0, 1.0, 0.0, 0.0), (1.0, 2.0, 0.5, 0.25), (0.0, 1.0, 0.0, 0.0)]
        ),
    )
    @settings(max_examples=25, deadline=None)
    def test_shortlist_parity_property(state_rng, m, preemptible, multipliers):
        """For ANY fleet, request, multipliers, and shortlist size, the
        pruned decision equals the full enumeration bit-for-bit."""
        state, rng = state_rng
        req = rng.integers(1, 10, (2,)).astype(np.float32)
        full = _decide(state, req, preemptible, shortlist=0, multipliers=multipliers)
        got = _decide(state, req, preemptible, shortlist=m, multipliers=multipliers)
        assert got == full

else:

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_shortlist_parity_property():
        pass
