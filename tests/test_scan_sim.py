"""Differential parity suite for the fully on-device scanned simulator.

``core.scan_sim.simulate_scan`` folds the ENTIRE event stream — arrivals
(mixed cost kinds / periods / priorities), departures, host failures and
heals, zone storms, checkpoints — into one jitted ``lax.scan``.  This suite
pins it **bit-exact** against the python ``SoASimulator`` oracle
(``run_trace``), which replays the identical ``EventTrace`` through the
seven-PR-old incremental fleet path:

  * final fleet-state arrays equal bitwise (every column, dead-slot
    payloads included);
  * per-arrival placement/rejection sequences identical (host, slot, ok,
    victim count per event);
  * every ``SimMetrics`` counter equal and every sample-point utilization
    reading equal bitwise (integer-resource f32 sums are exact under any
    association, so fused device reductions == sequential python adds);
  * resources are conserved at every sample point and at the end.

Randomness is a SEEDED SWEEP (``PARITY_SEEDS`` / property-style generators
with explicit ``np.random.default_rng`` seeds) — no hypothesis dependency,
no environment probing, NO skip paths: every test in this file always runs,
and CI gates the suite fail-on-skip next to the other parity gates.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import scan_sim as ss
from repro.core.policy import COST_KINDS, SchedulerPolicy
from repro.core.scan_sim import (
    ARRIVAL,
    EventTrace,
    TraceEvent,
    simulate_ensemble,
    simulate_scan,
    trace_from_workload,
)
from repro.core.simulator import SoASimulator, WorkloadSpec
from repro.core.types import VM_SPEC, Host

CAP = VM_SPEC.make(vcpus=8, ram_mb=16000, disk_gb=160)
SIZES = [
    VM_SPEC.make(vcpus=1, ram_mb=2000, disk_gb=20),
    VM_SPEC.make(vcpus=2, ram_mb=4000, disk_gb=40),
    VM_SPEC.make(vcpus=4, ram_mb=8000, disk_gb=80),
]
K = 8

#: the seeded sweep driving the randomized differential cases
PARITY_SEEDS = (1, 2, 3, 5)

#: every device-resident billing kind in one mixed table
MIXED_POLICY = SchedulerPolicy(
    cost_kind="period",
    cost_kinds=("count", "revenue", "recompute"),
)


def _hosts(n: int, n_zones: int = 3):
    return [
        Host(
            name=f"h{i}", capacity=CAP, domain=f"dom{i % 2}",
            zone=f"z{i % n_zones}",
        )
        for i in range(n)
    ]


def _workload(rate: float = 1 / 20.0, frac: float = 0.6) -> WorkloadSpec:
    return WorkloadSpec(
        arrival_rate_per_s=rate,
        flavors=[(f"f{i}", s) for i, s in enumerate(SIZES)],
        preemptible_fraction=frac,
    )


def _snapshot(state):
    """Deep-copy a fleet state: the python loop's donated transitions
    consume the original buffers."""
    return jax.tree_util.tree_map(
        lambda a: jnp.asarray(np.asarray(a)), state
    )


def _rich_trace(seed: int, duration: float = 8000.0,
                n_hosts: int = 16) -> EventTrace:
    """A randomized all-kinds trace: mixed billing, mixed priorities,
    storms in every zone, a mid-run host failure + heal, periodic
    checkpoints.  Always 300+ events at the default duration/rate."""
    rng = np.random.default_rng(seed * 7919)
    storms = [
        (float(rng.integers(int(duration * 0.2), int(duration * 0.9))),
         int(z), float(f))
        for z, f in zip(range(3), (0.5, 0.3, 0.8))
    ]
    failures = [
        (float(rng.integers(int(duration * 0.3), int(duration * 0.6))),
         int(rng.integers(0, n_hosts)), duration * 0.15),
    ]
    return trace_from_workload(
        _workload(), duration, seed=seed,
        storms=storms, failures=failures, checkpoint_every=3,
        cost_kinds=(-1, 0, 1, 2, 3, 1, -1, 3),
        priorities=(-1, 0, 1, 2),
    )


def _assert_bitwise_equal(py_sim: SoASimulator, dev: ss.ScanResult,
                          m_py, trace: EventTrace) -> None:
    # 1. final fleet-state arrays, every column bitwise
    for f in dataclasses.fields(py_sim.fleet.state):
        a = np.asarray(getattr(py_sim.fleet.state, f.name))
        b = np.asarray(getattr(dev.state, f.name))
        assert np.array_equal(a, b), f"state column {f.name} diverged"
    # 2. per-arrival placement/rejection sequence
    seq_dev = np.stack(
        [dev.host, dev.slot, dev.ok.astype(np.int64), dev.n_kill], axis=1
    )
    assert np.array_equal(seq_dev, py_sim.trace_outcomes), (
        "placement/rejection sequences diverged"
    )
    # 3. SimMetrics: every counter + every sample reading
    m_dev = dev.sim_metrics(py_sim.fleet._cap0_total)
    for name in (
        "placed_normal", "placed_preemptible", "failures_normal",
        "failures_preemptible", "preemptions", "storms", "storm_kills",
    ):
        assert getattr(m_py, name) == getattr(m_dev, name), name
    assert m_py.t == m_dev.t
    assert m_py.utilization == m_dev.utilization
    assert m_py.utilization_normal == m_dev.utilization_normal
    # 4. conservation at every sample point: the used capacity implied by
    #    each sample stays within [0, cap] on both engines (they are equal
    #    bitwise by now) ...
    cap = py_sim.fleet._cap0_total
    for u in m_dev.utilization:
        assert 0.0 <= u <= 1.0 + 1e-12
    # ... and exactly at the end: per host, free + live preemptible + live
    #     normal == capacity, cross-checked against the python mirror.
    free = np.asarray(dev.state.free_f)
    used_pre = np.asarray(
        jnp.sum(
            jnp.where(
                dev.state.inst_valid[:, :, None], dev.state.inst_res, 0.0
            ),
            axis=1,
        )
    )
    used_norm = np.zeros_like(free)
    for iid, (h, slot) in py_sim.fleet.locator.items():
        if slot is None:
            used_norm[h] += py_sim.fleet.instances[iid].resources.vec32
    total = free + used_pre + used_norm
    cap_vec = np.asarray(CAP.vec32)
    assert np.array_equal(total, np.broadcast_to(cap_vec, total.shape)), (
        "resource conservation violated at end of trace"
    )


def _run_both(trace: EventTrace, policy: SchedulerPolicy, n_hosts: int,
              seed: int = 0):
    sim = SoASimulator(
        _hosts(n_hosts), _workload(), seed=seed, k_slots=K, policy=policy
    )
    state0 = _snapshot(sim.fleet.state)
    m_py = sim.run_trace(trace)
    dev = simulate_scan(trace, policy, state0)
    return sim, dev, m_py


# ---------------------------------------------------------------------------
# 1. the headline differential sweep: all kinds, mixed billing, randomized
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", PARITY_SEEDS)
def test_scan_parity_randomized_all_kinds(seed):
    trace = _rich_trace(seed)
    assert trace.n_events >= 300, "sweep must exercise 300+ events"
    kinds = set(np.unique(trace.kind).tolist())
    assert {ss.ARRIVAL, ss.DEPARTURE, ss.FAIL_HOST, ss.HEAL_HOST,
            ss.CHECKPOINT, ss.ZONE_STORM} <= kinds
    assert len(set(np.unique(trace.cost_kind).tolist())) >= 4
    sim, dev, m_py = _run_both(trace, MIXED_POLICY, n_hosts=16, seed=seed)
    _assert_bitwise_equal(sim, dev, m_py, trace)


def test_scan_parity_default_policy_high_pressure():
    """Saturation regime: rejections + scheduler preemptions dominate."""
    trace = trace_from_workload(
        WorkloadSpec(
            arrival_rate_per_s=1 / 6.0,
            flavors=[(f"f{i}", s) for i, s in enumerate(SIZES)],
            preemptible_fraction=0.5,
        ),
        4000.0, seed=11,
    )
    assert trace.n_events >= 300
    sim, dev, m_py = _run_both(trace, SchedulerPolicy(), n_hosts=8, seed=11)
    assert m_py.failures_normal + m_py.failures_preemptible > 0
    assert m_py.preemptions > 0
    _assert_bitwise_equal(sim, dev, m_py, trace)


def test_scan_parity_storm_only_and_empty_zone():
    """Storms against both a populated and an EMPTY zone (counts a storm,
    kills nobody) stay exact, including the zone churn accumulators."""
    trace = trace_from_workload(
        _workload(frac=1.0), 3000.0, seed=4,
        storms=((100.0, 2, 0.7), (1500.0, 0, 0.5), (2500.0, 1, 1.0)),
    )
    sim, dev, m_py = _run_both(trace, SchedulerPolicy(), n_hosts=9, seed=4)
    assert m_py.storms == 3
    _assert_bitwise_equal(sim, dev, m_py, trace)


def test_scan_parity_failure_heal_cycle():
    trace = trace_from_workload(
        _workload(), 5000.0, seed=9,
        failures=((1200.0, 1, 600.0), (2400.0, 3, None), (3000.0, 0, 300.0)),
        checkpoint_every=2,
    )
    sim, dev, m_py = _run_both(trace, SchedulerPolicy(), n_hosts=10, seed=9)
    _assert_bitwise_equal(sim, dev, m_py, trace)


def test_scan_parity_sample_cadence():
    """Sample-point semantics match at a non-default cadence (sample rows
    interleave differently with flush boundaries)."""
    trace = _rich_trace(2, duration=4000.0)
    policy = MIXED_POLICY
    sim = SoASimulator(_hosts(16), _workload(), seed=2, k_slots=K,
                       policy=policy)
    state0 = _snapshot(sim.fleet.state)
    m_py = sim.run_trace(trace, sample_every_s=170.0)
    dev = simulate_scan(trace, policy, state0, sample_every_s=170.0)
    m_dev = dev.sim_metrics(sim.fleet._cap0_total)
    assert m_py.t == m_dev.t
    assert m_py.utilization == m_dev.utilization
    assert m_py.utilization_normal == m_dev.utilization_normal


# ---------------------------------------------------------------------------
# 2. trace round-trip + malformed-trace rejection
# ---------------------------------------------------------------------------
def _random_events(rng, n: int):
    events, arrivals = [], []
    t = 0.0
    for _ in range(n):
        t += float(rng.integers(0, 30))
        k = rng.choice(["arrival", "departure", "fail_host", "heal_host",
                        "checkpoint", "zone_storm", "pad"])
        if k == "arrival":
            ev = TraceEvent(
                kind=k, time=t,
                res=tuple(float(v) for v in rng.integers(1, 8, size=3)),
                preemptible=bool(rng.random() < 0.5),
                duration=float(rng.integers(60, 600)),
                cost_kind=int(rng.integers(-1, 4)),
                period=float(rng.choice([-1.0, 60.0, 3600.0])),
                price=float(rng.integers(1, 5)),
                priority=int(rng.integers(-1, 3)),
                domain=int(rng.integers(-1, 2)),
            )
            arrivals.append(len(events))
        elif k in ("departure", "checkpoint") and arrivals:
            ev = TraceEvent(kind=k, time=t,
                            inst_id=int(rng.choice(arrivals)))
        elif k == "fail_host" or k == "heal_host":
            ev = TraceEvent(kind=k, time=t, host=int(rng.integers(0, 8)))
        elif k == "zone_storm":
            ev = TraceEvent(kind=k, time=t, zone=int(rng.integers(0, 3)),
                            frac=float(rng.uniform(0.1, 1.0)))
        else:
            ev = TraceEvent(kind="pad", time=t)
        events.append(ev)
    return events


@pytest.mark.parametrize("seed", PARITY_SEEDS)
def test_trace_round_trip_identity(seed):
    rng = np.random.default_rng(seed)
    events = _random_events(rng, 120)
    trace = EventTrace.from_events(events, n_dims=3)
    back = EventTrace.from_events(trace.events(), n_dims=3)
    for f in dataclasses.fields(EventTrace):
        assert np.array_equal(getattr(trace, f.name), getattr(back, f.name)), (
            f"round-trip diverged on column {f.name}"
        )


def test_workload_trace_round_trips_too():
    trace = _rich_trace(1, duration=2000.0)
    back = EventTrace.from_events(trace.events(), n_dims=trace.n_dims)
    for f in dataclasses.fields(EventTrace):
        assert np.array_equal(getattr(trace, f.name), getattr(back, f.name))


def test_malformed_unsorted_times_rejected():
    ok = EventTrace.from_events(
        [TraceEvent(kind="pad", time=10.0), TraceEvent(kind="pad", time=5.0)][:1],
        n_dims=2,
    )
    assert ok.n_events == 1
    with pytest.raises(ValueError, match=r"unsorted times: time\[1\]"):
        EventTrace.from_events(
            [TraceEvent(kind="pad", time=10.0),
             TraceEvent(kind="pad", time=5.0)],
            n_dims=2,
        )


def test_malformed_unknown_kind_rejected():
    with pytest.raises(ValueError, match="unknown event kind 'meteor'"):
        EventTrace.from_events([TraceEvent(kind="meteor", time=0.0)], n_dims=2)
    good = EventTrace.from_events([TraceEvent(kind="pad", time=0.0)], n_dims=2)
    bad_kind = np.array([99], np.int32)
    with pytest.raises(ValueError, match="unknown event kind 99 at row 0"):
        dataclasses.replace(good, kind=bad_kind)


def test_malformed_nan_payload_rejected():
    with pytest.raises(ValueError, match="NaN payload in column 'frac' at row 0"):
        EventTrace.from_events(
            [TraceEvent(kind="zone_storm", time=0.0, zone=0, frac=np.nan)],
            n_dims=2,
        )
    with pytest.raises(ValueError, match="NaN payload in column 'res' at row 0"):
        EventTrace.from_events(
            [TraceEvent(kind="arrival", time=0.0, res=(1.0, np.nan),
                        duration=60.0)],
            n_dims=2,
        )
    with pytest.raises(ValueError, match="non-finite arrival size at row 0"):
        EventTrace.from_events(
            [TraceEvent(kind="arrival", time=0.0, res=(1.0, np.inf),
                        duration=60.0)],
            n_dims=2,
        )
    with pytest.raises(ValueError, match="non-finite time at row 1"):
        EventTrace.from_events(
            [TraceEvent(kind="pad", time=0.0),
             TraceEvent(kind="pad", time=np.nan)],
            n_dims=2,
        )


def test_malformed_targets_rejected():
    with pytest.raises(ValueError, match="departure at row 0 targets"):
        EventTrace.from_events(
            [TraceEvent(kind="departure", time=0.0, inst_id=5)], n_dims=2
        )
    with pytest.raises(ValueError, match="checkpoint at row 0 targets"):
        EventTrace.from_events(
            [TraceEvent(kind="checkpoint", time=0.0, inst_id=-1)], n_dims=2
        )
    with pytest.raises(ValueError, match="precedes its arrival"):
        EventTrace.from_events(
            [TraceEvent(kind="departure", time=0.0, inst_id=1),
             TraceEvent(kind="arrival", time=5.0, res=(1.0, 1.0),
                        duration=60.0)],
            n_dims=2,
        )
    with pytest.raises(ValueError, match="kill fraction 1.5"):
        EventTrace.from_events(
            [TraceEvent(kind="zone_storm", time=0.0, zone=0, frac=1.5)],
            n_dims=2,
        )
    with pytest.raises(ValueError, match="fail_host at row 0 has no host"):
        EventTrace.from_events(
            [TraceEvent(kind="fail_host", time=0.0)], n_dims=2
        )


def test_trace_vs_fleet_validation():
    trace = EventTrace.from_events(
        [TraceEvent(kind="fail_host", time=0.0, host=99)], n_dims=3
    )
    sim = SoASimulator(_hosts(4), _workload(), seed=0, k_slots=K,
                       policy=SchedulerPolicy())
    with pytest.raises(ValueError, match="host index out of range"):
        simulate_scan(trace, SchedulerPolicy(), sim.fleet.state)
    kinds = EventTrace.from_events(
        [TraceEvent(kind="arrival", time=0.0, res=(1.0, 1.0, 1.0),
                    duration=60.0, cost_kind=COST_KINDS.index("revenue"))],
        n_dims=3,
    )
    with pytest.raises(ValueError, match="not in the\\s+policy's kind table"):
        simulate_scan(kinds, SchedulerPolicy(), sim.fleet.state)


# ---------------------------------------------------------------------------
# 3. ensemble determinism
# ---------------------------------------------------------------------------
def _lane_equal(a: ss.ScanResult, b: ss.ScanResult) -> None:
    assert a.counters == b.counters
    assert np.array_equal(a.host, b.host)
    assert np.array_equal(a.slot, b.slot)
    assert np.array_equal(a.ok, b.ok)
    assert np.array_equal(a.n_kill, b.n_kill)
    assert np.array_equal(a.sample_t, b.sample_t)
    assert np.array_equal(a.sample_free0, b.sample_free0)
    assert np.array_equal(a.sample_free0_normal, b.sample_free0_normal)
    for f in dataclasses.fields(a.state):
        assert np.array_equal(
            np.asarray(getattr(a.state, f.name)),
            np.asarray(getattr(b.state, f.name)),
        ), f"lane state column {f.name}"


def test_ensemble_equals_independent_runs():
    """32 seeds in ONE vmapped dispatch == 32 independent simulate_scan
    dispatches, element-wise bitwise (integer-cost regime)."""
    n_seeds = 32
    policy = SchedulerPolicy()
    sim = SoASimulator(_hosts(8), _workload(), seed=0, k_slots=K,
                       policy=policy)
    state0 = sim.fleet.state
    traces = [
        trace_from_workload(
            _workload(rate=1 / 40.0), 1500.0, seed=s,
            storms=((800.0, s % 3, 0.5),),
        )
        for s in range(n_seeds)
    ]
    # pad singles to one shared length so they share one compiled program
    emax = max(t.n_events for t in traces)
    padded = [t.padded(emax) for t in traces]
    singles = [simulate_scan(t, policy, state0) for t in padded]
    lanes = simulate_ensemble(traces, policy, state0)
    assert len(lanes) == n_seeds
    for single, lane, t in zip(singles, lanes, traces):
        e = t.n_events
        trimmed = dataclasses.replace(
            single, host=single.host[:e], slot=single.slot[:e],
            ok=single.ok[:e], n_kill=single.n_kill[:e],
        )
        _lane_equal(trimmed, lane)


def test_ensemble_bitwise_reproducible_across_dispatches():
    policy = SchedulerPolicy()
    sim = SoASimulator(_hosts(8), _workload(), seed=0, k_slots=K,
                       policy=policy)
    state0 = sim.fleet.state
    traces = [
        trace_from_workload(_workload(rate=1 / 50.0), 1200.0, seed=s)
        for s in range(8)
    ]
    first = simulate_ensemble(traces, policy, state0)
    second = simulate_ensemble(traces, policy, state0)
    for a, b in zip(first, second):
        _lane_equal(a, b)


def test_ensemble_multiplier_axis():
    """The stacked-policy-scalars axis: traced weigher multipliers ride a
    vmap lane each; a row equal to the static policy's multipliers is
    bitwise identical to the plain scan."""
    policy = SchedulerPolicy()  # weigher (1, 1, 0, 0), churn 0
    sim = SoASimulator(_hosts(8), _workload(), seed=0, k_slots=K,
                       policy=policy)
    state0 = sim.fleet.state
    trace = trace_from_workload(_workload(rate=1 / 30.0), 1500.0, seed=3)
    mults = np.array(
        [
            [1.0, 1.0, 0.0, 0.0, 0.0],   # == static row
            [4.0, 0.25, 0.0, 0.0, 0.0],
            [0.5, 2.0, 0.0, 0.0, 0.0],
        ],
        np.float32,
    )
    lanes = simulate_ensemble([trace], policy, state0, mults=mults)
    assert len(lanes) == 3
    plain = simulate_scan(trace, policy, state0)
    _lane_equal(plain, lanes[0])
    one = simulate_scan(trace, policy, state0, mult=mults[1])
    _lane_equal(one, lanes[1])


def test_ensemble_multiplier_validation():
    policy = SchedulerPolicy()
    sim = SoASimulator(_hosts(4), _workload(), seed=0, k_slots=K,
                       policy=policy)
    trace = trace_from_workload(_workload(rate=1 / 100.0), 500.0, seed=0)
    with pytest.raises(ValueError, match="column 2 must be 0"):
        simulate_ensemble([trace], policy, sim.fleet.state,
                          mults=np.array([[1.0, 1.0, 0.5, 0.0, 0.0]]))
    with pytest.raises(ValueError, match="keep the\\s+static multiplier's sign"):
        simulate_ensemble([trace], policy, sim.fleet.state,
                          mults=np.array([[1.0, -1.0, 0.0, 0.0, 0.0]]))
    with pytest.raises(ValueError, match="must have 5 entries"):
        simulate_ensemble([trace], policy, sim.fleet.state,
                          mults=np.array([[1.0, 1.0]]))


# ---------------------------------------------------------------------------
# 4. unsupported-plane guards
# ---------------------------------------------------------------------------
def test_unsupported_planes_raise():
    sim = SoASimulator(_hosts(4), _workload(), seed=0, k_slots=K,
                       policy=SchedulerPolicy())
    trace = trace_from_workload(_workload(rate=1 / 100.0), 400.0, seed=0)
    for bad in (
        SchedulerPolicy(relocate_threshold=0.5),
        SchedulerPolicy(adaptive_shortlist=True, shortlist=32),
    ):
        with pytest.raises(NotImplementedError,
                           match="which-planes-scan"):
            simulate_scan(trace, bad, sim.fleet.state)
    with pytest.raises(NotImplementedError):
        simulate_ensemble([trace], SchedulerPolicy(use_pallas=True),
                          sim.fleet.state)


# ---------------------------------------------------------------------------
# 5. streaming admission: in-scan queue vs the python front-end oracle
# ---------------------------------------------------------------------------
#: plain streaming policy — batch-full + SLO + capacity-freed drains
STREAM_POLICY = SchedulerPolicy(
    queue_capacity=16, admit_batch=4, slo_target_s=120.0, max_retries=2,
    n_classes=3,
)

#: every admission knob live at once: aging, degradation, mixed billing
STREAM_MIXED_POLICY = SchedulerPolicy(
    queue_capacity=16, admit_batch=4, slo_target_s=90.0, max_retries=2,
    n_classes=3, aging_rate=0.01, storm_threshold=0.05,
    cost_kind="period", cost_kinds=("count", "revenue", "recompute"),
)

_ADM_KEYS = ("arrivals", "admitted", "rejected_overflow", "rejected_retry",
             "drains", "retries", "degraded")


def _assert_stream_equal(py_sim: SoASimulator, dev: ss.ScanResult) -> None:
    """Admission-plane parity: counters, queue arrays, latency samples."""
    front = py_sim.fleet.admission
    st = front.stats
    expected = {k: getattr(st, k) for k in _ADM_KEYS}
    expected["queue_depth"] = front.waiting
    assert dev.admission == expected, (
        f"admission counters diverged: {dev.admission} vs {expected}"
    )
    # conservation: every arrival is admitted, rejected, or still queued
    adm = dev.admission
    assert adm["arrivals"] == (
        adm["admitted"] + adm["rejected_overflow"] + adm["rejected_retry"]
        + adm["queue_depth"]
    )
    # final queue arrays, every column bitwise
    for f in dataclasses.fields(front.qstate):
        a = np.asarray(getattr(front.qstate, f.name))
        b = np.asarray(getattr(dev.queue, f.name))
        assert np.array_equal(a, b), f"queue column {f.name} diverged"
    # sim-time wait distribution: the per-placement f32 differences are the
    # same multiset, and both percentile readers agree bit-for-bit
    dev_w = np.sort(dev.wait_s[dev.wait_s >= 0])
    py_w = np.sort(np.asarray(st.wait_s, np.float32))
    assert np.array_equal(dev_w, py_w), "wait_s distributions diverged"
    assert dev.wait_percentiles() == front.wait_percentiles()


def _run_both_streaming(trace: EventTrace, policy: SchedulerPolicy,
                        n_hosts: int, seed: int = 0):
    sim, dev, m_py = _run_both(trace, policy, n_hosts, seed)
    _assert_bitwise_equal(sim, dev, m_py, trace)
    _assert_stream_equal(sim, dev)
    return sim, dev, m_py


@pytest.mark.parametrize("seed", PARITY_SEEDS)
def test_stream_parity_randomized_all_kinds(seed):
    """The headline streaming sweep: 400+-event randomized traces with
    storms-under-degradation, aging, mixed billing, failures + heals,
    checkpoints — scan vs python streaming oracle bit-exact."""
    trace = _rich_trace(seed)
    assert trace.n_events >= 300
    sim, dev, _ = _run_both_streaming(
        trace, STREAM_MIXED_POLICY, n_hosts=16, seed=seed
    )
    assert dev.admission["admitted"] > 0
    assert dev.admission["drains"] > 0


def test_stream_parity_overflow_and_retry_exhaustion():
    """Saturation on a 2-host fleet: persistent retries fill the queue so
    fresh arrivals overflow, and retry budgets exhaust."""
    policy = SchedulerPolicy(queue_capacity=8, admit_batch=4,
                             slo_target_s=60.0, max_retries=6, n_classes=2)
    trace = trace_from_workload(
        WorkloadSpec(
            arrival_rate_per_s=1 / 6.0,
            flavors=[(f"f{i}", s) for i, s in enumerate(SIZES)],
            preemptible_fraction=0.5,
        ),
        4000.0, seed=11, priorities=(-1, 0, 1),
    )
    assert trace.n_events >= 400
    _, dev, _ = _run_both_streaming(trace, policy, n_hosts=2, seed=11)
    assert dev.admission["rejected_overflow"] > 0
    assert dev.admission["rejected_retry"] > 0
    assert dev.admission["retries"] > 0


def test_stream_parity_slo_deadline_drains():
    """Sparse arrivals never fill a batch: every drain is SLO-deadline
    (or end-of-run) triggered."""
    policy = SchedulerPolicy(queue_capacity=32, admit_batch=16,
                             slo_target_s=25.0, max_retries=2)
    trace = trace_from_workload(_workload(rate=1 / 60.0), 6000.0, seed=7)
    _, dev, _ = _run_both_streaming(trace, policy, n_hosts=8, seed=7)
    assert dev.admission["admitted"] > 0
    # a batch of 16 never accumulates at this rate, yet drains fired
    # throughout the run, not only in the epilogue
    assert dev.admission["drains"] > dev.admission["admitted"] // 16 + 1


def test_stream_parity_storm_degradation():
    """A tight storm_threshold demotes preemptible attempts mid-storm; the
    degraded counter and the demoted placements stay exact."""
    policy = dataclasses.replace(STREAM_MIXED_POLICY, storm_threshold=0.001)
    trace = trace_from_workload(
        _workload(frac=1.0), 4000.0, seed=13,
        storms=((400.0, 0, 0.8), (1500.0, 1, 0.7), (2600.0, 2, 0.9)),
        priorities=(-1, 0, 1, 2),
        cost_kinds=(-1, 0, 1, 2, 3),
    )
    _, dev, _ = _run_both_streaming(trace, policy, n_hosts=9, seed=13)
    assert dev.admission["degraded"] > 0


def test_stream_knobs_neutral_identity():
    """A traced knob row equal to the static policy's values is bitwise
    identical to the untraced scan (floor(0*w)=0, inf threshold =
    constant-False predicate)."""
    policy = STREAM_POLICY
    sim = SoASimulator(_hosts(8), _workload(), seed=1, k_slots=K,
                       policy=policy)
    state0 = _snapshot(sim.fleet.state)
    trace = trace_from_workload(_workload(), 3000.0, seed=1,
                                priorities=(-1, 0, 1, 2))
    static = simulate_scan(trace, policy, state0)
    neutral = np.asarray(
        [policy.aging_rate, policy.slo_target_s,
         np.inf if policy.storm_threshold is None
         else policy.storm_threshold],
        np.float32,
    )
    knobbed = simulate_scan(trace, policy, state0, knobs=neutral)
    _lane_equal(static, knobbed)
    assert static.admission == knobbed.admission
    assert np.array_equal(static.wait_s, knobbed.wait_s)
    for f in dataclasses.fields(static.queue):
        assert np.array_equal(getattr(static.queue, f.name),
                              getattr(knobbed.queue, f.name))


def test_stream_knob_ensemble_lanes():
    """An admission-knob sweep in ONE dispatch == per-row single scans."""
    policy = STREAM_POLICY
    sim = SoASimulator(_hosts(8), _workload(), seed=1, k_slots=K,
                       policy=policy)
    state0 = _snapshot(sim.fleet.state)
    trace = trace_from_workload(_workload(), 3000.0, seed=1,
                                priorities=(-1, 0, 1, 2))
    knob_rows = np.asarray(
        [[0.0, 120.0, np.inf],
         [0.05, 30.0, 0.02],
         [0.2, 300.0, 1.0]],
        np.float32,
    )
    lanes = simulate_ensemble([trace], policy, state0, knobs=knob_rows)
    assert len(lanes) == 3
    for row, lane in zip(knob_rows, lanes):
        single = simulate_scan(trace, policy, state0, knobs=row)
        _lane_equal(single, lane)
        assert single.admission == lane.admission
        assert np.array_equal(single.wait_s, lane.wait_s)


def test_stream_ensemble_lanes_match_padded_singles():
    """Mixed-length streaming traces on the vmap axis: each lane equals a
    single scan of the SAME padded trace (PAD rows at t_last can fire
    extra SLO drains, so the comparison must share the padding)."""
    policy = STREAM_POLICY
    sim = SoASimulator(_hosts(6), _workload(), seed=0, k_slots=K,
                       policy=policy)
    state0 = _snapshot(sim.fleet.state)
    traces = [
        trace_from_workload(_workload(rate=1 / 30.0), 1500.0, seed=s,
                            priorities=(-1, 0, 1, 2))
        for s in (1, 2, 3, 4)
    ]
    emax = max(t.n_events for t in traces)
    lanes = simulate_ensemble(traces, policy, state0)
    for t, lane in zip(traces, lanes):
        e = t.n_events
        single = simulate_scan(t.padded(emax), policy, state0)
        trimmed = dataclasses.replace(
            single, host=single.host[:e], slot=single.slot[:e],
            ok=single.ok[:e], n_kill=single.n_kill[:e],
        )
        _lane_equal(trimmed, lane)
        assert single.admission == lane.admission
        assert np.array_equal(single.wait_s[:e], lane.wait_s)
        for f in dataclasses.fields(single.queue):
            assert np.array_equal(getattr(single.queue, f.name),
                                  getattr(lane.queue, f.name))


def test_stream_knob_validation():
    sim = SoASimulator(_hosts(4), _workload(), seed=0, k_slots=K,
                       policy=STREAM_POLICY)
    state0 = _snapshot(sim.fleet.state)
    trace = trace_from_workload(_workload(rate=1 / 100.0), 400.0, seed=0)
    with pytest.raises(ValueError, match="queue_capacity > 0"):
        simulate_scan(trace, SchedulerPolicy(), state0,
                      knobs=np.array([0.0, 60.0, np.inf], np.float32))
    with pytest.raises(ValueError, match="knob rows must be"):
        simulate_scan(trace, STREAM_POLICY, state0,
                      knobs=np.array([0.0, 60.0], np.float32))
    with pytest.raises(ValueError, match="aging_rate knob"):
        simulate_scan(trace, STREAM_POLICY, state0,
                      knobs=np.array([-1.0, 60.0, np.inf], np.float32))
    with pytest.raises(ValueError, match="slo_target_s knob"):
        simulate_scan(trace, STREAM_POLICY, state0,
                      knobs=np.array([0.0, 0.0, np.inf], np.float32))
    with pytest.raises(ValueError, match="storm_threshold knob"):
        simulate_scan(trace, STREAM_POLICY, state0,
                      knobs=np.array([0.0, 60.0, np.nan], np.float32))
    with pytest.raises(ValueError, match="one knob row"):
        simulate_scan(trace, STREAM_POLICY, state0,
                      knobs=np.array([[0.0, 60.0, np.inf]], np.float32))
    with pytest.raises(ValueError, match="3 traces vs 2 knob rows"):
        simulate_ensemble([trace, trace, trace], STREAM_POLICY, state0,
                          knobs=np.full((2, 3), 60.0, np.float32))


def test_stream_trace_priority_validation():
    sim = SoASimulator(_hosts(4), _workload(), seed=0, k_slots=K,
                       policy=STREAM_POLICY)
    trace = trace_from_workload(_workload(rate=1 / 50.0), 800.0, seed=0,
                                priorities=(5,))
    with pytest.raises(ValueError, match="priority"):
        simulate_scan(trace, STREAM_POLICY, sim.fleet.state)
