"""Substrate layers: optimizers, schedules, data pipeline, sharding specs."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import DataConfig, SyntheticLMDataset
from repro.optim.optimizers import (
    adamw_init,
    adamw_update,
    adafactor_init,
    adafactor_update,
    clip_by_global_norm,
    cosine_schedule,
    make_optimizer,
)


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------


def quadratic_params():
    return {"w": jnp.asarray([3.0, -2.0, 1.0]), "b": jnp.ones((2, 4)) * 5}


@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_optimizer_minimizes_quadratic(name):
    opt = make_optimizer(name, weight_decay=0.0)
    params = quadratic_params()
    state = opt.init(params)

    def loss(p):
        return sum(jnp.sum(jnp.square(x)) for x in jax.tree.leaves(p))

    l0 = float(loss(params))
    for _ in range(200):
        grads = jax.grad(loss)(params)
        delta, state = opt.update(grads, state, params, jnp.asarray(0.05))
        params = jax.tree.map(lambda p, d: p + d, params, delta)
    assert float(loss(params)) < 0.05 * l0


def test_adafactor_state_is_factored():
    params = {"big": jnp.zeros((64, 32)), "vec": jnp.zeros((7,))}
    state = adafactor_init(params)
    row, col = state.nu["big"]
    assert row.shape == (64,) and col.shape == (32,)
    assert state.nu["vec"].shape == (7,)
    assert state.mu is None  # no first moment → 1/3 the AdamW state


def test_clip_by_global_norm():
    tree = {"a": jnp.ones((10,)) * 100.0}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(10) * 100, rel=1e-5)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, rel=1e-5)


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, warmup=10, total=100, min_frac=0.1)
    assert float(lr(jnp.asarray(0))) == 0.0
    assert float(lr(jnp.asarray(10))) == pytest.approx(1e-3)
    assert float(lr(jnp.asarray(100))) == pytest.approx(1e-4, rel=1e-2)
    assert float(lr(jnp.asarray(5))) == pytest.approx(5e-4)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_data_deterministic_and_seekable():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=4, seed=9)
    d1, d2 = SyntheticLMDataset(cfg), SyntheticLMDataset(cfg)
    b1, b2 = d1.batch_at(17), d2.batch_at(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(d1.batch_at(17)["tokens"], d1.batch_at(18)["tokens"])


def test_data_labels_are_shifted_tokens():
    cfg = DataConfig(vocab_size=50, seq_len=8, global_batch=2)
    b = SyntheticLMDataset(cfg).batch_at(0)
    assert b["tokens"].shape == b["labels"].shape == (2, 8)
    assert (b["tokens"] < 50).all() and (b["labels"] < 50).all()


def test_data_host_shards_differ():
    k = dict(vocab_size=100, seq_len=16, global_batch=8, seed=1)
    a = SyntheticLMDataset(DataConfig(host_shard=(0, 2), **k)).batch_at(0)
    b = SyntheticLMDataset(DataConfig(host_shard=(1, 2), **k)).batch_at(0)
    assert a["tokens"].shape == (4, 16)
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_data_prefetch_thread():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=2)
    ds = SyntheticLMDataset(cfg)
    ds.start(from_step=5)
    step, batch = next(ds)
    assert step == 5
    step2, _ = next(ds)
    assert step2 == 6
    ds.stop()
    np.testing.assert_array_equal(batch["tokens"], ds.batch_at(5)["tokens"])


# ---------------------------------------------------------------------------
# param/pspec coherence
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "arctic-480b", "zamba2-7b", "xlstm-125m"])
def test_param_defs_match_params_structure(arch):
    from repro.configs import get_config, reduced
    from repro.models.layers import pspec_tree, shape_tree
    from repro.models.model import init_params, model_defs

    cfg = reduced(get_config(arch))
    params = init_params(cfg, jax.random.PRNGKey(0))
    shapes = shape_tree(model_defs(cfg))
    specs = pspec_tree(model_defs(cfg))
    assert jax.tree.structure(params) == jax.tree.structure(shapes)
    assert jax.tree.structure(params) == jax.tree.structure(
        specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)
    )
    for p, s in zip(jax.tree.leaves(params), jax.tree.leaves(shapes)):
        assert p.shape == s.shape


# ---------------------------------------------------------------------------
# gradient compression (cross-pod wire format)
# ---------------------------------------------------------------------------


def test_int8_compression_bounded_error():
    from repro.training.train_step import _compress_int8

    g = jax.random.normal(jax.random.PRNGKey(0), (256, 64)) * 3.0
    q = _compress_int8(g)
    scale = float(jnp.max(jnp.abs(g))) / 127.0
    assert float(jnp.max(jnp.abs(q - g))) <= scale * 0.5 + 1e-6
    # int8 payload is 4x smaller on the wire than f32
    assert q.dtype == g.dtype  # dequantized in-graph; wire format is int8


def test_training_with_compression_tracks_uncompressed():
    """int8 wire compression must not derail optimization: the loss
    trajectory stays within noise of the uncompressed run and gradients
    stay finite (the convergence contract at this scale)."""
    from repro.configs import get_config, reduced
    from repro.data.pipeline import DataConfig, SyntheticLMDataset
    from repro.models.model import init_params
    from repro.optim.optimizers import make_optimizer
    from repro.training.train_step import TrainSettings, make_train_step

    cfg = reduced(get_config("qwen2-1.5b"))
    data = SyntheticLMDataset(DataConfig(vocab_size=cfg.vocab_size,
                                         seq_len=32, global_batch=4))
    trajs = {}
    for comp in ("none", "int8"):
        params = init_params(cfg, jax.random.PRNGKey(0))
        opt = make_optimizer("adamw")
        state = opt.init(params)
        step = jax.jit(make_train_step(
            cfg, TrainSettings(learning_rate=1e-3, warmup_steps=2,
                               grad_compression=comp), opt))
        losses = []
        for i in range(15):
            params, state, m = step(params, state, data.batch_at(i))
            losses.append(float(m["loss"]))
            assert np.isfinite(losses[-1])
        trajs[comp] = losses
    diff = np.abs(np.array(trajs["int8"]) - np.array(trajs["none"]))
    # trajectories drift as quantization noise compounds; the contract is
    # "stays in the same loss regime": small mean gap, no blow-up.
    assert diff.mean() < 0.05 and diff.max() < 0.3


def test_microbatched_step_matches_single_batch_grads():
    """Gradient accumulation over microbatches equals the full-batch step
    (same data, fp32 accumulation)."""
    from repro.configs import get_config, reduced
    from repro.data.pipeline import DataConfig, SyntheticLMDataset
    from repro.models.model import init_params
    from repro.optim.optimizers import make_optimizer
    from repro.training.train_step import TrainSettings, make_train_step

    cfg = reduced(get_config("qwen2-1.5b"))
    data = SyntheticLMDataset(DataConfig(vocab_size=cfg.vocab_size,
                                         seq_len=16, global_batch=8))
    batch = data.batch_at(0)
    p0 = init_params(cfg, jax.random.PRNGKey(0))
    outs = []
    for mb in (1, 4):
        opt = make_optimizer("adamw")
        step = jax.jit(make_train_step(cfg, TrainSettings(microbatches=mb), opt))
        p, _, m = step(p0, opt.init(p0), batch)
        outs.append((jax.tree.leaves(p), float(m["loss"])))
    # losses are means over microbatches of per-mb means — equal batch sizes
    assert outs[0][1] == pytest.approx(outs[1][1], rel=2e-2)
    for a, b in zip(outs[0][0], outs[1][0]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-2)
