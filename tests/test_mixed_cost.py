"""Heterogeneous per-instance cost models vs the python ``MixedCost`` oracle.

The contract: a fleet whose instances each bill by their OWN kind (the
``inst_cost_kind`` column + the policy's cost-kind table) makes decisions
bit-identical to the python ``MixedCost`` oracle — slot cost for slot cost,
and decision for decision on states whose costs were computed entirely in
python (``build_soa_state(cost_fn=MixedCost(...))``).

Inputs are chosen so every kind's arithmetic is EXACT in f32 (integer
resources/prices; times in multiples of 900 s, so the revenue kind's
``part/period`` is a dyadic fraction of 3600) — parity can be strict.

Cost models only influence *normal* requests (preemptible placements never
terminate anyone), so the decision-level oracle runs on normal arrivals;
preemptible arrivals drive the fleet between comparisons (their placements
land with per-request kinds, which the next normal decision must price).

CI treats a skip of this file as a failure (see .github/workflows/ci.yml):
the hypothesis sweep is the acceptance gate for mixed-kind billing.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cost import MixedCost
from repro.core.jax_scheduler import (
    SoAHostState,
    build_fleet_state,
    fleet_slot_costs,
    schedule_decision,
    schedule_step,
)
from repro.core.policy import COST_KINDS, SchedulerPolicy
from repro.core.soa_fleet import SoAFleet
from repro.core.types import VM_SPEC, Host, Instance, Request

NOW = 450_000.0
CAP = VM_SPEC.make(vcpus=8, ram_mb=16000, disk_gb=160)
SIZES = [
    VM_SPEC.make(vcpus=1, ram_mb=2000, disk_gb=20),
    VM_SPEC.make(vcpus=2, ram_mb=4000, disk_gb=40),
    VM_SPEC.make(vcpus=4, ram_mb=8000, disk_gb=80),
]
K = 8
MIXED = MixedCost(default="period", kinds=COST_KINDS)
POLICY = SchedulerPolicy.for_cost(MIXED)


def _mixed_fleet(rng, n_hosts, fill=0.85):
    """Random fleet whose preemptible instances carry all four kinds.
    Times are multiples of 900 s so every kind is f32-exact."""
    hosts = []
    iid = 0
    for i in range(n_hosts):
        h = Host(name=f"h{i}", capacity=CAP)
        while h.used().vec[0] < fill * CAP.vec[0]:
            size = SIZES[int(rng.integers(3))]
            if not size.fits_in(h.free_full):
                break
            pre = bool(rng.random() < 0.6) and len(h.preemptible_instances()) < K
            start = NOW - float(rng.integers(1, 400)) * 900.0
            inst = Instance(
                id=f"x{iid}", resources=size, preemptible=pre, host=h.name,
                start_time=start,
                price_rate=float(rng.integers(1, 5)),
                cost_kind=COST_KINDS[int(rng.integers(4))] if pre else None,
            )
            if pre and rng.random() < 0.5:
                inst.last_checkpoint = start + float(rng.integers(0, 100)) * 900.0
            h.place(inst)
            iid += 1
        hosts.append(h)
    return hosts


def _python_slot_costs(fleet: SoAFleet, now: float) -> np.ndarray:
    """Every live slot's cost computed by the PYTHON oracle, laid out like
    the device column."""
    out = np.zeros((fleet.n_hosts, fleet.k_slots), np.float32)
    for host_idx, row in enumerate(fleet.slot_ids):
        for slot, iid in enumerate(row):
            if iid is not None:
                out[host_idx, slot] = MIXED.cost([fleet.instances[iid]], now)
    return out


def _oracle_state(fleet: SoAFleet, now: float) -> SoAHostState:
    """The python-cost oracle: the fleet's own arrays (same slot layout, so
    tie-breaks align bit-for-bit) with ``inst_cost`` REPLACED by the
    per-instance python ``MixedCost`` values — the frozen-cost state flavor
    the rebuild path schedules on."""
    s = fleet.state
    return SoAHostState(
        free_f=s.free_f, free_n=s.free_n, schedulable=s.schedulable,
        domain=s.domain, slow=s.slow, inst_res=s.inst_res,
        inst_cost=jnp.asarray(_python_slot_costs(fleet, now)),
        inst_valid=s.inst_valid,
    )


def test_mixed_slot_costs_match_python_oracle():
    """The branchless kind-select column == per-instance python MixedCost,
    slot for slot, on a fleet mixing all four kinds."""
    rng = np.random.default_rng(0)
    fleet = SoAFleet(_mixed_fleet(rng, 24), cost_fn=MIXED, k_slots=K)
    assert fleet.policy.mixed
    for step in range(4):
        now = NOW + 900.0 * step
        got = np.asarray(
            jnp.where(
                fleet.state.inst_valid,
                fleet_slot_costs(fleet.state, jnp.float32(now), fleet.policy),
                0.0,
            )
        )
        np.testing.assert_array_equal(got, _python_slot_costs(fleet, now))
    # all four kinds are live, otherwise the comparison is vacuous
    col = np.asarray(fleet.state.inst_cost_kind)[np.asarray(fleet.state.inst_valid)]
    assert set(np.unique(col)) >= {0, 1, 2, 3}


@pytest.mark.parametrize("seed,shortlist", [(1, None), (2, 2), (3, 1)])
def test_mixed_decisions_match_python_oracle_over_events(seed, shortlist):
    """Randomized event run (arrivals with per-request kinds, checkpoints,
    preemptions, departures): every NORMAL decision on the incremental
    mixed-kind fleet equals the decision taken on a state whose slot costs
    were computed in python by MixedCost.  Tiny shortlists force the
    admissibility fallback through the mixed-cost path too."""
    rng = np.random.default_rng(seed)
    policy = (
        POLICY if shortlist is None
        else dataclasses.replace(POLICY, shortlist=shortlist)
    )
    fleet = SoAFleet(_mixed_fleet(rng, 24), cost_fn=MIXED, k_slots=K,
                     policy=policy)
    # python mirror of live instances (the oracle's ground truth)
    live = list(fleet.instances.values())
    now = NOW
    compared = 0
    for step in range(60):
        now += float(rng.integers(1, 5)) * 900.0
        roll = rng.random()
        if roll < 0.15 and live:  # checkpoint a random preemptible instance
            pre_live = [i for i in live if i.preemptible]
            if pre_live:
                inst = pre_live[int(rng.integers(len(pre_live)))]
                fleet.checkpoint(inst.id, now)  # mutates the shared Instance
            continue
        if roll < 0.30 and live:  # voluntary departure
            inst = live.pop(int(rng.integers(len(live))))
            fleet.depart(inst.id)
            continue
        pre = bool(rng.random() < 0.4)
        req = Request(
            id=f"r{step}",
            resources=SIZES[int(rng.integers(3))],
            preemptible=pre,
            cost_kind=COST_KINDS[int(rng.integers(4))] if pre else None,
        )
        if not pre:
            # ---- the oracle: python-computed slot costs, same layout ----
            oracle = _oracle_state(fleet, now)
            oh, om, ook = schedule_decision(
                oracle, jnp.asarray(req.resources.vec32), False,
                jnp.asarray(-1, jnp.int32), policy=policy,
            )
            expect_victims = (
                {
                    fleet.slot_ids[int(oh)][k]
                    for k in range(fleet.k_slots)
                    if (int(om) >> k) & 1
                    and fleet.slot_ids[int(oh)][k] is not None
                }
                if bool(ook)
                else set()
            )
            out = fleet.schedule_request(req, now, price=float(rng.integers(1, 5)))
            assert out.ok == bool(ook), f"step {step}: ok mismatch"
            if out.ok:
                assert out.host == fleet.names[int(oh)], f"step {step}"
                assert {v.id for v in out.victims} == expect_victims, f"step {step}"
                for v in out.victims:
                    live.remove(v)
                live.append(out.instance)
            compared += 1
        else:
            out = fleet.schedule_request(req, now, price=float(rng.integers(1, 5)))
            if out.ok:
                live.append(out.instance)
    assert compared >= 15  # the oracle actually ran
    if shortlist == 1:  # tiny shortlist must have exercised the fallback
        assert fleet.fallbacks > 0


def test_per_instance_periods_match_python_oracle():
    """Per-instance contract periods (``Instance.period`` → the state's
    ``inst_period`` column): slots billing by the period/revenue kinds must
    price by their OWN period where one is set, falling back to
    ``policy.period`` otherwise — slot for slot against the python oracle,
    and decision for decision on the frozen-cost oracle state.  Periods are
    dyadic multiples of 900 s so the revenue kind's ``part/period`` stays
    f32-exact."""
    rng = np.random.default_rng(31)
    hosts = _mixed_fleet(rng, 20)
    periods = [900.0, 1800.0, 7200.0]
    for h in hosts:
        for inst in h.preemptible_instances():
            if rng.random() < 0.7:
                inst.period = float(periods[int(rng.integers(3))])
    fleet = SoAFleet(hosts, cost_fn=MIXED, k_slots=K)
    # the column really carries overrides AND defaults (-1 sentinel)
    col = np.asarray(fleet.state.inst_period)[np.asarray(fleet.state.inst_valid)]
    assert (col > 0).any() and (col < 0).any()

    for step in range(4):
        now = NOW + 900.0 * step
        got = np.asarray(
            jnp.where(
                fleet.state.inst_valid,
                fleet_slot_costs(fleet.state, jnp.float32(now), fleet.policy),
                0.0,
            )
        )
        np.testing.assert_array_equal(got, _python_slot_costs(fleet, now))

    # decisions: arrivals carrying per-REQUEST periods land in the column
    # and the next normal decision must price them identically to python
    now = NOW
    compared = 0
    for step in range(40):
        now += float(rng.integers(1, 5)) * 900.0
        pre = bool(rng.random() < 0.5)
        req = Request(
            id=f"r{step}",
            resources=SIZES[int(rng.integers(3))],
            preemptible=pre,
            cost_kind=COST_KINDS[int(rng.integers(2)) * 2] if pre else None,
            period=(
                float(periods[int(rng.integers(3))])
                if pre and rng.random() < 0.7 else None
            ),
        )
        if pre:
            fleet.schedule_request(req, now, price=float(rng.integers(1, 5)))
            continue
        oracle = _oracle_state(fleet, now)
        oh, om, ook = schedule_decision(
            oracle, jnp.asarray(req.resources.vec32), False,
            jnp.asarray(-1, jnp.int32), policy=fleet.policy,
        )
        out = fleet.schedule_request(req, now)
        assert out.ok == bool(ook), f"step {step}: ok mismatch"
        if out.ok:
            assert out.host == fleet.names[int(oh)], f"step {step}"
        compared += 1
    assert compared >= 10


def test_single_kind_policy_ignores_kind_column():
    """A homogeneous policy must reproduce today's decisions unchanged even
    if the state carries a (stale) kind column — the column is only read
    under a mixed table."""
    rng = np.random.default_rng(9)
    hosts = _mixed_fleet(rng, 16)
    state, _ = build_fleet_state(hosts, k_slots=K)
    single = SchedulerPolicy()  # period-only
    scrambled = dataclasses.replace(
        state,
        inst_cost_kind=jnp.asarray(
            rng.integers(-1, 4, np.asarray(state.inst_cost_kind).shape),
            jnp.int32,
        ),
    )
    req = np.asarray(SIZES[2].vec, np.float32)
    _, a = schedule_step(state, req, False, np.int32(-1), NOW, 1.0,
                         policy=single, donate=False)
    _, b = schedule_step(scrambled, req, False, np.int32(-1), NOW, 1.0,
                         policy=single, donate=False)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# Property-based sweep (hypothesis): arbitrary mixed fleets and requests.
# Guarded per-test (NOT importorskip) so the deterministic cases above always
# run; the leftover skip is what the CI gate turns into a failure.
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @given(
        st.integers(0, 2**31 - 1),
        st.sampled_from([0, 1, 4, 16]),
        st.booleans(),
    )
    @settings(max_examples=20, deadline=None)
    def test_mixed_decision_parity_property(seed, shortlist, fused):
        """For ANY mixed-kind fleet: the normal-request decision on the
        device kind column equals the decision on python-computed MixedCost
        slot costs, at every shortlist size, jnp and fused-interpret."""
        rng = np.random.default_rng(seed)
        hosts = _mixed_fleet(rng, int(rng.integers(6, 28)))
        policy = dataclasses.replace(
            POLICY, shortlist=shortlist, fused_screen=fused or None
        )
        fleet = SoAFleet(hosts, cost_fn=MIXED, k_slots=K, policy=policy)
        now = NOW + float(rng.integers(1, 50)) * 900.0
        req_res = SIZES[int(rng.integers(3))]
        oracle = _oracle_state(fleet, now)
        oh, om, ook = schedule_decision(
            oracle, jnp.asarray(req_res.vec32), False,
            jnp.asarray(-1, jnp.int32), policy=policy,
        )
        out = fleet.schedule_request(
            Request(id="q", resources=req_res), now
        )
        assert out.ok == bool(ook)
        if out.ok:
            assert out.host == fleet.names[int(oh)]

else:

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_mixed_decision_parity_property():
        pass
