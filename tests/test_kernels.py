"""Per-kernel interpret-mode validation against the pure-jnp oracles,
swept over shapes and dtypes (deliverable (c))."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import attention_ref, rmsnorm_ref
from repro.kernels.rmsnorm import rmsnorm


def _qkv(key, b, s, h, g, hd, dtype):
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, s, h, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(kk, (b, s, g, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(kv, (b, s, g, hd), jnp.float32).astype(dtype)
    return q, k, v


SHAPES = [
    # b, s, h, g, hd
    (1, 128, 1, 1, 64),
    (2, 256, 4, 2, 64),     # GQA
    (1, 256, 4, 1, 128),    # MQA
    (2, 512, 2, 2, 32),
]


@pytest.mark.parametrize("shape", SHAPES, ids=str)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16], ids=["f32", "bf16"])
@pytest.mark.parametrize("causal", [True, False], ids=["causal", "full"])
def test_flash_forward_matches_ref(shape, dtype, causal):
    b, s, h, g, hd = shape
    q, k, v = _qkv(jax.random.PRNGKey(0), b, s, h, g, hd, dtype)
    out = flash_attention(q, k, v, causal=causal, interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=tol, rtol=tol,
    )


@pytest.mark.parametrize("shape", SHAPES[:3], ids=str)
def test_flash_backward_matches_ref(shape):
    b, s, h, g, hd = shape
    q, k, v = _qkv(jax.random.PRNGKey(1), b, s, h, g, hd, jnp.float32)

    def f_kernel(q, k, v):
        return jnp.sum(flash_attention(q, k, v, causal=True, interpret=True) ** 2)

    def f_ref(q, k, v):
        return jnp.sum(attention_ref(q, k, v, causal=True) ** 2)

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("block", [(64, 128), (128, 64)])
def test_flash_block_shape_independence(block):
    bq, bk = block
    q, k, v = _qkv(jax.random.PRNGKey(2), 1, 256, 2, 2, 64, jnp.float32)
    a = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk, interpret=True)
    b = flash_attention(q, k, v, causal=True, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("rows", [8, 100, 256, 1000])
@pytest.mark.parametrize("d", [128, 384])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16], ids=["f32", "bf16"])
def test_rmsnorm_matches_ref(rows, d, dtype):
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (rows, d), jnp.float32).astype(dtype)
    w = jax.random.normal(jax.random.fold_in(key, 1), (d,), jnp.float32) * 0.1
    out = rmsnorm(x, w, interpret=True)
    ref = rmsnorm_ref(x, w)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


def test_rmsnorm_3d_shape():
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 33, 128))
    w = jnp.zeros((128,))
    out = rmsnorm(x, w, interpret=True)
    assert out.shape == x.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(rmsnorm_ref(x, w)),
                               atol=1e-5, rtol=1e-5)
