"""Event-driven simulator: backfill utilization, fault injection, stragglers."""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.cluster import Cluster, make_uniform_fleet
from repro.core.cost import PeriodCost
from repro.core.scheduler import FilterScheduler, PreemptibleScheduler
from repro.core.simulator import Simulator, WorkloadSpec
from repro.core.types import VM_SPEC
from repro.core.weighers import StragglerRank, TerminationCostRank, OvercommitRank

NODE = VM_SPEC.make(vcpus=8, ram_mb=16000, disk_gb=10_000)
MEDIUM = VM_SPEC.make(vcpus=2, ram_mb=4000, disk_gb=40)


def spec(frac, rate=1 / 20.0):
    return WorkloadSpec(
        arrival_rate_per_s=rate,
        preemptible_fraction=frac,
        flavors=(("medium", MEDIUM),),
    )


def run_sim(sched_cls, frac, n_hosts=16, seed=3, duration=24 * 3600.0, **kw):
    cluster = Cluster(make_uniform_fleet(n_hosts, NODE))
    sim = Simulator(cluster, sched_cls(cost_fn=PeriodCost(), **kw), spec(frac), seed=seed)
    return sim, sim.run(duration)


def test_backfill_eliminates_normal_failures():
    _, blind = run_sim(FilterScheduler, 0.5)
    _, aware = run_sim(PreemptibleScheduler, 0.5)
    assert aware.failures_normal < blind.failures_normal
    assert aware.preemptions > 0


def test_preemptible_keeps_ondemand_capacity():
    """With normal demand well under capacity (preemptible demand above it),
    normal requests never fail — spot capacity is always evacuable."""
    cluster = Cluster(make_uniform_fleet(16, NODE))
    sim = Simulator(cluster, PreemptibleScheduler(cost_fn=PeriodCost()),
                    spec(0.7, rate=1 / 80.0), seed=3)
    m = sim.run(24 * 3600.0)
    assert m.failures_normal == 0
    assert np.mean(m.utilization) > 0.4


def test_host_failure_evacuates_and_heals():
    cluster = Cluster(make_uniform_fleet(4, NODE))
    sim = Simulator(cluster, PreemptibleScheduler(cost_fn=PeriodCost()), spec(0.5), seed=0)
    sim.inject_host_failure("host-1", at_s=3600.0, heal_after_s=7200.0)
    sim.run(6 * 3600.0)
    assert cluster.hosts["host-1"].schedulable  # healed
    # all preempted instances were routed through the protocol
    assert cluster.stats.preemptions == len(cluster.preempted)


def test_straggler_weigher_avoids_slow_hosts():
    cluster = Cluster(make_uniform_fleet(8, NODE))
    slow = {"host-0", "host-1"}
    for name in slow:
        cluster.hosts[name].slow_factor = 5.0
    sched = PreemptibleScheduler(
        cost_fn=PeriodCost(),
        weighers=(OvercommitRank(), TerminationCostRank(), StragglerRank()),
    )
    # light load: the fleet never saturates, so the weigher has free choice
    sim = Simulator(cluster, sched, spec(0.3, rate=1 / 600.0), seed=1)
    sim.run(24 * 3600.0)
    placed_slow = sum(len(cluster.hosts[h].instances) for h in slow)
    placed_fast = sum(
        len(h.instances) for n, h in cluster.hosts.items() if n not in slow
    )
    # slow hosts get strictly less than their proportional share
    assert placed_slow / 2 < placed_fast / 6


def test_simulation_is_deterministic():
    _, a = run_sim(PreemptibleScheduler, 0.5, seed=11)
    _, b = run_sim(PreemptibleScheduler, 0.5, seed=11)
    assert a.placed_normal == b.placed_normal
    assert a.preemptions == b.preemptions
    assert a.utilization == b.utilization
