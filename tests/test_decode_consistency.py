"""Decode-path consistency: token-by-token decoding with caches/states must
reproduce the teacher-forced full-sequence forward — the strongest oracle
for KV-cache indexing, Mamba2 SSD chunk algebra, and xLSTM recurrences.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models.model import (
    decode_step,
    forward_logits,
    init_decode_state,
    init_params,
)

S = 12
B = 2

# moonshot: capacity_factor large so neither path drops tokens (dropping is
# batch-dependent and would make the two paths legitimately differ).
CASES = {
    "qwen2-1.5b": {},                      # GQA + qkv bias + tied embeddings
    "gemma-2b": {},                        # MQA + GeGLU + head_dim=256
    "moonshot-v1-16b-a3b": {"capacity_factor": 16.0},   # MoE top-k
    "zamba2-7b": {},                       # Mamba2 + shared attention
    "xlstm-125m": {},                      # mLSTM + sLSTM
}


@pytest.mark.parametrize("arch", sorted(CASES))
def test_decode_matches_teacher_forced_forward(arch):
    cfg = reduced(get_config(arch), **CASES[arch])
    if cfg.block_pattern == "zamba_hybrid":
        cfg = dataclasses.replace(cfg, ssm_chunk=S)  # chunked path, 1 chunk
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 2, cfg.vocab_size)

    # teacher-forced: logits at every position
    full = forward_logits(cfg, params, {"tokens": tokens}, last_only=False)

    # token-by-token decode
    state = init_decode_state(cfg, batch=B, max_len=S + 1, dtype=jnp.float32)
    step = jax.jit(lambda t, s: decode_step(cfg, params, t, s))
    outs = []
    for t in range(S):
        logits, state = step(tokens[:, t: t + 1], state)
        outs.append(logits[:, 0, :])
    dec = jnp.stack(outs, axis=1)                      # (B, S, V)

    np.testing.assert_allclose(
        np.asarray(dec, np.float32),
        np.asarray(full[..., : cfg.vocab_size], np.float32),
        atol=2e-2, rtol=2e-2,
    )
    # the argmax (greedy) decisions must agree everywhere
    np.testing.assert_array_equal(
        np.asarray(jnp.argmax(dec, -1)),
        np.asarray(jnp.argmax(full[..., : cfg.vocab_size], -1)),
    )


def test_blocked_attention_matches_reference_forward():
    cfg = reduced(get_config("yi-9b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, 16), 2, cfg.vocab_size)
    ref = forward_logits(cfg, params, {"tokens": tokens}, last_only=False)
    blk = forward_logits(
        dataclasses.replace(cfg, attention_impl="blocked"),
        params, {"tokens": tokens}, last_only=False,
    )
    np.testing.assert_allclose(
        np.asarray(blk, np.float32), np.asarray(ref, np.float32),
        atol=1e-3, rtol=1e-3,
    )


def test_blocked_attention_gradients_match_reference():
    cfg = reduced(get_config("yi-9b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (B, 16), 2, cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (B, 16), 2, cfg.vocab_size),
    }
    from repro.models.model import forward_train

    def loss(cfg_, p):
        return forward_train(cfg_, p, batch)[0]

    g_ref = jax.grad(lambda p: loss(cfg, p))(params)
    g_blk = jax.grad(
        lambda p: loss(dataclasses.replace(cfg, attention_impl="blocked"), p)
    )(params)
    for a, b in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_blk)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-3, rtol=2e-3)


def test_unrolled_decode_matches_scan_decode():
    """The serving-mode unrolled decode graph (scan_layers=False) is
    numerically identical to the scanned one (§Perf E)."""
    cfg = reduced(get_config("qwen2-1.5b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, 4), 2, cfg.vocab_size)
    outs = {}
    for scan in (True, False):
        c = dataclasses.replace(cfg, scan_layers=scan)
        state = init_decode_state(c, batch=B, max_len=8, dtype=jnp.float32)
        step = jax.jit(lambda t, s, c=c: decode_step(c, params, t, s))
        ls = []
        for t in range(4):
            logits, state = step(tokens[:, t: t + 1], state)
            ls.append(logits)
        outs[scan] = jnp.concatenate(ls, axis=1)
    np.testing.assert_allclose(
        np.asarray(outs[True]), np.asarray(outs[False]), atol=1e-5, rtol=1e-5
    )


def test_per_layer_cache_decode_matches_stacked():
    """Serving-mode per-layer cache buffers (decode_cache_layout=per_layer)
    decode identically to the stacked layout (§Perf E iter 5)."""
    cfg = reduced(get_config("qwen2-1.5b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, 4), 2, cfg.vocab_size)
    outs = {}
    for layout in ("stacked", "per_layer"):
        c = dataclasses.replace(cfg, decode_cache_layout=layout)
        state = init_decode_state(c, batch=B, max_len=8, dtype=jnp.float32)
        step = jax.jit(lambda t, s, c=c: decode_step(c, params, t, s))
        ls = []
        for t in range(4):
            logits, state = step(tokens[:, t: t + 1], state)
            ls.append(logits)
        outs[layout] = jnp.concatenate(ls, axis=1)
    np.testing.assert_allclose(
        np.asarray(outs["stacked"]), np.asarray(outs["per_layer"]),
        atol=1e-5, rtol=1e-5,
    )
