"""Interpret-mode validation of the fused stage-1 screen kernel
(``repro.kernels.sched_screen``) against the pure-jnp screen: the same
shared ``screen_math`` executed per tile with an on-chip running top-M must
emit exactly the shortlist ``lax.top_k`` would pick from the fleet-wide
``omega_ub`` (including tie ordering: lowest host index first), plus the
same 8 normalization constants.

Swept over K ∈ {4, 8, 12}, host counts that are NOT multiples of the
128-host tile, every device-resident slot-cost kind (incl. ``"recompute"``),
normal + preemptible requests, and non-default weigher multipliers.  Inputs
are integer-valued (the paper's workload regime) so f32 arithmetic is exact
and every comparison can be strict.

CI treats a skip of this file as a failure (see .github/workflows/ci.yml):
the hypothesis sweep below is the acceptance gate for the fused screen.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.jax_scheduler import (
    SoAHostState,
    schedule_decision,
    screen_terms,
    slot_costs,
)
from repro.core.policy import SchedulerPolicy
from repro.core.screen_math import (
    EPS,
    base_from_consts,
    consts_of,
    inv_span,
    omega_of,
    raw_base_terms,
)
from repro.kernels.sched_screen import sched_screen

DEFAULT_MULT = (1.0, 1.0, 0.0, 0.0)


def _rand_arrays(rng, n, k, d=3):
    """Random integer-valued fleet arrays (all exactly representable)."""
    return dict(
        free_f=rng.integers(0, 9, (n, d)).astype(np.float32),
        free_n=rng.integers(2, 12, (n, d)).astype(np.float32),
        schedulable=rng.random(n) < 0.9,
        domain=rng.integers(0, 3, (n,)).astype(np.int32),
        slow=rng.integers(1, 5, (n,)).astype(np.float32),
        inst_res=rng.integers(0, 5, (n, k, d)).astype(np.float32),
        inst_cost=(rng.integers(0, 60, (n, k)) * 60).astype(np.float32),
        inst_valid=rng.random((n, k)) < 0.7,
    )


def _oracle_topm(a, req, pre, rdom, mult, require_free_slot, m_keep,
                 churn=None, churn_threshold=None):
    """The jnp stage-1 assembly (same shared math as ``_decision_core``):
    fleet-wide ``omega_ub`` → ``lax.top_k`` shortlist + packed consts.

    Jit-compiled, like every real decision path: XLA CPU's op-fusion choices
    (e.g. multiply-add contraction) differ between jit and eager by an ulp
    on some multiplier configs, and the parity contract is between the two
    *compiled* screens."""

    def run(req, pre_b, rdom, churn):
        free_f = jnp.asarray(a["free_f"])
        view = jnp.where(pre_b, free_f, jnp.asarray(a["free_n"]))
        fits = jnp.all(view >= req[None, :] - EPS, axis=-1)
        fits &= jnp.asarray(a["schedulable"])
        fits &= (rdom < 0) | (jnp.asarray(a["domain"]) == rdom)
        if churn_threshold is not None and churn is not None:
            fits &= jnp.where(
                pre_b, churn <= jnp.float32(churn_threshold), True
            )
        inst_valid = jnp.asarray(a["inst_valid"])
        if require_free_slot:
            fits &= jnp.where(pre_b, jnp.any(~inst_valid, axis=-1), True)
        feas, over, lb, ub = screen_terms(
            free_f, jnp.asarray(a["inst_res"]), jnp.asarray(a["inst_cost"]),
            inst_valid, req,
        )
        lb = jnp.where(pre_b, 0.0, lb)
        ub = jnp.where(pre_b, 0.0, ub)
        feas = jnp.where(pre_b, fits, feas)
        valid = fits & feas
        raw = raw_base_terms(
            jnp.sum(free_f, axis=-1), jnp.asarray(a["slow"]), over, churn
        )
        consts = consts_of(mult, valid, lb, ub, *raw)
        base = base_from_consts(
            mult, raw[0], raw[1], raw[2], consts,
            churn_raw=raw[3] if len(raw) > 3 else None,
        )
        ispan = inv_span(consts.c_lo, consts.c_hi)
        opt = lb if mult[1] >= 0 else ub
        omega_ub = omega_of(opt, base, valid, consts, ispan, mult[1])
        s, i = jax.lax.top_k(omega_ub, m_keep)              # ties → low idx
        return s, i, consts.pack()

    s, i, c = jax.jit(run, static_argnames=())(
        jnp.asarray(req), jnp.asarray(pre), jnp.asarray(rdom, jnp.int32),
        None if churn is None else jnp.asarray(churn, jnp.float32),
    )
    return np.asarray(s), np.asarray(i), np.asarray(c)


def _fused_topm(a, req, pre, rdom, mult, require_free_slot, m_keep,
                churn=None, churn_threshold=None):
    s, i, c = sched_screen(
        a["free_f"], a["free_n"], a["schedulable"], a["domain"], a["slow"],
        a["inst_res"], a["inst_cost"], a["inst_valid"],
        req, jnp.asarray(pre), jnp.asarray(rdom, jnp.int32),
        weigher_multipliers=mult,
        require_free_slot=require_free_slot,
        m_keep=m_keep,
        interpret=True,
        churn=None if churn is None else jnp.asarray(churn, jnp.float32),
        churn_threshold=churn_threshold,
    )
    return np.asarray(s), np.asarray(i), np.asarray(c)


def _assert_screen_parity(a, req, pre, rdom, mult, require_free_slot, m_keep,
                          churn=None, churn_threshold=None):
    ref = _oracle_topm(a, jnp.asarray(req), pre, jnp.asarray(rdom, jnp.int32),
                       mult, require_free_slot, m_keep,
                       churn=churn, churn_threshold=churn_threshold)
    got = _fused_topm(a, req, pre, rdom, mult, require_free_slot, m_keep,
                      churn=churn, churn_threshold=churn_threshold)
    np.testing.assert_array_equal(got[0], ref[0], err_msg="top-M scores")
    np.testing.assert_array_equal(got[1], ref[1], err_msg="top-M host indices")
    np.testing.assert_array_equal(got[2], ref[2], err_msg="normalization consts")


@pytest.mark.parametrize("k", [4, 8, 12])
@pytest.mark.parametrize("n", [1, 37, 130, 300])
def test_fused_screen_matches_jnp_screen(k, n):
    """Bit-exact (score, index, consts) parity across slot counts and host
    counts straddling the 128-lane tile, both request flavors."""
    rng = np.random.default_rng(k * 1000 + n)
    a = _rand_arrays(rng, n, k)
    req = rng.integers(2, 14, (3,)).astype(np.float32)
    m_keep = min(65, n)
    for pre in (False, True):
        _assert_screen_parity(a, req, pre, -1, DEFAULT_MULT, True, m_keep)


def test_fused_screen_all_multipliers_and_domain():
    """Packing/straggler weighers on (non-default multipliers) and a domain
    constraint: the gated const folds must match the jnp gating exactly."""
    rng = np.random.default_rng(9)
    a = _rand_arrays(rng, 200, 6)
    req = rng.integers(2, 10, (3,)).astype(np.float32)
    for mult in [(1.0, 2.0, 0.5, 0.25), (0.0, 1.0, 0.0, 0.0), (1.0, -1.0, 0.0, 0.5)]:
        for rdom in (-1, 1):
            _assert_screen_parity(a, req, False, rdom, mult, True, 33)


COST_KINDS = ["period", "count", "revenue", "recompute"]


@pytest.mark.parametrize("kind", COST_KINDS)
def test_fused_screen_all_cost_kinds(kind):
    """Slot costs derived by every device-resident cost kind (integer-minute
    starts/checkpoints, so the screens' sums stay exact)."""
    rng = np.random.default_rng(5000 + COST_KINDS.index(kind))
    n, k = 150, 8
    a = _rand_arrays(rng, n, k)
    now = 500_000.0
    start = now - rng.integers(10, 500, (n, k)).astype(np.float32) * 60.0
    price = rng.integers(1, 5, (n, k)).astype(np.float32)
    ckpt = start + rng.integers(0, 100, (n, k)).astype(np.float32) * 60.0
    a["inst_cost"] = np.asarray(slot_costs(
        kind, jnp.asarray(start), jnp.asarray(price), now, 3600.0,
        inst_ckpt=jnp.asarray(ckpt), inst_res=jnp.asarray(a["inst_res"]),
    ))
    req = rng.integers(2, 14, (3,)).astype(np.float32)
    _assert_screen_parity(a, req, False, -1, DEFAULT_MULT, True, 65)


def test_fused_screen_mixed_cost_kinds():
    """Heterogeneous billing: slot costs derived per-slot through the
    kind-table select (``mixed_slot_costs``) feed the kernel exactly like a
    homogeneous column — the select runs upstream of every screen backend,
    so the kernel's shortlist must stay bit-equal to the jnp screen's on a
    fleet mixing all four kinds."""
    from repro.core.jax_scheduler import mixed_slot_costs

    rng = np.random.default_rng(4242)
    n, k = 150, 8
    a = _rand_arrays(rng, n, k)
    now = 500_000.0
    start = now - rng.integers(10, 500, (n, k)).astype(np.float32) * 60.0
    price = rng.integers(1, 5, (n, k)).astype(np.float32)
    ckpt = start + rng.integers(0, 100, (n, k)).astype(np.float32) * 60.0
    kind_col = rng.integers(-1, 4, (n, k)).astype(np.int32)  # -1 = default
    policy = SchedulerPolicy(cost_kinds=("count", "revenue", "recompute"))
    a["inst_cost"] = np.asarray(mixed_slot_costs(
        policy, jnp.asarray(kind_col), jnp.asarray(start), jnp.asarray(price),
        jnp.asarray(ckpt), jnp.asarray(a["inst_res"]), now,
    ))
    req = rng.integers(2, 14, (3,)).astype(np.float32)
    _assert_screen_parity(a, req, False, -1, DEFAULT_MULT, True, 65)
    # sanity: the select really produced per-kind values (a homogeneous
    # column would make this test vacuous)
    per = np.asarray(slot_costs("period", jnp.asarray(start), jnp.asarray(price),
                                now, 3600.0, inst_ckpt=jnp.asarray(ckpt),
                                inst_res=jnp.asarray(a["inst_res"])))
    assert not np.array_equal(a["inst_cost"], per)


CHURN_MULT = (1.0, 1.0, 0.5, 0.25, 2.0)  # 5th entry = churn multiplier


def _rand_churn(rng, n):
    """Per-host ẑ column: a few distinct zone rates gathered onto hosts —
    the exact shape ``churn_of`` produces from the accumulators."""
    zone_rates = rng.integers(0, 8, (4,)).astype(np.float32) / 8.0
    return zone_rates[rng.integers(0, 4, (n,))]


@pytest.mark.parametrize("n", [37, 130, 300])
def test_fused_screen_churn_weigher(n):
    """Nonzero churn multiplier (5-tuple): the kernel's churn-penalty term
    and its min/max normalization folds must match the jnp screen bitwise,
    host counts straddling the tile."""
    rng = np.random.default_rng(7000 + n)
    a = _rand_arrays(rng, n, 8)
    req = rng.integers(2, 14, (3,)).astype(np.float32)
    churn = _rand_churn(rng, n)
    m_keep = min(65, n)
    for pre in (False, True):
        _assert_screen_parity(
            a, req, pre, -1, CHURN_MULT, True, m_keep, churn=churn
        )


def test_fused_screen_churn_threshold_gate():
    """The hot-zone hard filter: with a threshold the kernel must gate
    preemptible requests off high-ẑ hosts exactly like the jnp screen (and
    leave normal requests ungated) — including the degenerate all-hot fleet
    where every preemptible candidate dies."""
    rng = np.random.default_rng(77)
    n = 200
    a = _rand_arrays(rng, n, 6)
    req = rng.integers(2, 10, (3,)).astype(np.float32)
    churn = _rand_churn(rng, n)
    for pre in (False, True):
        for thr in (0.5, 0.0):
            _assert_screen_parity(
                a, req, pre, 1, CHURN_MULT, True, 33,
                churn=churn, churn_threshold=thr,
            )
    # threshold without a churn weigher term (multiplier 0): gate-only mode
    _assert_screen_parity(
        a, req, True, -1, (1.0, 1.0, 0.0, 0.0, 0.0), True, 33,
        churn=churn, churn_threshold=0.25,
    )


def test_split_phase_kernels_match_fused_churn():
    """The sharded split (consts barrier) fed a churn column must reproduce
    the 2-phase fused churn screen bit-for-bit."""
    from repro.kernels.sched_screen import sched_screen_consts, sched_screen_topm

    rng = np.random.default_rng(42)
    n = 150
    a = _rand_arrays(rng, n, 6)
    req = rng.integers(2, 10, (3,)).astype(np.float32)
    churn = jnp.asarray(_rand_churn(rng, n))
    args = (
        a["free_f"], a["free_n"], a["schedulable"], a["domain"], a["slow"],
        a["inst_res"], a["inst_cost"], a["inst_valid"],
        req, jnp.asarray(True), jnp.asarray(-1, jnp.int32),
    )
    kw = dict(
        weigher_multipliers=CHURN_MULT, require_free_slot=True,
        churn=churn, churn_threshold=0.5, interpret=True,
    )
    ref_s, ref_i, ref_c = sched_screen(*args, m_keep=33, **kw)
    consts = sched_screen_consts(*args, **kw)
    np.testing.assert_array_equal(np.asarray(consts), np.asarray(ref_c))
    s, i = sched_screen_topm(*args, consts=consts, m_keep=33, **kw)
    np.testing.assert_array_equal(np.asarray(s), np.asarray(ref_s))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ref_i))


@pytest.mark.parametrize("n", [37, 200])
def test_split_phase_kernels_match_fused(n):
    """The consts-only + topm-only kernel pair (what the sharded fused
    screen runs per shard, split at the constants barrier) must reproduce
    the 2-phase fused kernel bit-for-bit when fed its own constants."""
    from repro.kernels.sched_screen import sched_screen_consts, sched_screen_topm

    rng = np.random.default_rng(n)
    a = _rand_arrays(rng, n, 6)
    req = rng.integers(2, 10, (3,)).astype(np.float32)
    args = (
        a["free_f"], a["free_n"], a["schedulable"], a["domain"], a["slow"],
        a["inst_res"], a["inst_cost"], a["inst_valid"],
        req, jnp.asarray(False), jnp.asarray(-1, jnp.int32),
    )
    m_keep = min(33, n)
    ref_s, ref_i, ref_c = sched_screen(
        *args, weigher_multipliers=DEFAULT_MULT, require_free_slot=True,
        m_keep=m_keep, interpret=True,
    )
    consts = sched_screen_consts(
        *args, weigher_multipliers=DEFAULT_MULT, require_free_slot=True,
        interpret=True,
    )
    np.testing.assert_array_equal(np.asarray(consts), np.asarray(ref_c))
    s, i = sched_screen_topm(
        *args, consts=consts, weigher_multipliers=DEFAULT_MULT,
        require_free_slot=True, m_keep=m_keep, interpret=True,
    )
    np.testing.assert_array_equal(np.asarray(s), np.asarray(ref_s))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ref_i))


def _soa_state(a):
    return SoAHostState(
        free_f=jnp.asarray(a["free_f"]),
        free_n=jnp.asarray(a["free_n"]),
        schedulable=jnp.asarray(a["schedulable"]),
        domain=jnp.asarray(a["domain"]),
        slow=jnp.asarray(a["slow"]),
        inst_res=jnp.asarray(a["inst_res"]),
        inst_cost=jnp.asarray(a["inst_cost"]),
        inst_valid=jnp.asarray(a["inst_valid"]),
    )


def test_fused_decision_parity():
    """End to end: schedule_decision with the fused screen returns the same
    (host, mask, ok) as the jnp screen AND as the full enumeration."""
    rng = np.random.default_rng(3)
    n, k = 48, 6
    for trial in range(6):
        a = _rand_arrays(rng, n, k)
        state = _soa_state(a)
        req = jnp.asarray(rng.integers(1, 10, (3,)).astype(np.float32))
        pre = bool(trial % 2)
        full = schedule_decision(
            state, req, jnp.asarray(pre), jnp.asarray(-1, jnp.int32),
            policy=SchedulerPolicy(shortlist=0, fused_screen=False),
        )
        full = tuple(np.asarray(x).item() for x in full)
        for m in (4, 16):
            for fused in (False, True):
                got = schedule_decision(
                    state, req, jnp.asarray(pre), jnp.asarray(-1, jnp.int32),
                    policy=SchedulerPolicy(shortlist=m, fused_screen=fused),
                )
                assert tuple(np.asarray(x).item() for x in got) == full, (
                    f"trial={trial} m={m} fused={fused} pre={pre}"
                )


def test_fused_fallback_on_loose_bound():
    """The deterministic loose-bound construction (host A's cheap slots
    conflict across dims) must trigger the admissibility fallback on the
    fused path too, landing on the true winner B."""
    state = SoAHostState(
        free_f=jnp.zeros((2, 2), jnp.float32),
        free_n=jnp.full((2, 2), 4.0, jnp.float32),
        schedulable=jnp.ones((2,), bool),
        domain=jnp.zeros((2,), jnp.int32),
        slow=jnp.ones((2,), jnp.float32),
        inst_res=jnp.asarray(
            [[[4, 0], [0, 4], [4, 4]], [[4, 4], [0, 0], [0, 0]]], jnp.float32
        ),
        inst_cost=jnp.asarray([[10, 10, 50], [15, 0, 0]], jnp.float32),
        inst_valid=jnp.asarray([[1, 1, 1], [1, 0, 0]], bool),
    )
    req = jnp.asarray([4.0, 4.0], jnp.float32)
    args = (state, req, jnp.asarray(False), jnp.asarray(-1, jnp.int32))
    full = tuple(
        np.asarray(x).item()
        for x in schedule_decision(
            *args, policy=SchedulerPolicy(shortlist=0, fused_screen=False)
        )
    )
    assert full[0] == 1 and full[2]          # B's single 15-cost slot wins
    got = tuple(
        np.asarray(x).item()
        for x in schedule_decision(
            *args, policy=SchedulerPolicy(shortlist=1, fused_screen=True)
        )
    )
    assert got == full


# ---------------------------------------------------------------------------
# Property-based sweep (hypothesis): arbitrary integer fleets and requests.
# Guarded per-test (NOT importorskip) so the deterministic parity cases above
# always run; the leftover skip is what the CI gate turns into a failure.
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @given(
        st.integers(0, 2**31 - 1),
        st.booleans(),
        st.sampled_from([4, 8]),
    )
    @settings(max_examples=20, deadline=None)
    def test_fused_shortlist_equals_topk_property(seed, pre, k):
        """For ANY integer fleet, the kernel's emitted shortlist equals the
        jnp ``lax.top_k`` shortlist — scores bitwise, indices including tie
        ordering (both resolve ties to the lowest host index)."""
        rng = np.random.default_rng(seed)
        n = int(rng.integers(2, 200))
        a = _rand_arrays(rng, n, k, d=2)
        req = rng.integers(1, 10, (2,)).astype(np.float32)
        m_keep = min(int(rng.integers(1, 40)), n)
        _assert_screen_parity(a, req, pre, -1, DEFAULT_MULT, True, m_keep)

else:

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_fused_shortlist_equals_topk_property():
        pass
