"""Serving engine: wave batching correctness + preemption drain."""
from __future__ import annotations

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models.model import init_params
from repro.serving import ServeConfig, ServingEngine


def _engine(max_batch=3, max_len=64):
    cfg = reduced(get_config("qwen2-1.5b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, ServingEngine(cfg, params, ServeConfig(max_batch=max_batch, max_len=max_len))


def test_serves_batched_requests():
    cfg, eng = _engine()
    rng = np.random.default_rng(0)
    for i in range(5):  # 5 requests, batch 3 → two waves
        eng.submit(f"r{i}", rng.integers(2, cfg.vocab_size, rng.integers(3, 9)), max_new=6)
    out = eng.run_until_drained()
    assert set(out) == {f"r{i}" for i in range(5)}
    for toks in out.values():
        assert 1 <= len(toks) <= 6
        assert all(0 <= t < cfg.vocab_size for t in toks)


def test_greedy_decode_is_deterministic():
    cfg, eng1 = _engine(max_batch=1)
    _, eng2 = _engine(max_batch=1)
    prompt = np.arange(2, 8, dtype=np.int64)
    eng1.submit("a", prompt, max_new=8)
    eng2.submit("a", prompt, max_new=8)
    assert eng1.run_until_drained()["a"] == eng2.run_until_drained()["a"]


def test_preemption_requeues_unfinished():
    cfg, eng = _engine(max_batch=2)
    rng = np.random.default_rng(1)
    for i in range(2):
        eng.submit(f"r{i}", rng.integers(2, cfg.vocab_size, 4), max_new=50)
    eng.on_preempt(now=0.0, deadline=30.0)  # preempt before any wave runs
    out = eng.run_until_drained()
    assert out == {}  # nothing completed...
    assert len(eng.queue) == 2  # ...but no request lost — ready for resume
