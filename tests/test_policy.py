"""``SchedulerPolicy`` contract tests: construction-time validation, value
equality/hashability (the property that makes it a well-behaved jit static),
the no-retrace guarantee, and the post-removal contract of the old loose
kwargs (they are plain ``TypeError`` now — the one-release deprecation shims
are gone).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cost import (
    CountCost,
    MixedCost,
    PeriodCost,
    RecomputeCost,
    RevenueCost,
    WeightedSumCost,
)
from repro.core.jax_scheduler import (
    _decision_entry,
    _step_kept,
    build_soa_state,
    schedule_decision,
    schedule_step,
)
from repro.core.policy import (
    COST_KIND_IDS,
    COST_KINDS,
    SchedulerPolicy,
)
from repro.core.soa_fleet import SoAFleet
from repro.core.types import VM_SPEC, Host, Request

CAP = VM_SPEC.make(vcpus=8, ram_mb=16000, disk_gb=160)
SMALL = VM_SPEC.make(vcpus=1, ram_mb=2000, disk_gb=20)


# ---------------------------------------------------------------------------
# Construction-time validation (consolidated from the old per-call checks)
# ---------------------------------------------------------------------------


def test_defaults_are_todays_behavior():
    p = SchedulerPolicy()
    assert p.weigher_multipliers == (1.0, 1.0, 0.0, 0.0)
    assert p.cost_kind == "period" and p.kind_table == ("period",)
    assert not p.mixed and p.shortlist is None and p.donate


def test_multipliers_tuple_normalized_and_hashable():
    p = SchedulerPolicy(weigher_multipliers=[1, 2, 0, 0])  # list + ints
    assert p.weigher_multipliers == (1.0, 2.0, 0.0, 0.0)
    assert isinstance(p.weigher_multipliers, tuple)
    hash(p)  # must not raise


def test_rejects_wrong_arity_multipliers():
    with pytest.raises(ValueError, match="4 entries"):
        SchedulerPolicy(weigher_multipliers=(1.0, 1.0))


@pytest.mark.parametrize("field", ["cost_kind", "cost_kinds"])
def test_rejects_unknown_cost_kind(field):
    kw = {"cost_kind": "karma"} if field == "cost_kind" else {
        "cost_kinds": ("count", "karma")
    }
    with pytest.raises(ValueError, match="unknown cost kind"):
        SchedulerPolicy(**kw)


def test_rejects_non_power_of_two_adaptive_bounds():
    with pytest.raises(ValueError, match="powers of two"):
        SchedulerPolicy(adaptive_bounds=(12, 64))
    with pytest.raises(ValueError, match="m_min > m_max"):
        SchedulerPolicy(adaptive_bounds=(64, 16))


def test_rejects_adaptive_contradictions():
    with pytest.raises(ValueError, match="contradicts shortlist=0"):
        SchedulerPolicy(adaptive_shortlist=True, shortlist=0)


def test_adaptive_start_outside_bounds_is_legal_and_flushes():
    """The starting M may sit outside adaptive_bounds (pre-policy behavior:
    the controller clamps as it moves) — construction AND the first flush
    must both work, including when shortlist=None resolves to a default
    below m_min."""
    hosts = [Host(name=f"h{i}", capacity=CAP) for i in range(6)]
    for policy in (
        SchedulerPolicy(adaptive_shortlist=True, shortlist=4),       # < m_min
        SchedulerPolicy(adaptive_shortlist=True,
                        adaptive_bounds=(128, 256)),                 # 64 < 128
    ):
        fleet = SoAFleet(hosts, policy=policy)
        out = fleet.schedule_request(
            Request(id="r", resources=SMALL), now=60.0
        )
        assert out.ok


def test_cost_fn_policy_disagreement_is_loud():
    """Pre-policy, billing was always derived from cost_fn; passing a
    policy that bills differently from an explicit cost_fn must raise, not
    silently reprice decisions."""
    hosts = [Host(name=f"h{i}", capacity=CAP) for i in range(4)]
    with pytest.raises(ValueError, match="drop cost_fn"):
        SoAFleet(hosts, cost_fn=RevenueCost(), policy=SchedulerPolicy())
    # agreeing pairs stay fine
    SoAFleet(hosts, cost_fn=RevenueCost(),
             policy=SchedulerPolicy.for_cost(RevenueCost(), shortlist=8))


def test_rejects_bad_period_and_shortlist():
    with pytest.raises(ValueError, match="period"):
        SchedulerPolicy(period=0.0)
    with pytest.raises(ValueError, match="shortlist"):
        SchedulerPolicy(shortlist=-3)


def test_kind_table_dedups_and_leads_with_default():
    p = SchedulerPolicy(cost_kind="revenue", cost_kinds=("count", "revenue", "count"))
    assert p.kind_table == ("revenue", "count")
    assert p.mixed and p.default_kind_id == COST_KIND_IDS["revenue"]


def test_for_cost_roundtrip():
    for fn in (PeriodCost(1800.0), CountCost(), RevenueCost(), RecomputeCost()):
        p = SchedulerPolicy.for_cost(fn)
        assert type(p.make_cost_fn()) is type(fn)
        assert not p.mixed
    mixed = MixedCost(default="count", kinds=("revenue",), period_s=900.0)
    p = SchedulerPolicy.for_cost(mixed)
    assert p.mixed and p.kind_table == ("count", "revenue") and p.period == 900.0
    back = p.make_cost_fn()
    assert isinstance(back, MixedCost) and back.default == "count"
    with pytest.raises(ValueError, match="no device-resident"):
        SchedulerPolicy.for_cost(WeightedSumCost([(1.0, CountCost())]))


def test_value_equality_across_constructions():
    a = SchedulerPolicy(shortlist=8, cost_kinds=["count"])
    b = SchedulerPolicy(shortlist=8, cost_kinds=("count",))
    assert a == b and hash(a) == hash(b)
    assert a != dataclasses.replace(a, shortlist=16)


# ---------------------------------------------------------------------------
# The no-retrace guard: equal policies must hit ONE compile-cache entry
# ---------------------------------------------------------------------------


def _fresh_policy():
    # built from scratch each time — equality must be by value, not identity
    return SchedulerPolicy(
        weigher_multipliers=[1.0, 1.0, 0.0, 0.0], shortlist=4,
        cost_kinds=("count",),
    )


def test_equal_policies_share_compile_cache_decision():
    hosts = [Host(name=f"h{i}", capacity=CAP) for i in range(12)]
    state, _ = build_soa_state(hosts, 100.0, PeriodCost(), k_slots=4)
    req = jnp.asarray(SMALL.vec, jnp.float32)
    before = _decision_entry._cache_size()
    a = schedule_decision(state, req, False, -1, policy=_fresh_policy())
    mid = _decision_entry._cache_size()
    b = schedule_decision(state, req, False, -1, policy=_fresh_policy())
    after = _decision_entry._cache_size()
    assert mid == before + 1, "first call must compile exactly once"
    assert after == mid, "an equal (distinct) policy object must NOT retrace"
    assert tuple(map(int, a)) == tuple(map(int, b))


def test_equal_policies_share_compile_cache_step():
    hosts = [Host(name=f"h{i}", capacity=CAP) for i in range(12)]
    fleet = SoAFleet(hosts, k_slots=4, policy=_fresh_policy())
    req = np.asarray(SMALL.vec, np.float32)
    before = _step_kept._cache_size()
    schedule_step(fleet.state, req, False, np.int32(-1), 60.0, 1.0,
                  policy=_fresh_policy(), donate=False)
    mid = _step_kept._cache_size()
    schedule_step(fleet.state, req, False, np.int32(-1), 120.0, 1.0,
                  policy=_fresh_policy(), donate=False)
    after = _step_kept._cache_size()
    assert mid == before + 1 and after == mid


# ---------------------------------------------------------------------------
# Post-deprecation contract: the loose kwargs are GONE (plain TypeError),
# and policy= remains the only knob channel
# ---------------------------------------------------------------------------


def test_loose_kwargs_are_gone():
    hosts = [Host(name=f"h{i}", capacity=CAP) for i in range(10)]
    state, _ = build_soa_state(hosts, 100.0, PeriodCost(), k_slots=4)
    req = jnp.asarray(SMALL.vec, jnp.float32)
    with pytest.raises(TypeError, match="unexpected keyword"):
        schedule_decision(state, req, False, -1, shortlist=2)
    with pytest.raises(TypeError, match="unexpected keyword"):
        SoAFleet(hosts, cost_fn=RevenueCost(), shortlist=4)


def test_unknown_kwargs_rejected():
    hosts = [Host(name=f"h{i}", capacity=CAP) for i in range(4)]
    with pytest.raises(TypeError, match="unexpected keyword"):
        SoAFleet(hosts, shortliist=4)  # typo must not pass silently


def test_policy_must_be_a_policy():
    hosts = [Host(name=f"h{i}", capacity=CAP) for i in range(4)]
    with pytest.raises(TypeError, match="must be a SchedulerPolicy"):
        SoAFleet(hosts, policy={"shortlist": 4})


# ---------------------------------------------------------------------------
# Admission-plane knob validation (queue_capacity & co.)
# ---------------------------------------------------------------------------


def test_admission_defaults_are_off():
    p = SchedulerPolicy()
    assert p.queue_capacity == 0 and p.admit_batch == 32
    assert p.slo_target_s == 60.0 and p.max_retries == 8 and p.n_classes == 2


def test_admission_knob_validation():
    with pytest.raises(ValueError, match="queue_capacity"):
        SchedulerPolicy(queue_capacity=-1)
    with pytest.raises(ValueError, match="admit_batch"):
        SchedulerPolicy(admit_batch=0)
    with pytest.raises(ValueError, match="cannot exceed queue_capacity"):
        SchedulerPolicy(queue_capacity=8, admit_batch=16)
    with pytest.raises(ValueError, match="slo_target_s"):
        SchedulerPolicy(slo_target_s=0.0)
    with pytest.raises(ValueError, match="max_retries"):
        SchedulerPolicy(max_retries=0)
    with pytest.raises(ValueError, match="n_classes"):
        SchedulerPolicy(n_classes=0)
    # queued policies stay hashable/value-equal (the jit-static contract)
    a = SchedulerPolicy(queue_capacity=64, admit_batch=16)
    b = SchedulerPolicy(queue_capacity=64, admit_batch=16)
    assert a == b and hash(a) == hash(b)


# ---------------------------------------------------------------------------
# Request/fleet kind-table enforcement
# ---------------------------------------------------------------------------


def test_request_kind_outside_table_rejected():
    hosts = [Host(name=f"h{i}", capacity=CAP) for i in range(4)]
    fleet = SoAFleet(hosts, policy=SchedulerPolicy())  # period only
    with pytest.raises(ValueError, match="cost-kind table"):
        fleet.schedule_request(
            Request(id="r", resources=SMALL, preemptible=True,
                    cost_kind="revenue"),
            now=60.0,
        )


def test_all_known_kinds_are_registered():
    assert COST_KINDS == ("period", "count", "revenue", "recompute")
    assert [COST_KIND_IDS[k] for k in COST_KINDS] == [0, 1, 2, 3]
