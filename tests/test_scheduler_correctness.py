"""Exact reproduction of the paper's correctness evaluation (§4.4).

Tables 3–6 give concrete testbed snapshots (instances, their run times and
sizes) and state which preemptible instance(s) the scheduler must select for
termination.  These are the paper's own oracles; we reproduce all four.

Testbed: 8 vCPU / 16000 MB RAM / 140 GB disk hosts (Table 1); VM sizes from
Table 2.  Run times in the paper are minutes; we use seconds internally.
"""
from __future__ import annotations

import pytest

from repro.core.cluster import Cluster
from repro.core.cost import PeriodCost
from repro.core.scheduler import PreemptibleScheduler, RetryScheduler
from repro.core.types import VM_SPEC, Host, Instance, Request

NOW = 1_000_000.0  # arbitrary "now"

SIZES = {
    "small": VM_SPEC.make(vcpus=1, ram_mb=2000, disk_gb=20),
    "medium": VM_SPEC.make(vcpus=2, ram_mb=4000, disk_gb=40),
    "large": VM_SPEC.make(vcpus=4, ram_mb=8000, disk_gb=80),
}
# Table 1 lists 140 GB disks, yet Tables 3-6 host 4x40GB VMs per node: the
# paper's deployment did not bind on disk (thin provisioning).  We reflect
# that by making disk non-binding.
NODE_CAP = VM_SPEC.make(vcpus=8, ram_mb=16000, disk_gb=10_000)


def mk_host(name: str, instances):
    """instances: list of (id, size, minutes, preemptible)."""
    h = Host(name=name, capacity=NODE_CAP)
    for iid, size, minutes, pre in instances:
        h.place(
            Instance(
                id=iid,
                resources=SIZES[size],
                preemptible=pre,
                host=name,
                start_time=NOW - minutes * 60.0,
            )
        )
    return h


def run_case(hosts, size: str, expect_host: str, expect_victims: set):
    sched = PreemptibleScheduler(cost_fn=PeriodCost())
    req = Request(id="new", resources=SIZES[size], preemptible=False)
    res = sched.schedule(req, hosts, NOW)
    assert res.ok, "paper scenario must be schedulable"
    assert res.host == expect_host
    assert set(res.plan.ids) == expect_victims
    return res


class TestTable3:
    """Same-size (medium) — expected victim BP1 on host-B."""

    def hosts(self):
        return [
            mk_host("host-A", [("A1", "medium", 272, False), ("A2", "medium", 172, False),
                               ("AP1", "medium", 96, True), ("AP2", "medium", 207, True)]),
            mk_host("host-B", [("B1", "medium", 136, False), ("B2", "medium", 200, False),
                               ("BP1", "medium", 71, True), ("BP2", "medium", 91, True)]),
            mk_host("host-C", [("C1", "medium", 97, False), ("C2", "medium", 275, False),
                               ("CP1", "medium", 210, True), ("CP2", "medium", 215, True)]),
            mk_host("host-D", [("D1", "medium", 16, False), ("DP1", "medium", 85, True),
                               ("DP2", "medium", 199, True), ("DP3", "medium", 152, True)]),
        ]

    def test_selection(self):
        run_case(self.hosts(), "medium", "host-B", {"BP1"})

    def test_cost_is_partial_hour(self):
        res = run_case(self.hosts(), "medium", "host-B", {"BP1"})
        assert res.plan.cost == pytest.approx(11 * 60.0)  # 71 min → 11 min remainder

    def test_single_pass(self):
        res = run_case(self.hosts(), "medium", "host-B", {"BP1"})
        assert res.passes == 1


class TestTable4:
    """Same-size (medium) — expected victim CP1 (remainder 1 min), which is
    NOT the lowest-run-time preemptible instance (that is CP2)."""

    def hosts(self):
        return [
            mk_host("host-A", [("AP1", "medium", 247, True), ("AP2", "medium", 463, True),
                               ("AP3", "medium", 403, True), ("AP4", "medium", 410, True)]),
            mk_host("host-B", [("B1", "medium", 388, False), ("B2", "medium", 103, False),
                               ("BP1", "medium", 344, True), ("BP2", "medium", 476, True)]),
            mk_host("host-C", [("C1", "medium", 481, False), ("C2", "medium", 177, False),
                               ("CP1", "medium", 181, True), ("CP2", "medium", 160, True)]),
            mk_host("host-D", [("D1", "medium", 173, False), ("DP1", "medium", 384, True),
                               ("DP2", "medium", 168, True), ("DP3", "medium", 232, True)]),
        ]

    def test_selection(self):
        res = run_case(self.hosts(), "medium", "host-C", {"CP1"})
        assert res.plan.cost == pytest.approx(1 * 60.0)


class TestTable5:
    """Multi-size, large request — victims AP2+AP3+AP4 (sum of remainders 55)
    beat single-instance options on B (58) and C (57)."""

    def hosts(self):
        return [
            mk_host("host-A", [("AP1", "large", 298, True), ("AP2", "medium", 278, True),
                               ("AP3", "small", 190, True), ("AP4", "small", 187, True)]),
            mk_host("host-B", [("B1", "large", 494, False), ("BP1", "large", 178, True)]),
            mk_host("host-C", [("CP1", "large", 297, True), ("CP2", "medium", 296, True),
                               ("CP3", "small", 296, True)]),
            mk_host("host-D", [("D1", "medium", 176, False), ("D2", "medium", 200, False),
                               ("D3", "large", 116, False)]),
        ]

    def test_selection(self):
        res = run_case(self.hosts(), "large", "host-A", {"AP2", "AP3", "AP4"})
        assert res.plan.cost == pytest.approx(55 * 60.0)


class TestTable6:
    """Multi-size, medium request — single small victim BP3: host-B has one
    small slot free already, so evacuating one small instance suffices."""

    def hosts(self):
        return [
            mk_host("host-A", [("A1", "large", 234, False), ("A2", "medium", 122, False),
                               ("AP1", "medium", 172, True)]),
            mk_host("host-B", [("BP1", "large", 272, True), ("BP2", "medium", 212, True),
                               ("BP3", "small", 380, True)]),
            mk_host("host-C", [("C1", "small", 182, False), ("C2", "medium", 120, False),
                               ("C3", "large", 116, False)]),
            mk_host("host-D", [("DP1", "large", 232, True), ("DP2", "small", 213, True),
                               ("DP3", "medium", 324, True), ("DP4", "small", 314, True)]),
        ]

    def test_selection(self):
        res = run_case(self.hosts(), "medium", "host-B", {"BP3"})
        assert res.plan.cost == pytest.approx(20 * 60.0)

    def test_retry_scheduler_agrees_but_needs_two_passes(self):
        sched = RetryScheduler(cost_fn=PeriodCost())
        req = Request(id="new", resources=SIZES["medium"], preemptible=False)
        res = sched.schedule(req, self.hosts(), NOW)
        assert res.ok and res.host == "host-B" and set(res.plan.ids) == {"BP3"}
        assert res.passes == 2  # the latency penalty Fig. 2 measures


class TestClusterApply:
    def test_apply_evacuates_and_places(self):
        hosts = TestTable6().hosts()
        cluster = Cluster(hosts)
        sched = PreemptibleScheduler(cost_fn=PeriodCost())
        req = Request(id="new", resources=SIZES["medium"], preemptible=False)
        inst = cluster.schedule_and_place(sched, req, NOW)
        assert inst is not None and inst.host == "host-B"
        ids = {i.id for i in cluster.hosts["host-B"].instances.values()}
        assert "BP3" not in ids and inst.id in ids
        assert cluster.stats.preemptions == 1
        # h_f accounting is consistent after the swap
        assert not cluster.hosts["host-B"].free_full.any_negative()
